//! # Greenformer — factorization toolkit for efficient deep neural networks
//!
//! Rust + JAX + Pallas reproduction of *Greenformer: Factorization Toolkit
//! for Efficient Deep Neural Networks* (Cahyawijaya et al., 2021).
//!
//! The toolkit's contract is the paper's one-liner:
//!
//! ```no_run
//! use greenformer::factorize::{auto_fact, AutoFactConfig, Solver};
//! use greenformer::tensor::ParamStore;
//!
//! let mut params = ParamStore::load_gtz("artifacts/init/text_dense.gtz").unwrap();
//! let report = auto_fact(
//!     &mut params,
//!     &AutoFactConfig { rank: greenformer::factorize::Rank::Ratio(0.25),
//!                       solver: Solver::Svd, num_iter: 50, submodules: None },
//! ).unwrap();
//! println!("{}", report);
//! ```
//!
//! Layer map (see DESIGN.md):
//! * [`factorize`] — the paper's contribution: `auto_fact`, LED/CED
//!   replacement, rank policy (Eq. 1), solver dispatch, submodule filtering.
//! * [`linalg`] — from-scratch numerical substrate: blocked parallel matmul,
//!   Householder QR, one-sided Jacobi SVD, randomized SVD, Semi-NMF.
//! * [`tensor`] — tensor container + the GTZ checkpoint format shared with
//!   the Python build path.
//! * [`model`] — module-tree reconstruction from parameter names; per-layer
//!   classification (Linear/Conv/Embedding/LayerNorm) for `auto_fact`.
//! * [`runtime`] — PJRT engine: loads AOT HLO-text artifacts (built once by
//!   `python/compile/aot.py`), compiles, caches, executes. Python never runs
//!   at request time.
//! * [`train`] — training driver over the fused `train_step` artifacts.
//! * [`coordinator`] — serving: dynamic batcher, variant router, in-context
//!   learning prompt composer, metrics.
//! * [`data`] — synthetic task suite (3 text + 2 image + LM corpus) and the
//!   tokenizer; see DESIGN.md §3 for the substitution rationale.
//! * [`flops`] — analytical cost model: params/FLOPs/VMEM/MXU estimates,
//!   the source of the paper's "theoretical computational cost" gate.
//! * [`eval`] — accuracy evaluation harnesses shared by examples/benches.
//! * [`experiments`] — Figure-2 / table regeneration harnesses.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod factorize;
pub mod flops;
pub mod linalg;
pub mod model;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Locate the artifacts directory: `$GREENFORMER_ARTIFACTS` or `./artifacts`
/// relative to the workspace root (walking up from the current directory so
/// tests, examples and benches all find it).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("GREENFORMER_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
