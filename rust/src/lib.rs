//! # Greenformer — factorization toolkit for efficient deep neural networks
//!
//! Rust + JAX + Pallas reproduction of *Greenformer: Factorization Toolkit
//! for Efficient Deep Neural Networks* (Cahyawijaya et al., 2021).
//!
//! The toolkit's contract is the paper's one-liner:
//!
//! ```no_run
//! use greenformer::factorize::{auto_fact, AutoFactConfig, Solver};
//! use greenformer::tensor::ParamStore;
//!
//! let mut params = ParamStore::load_gtz("artifacts/init/text_dense.gtz").unwrap();
//! let report = auto_fact(
//!     &mut params,
//!     &AutoFactConfig { rank: greenformer::factorize::Rank::Ratio(0.25),
//!                       solver: Solver::Svd, ..AutoFactConfig::default() },
//! ).unwrap();
//! println!("{}", report);
//! ```
//!
//! Layer map (see DESIGN.md):
//! * [`factorize`] — the paper's contribution: `auto_fact`, LED/CED
//!   replacement, rank policy (Eq. 1), solver dispatch, submodule filtering.
//! * [`linalg`] — from-scratch numerical substrate: packed SIMD-tiled GEMM
//!   + column-split GEMV with fused epilogues over a persistent worker
//!   pool, workspace arenas, Householder QR, one-sided Jacobi SVD,
//!   randomized SVD, Semi-NMF.
//! * [`tensor`] — tensor container + the GTZ checkpoint format shared with
//!   the Python build path.
//! * [`model`] — module-tree reconstruction from parameter names; per-layer
//!   classification (Linear/Conv/Embedding/LayerNorm) for `auto_fact`.
//! * [`runtime`] — PJRT engine: loads AOT HLO-text artifacts (built once by
//!   `python/compile/aot.py`), compiles, caches, executes. Python never runs
//!   at request time.
//! * [`backend`] — the execution abstraction: one `Backend` trait over the
//!   PJRT engine and a pure-Rust `NativeBackend` interpreter, so serving and
//!   evaluation run hermetically when artifacts are absent (DESIGN.md §8);
//!   includes KV-cached incremental decoding for the LM path (§10).
//! * [`train`] — training driver over the fused `train_step` artifacts.
//! * [`coordinator`] — serving: dynamic batcher, variant router, streaming
//!   KV-cached generation, in-context learning prompt composer, metrics.
//! * [`registry`] — fail-closed model registry: versioned manifests,
//!   sha256-verified checkpoints, atomic epoch-pinned hot-swap.
//! * [`serve_http`] — hardened hand-rolled HTTP/1.1 front end over the
//!   registry: schema-validated JSON endpoints, chunked token streaming,
//!   deadlines/limits/shed mapping, plus its own hermetic test client.
//! * [`data`] — synthetic task suite (3 text + 2 image + LM corpus) and the
//!   tokenizer; see DESIGN.md §3 for the substitution rationale.
//! * [`flops`] — analytical cost model: params/FLOPs/VMEM/MXU estimates,
//!   the source of the paper's "theoretical computational cost" gate.
//! * [`eval`] — accuracy evaluation harnesses shared by examples/benches.
//! * [`experiments`] — Figure-2 / table regeneration harnesses.
//!
//! ARCHITECTURE.md maps every subsystem and walks the request lifecycle
//! (client → router → batcher/decoder → backend).

#![warn(missing_docs)]

pub mod backend;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod factorize;
pub mod flops;
pub mod linalg;
pub mod model;
pub mod registry;
pub mod runtime;
pub mod serve_http;
pub mod tensor;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Locate the artifacts directory: `$GREENFORMER_ARTIFACTS` (when set and
/// non-empty) or the nearest `artifacts/` holding a `manifest.json`, walking
/// up from the current directory so tests, examples and benches all find it.
/// Falls back to the relative `artifacts` path when nothing is found.
pub fn artifacts_dir() -> std::path::PathBuf {
    resolve_artifacts_dir(
        std::env::var_os("GREENFORMER_ARTIFACTS"),
        std::env::current_dir().ok(),
    )
}

/// Testable core of [`artifacts_dir`]: the env override and starting
/// directory are explicit so the resolution rules can be pinned by unit
/// tests without touching process-global state.
fn resolve_artifacts_dir(
    env_override: Option<std::ffi::OsString>,
    cwd: Option<std::path::PathBuf>,
) -> std::path::PathBuf {
    if let Some(p) = env_override {
        if !p.is_empty() {
            return std::path::PathBuf::from(p);
        }
    }
    let mut dir = cwd.unwrap_or_else(|| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}

#[cfg(test)]
mod tests {
    use std::path::{Path, PathBuf};

    use super::resolve_artifacts_dir;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("gf_artdir_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn env_override_wins_even_without_manifest() {
        let got = resolve_artifacts_dir(Some("/somewhere/else".into()), Some("/tmp".into()));
        assert_eq!(got, Path::new("/somewhere/else"));
    }

    #[test]
    fn empty_env_override_is_ignored() {
        let base = scratch("empty_env");
        let got = resolve_artifacts_dir(Some("".into()), Some(base.clone()));
        assert_ne!(got, Path::new(""));
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn walk_up_finds_nearest_manifest() {
        let base = scratch("walk");
        let deep = base.join("a").join("b");
        std::fs::create_dir_all(&deep).unwrap();
        let far = base.join("artifacts");
        std::fs::create_dir_all(&far).unwrap();
        std::fs::write(far.join("manifest.json"), "{}").unwrap();
        assert_eq!(resolve_artifacts_dir(None, Some(deep.clone())), far);

        // A nearer artifacts/manifest.json must shadow the farther one.
        let near = base.join("a").join("artifacts");
        std::fs::create_dir_all(&near).unwrap();
        std::fs::write(near.join("manifest.json"), "{}").unwrap();
        assert_eq!(resolve_artifacts_dir(None, Some(deep)), near);
        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn miss_falls_back_to_relative_path() {
        let base = scratch("miss").join("no").join("manifest").join("here");
        std::fs::create_dir_all(&base).unwrap();
        let got = resolve_artifacts_dir(None, Some(base.clone()));
        // Ancestors outside the scratch dir could legitimately hold a real
        // artifacts tree; the contract is: either an existing manifest dir,
        // or the bare relative fallback.
        assert!(
            got == Path::new("artifacts") || got.join("manifest.json").exists(),
            "unexpected fallback: {got:?}"
        );
        std::fs::remove_dir_all(&base).ok();
    }
}
