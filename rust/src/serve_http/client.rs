//! Minimal blocking HTTP/1.1 client for the front end's own tests,
//! benches, and the CI boot check — speaks exactly the dialect the server
//! emits (`Connection: close`, full bodies or chunked ndjson), nothing
//! more.
//!
//! [`request_raw`] additionally lets the fault-injection suite send
//! arbitrary (malformed, truncated) bytes and observe the server's exact
//! reply.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;

/// A fully-read response: status, lowercased headers, de-chunked body.
#[derive(Clone, Debug)]
pub struct HttpReply {
    /// Numeric status code from the status line.
    pub status: u16,
    /// Headers, names lowercased.
    pub headers: BTreeMap<String, String>,
    /// Body bytes (already de-chunked when the response was chunked).
    pub body: Vec<u8>,
}

impl HttpReply {
    /// Body as UTF-8 (lossy — diagnostics only).
    pub fn body_text(&self) -> String {
        String::from_utf8_lossy(&self.body).to_string()
    }

    /// Parse the body as a single JSON document.
    pub fn json(&self) -> Result<Value> {
        Value::parse_bytes(&self.body)
    }

    /// Parse the body as ndjson (one JSON document per non-empty line) —
    /// the `/v1/generate` stream format.
    pub fn ndjson(&self) -> Result<Vec<Value>> {
        let text = std::str::from_utf8(&self.body).context("ndjson body is not UTF-8")?;
        text.lines().filter(|l| !l.trim().is_empty()).map(Value::parse).collect()
    }
}

/// Issue one request and read the response to EOF. `body = Some(json)`
/// sends a POST with `Content-Length`; `None` sends a GET.
pub fn request(
    addr: SocketAddr,
    path: &str,
    body: Option<&str>,
    timeout: Duration,
) -> Result<HttpReply> {
    let method = if body.is_some() { "POST" } else { "GET" };
    let payload = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        payload.len()
    );
    let raw = send(addr, head.as_bytes(), payload.as_bytes(), timeout, false)?;
    parse_response(&raw)
}

/// Write arbitrary raw bytes, half-close the write side, and read whatever
/// the server answers until it closes. The fault-injection entry point:
/// the bytes need not be valid HTTP, and a deliberately short body (with a
/// larger declared `Content-Length`) exercises the truncation path.
pub fn request_raw(addr: SocketAddr, raw: &[u8], timeout: Duration) -> Result<Vec<u8>> {
    send(addr, raw, &[], timeout, true)
}

fn send(
    addr: SocketAddr,
    head: &[u8],
    body: &[u8],
    timeout: Duration,
    half_close: bool,
) -> Result<Vec<u8>> {
    let mut stream = TcpStream::connect_timeout(&addr, timeout)
        .with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout)).context("setting read timeout")?;
    stream.set_write_timeout(Some(timeout)).context("setting write timeout")?;
    stream.write_all(head).context("writing request head")?;
    if !body.is_empty() {
        stream.write_all(body).context("writing request body")?;
    }
    if half_close {
        let _ = stream.shutdown(std::net::Shutdown::Write);
    }
    let mut out = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => out.extend_from_slice(&chunk[..n]),
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                bail!("timed out reading response from {addr}")
            }
            Err(e) => return Err(anyhow!("reading response from {addr}: {e}")),
        }
    }
    Ok(out)
}

/// Parse a complete HTTP/1.1 response (status line, headers, body),
/// de-chunking when `Transfer-Encoding: chunked`.
pub fn parse_response(raw: &[u8]) -> Result<HttpReply> {
    let head_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .ok_or_else(|| anyhow!("response has no head/body separator"))?;
    let head = std::str::from_utf8(&raw[..head_end]).context("response head is not UTF-8")?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let mut parts = status_line.splitn(3, ' ');
    let proto = parts.next().unwrap_or("");
    if !proto.starts_with("HTTP/1.") {
        bail!("unexpected status line {status_line:?}");
    }
    let status: u16 = parts
        .next()
        .unwrap_or("")
        .parse()
        .with_context(|| format!("unparseable status in {status_line:?}"))?;
    let mut headers = BTreeMap::new();
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }
    let mut body = raw[head_end + 4..].to_vec();
    if headers.get("transfer-encoding").map(String::as_str) == Some("chunked") {
        body = dechunk(&body)?;
    }
    Ok(HttpReply { status, headers, body })
}

/// Decode a chunked body: `hexlen\r\n payload \r\n ... 0\r\n\r\n`.
fn dechunk(mut raw: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    loop {
        let line_end = raw
            .windows(2)
            .position(|w| w == b"\r\n")
            .ok_or_else(|| anyhow!("chunked body missing a size line"))?;
        let size_text =
            std::str::from_utf8(&raw[..line_end]).context("chunk size is not UTF-8")?;
        let size = usize::from_str_radix(size_text.trim(), 16)
            .with_context(|| format!("invalid chunk size {size_text:?}"))?;
        raw = &raw[line_end + 2..];
        if size == 0 {
            return Ok(out);
        }
        if raw.len() < size + 2 {
            bail!("truncated chunk (declared {size} bytes, {} available)", raw.len());
        }
        out.extend_from_slice(&raw[..size]);
        raw = &raw[size + 2..];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_response() {
        let raw = b"HTTP/1.1 200 OK\r\nContent-Type: application/json\r\nContent-Length: 2\r\n\r\n{}";
        let r = parse_response(raw).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.headers.get("content-type").map(String::as_str), Some("application/json"));
        assert_eq!(r.body, b"{}");
        assert!(r.json().is_ok());
    }

    #[test]
    fn dechunks_streamed_body() {
        let raw = b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n4\r\nab\ncd\r\n3\r\nef\n\r\n0\r\n\r\n";
        let r = parse_response(raw).unwrap();
        assert_eq!(r.body, b"ab\ncdef\n");
    }

    #[test]
    fn malformed_responses_fail_closed() {
        assert!(parse_response(b"garbage").is_err());
        assert!(parse_response(b"HTTP/1.1 abc OK\r\n\r\n").is_err());
        let truncated = b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\nff\r\nshort";
        assert!(parse_response(truncated).is_err());
    }
}
