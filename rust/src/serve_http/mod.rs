//! Hardened HTTP/1.1 serving front end over the model [`crate::registry`].
//!
//! Hand-rolled on [`std::net::TcpListener`] — hermetic, thread-per-
//! connection, no async runtime, no external dependencies. The surface:
//!
//! | Endpoint        | Method | Semantics                                   |
//! |-----------------|--------|---------------------------------------------|
//! | `/v1/healthz`   | GET    | liveness + registered model count            |
//! | `/v1/models`    | GET    | registry listing (versions, epochs, tallies) |
//! | `/v1/metrics`   | GET    | registry + per-model + HTTP counters         |
//! | `/v1/classify`  | POST   | schema-validated classify → JSON             |
//! | `/v1/generate`  | POST   | schema-validated generate → chunked ndjson   |
//!
//! Robustness posture (exercised end-to-end by
//! `tests/fault_injection_http.rs`):
//!
//! * **Deadlines everywhere.** Head and body reads run under absolute
//!   deadlines ([`HttpConfig::header_deadline`] / [`HttpConfig::body_deadline`]);
//!   a slow-loris peer is evicted with a 408 and counted in
//!   [`HttpMetrics::evictions`]. Writes carry [`HttpConfig::write_timeout`].
//! * **Bounded everything.** Head bytes, body bytes, concurrent
//!   connections and `max_new` are all capped; breaches answer 431 / 413 /
//!   503 / 400 — never unbounded buffering.
//! * **Strict inputs.** Bodies are parsed by the fail-closed
//!   [`crate::util::json`] codec and validated against per-endpoint
//!   [`crate::util::json::Schema`]s: unknown fields, missing fields, and
//!   type mismatches are structured 400s with JSON-path messages. No
//!   handler panics on any input.
//! * **Typed overload.** Admission-control sheds surface as 429 (or 503 on
//!   shutdown) with `Retry-After` derived from the dispatcher's own
//!   [`crate::coordinator::ShedReason::retry_after`] hint.
//! * **Graceful shutdown.** [`HttpServer::shutdown`] stops accepting, then
//!   waits for in-flight connections — including streaming generations —
//!   to drain.

mod api;
pub mod client;
mod conn;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::registry::ModelRegistry;
use crate::util::json::{ObjBuilder, Value};

/// Hardening knobs for the HTTP front end. The defaults are production-
/// shaped; the fault-injection suite shrinks them to make limits cheap to
/// hit.
#[derive(Clone, Debug)]
pub struct HttpConfig {
    /// Hard cap on request-head bytes (431 beyond it).
    pub max_header_bytes: usize,
    /// Hard cap on declared body bytes (413 beyond it).
    pub max_body_bytes: usize,
    /// Absolute deadline for receiving the full request head; exceeding it
    /// evicts the connection (slow-loris defense).
    pub header_deadline: Duration,
    /// Absolute deadline for receiving the full body after the head.
    pub body_deadline: Duration,
    /// Per-write socket timeout; a stalled reader is a disconnect, not a
    /// wedged worker.
    pub write_timeout: Duration,
    /// Concurrent-connection ceiling; excess accepts answer 503.
    pub max_connections: usize,
    /// Upper bound a single `/v1/generate` may request via `max_new`.
    pub max_generate_tokens: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            max_header_bytes: 8 * 1024,
            max_body_bytes: 1024 * 1024,
            header_deadline: Duration::from_secs(2),
            body_deadline: Duration::from_secs(5),
            write_timeout: Duration::from_secs(5),
            max_connections: 64,
            max_generate_tokens: 512,
        }
    }
}

/// Front-end counters. `requests == ok + client_errors + server_errors +
/// shed` holds exactly (the fault-injection suite asserts it);
/// `evictions` and `disconnects` are orthogonal tallies of *why* some of
/// those requests ended early.
#[derive(Debug, Default)]
pub struct HttpMetrics {
    /// Connections accepted off the listener.
    pub conns_accepted: AtomicU64,
    /// Connections refused at the [`HttpConfig::max_connections`] ceiling.
    pub conns_rejected: AtomicU64,
    /// Responses written, by status class below.
    pub requests: AtomicU64,
    /// 2xx responses.
    pub ok: AtomicU64,
    /// 4xx responses other than 429.
    pub client_errors: AtomicU64,
    /// 5xx responses.
    pub server_errors: AtomicU64,
    /// 429 responses (admission-control sheds).
    pub shed: AtomicU64,
    /// Connections evicted for blowing a read deadline (the 408 path).
    pub evictions: AtomicU64,
    /// Write failures — the client vanished mid-response/mid-stream.
    pub disconnects: AtomicU64,
}

impl HttpMetrics {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one terminal response status.
    pub fn record_status(&self, status: u16) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let class = match status {
            429 => &self.shed,
            200..=299 => &self.ok,
            400..=499 => &self.client_errors,
            _ => &self.server_errors,
        };
        class.fetch_add(1, Ordering::Relaxed);
    }

    /// Compose the counters as a JSON object (the `/v1/metrics` `http`
    /// section).
    pub fn compose(&self) -> Value {
        ObjBuilder::new()
            .uint("conns_accepted", self.conns_accepted.load(Ordering::Relaxed))
            .uint("conns_rejected", self.conns_rejected.load(Ordering::Relaxed))
            .uint("requests", self.requests.load(Ordering::Relaxed))
            .uint("ok", self.ok.load(Ordering::Relaxed))
            .uint("client_errors", self.client_errors.load(Ordering::Relaxed))
            .uint("server_errors", self.server_errors.load(Ordering::Relaxed))
            .uint("shed", self.shed.load(Ordering::Relaxed))
            .uint("evictions", self.evictions.load(Ordering::Relaxed))
            .uint("disconnects", self.disconnects.load(Ordering::Relaxed))
            .build()
    }
}

/// Decrement-on-drop guard for the live-connection gauge, so the count
/// stays exact even if a handler unwinds.
struct ActiveGuard(Arc<AtomicUsize>);

impl Drop for ActiveGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A running HTTP front end: accept loop + per-connection worker threads
/// over a shared [`ModelRegistry`].
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    active: Arc<AtomicUsize>,
    /// Front-end counters (shared with every worker).
    pub metrics: Arc<HttpMetrics>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting.
    pub fn bind(addr: &str, registry: Arc<ModelRegistry>, cfg: HttpConfig) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding http server on {addr}"))?;
        let local = listener.local_addr().context("resolving bound address")?;
        listener.set_nonblocking(true).context("setting listener nonblocking")?;

        let stop = Arc::new(AtomicBool::new(false));
        let active = Arc::new(AtomicUsize::new(0));
        let metrics = Arc::new(HttpMetrics::new());

        let stop_bg = stop.clone();
        let active_bg = active.clone();
        let metrics_bg = metrics.clone();
        let accept_thread = std::thread::Builder::new()
            .name("gf-http-accept".into())
            .spawn(move || {
                accept_loop(listener, registry, cfg, stop_bg, active_bg, metrics_bg);
            })
            .context("spawning http accept thread")?;

        Ok(HttpServer { addr: local, stop, accept_thread: Some(accept_thread), active, metrics })
    }

    /// The bound socket address (real port even when bound with `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Live worker connections right now.
    pub fn active_connections(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Graceful shutdown: stop accepting, then wait (bounded) for every
    /// in-flight connection — including streaming generations — to drain.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            h.join().map_err(|_| anyhow!("http accept thread panicked"))?;
        }
        let deadline = Instant::now() + Duration::from_secs(10);
        while self.active.load(Ordering::SeqCst) > 0 {
            if Instant::now() >= deadline {
                return Err(anyhow!(
                    "http shutdown timed out with {} connections still active",
                    self.active.load(Ordering::SeqCst)
                ));
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        Ok(())
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        // Un-shutdown drops still stop the accept loop; workers run their
        // connections to completion on their own threads.
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    registry: Arc<ModelRegistry>,
    cfg: HttpConfig,
    stop: Arc<AtomicBool>,
    active: Arc<AtomicUsize>,
    metrics: Arc<HttpMetrics>,
) {
    let ctx = Arc::new(conn::ConnCtx { registry, cfg, metrics });
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                ctx.metrics.conns_accepted.fetch_add(1, Ordering::Relaxed);
                // Admission at the connection level: beyond the ceiling we
                // answer 503 inline (cheap, bounded) instead of queueing.
                if active.load(Ordering::SeqCst) >= ctx.cfg.max_connections {
                    ctx.metrics.conns_rejected.fetch_add(1, Ordering::Relaxed);
                    reject_connection(stream, &ctx);
                    continue;
                }
                active.fetch_add(1, Ordering::SeqCst);
                let guard = ActiveGuard(active.clone());
                let ctx = ctx.clone();
                // On spawn failure the un-run closure (and the guard with
                // it) is dropped, restoring the gauge; nothing else to do.
                let _ = std::thread::Builder::new().name("gf-http-conn".into()).spawn(move || {
                    let _guard = guard;
                    conn::handle_connection(stream, &ctx);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Inline 503 for connections beyond the ceiling.
fn reject_connection(mut stream: TcpStream, ctx: &Arc<conn::ConnCtx>) {
    ctx.metrics.record_status(503);
    let _ = stream.set_write_timeout(Some(ctx.cfg.write_timeout));
    let retry = Some(Duration::from_secs(1));
    let body = conn::error_body(503, "unavailable", "connection limit reached", retry).render();
    let head = format!(
        "HTTP/1.1 503 {}\r\nConnection: close\r\nContent-Type: application/json\r\n\
         Retry-After: 1\r\nContent-Length: {}\r\n\r\n",
        conn::reason(503),
        body.len()
    );
    use std::io::Write;
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}
