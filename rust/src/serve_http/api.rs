//! Endpoint handlers: schema-validated JSON in, structured JSON (or a
//! chunked token stream) out, every outcome mapped onto a specific status
//! code.
//!
//! The status mapping is deliberate and documented (SERVING.md):
//!
//! * validation failures the client caused → **400** (with the validator's
//!   path-bearing message);
//! * unknown model → **404**;
//! * admission-control sheds ([`ServeError::Overloaded`], including the
//!   dispatcher's [`crate::coordinator::ShedReason::SessionsFull`]) →
//!   **429** + `Retry-After`;
//! * dispatcher-side failures after the HTTP layer's own screening →
//!   **500** (the layer already rejected every client-attributable cause);
//! * dispatcher shut down → **503** + `Retry-After`.

use std::sync::mpsc::Receiver;
use std::time::Duration;

use crate::backend::SamplingCfg;
use crate::coordinator::{ServeError, Tier, TokenEvent};
use crate::registry::{RegistryError, ServingModel};
use crate::util::json::{Kind, ObjBuilder, Schema, Value};

use super::conn::{ConnCtx, HttpRequest, Reply};

/// What a routed handler produced: a complete response, or an admitted
/// generation to stream (the first event is pre-read — it decided the 200).
pub(crate) enum Outcome {
    Json(Reply),
    Stream {
        first: TokenEvent,
        rx: Receiver<TokenEvent>,
        model: String,
        version: String,
        epoch: u64,
    },
}

/// Dispatch a fully-read request. Method/path existence were already
/// enforced by the connection layer.
pub(crate) fn route(req: &HttpRequest, ctx: &ConnCtx) -> Outcome {
    match req.path.as_str() {
        "/v1/healthz" => Outcome::Json(healthz(ctx)),
        "/v1/models" => Outcome::Json(models(ctx)),
        "/v1/metrics" => Outcome::Json(metrics(ctx)),
        "/v1/classify" => Outcome::Json(classify(&req.body, ctx)),
        "/v1/generate" => generate(&req.body, ctx),
        other => Outcome::Json(Reply::error(404, "not_found", &format!("no route for {other:?}"))),
    }
}

fn healthz(ctx: &ConnCtx) -> Reply {
    Reply::ok(
        ObjBuilder::new()
            .str("status", "ok")
            .uint("models", ctx.registry.len() as u64)
            .build(),
    )
}

fn model_summary(m: &ServingModel, requests: u64) -> Value {
    let mut b = ObjBuilder::new()
        .str("name", &m.name)
        .str("family", &m.family)
        .str("version", &m.version)
        .uint("epoch", m.epoch)
        .str("default", &m.default)
        .arr("variants", m.variants.iter().map(|v| Value::Str(v.clone())).collect())
        .uint("seq", m.seq as u64)
        .uint("requests", requests);
    if let Some(vocab) = m.vocab {
        b = b.uint("vocab", vocab as u64);
    }
    b.build()
}

fn models(ctx: &ConnCtx) -> Reply {
    use std::sync::atomic::Ordering::Relaxed;
    let counts = ctx.registry.metrics.request_counts();
    let models = ctx
        .registry
        .models()
        .iter()
        .map(|m| model_summary(m, counts.get(&m.name).copied().unwrap_or(0)))
        .collect();
    Reply::ok(
        ObjBuilder::new()
            .arr("models", models)
            .uint("installs", ctx.registry.metrics.installs.load(Relaxed))
            .uint("swaps", ctx.registry.metrics.swaps.load(Relaxed))
            .uint("rejected_manifests", ctx.registry.metrics.rejected_manifests.load(Relaxed))
            .uint("rejected_models", ctx.registry.metrics.rejected_models.load(Relaxed))
            .build(),
    )
}

fn metrics(ctx: &ConnCtx) -> Reply {
    use std::sync::atomic::Ordering::Relaxed;
    let reg = &ctx.registry.metrics;
    let registry = ObjBuilder::new()
        .uint("installs", reg.installs.load(Relaxed))
        .uint("swaps", reg.swaps.load(Relaxed))
        .uint("rejected_manifests", reg.rejected_manifests.load(Relaxed))
        .uint("rejected_models", reg.rejected_models.load(Relaxed))
        .build();
    let http = ctx.metrics.compose();
    let counts = ctx.registry.metrics.request_counts();
    let models = ctx
        .registry
        .models()
        .iter()
        .map(|m| {
            let s = m.handle();
            let mm = &s.metrics;
            ObjBuilder::new()
                .str("name", &m.name)
                .uint("epoch", m.epoch)
                .uint("http_requests", counts.get(&m.name).copied().unwrap_or(0))
                .uint("requests", mm.requests.load(Relaxed))
                .uint("responses", mm.responses.load(Relaxed))
                .uint("errors", mm.errors.load(Relaxed))
                .uint("shed_requests", mm.shed_requests.load(Relaxed))
                .uint("decode_sessions", mm.decode_sessions.load(Relaxed))
                .uint("generated_tokens", mm.generated_tokens.load(Relaxed))
                .uint("p50_us", mm.latency_percentile_us(50.0))
                .uint("p95_us", mm.latency_percentile_us(95.0))
                .build()
        })
        .collect();
    Reply::ok(
        ObjBuilder::new()
            .set("registry", registry)
            .set("http", http)
            .arr("models", models)
            .build(),
    )
}

fn classify_schema() -> Schema {
    Schema::new("body")
        .optional("model", Kind::Str)
        .required("tokens", Kind::Arr(Box::new(Kind::UInt)))
        .optional("tier", Kind::Str)
}

fn generate_schema() -> Schema {
    Schema::new("body")
        .optional("model", Kind::Str)
        .required("prompt", Kind::Arr(Box::new(Kind::UInt)))
        .optional("max_new", Kind::UInt)
        .optional("temperature", Kind::Num)
        .optional("top_k", Kind::UInt)
        .optional("seed", Kind::UInt)
        .optional("tier", Kind::Str)
}

/// Parse + schema-validate a POST body; any failure is a structured 400.
fn parse_body(body: &[u8], schema: &Schema) -> Result<Value, Reply> {
    let v = Value::parse_bytes(body)
        .map_err(|e| Reply::error(400, "bad_request", &format!("{e:#}")))?;
    schema
        .validate(&v)
        .map_err(|e| Reply::error(400, "invalid_request", &e.to_string()))?;
    Ok(v)
}

/// Extract a schema-validated UInt array as token ids, bounding each value
/// to `i32` (the wire type of the model vocabulary).
fn token_field(v: &Value, key: &str) -> Result<Vec<i32>, Reply> {
    let arr = v.get(key).and_then(|a| a.as_arr().ok()).unwrap_or_default();
    let mut out = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        let n = item.as_f64().unwrap_or(-1.0);
        if !(0.0..=i32::MAX as f64).contains(&n) {
            let msg = format!("body.{key}[{i}]: token id out of range (0..={})", i32::MAX);
            return Err(Reply::error(400, "invalid_request", &msg));
        }
        out.push(n as i32);
    }
    Ok(out)
}

fn tier_field(v: &Value) -> Result<Tier, Reply> {
    match v.get("tier") {
        None => Ok(Tier::Quality),
        Some(t) => {
            let text = t.as_str().unwrap_or_default();
            text.parse::<Tier>()
                .map_err(|e| Reply::error(400, "invalid_request", &format!("body.tier: {e}")))
        }
    }
}

/// Resolve `body.model` against the registry, enforcing the family the
/// endpoint requires.
fn resolve_model(
    v: &Value,
    ctx: &ConnCtx,
    family: &str,
    endpoint: &str,
) -> Result<std::sync::Arc<ServingModel>, Reply> {
    let name = v.get("model").and_then(|m| m.as_str().ok());
    let model = ctx.registry.resolve(name).map_err(|e| registry_reply(&e))?;
    if model.family != family {
        let msg = format!(
            "model {:?} has family {:?}; {endpoint} requires family {family:?}",
            model.name, model.family
        );
        return Err(Reply::error(400, "invalid_request", &msg));
    }
    Ok(model)
}

fn registry_reply(e: &RegistryError) -> Reply {
    match e {
        RegistryError::UnknownModel { .. } => Reply::error(404, "not_found", &e.to_string()),
        RegistryError::NoDefaultModel { .. } => Reply::error(400, "invalid_request", &e.to_string()),
        _ => Reply::error(500, "internal", &e.to_string()),
    }
}

fn serve_reply(e: &ServeError) -> Reply {
    match e {
        ServeError::Overloaded { reason, retry_after } => {
            Reply::overloaded(429, "overloaded", &reason.to_string(), *retry_after)
        }
        // The HTTP layer already screened client-attributable causes
        // (shape, family, bounds), so a dispatcher-side failure is ours.
        ServeError::Failed(msg) => Reply::error(500, "internal", msg),
        ServeError::Shutdown => {
            Reply::overloaded(503, "unavailable", "server shutting down", Duration::from_secs(1))
        }
    }
}

fn classify(body: &[u8], ctx: &ConnCtx) -> Reply {
    let v = match parse_body(body, &classify_schema()) {
        Ok(v) => v,
        Err(r) => return r,
    };
    let model = match resolve_model(&v, ctx, "text", "/v1/classify") {
        Ok(m) => m,
        Err(r) => return r,
    };
    let tokens = match token_field(&v, "tokens") {
        Ok(t) => t,
        Err(r) => return r,
    };
    if tokens.len() != model.seq {
        let msg = format!(
            "body.tokens: expected exactly {} token ids (model window), got {}",
            model.seq,
            tokens.len()
        );
        return Reply::error(400, "invalid_request", &msg);
    }
    let tier = match tier_field(&v) {
        Ok(t) => t,
        Err(r) => return r,
    };
    ctx.registry.metrics.record_request(&model.name);
    match model.handle().classify_or_shed(tokens, tier) {
        Ok(resp) => Reply::ok(
            ObjBuilder::new()
                .str("model", &model.name)
                .str("version", &model.version)
                .uint("epoch", model.epoch)
                .str("variant", &resp.variant)
                .uint("label", resp.label as u64)
                .arr_f32("logits", &resp.logits)
                .uint("latency_us", resp.latency.as_micros() as u64)
                .build(),
        ),
        Err(e) => serve_reply(&e),
    }
}

fn generate(body: &[u8], ctx: &ConnCtx) -> Outcome {
    let v = match parse_body(body, &generate_schema()) {
        Ok(v) => v,
        Err(r) => return Outcome::Json(r),
    };
    let model = match resolve_model(&v, ctx, "lm", "/v1/generate") {
        Ok(m) => m,
        Err(r) => return Outcome::Json(r),
    };
    let prompt = match token_field(&v, "prompt") {
        Ok(p) => p,
        Err(r) => return Outcome::Json(r),
    };
    if prompt.is_empty() || prompt.len() > model.seq {
        let msg = format!(
            "body.prompt: expected 1..={} token ids (model window), got {}",
            model.seq,
            prompt.len()
        );
        return Outcome::Json(Reply::error(400, "invalid_request", &msg));
    }
    let max_new = v.usize_or("max_new", 16);
    if max_new == 0 || max_new > ctx.cfg.max_generate_tokens {
        let msg = format!(
            "body.max_new: expected 1..={}, got {max_new}",
            ctx.cfg.max_generate_tokens
        );
        return Outcome::Json(Reply::error(400, "invalid_request", &msg));
    }
    let tier = match tier_field(&v) {
        Ok(t) => t,
        Err(r) => return Outcome::Json(r),
    };
    let sampling = SamplingCfg {
        temperature: v.f64_opt("temperature").unwrap_or(0.0) as f32,
        top_k: v.usize_or("top_k", 0),
        seed: v.get("seed").and_then(|s| s.as_f64().ok()).unwrap_or(0.0) as u64,
    };
    ctx.registry.metrics.record_request(&model.name);
    let rx = match model.handle().generate_or_shed(prompt, max_new, sampling, tier) {
        Ok(rx) => rx,
        Err(e) => return Outcome::Json(serve_reply(&e)),
    };
    // Peek the first event before committing to a status line: a shed or an
    // immediate failure must answer 429/500, not a 200 that then errors.
    match rx.recv() {
        Err(_) => Outcome::Json(serve_reply(&ServeError::Shutdown)),
        Ok(TokenEvent::Rejected(reason)) => Outcome::Json(Reply::overloaded(
            429,
            "overloaded",
            &reason.to_string(),
            reason.retry_after(),
        )),
        Ok(TokenEvent::Failed(msg)) => Outcome::Json(Reply::error(500, "internal", &msg)),
        Ok(first) => Outcome::Stream {
            first,
            rx,
            model: model.name.clone(),
            version: model.version.clone(),
            epoch: model.epoch,
        },
    }
}
