//! Per-connection mechanics: deadline-bounded head/body reads, strict
//! HTTP/1.1 parsing, full + chunked response writers.
//!
//! Everything here is fail-closed and panic-free: every malformed input,
//! limit breach, timeout and socket error maps to a specific close path
//! (structured error response, eviction, or silent close), and every
//! terminal status is recorded in [`HttpMetrics`] exactly once.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::registry::ModelRegistry;
use crate::util::json::{ObjBuilder, Value};

use super::api::{self, Outcome};
use super::{HttpConfig, HttpMetrics};

/// Shared per-connection context (one registry + config + counters for the
/// whole server).
pub(crate) struct ConnCtx {
    pub registry: Arc<ModelRegistry>,
    pub cfg: HttpConfig,
    pub metrics: Arc<HttpMetrics>,
}

/// A fully-read request, reduced to what the routed handlers consume: the
/// path (method and headers were already enforced here) + raw body bytes.
pub(crate) struct HttpRequest {
    pub path: String,
    pub body: Vec<u8>,
}

/// A complete (non-streamed) response.
pub(crate) struct Reply {
    pub status: u16,
    pub body: Value,
    /// Serialized as a `Retry-After` header (whole seconds, rounded up,
    /// minimum 1) and echoed as `retry_after_ms` in the error body.
    pub retry_after: Option<Duration>,
}

impl Reply {
    pub fn ok(body: Value) -> Self {
        Reply { status: 200, body, retry_after: None }
    }

    pub fn error(status: u16, code: &str, message: &str) -> Self {
        Reply { status, body: error_body(status, code, message, None), retry_after: None }
    }

    pub fn overloaded(status: u16, code: &str, message: &str, retry_after: Duration) -> Self {
        Reply {
            status,
            body: error_body(status, code, message, Some(retry_after)),
            retry_after: Some(retry_after),
        }
    }
}

/// The canonical structured error body:
/// `{"error":{"status":N,"code":"...","message":"..."}}`.
pub(crate) fn error_body(
    status: u16,
    code: &str,
    message: &str,
    retry_after: Option<Duration>,
) -> Value {
    let mut e = ObjBuilder::new()
        .uint("status", status as u64)
        .str("code", code)
        .str("message", message);
    if let Some(d) = retry_after {
        e = e.uint("retry_after_ms", d.as_millis() as u64);
    }
    ObjBuilder::new().set("error", e.build()).build()
}

pub(crate) fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Why a read loop gave up before producing a request.
enum ReadErr {
    /// Deadline exceeded — the slow-loris/eviction path (408).
    Evicted,
    /// Head grew past [`HttpConfig::max_header_bytes`] (431).
    TooLarge,
    /// Peer closed mid-message (400).
    Truncated,
    /// Peer closed before sending anything — not an error, just close.
    SilentClose,
    /// Socket error — nothing to say to the peer, just close.
    Io,
}

fn head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n").map(|p| p + 4)
}

/// Read until the blank line ending the head, under
/// [`HttpConfig::header_deadline`]. Returns the buffer and the offset just
/// past `\r\n\r\n` (bytes beyond it are the start of the body).
fn read_head(stream: &mut TcpStream, cfg: &HttpConfig) -> Result<(Vec<u8>, usize), ReadErr> {
    let deadline = Instant::now() + cfg.header_deadline;
    let mut buf = Vec::new();
    loop {
        if let Some(end) = head_end(&buf) {
            return Ok((buf, end));
        }
        if buf.len() > cfg.max_header_bytes {
            return Err(ReadErr::TooLarge);
        }
        read_some(stream, &mut buf, deadline, buf.is_empty())?;
    }
}

/// Read the remaining `want` body bytes under
/// [`HttpConfig::body_deadline`].
fn read_body(
    stream: &mut TcpStream,
    mut body: Vec<u8>,
    want: usize,
    cfg: &HttpConfig,
) -> Result<Vec<u8>, ReadErr> {
    let deadline = Instant::now() + cfg.body_deadline;
    while body.len() < want {
        read_some(stream, &mut body, deadline, false)?;
    }
    body.truncate(want);
    Ok(body)
}

/// One bounded read: enforce the deadline, tolerate timeout wakeups, map
/// EOF to `Truncated` (or `SilentClose` when nothing was ever received).
fn read_some(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    deadline: Instant,
    nothing_yet: bool,
) -> Result<(), ReadErr> {
    let now = Instant::now();
    if now >= deadline {
        return Err(ReadErr::Evicted);
    }
    let wait = (deadline - now).min(Duration::from_millis(100));
    stream.set_read_timeout(Some(wait)).map_err(|_| ReadErr::Io)?;
    let mut chunk = [0u8; 2048];
    match stream.read(&mut chunk) {
        Ok(0) => Err(if nothing_yet { ReadErr::SilentClose } else { ReadErr::Truncated }),
        Ok(n) => {
            buf.extend_from_slice(&chunk[..n]);
            Ok(())
        }
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            Ok(())
        }
        Err(_) => Err(ReadErr::Io),
    }
}

/// Parse the head: a strict request line (`METHOD SP PATH SP HTTP/1.x`)
/// plus `name: value` header lines, names lowercased.
fn parse_head(
    head: &[u8],
) -> Result<(String, String, BTreeMap<String, String>), String> {
    let text = std::str::from_utf8(head).map_err(|_| "head is not valid UTF-8".to_string())?;
    let mut lines = text.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let parts: Vec<&str> = request_line.split(' ').collect();
    if parts.len() != 3 || parts[0].is_empty() || parts[1].is_empty() {
        return Err(format!("malformed request line {request_line:?}"));
    }
    if !parts[2].starts_with("HTTP/1.") {
        return Err(format!("unsupported protocol {:?}", parts[2]));
    }
    let mut headers = BTreeMap::new();
    for line in lines {
        if line.is_empty() {
            break;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(format!("malformed header line {line:?}"));
        };
        headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
    }
    Ok((parts[0].to_string(), parts[1].to_string(), headers))
}

/// Serve one connection start to finish. Exactly one of: a full response, a
/// chunked stream, an eviction, or a silent close.
pub(crate) fn handle_connection(mut stream: TcpStream, ctx: &ConnCtx) {
    let _ = stream.set_nodelay(true);

    let (buf, body_start) = match read_head(&mut stream, &ctx.cfg) {
        Ok(ok) => ok,
        Err(ReadErr::Evicted) => return evict(&mut stream, ctx, "request head timed out"),
        Err(ReadErr::TooLarge) => {
            return reply_and_close(
                &mut stream,
                ctx,
                Reply::error(431, "header_too_large", "request head exceeds the configured limit"),
            )
        }
        Err(ReadErr::Truncated) => {
            return reply_and_close(
                &mut stream,
                ctx,
                Reply::error(400, "bad_request", "connection closed mid-head"),
            )
        }
        Err(ReadErr::SilentClose) | Err(ReadErr::Io) => return,
    };

    let (method, path, headers) = match parse_head(&buf[..body_start]) {
        Ok(h) => h,
        Err(msg) => {
            return reply_and_close(&mut stream, ctx, Reply::error(400, "bad_request", &msg))
        }
    };

    // Route existence first (404), then method (405).
    let known_get = matches!(path.as_str(), "/v1/healthz" | "/v1/models" | "/v1/metrics");
    let known_post = matches!(path.as_str(), "/v1/classify" | "/v1/generate");
    if !known_get && !known_post {
        return reply_and_close(
            &mut stream,
            ctx,
            Reply::error(404, "not_found", &format!("no route for {path:?}")),
        );
    }
    let expected = if known_get { "GET" } else { "POST" };
    if method != expected {
        return reply_and_close(
            &mut stream,
            ctx,
            Reply::error(405, "method_not_allowed", &format!("{path} requires {expected}")),
        );
    }

    let mut body = Vec::new();
    if known_post {
        if headers.contains_key("transfer-encoding") {
            return reply_and_close(
                &mut stream,
                ctx,
                Reply::error(501, "not_implemented", "chunked request bodies are not supported"),
            );
        }
        let Some(len_text) = headers.get("content-length") else {
            return reply_and_close(
                &mut stream,
                ctx,
                Reply::error(411, "length_required", "POST requires Content-Length"),
            );
        };
        let Ok(len) = len_text.parse::<usize>() else {
            return reply_and_close(
                &mut stream,
                ctx,
                Reply::error(400, "bad_request", &format!("invalid Content-Length {len_text:?}")),
            );
        };
        if len > ctx.cfg.max_body_bytes {
            let msg =
                format!("body of {len} bytes exceeds the {} byte limit", ctx.cfg.max_body_bytes);
            return reply_and_close(&mut stream, ctx, Reply::error(413, "payload_too_large", &msg));
        }
        body = match read_body(&mut stream, buf[body_start..].to_vec(), len, &ctx.cfg) {
            Ok(b) => b,
            Err(ReadErr::Evicted) => return evict(&mut stream, ctx, "request body timed out"),
            Err(ReadErr::Truncated) => {
                return reply_and_close(
                    &mut stream,
                    ctx,
                    Reply::error(400, "bad_request", "connection closed mid-body"),
                )
            }
            Err(_) => return,
        };
    }

    let req = HttpRequest { path, body };
    match api::route(&req, ctx) {
        Outcome::Json(reply) => reply_and_close(&mut stream, ctx, reply),
        Outcome::Stream { first, rx, model, version, epoch } => {
            stream_generate(&mut stream, ctx, first, rx, &model, &version, epoch)
        }
    }
}

/// Deadline eviction: best-effort 408, count it, close.
fn evict(stream: &mut TcpStream, ctx: &ConnCtx, msg: &str) {
    ctx.metrics.evictions.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    reply_and_close(stream, ctx, Reply::error(408, "timeout", msg));
}

/// Serialize + send a full response; every failure mode is a counted close.
fn reply_and_close(stream: &mut TcpStream, ctx: &ConnCtx, reply: Reply) {
    ctx.metrics.record_status(reply.status);
    let body = reply.body.render();
    let _ = stream.set_write_timeout(Some(ctx.cfg.write_timeout));
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nConnection: close\r\nContent-Type: application/json\r\nContent-Length: {}\r\n",
        reply.status,
        reason(reply.status),
        body.len()
    );
    if let Some(d) = reply.retry_after {
        head.push_str(&format!("Retry-After: {}\r\n", retry_after_secs(d)));
    }
    head.push_str("\r\n");
    if stream.write_all(head.as_bytes()).is_err() || stream.write_all(body.as_bytes()).is_err() {
        ctx.metrics.disconnects.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
    let _ = stream.flush();
}

/// `Retry-After` is whole seconds: round up, minimum 1.
pub(crate) fn retry_after_secs(d: Duration) -> u64 {
    d.as_secs() + u64::from(d.subsec_nanos() > 0).max(u64::from(d.as_secs() == 0))
}

/// Stream a generation as chunked ndjson. The first event was already
/// peeked (it decided the 200); the rest drain from `rx`. A write failure
/// means the client went away mid-stream: count the disconnect and drop the
/// receiver — the dispatcher finishes the session into the buffered channel
/// and reconciles its own metrics, so nothing leaks.
fn stream_generate(
    stream: &mut TcpStream,
    ctx: &ConnCtx,
    first: crate::coordinator::TokenEvent,
    rx: std::sync::mpsc::Receiver<crate::coordinator::TokenEvent>,
    model: &str,
    version: &str,
    epoch: u64,
) {
    use crate::coordinator::TokenEvent;

    ctx.metrics.record_status(200);
    let _ = stream.set_write_timeout(Some(ctx.cfg.write_timeout));
    let head = "HTTP/1.1 200 OK\r\nConnection: close\r\nContent-Type: application/x-ndjson\r\n\
                Transfer-Encoding: chunked\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        ctx.metrics.disconnects.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        return;
    }

    let mut event = Some(first);
    loop {
        let ev = match event.take() {
            Some(ev) => ev,
            None => match rx.recv() {
                Ok(ev) => ev,
                // Dispatcher gone mid-stream: close out the chunk stream
                // with a terminal error event.
                Err(_) => TokenEvent::Failed("server shut down mid-stream".to_string()),
            },
        };
        let (line, done) = match &ev {
            TokenEvent::Token { index, token } => (
                ObjBuilder::new()
                    .str("event", "token")
                    .uint("index", *index as u64)
                    .num("token", f64::from(*token))
                    .render(),
                false,
            ),
            TokenEvent::Done(resp) => (
                ObjBuilder::new()
                    .str("event", "done")
                    .arr_i32("tokens", &resp.tokens)
                    .str("variant", &resp.variant)
                    .str("model", model)
                    .str("version", version)
                    .uint("epoch", epoch)
                    .uint("prefill_tokens", resp.prefill_tokens as u64)
                    .uint("latency_us", resp.latency.as_micros() as u64)
                    .render(),
                true,
            ),
            TokenEvent::Failed(msg) => (
                ObjBuilder::new().str("event", "error").str("message", msg).render(),
                true,
            ),
            // Rejections only ever arrive as the first event, which the
            // handler already mapped to a 429 — but stay total.
            TokenEvent::Rejected(reason) => (
                ObjBuilder::new()
                    .str("event", "error")
                    .str("message", &format!("rejected: {reason}"))
                    .render(),
                true,
            ),
        };
        if write_chunk(stream, line.as_bytes()).is_err() {
            ctx.metrics.disconnects.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            return;
        }
        if done {
            break;
        }
    }
    if stream.write_all(b"0\r\n\r\n").is_err() {
        ctx.metrics.disconnects.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
    let _ = stream.flush();
}

/// One chunk: hex length, CRLF, payload + trailing newline, CRLF.
fn write_chunk(stream: &mut TcpStream, data: &[u8]) -> std::io::Result<()> {
    // Each event is its own chunk and its own line (ndjson).
    write!(stream, "{:x}\r\n", data.len() + 1)?;
    stream.write_all(data)?;
    stream.write_all(b"\n\r\n")?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn head_end_finds_blank_line() {
        assert_eq!(head_end(b"GET / HTTP/1.1\r\n\r\nrest"), Some(18));
        assert_eq!(head_end(b"GET / HTTP/1.1\r\n"), None);
    }

    #[test]
    fn parse_head_is_strict() {
        let (m, p, h) =
            parse_head(b"POST /v1/classify HTTP/1.1\r\nContent-Length: 2\r\nHost: x\r\n\r\n")
                .unwrap();
        assert_eq!((m.as_str(), p.as_str()), ("POST", "/v1/classify"));
        assert_eq!(h.get("content-length").map(String::as_str), Some("2"));

        assert!(parse_head(b"GARBAGE\r\n\r\n").is_err());
        assert!(parse_head(b"GET /path\r\n\r\n").is_err(), "two-part request line");
        assert!(parse_head(b"GET /path SPDY/3\r\n\r\n").is_err(), "non-HTTP protocol");
        assert!(parse_head(b"GET / HTTP/1.1\r\nbadheader\r\n\r\n").is_err());
    }

    #[test]
    fn retry_after_rounds_up_with_floor_of_one() {
        assert_eq!(retry_after_secs(Duration::from_millis(10)), 1);
        assert_eq!(retry_after_secs(Duration::from_secs(2)), 2);
        assert_eq!(retry_after_secs(Duration::from_millis(2500)), 3);
    }

    #[test]
    fn error_body_is_structured() {
        let v = error_body(429, "overloaded", "busy", Some(Duration::from_millis(50)));
        let e = v.get("error").unwrap();
        assert_eq!(e.usize_or("status", 0), 429);
        assert_eq!(e.str_or("code", ""), "overloaded");
        assert_eq!(e.usize_or("retry_after_ms", 0), 50);
    }
}
