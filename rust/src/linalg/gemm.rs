//! Packed, cache-tiled GEMM / GEMV kernels with fused epilogues.
//!
//! This is the dense compute core every solver, interpreter and gradient
//! pass runs on. Three kernels sit behind one dispatch:
//!
//! * **Packed GEMM** — BLIS-style `NC`/`KC`/`MC` cache blocking around an
//!   `MR×NR = 8×8` register microkernel. A- and B-panels are packed into
//!   contiguous, zero-padded buffers (reused across row blocks and calls
//!   via thread-local scratch), so the microkernel's inner loop is pure
//!   contiguous loads + 8-wide multiply-adds that LLVM vectorizes.
//! * **Column-split GEMV** — the `m = 1` case (every per-token decode
//!   matmul) cannot be row-parallelized; it is split over output columns
//!   across the [`super::pool`] workers instead.
//! * **Fused epilogues** — [`matmul_bias_into`] adds the bias row and
//!   applies an optional activation while the output tile is still hot,
//!   removing the separate read-modify-write passes the interpreters used
//!   to make over every activation buffer.
//!
//! # Accumulation-order compatibility
//!
//! Every path — reference, packed, GEMV, serial or pooled, any tile size —
//! accumulates each output element through a *single* f32 accumulator chain
//! in ascending k order: `((out + a₀·b₀) + a₁·b₁) + …`. k-blocking only
//! round-trips the running sum through memory (exact for f32), row/column
//! splits never touch the k order, and the epilogue runs strictly after the
//! full sum, exactly where the unfused bias/activation passes ran. The
//! result is **bit-identical** across every dispatch boundary — the
//! property `tests/proptest_linalg.rs` pins against
//! [`matmul_into_reference`] and the property the KV-cache decode path
//! (DESIGN.md §10) and the golden training curves rely on.

use std::cell::RefCell;

use super::pool;

/// Register microkernel tile rows.
const MR: usize = 8;
/// Register microkernel tile columns (one 8-wide SIMD vector of f32).
const NR: usize = 8;
/// k-dimension cache block: one packed B panel spans `KC` rows.
const KC: usize = 256;
/// Row cache block: one packed A panel spans up to `MC` rows.
const MC: usize = 64;
/// Column cache block: B panels cover `NC` columns per pass.
const NC: usize = 1024;

/// Below this many multiply-adds the packing overhead loses to the plain
/// serial loop.
const PACKED_MIN_MACS: usize = 1 << 15;
/// Below this many multiply-adds a GEMM runs on one thread.
const GEMM_PARALLEL_MIN_MACS: usize = 1 << 19;
/// Below this many multiply-adds a GEMV runs on one thread.
const GEMV_PARALLEL_MIN_MACS: usize = 100_000;
/// Minimum columns per GEMV shard (keeps per-task work vectorizable).
const GEMV_MIN_COLS_PER_TASK: usize = 64;

/// Activation fused into the GEMM epilogue by [`matmul_bias_into`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Activation {
    /// No activation.
    #[default]
    None,
    /// tanh-approximated GELU (same formula as the JAX graphs).
    Gelu,
    /// max(0, x).
    Relu,
}

/// tanh-approximated GELU in place (the JAX default the AOT graphs lower).
/// Single source of truth: the interpreters and the fused epilogue both
/// call this, so fused vs unfused execution is bit-identical.
pub fn gelu_slice(x: &mut [f32]) {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    for v in x.iter_mut() {
        let t = C * (*v + 0.044715 * *v * *v * *v);
        *v = 0.5 * *v * (1.0 + t.tanh());
    }
}

/// ReLU in place.
pub fn relu_slice(x: &mut [f32]) {
    for v in x.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// `out(m,n) += a(m,k) @ b(k,n)`, all row-major. Parallel packed GEMM (or
/// column-split GEMV when `m == 1`); numerically identical to
/// [`matmul_into_reference`] bit for bit.
pub fn matmul_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    matmul_bias_into(m, k, n, a, b, None, Activation::None, out);
}

/// `out(m,n) = act(out + a(m,k) @ b(k,n) + bias)` with the bias add and
/// activation fused into the kernel's final pass over each output tile.
///
/// `bias` (length `n`, broadcast over rows) and `act` apply strictly after
/// the complete k-sum of each element — the same value the unfused
/// GEMM-then-bias-then-activation sequence produces, bit for bit. With
/// `bias = None` and `Activation::None` this is exactly [`matmul_into`].
/// `out` still participates as the accumulator base, so pass a zeroed
/// buffer for plain `y = act(x·W + b)` semantics.
#[allow(clippy::too_many_arguments)]
pub fn matmul_bias_into(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    act: Activation,
    out: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k, "gemm: a length");
    debug_assert_eq!(b.len(), k * n, "gemm: b length");
    debug_assert_eq!(out.len(), m * n, "gemm: out length");
    if let Some(bias) = bias {
        debug_assert_eq!(bias.len(), n, "gemm: bias length");
    }
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        // Nothing to accumulate; the epilogue still applies.
        for row in out.chunks_exact_mut(n) {
            apply_epilogue(row, bias, act);
        }
        return;
    }
    if m == 1 {
        gemv(k, n, a, b, bias, act, out);
        return;
    }
    let macs = m * k * n;
    if macs < PACKED_MIN_MACS {
        matmul_into_reference(m, k, n, a, b, out);
        for row in out.chunks_exact_mut(n) {
            apply_epilogue(row, bias, act);
        }
        return;
    }
    let width = pool::parallelism();
    if macs < GEMM_PARALLEL_MIN_MACS || width <= 1 {
        packed_gemm_serial(m, k, n, a, b, bias, act, out);
        return;
    }
    // Shard rows across the pool, MR-aligned so shards tile cleanly. Each
    // shard packs its own B panels (thread-local scratch): redundant work of
    // O(k·n) copies per shard against O(m·k·n / shards) MACs each, accepted
    // to keep tasks fully independent — sharing one packed B across shards
    // needs cross-task synchronization the single-job pool deliberately
    // avoids. Revisit if shard counts grow past ~16.
    let n_tasks = width.min(m.div_ceil(MR));
    let rows_per = m.div_ceil(n_tasks).div_ceil(MR) * MR;
    let n_tasks = m.div_ceil(rows_per);
    let optr = SendPtr(out.as_mut_ptr());
    pool::run(n_tasks, &|t| {
        let r0 = t * rows_per;
        let r1 = (r0 + rows_per).min(m);
        let a_sub = &a[r0 * k..r1 * k];
        // SAFETY: tasks own disjoint row ranges [r0, r1) of `out`.
        let o_sub = unsafe { std::slice::from_raw_parts_mut(optr.0.add(r0 * n), (r1 - r0) * n) };
        packed_gemm_serial(r1 - r0, k, n, a_sub, b, bias, act, o_sub);
    });
}

/// The legacy serial i-k-j kernel (pre-PR-5 `matmul_rows`, minus the dead
/// `a != 0` branch that defeated vectorization on dense inputs). Kept as
/// the measured baseline for `benches/kernel_speedup.rs` and as the parity
/// oracle for `tests/proptest_linalg.rs`; not used on any hot path above
/// the small-problem cutoff.
pub fn matmul_into_reference(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

/// Bias + activation over one finished output row (or row fragment, with
/// `bias` pre-sliced to match). Shared with the quantized containers in
/// [`super::quant`] so every path runs the identical epilogue sequence.
pub(crate) fn apply_epilogue(row: &mut [f32], bias: Option<&[f32]>, act: Activation) {
    if let Some(bias) = bias {
        debug_assert_eq!(row.len(), bias.len());
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
        }
    }
    match act {
        Activation::None => {}
        Activation::Gelu => gelu_slice(row),
        Activation::Relu => relu_slice(row),
    }
}

// ---------------------------------------------------------------------------
// GEMV (m = 1): column-split parallel
// ---------------------------------------------------------------------------

/// `out(n) += a(k) @ b(k,n)` over columns `[j0, j1)`; `out` holds exactly
/// that range. k-outer order streams `b`'s rows contiguously (vectorized),
/// and each element keeps the ascending-k single-accumulator chain.
fn gemv_range(k: usize, n: usize, j0: usize, j1: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), j1 - j0);
    debug_assert_eq!(a.len(), k);
    for (p, &av) in a.iter().enumerate() {
        let brow = &b[p * n + j0..p * n + j1];
        for (o, &bv) in out.iter_mut().zip(brow) {
            *o += av * bv;
        }
    }
}

fn gemv(
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    act: Activation,
    out: &mut [f32],
) {
    let macs = k * n;
    let width = pool::parallelism();
    if macs < GEMV_PARALLEL_MIN_MACS || width <= 1 || n < 2 * GEMV_MIN_COLS_PER_TASK {
        gemv_range(k, n, 0, n, a, b, out);
        apply_epilogue(out, bias, act);
        return;
    }
    let n_tasks = width.min(n / GEMV_MIN_COLS_PER_TASK).max(1);
    let cols_per = n.div_ceil(n_tasks);
    let n_tasks = n.div_ceil(cols_per);
    let optr = SendPtr(out.as_mut_ptr());
    pool::run(n_tasks, &|t| {
        let j0 = t * cols_per;
        let j1 = (j0 + cols_per).min(n);
        // SAFETY: tasks own disjoint column ranges [j0, j1) of `out`.
        let o = unsafe { std::slice::from_raw_parts_mut(optr.0.add(j0), j1 - j0) };
        gemv_range(k, n, j0, j1, a, b, o);
        apply_epilogue(o, bias.map(|bs| &bs[j0..j1]), act);
    });
}

// ---------------------------------------------------------------------------
// Packed GEMM
// ---------------------------------------------------------------------------

/// Raw `*mut f32` that tasks offset into disjoint regions.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: every use derives non-overlapping sub-slices per task.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

thread_local! {
    /// Per-thread packing scratch `(apack, bpack)`, reused across calls so
    /// steady-state GEMMs do zero heap allocation.
    static PACK_BUFS: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Serial packed GEMM over the caller's row range. Loop nest (outside in):
/// `jc` over `NC` column blocks, `pc` over `KC` k blocks (B packed once per
/// `(jc, pc)` and reused across every row block), `ic` over `MC` row blocks
/// (A packed per `(ic, pc)`), then `NR`-wide B micro-panels × `MR`-tall A
/// micro-panels into the register tile. The epilogue is applied to each
/// tile on the final k block, while it is still in registers.
#[allow(clippy::too_many_arguments)]
fn packed_gemm_serial(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    act: Activation,
    out: &mut [f32],
) {
    PACK_BUFS.with(|cell| {
        let mut bufs = cell.borrow_mut();
        let (apack, bpack) = &mut *bufs;
        let kc_blocks = k.div_ceil(KC);
        for jc in (0..n).step_by(NC) {
            let ncb = NC.min(n - jc);
            let n_jp = ncb.div_ceil(NR);
            for (kb, pc) in (0..k).step_by(KC).enumerate() {
                let kcb = KC.min(k - pc);
                let last_k = kb == kc_blocks - 1;
                pack_b(b, n, pc, kcb, jc, ncb, bpack);
                for ic in (0..m).step_by(MC) {
                    let mcb = MC.min(m - ic);
                    let n_ip = mcb.div_ceil(MR);
                    pack_a(a, k, pc, kcb, ic, mcb, apack);
                    for jp in 0..n_jp {
                        let jr = jp * NR;
                        let nr = NR.min(ncb - jr);
                        let bpanel = &bpack[jp * kcb * NR..(jp + 1) * kcb * NR];
                        for ip in 0..n_ip {
                            let ir = ip * MR;
                            let mr = MR.min(mcb - ir);
                            let apanel = &apack[ip * kcb * MR..(ip + 1) * kcb * MR];
                            micro_tile(
                                kcb,
                                apanel,
                                bpanel,
                                out,
                                n,
                                ic + ir,
                                jc + jr,
                                mr,
                                nr,
                                last_k,
                                bias,
                                act,
                            );
                        }
                    }
                }
            }
        }
    });
}

/// Pack `b[pc..pc+kcb, jc..jc+ncb]` into `NR`-wide column micro-panels
/// (`panel[p*NR + c]`), zero-padding the final partial panel so the
/// microkernel never branches on width.
fn pack_b(b: &[f32], n: usize, pc: usize, kcb: usize, jc: usize, ncb: usize, bpack: &mut Vec<f32>) {
    let n_jp = ncb.div_ceil(NR);
    bpack.clear();
    bpack.resize(n_jp * kcb * NR, 0.0);
    for p in 0..kcb {
        let brow = &b[(pc + p) * n + jc..(pc + p) * n + jc + ncb];
        for jp in 0..n_jp {
            let jr = jp * NR;
            let nr = NR.min(ncb - jr);
            let dst = (jp * kcb + p) * NR;
            bpack[dst..dst + nr].copy_from_slice(&brow[jr..jr + nr]);
        }
    }
}

/// Pack `a[ic..ic+mcb, pc..pc+kcb]` into `MR`-tall row micro-panels
/// transposed to k-major (`panel[p*MR + r]`), zero-padding the final
/// partial panel. Padded rows multiply real B values by 0.0 into lanes the
/// store mask discards, so they never touch live output.
fn pack_a(a: &[f32], k: usize, pc: usize, kcb: usize, ic: usize, mcb: usize, apack: &mut Vec<f32>) {
    let n_ip = mcb.div_ceil(MR);
    apack.clear();
    apack.resize(n_ip * kcb * MR, 0.0);
    for ip in 0..n_ip {
        let ir = ip * MR;
        let mr = MR.min(mcb - ir);
        for r in 0..mr {
            let arow = &a[(ic + ir + r) * k + pc..(ic + ir + r) * k + pc + kcb];
            let base = ip * kcb * MR + r;
            for (p, &v) in arow.iter().enumerate() {
                apack[base + p * MR] = v;
            }
        }
    }
}

/// One `MR×NR` register tile: load the live `mr×nr` region of `out` into
/// the tile (padded lanes zero), run the microkernel over the packed
/// panels, then store the live region back — applying the fused epilogue
/// if this was the final k block.
#[allow(clippy::too_many_arguments)]
fn micro_tile(
    kcb: usize,
    apanel: &[f32],
    bpanel: &[f32],
    out: &mut [f32],
    ldc: usize,
    row0: usize,
    col0: usize,
    mr: usize,
    nr: usize,
    last_k: bool,
    bias: Option<&[f32]>,
    act: Activation,
) {
    let mut tile = [0.0f32; MR * NR];
    for r in 0..mr {
        let src = &out[(row0 + r) * ldc + col0..(row0 + r) * ldc + col0 + nr];
        tile[r * NR..r * NR + nr].copy_from_slice(src);
    }
    microkernel(kcb, apanel, bpanel, &mut tile);
    for r in 0..mr {
        let dst = &mut out[(row0 + r) * ldc + col0..(row0 + r) * ldc + col0 + nr];
        dst.copy_from_slice(&tile[r * NR..r * NR + nr]);
        if last_k {
            apply_epilogue(dst, bias.map(|bs| &bs[col0..col0 + nr]), act);
        }
    }
}

/// The register microkernel: `tile(MR,NR) += apanel ᵀ-major @ bpanel`. For
/// each k step it broadcasts `MR` A values against one `NR`-wide B vector —
/// fixed-size array rows that LLVM keeps in SIMD registers and lowers to
/// 8-wide multiply-add sequences.
#[inline(always)]
fn microkernel(kcb: usize, apanel: &[f32], bpanel: &[f32], tile: &mut [f32; MR * NR]) {
    debug_assert!(apanel.len() >= kcb * MR);
    debug_assert!(bpanel.len() >= kcb * NR);
    for p in 0..kcb {
        let av: &[f32; MR] = apanel[p * MR..p * MR + MR].try_into().unwrap();
        let bv: &[f32; NR] = bpanel[p * NR..p * NR + NR].try_into().unwrap();
        for (r, &ar) in av.iter().enumerate() {
            let trow = &mut tile[r * NR..r * NR + NR];
            for (t, &bb) in trow.iter_mut().zip(bv) {
                *t += ar * bb;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Quantized int8 GEMM / GEMV (i8×i8 → i32 accumulate, f32 dequant epilogue)
// ---------------------------------------------------------------------------

/// Largest `k` the int8 kernels accept: `k · 127² < i32::MAX`, so the i32
/// accumulator provably cannot overflow. Far above any model dimension here.
pub const QGEMM_MAX_K: usize = 130_000;

thread_local! {
    /// Per-thread int8 packing scratch `(apack, bpack)` for the quantized
    /// GEMM, mirroring [`PACK_BUFS`].
    static QPACK_BUFS: RefCell<(Vec<i8>, Vec<i8>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
    /// Per-thread i32 accumulator scratch for the quantized GEMV shards.
    static QGEMV_ACC: RefCell<Vec<i32>> = const { RefCell::new(Vec::new()) };
}

/// Quantized `out(m,n) = act(out + dequant(xq(m,k) @ wq(k,n)) + bias)`.
///
/// `xq` is the per-row symmetric int8 quantization of the activations with
/// row scales `xscale` (length `m`); `wq` is the per-output-channel symmetric
/// int8 weight with column scales `wscale` (length `n`). Each output element
/// accumulates the full dot product in one i32 (exact — integer addition is
/// associative, so unlike the f32 kernels no accumulation-order argument is
/// needed) and is dequantized by a single f32 multiply:
/// `out[i,j] += (acc as f32) * (xscale[i] * wscale[j])`, after which the
/// fused bias/activation epilogue runs exactly as in [`matmul_bias_into`].
///
/// Every dispatch target — the scalar reference, the packed tiles, the
/// column-split GEMV, serial or pooled — performs that identical per-element
/// f32 sequence, so the result is **bit-identical** across all of them
/// (pinned by `tests/proptest_quant.rs`).
#[allow(clippy::too_many_arguments)]
pub fn qmatmul_bias_into(
    m: usize,
    k: usize,
    n: usize,
    xq: &[i8],
    xscale: &[f32],
    wq: &[i8],
    wscale: &[f32],
    bias: Option<&[f32]>,
    act: Activation,
    out: &mut [f32],
) {
    debug_assert_eq!(xq.len(), m * k, "qgemm: xq length");
    debug_assert_eq!(xscale.len(), m, "qgemm: xscale length");
    debug_assert_eq!(wq.len(), k * n, "qgemm: wq length");
    debug_assert_eq!(wscale.len(), n, "qgemm: wscale length");
    debug_assert_eq!(out.len(), m * n, "qgemm: out length");
    debug_assert!(k <= QGEMM_MAX_K, "qgemm: k={k} risks i32 overflow");
    if let Some(bias) = bias {
        debug_assert_eq!(bias.len(), n, "qgemm: bias length");
    }
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        // Nothing to accumulate; the epilogue still applies.
        for row in out.chunks_exact_mut(n) {
            apply_epilogue(row, bias, act);
        }
        return;
    }
    if m == 1 {
        qgemv(k, n, xq, xscale[0], wq, wscale, bias, act, out);
        return;
    }
    let macs = m * k * n;
    if macs < PACKED_MIN_MACS {
        qmatmul_into_reference(m, k, n, xq, xscale, wq, wscale, bias, act, out);
        return;
    }
    let width = pool::parallelism();
    if macs < GEMM_PARALLEL_MIN_MACS || width <= 1 {
        qpacked_gemm_serial(m, k, n, xq, xscale, wq, wscale, bias, act, out);
        return;
    }
    // Row shards across the pool, MR-aligned — same skeleton (and the same
    // redundant-B-pack trade) as the f32 parallel path above.
    let n_tasks = width.min(m.div_ceil(MR));
    let rows_per = m.div_ceil(n_tasks).div_ceil(MR) * MR;
    let n_tasks = m.div_ceil(rows_per);
    let optr = SendPtr(out.as_mut_ptr());
    pool::run(n_tasks, &|t| {
        let r0 = t * rows_per;
        let r1 = (r0 + rows_per).min(m);
        let x_sub = &xq[r0 * k..r1 * k];
        let xs_sub = &xscale[r0..r1];
        // SAFETY: tasks own disjoint row ranges [r0, r1) of `out`.
        let o_sub = unsafe { std::slice::from_raw_parts_mut(optr.0.add(r0 * n), (r1 - r0) * n) };
        qpacked_gemm_serial(r1 - r0, k, n, x_sub, xs_sub, wq, wscale, bias, act, o_sub);
    });
}

/// The scalar reference quantized matmul — the oracle `qmatmul_bias_into`
/// must match bit for bit. Plain i-j-k triple loop, one i32 accumulator per
/// element, then the shared dequant + epilogue sequence.
#[allow(clippy::too_many_arguments)]
pub fn qmatmul_into_reference(
    m: usize,
    k: usize,
    n: usize,
    xq: &[i8],
    xscale: &[f32],
    wq: &[i8],
    wscale: &[f32],
    bias: Option<&[f32]>,
    act: Activation,
    out: &mut [f32],
) {
    debug_assert_eq!(xq.len(), m * k);
    debug_assert_eq!(wq.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let xrow = &xq[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (j, o) in orow.iter_mut().enumerate() {
            let mut acc = 0i32;
            for (p, &xv) in xrow.iter().enumerate() {
                acc += xv as i32 * wq[p * n + j] as i32;
            }
            *o += acc as f32 * (xscale[i] * wscale[j]);
        }
        apply_epilogue(orow, bias, act);
    }
}

/// Quantized GEMV over columns `[j0, j1)`: i32 accumulators in `acc`
/// (resized, zeroed), k-outer so `wq`'s rows stream contiguously.
fn qgemv_range(
    k: usize,
    n: usize,
    j0: usize,
    j1: usize,
    xq: &[i8],
    wq: &[i8],
    acc: &mut Vec<i32>,
) {
    acc.clear();
    acc.resize(j1 - j0, 0);
    for (p, &xv) in xq.iter().enumerate() {
        let xv = xv as i32;
        let wrow = &wq[p * n + j0..p * n + j1];
        for (a, &wv) in acc.iter_mut().zip(wrow) {
            *a += xv * wv as i32;
        }
    }
    debug_assert_eq!(k, xq.len());
}

/// Dequantize an accumulator range into `out` and run the fused epilogue.
fn qstore_row(
    acc: &[i32],
    xscale: f32,
    wscale: &[f32],
    bias: Option<&[f32]>,
    act: Activation,
    out: &mut [f32],
) {
    for ((o, &a), &ws) in out.iter_mut().zip(acc).zip(wscale) {
        *o += a as f32 * (xscale * ws);
    }
    apply_epilogue(out, bias, act);
}

/// The m = 1 decode step: column-split like [`gemv`], with per-thread i32
/// accumulator scratch so the steady-state decode loop allocates nothing.
#[allow(clippy::too_many_arguments)]
fn qgemv(
    k: usize,
    n: usize,
    xq: &[i8],
    xscale: f32,
    wq: &[i8],
    wscale: &[f32],
    bias: Option<&[f32]>,
    act: Activation,
    out: &mut [f32],
) {
    let macs = k * n;
    let width = pool::parallelism();
    if macs < GEMV_PARALLEL_MIN_MACS || width <= 1 || n < 2 * GEMV_MIN_COLS_PER_TASK {
        QGEMV_ACC.with(|cell| {
            let acc = &mut *cell.borrow_mut();
            qgemv_range(k, n, 0, n, xq, wq, acc);
            qstore_row(acc, xscale, wscale, bias, act, out);
        });
        return;
    }
    let n_tasks = width.min(n / GEMV_MIN_COLS_PER_TASK).max(1);
    let cols_per = n.div_ceil(n_tasks);
    let n_tasks = n.div_ceil(cols_per);
    let optr = SendPtr(out.as_mut_ptr());
    pool::run(n_tasks, &|t| {
        let j0 = t * cols_per;
        let j1 = (j0 + cols_per).min(n);
        // SAFETY: tasks own disjoint column ranges [j0, j1) of `out`.
        let o = unsafe { std::slice::from_raw_parts_mut(optr.0.add(j0), j1 - j0) };
        QGEMV_ACC.with(|cell| {
            let acc = &mut *cell.borrow_mut();
            qgemv_range(k, n, j0, j1, xq, wq, acc);
            qstore_row(acc, xscale, &wscale[j0..j1], bias.map(|bs| &bs[j0..j1]), act, o);
        });
    });
}

/// Serial packed int8 GEMM over the caller's row range. Same `jc`/`ic`
/// blocking and micro-panel layout as [`packed_gemm_serial`], with one
/// deliberate difference: **no `KC` split**. The microkernel accumulates the
/// *entire* k extent into an i32 register tile — exact regardless of order —
/// so each output element is produced by one tile pass and dequantized with
/// a single f32 multiply at store time. (An int8 A panel at the dimensions
/// this crate runs is ≤ a few KB, so the k-blocking that keeps f32 panels in
/// cache buys nothing here.)
#[allow(clippy::too_many_arguments)]
fn qpacked_gemm_serial(
    m: usize,
    k: usize,
    n: usize,
    xq: &[i8],
    xscale: &[f32],
    wq: &[i8],
    wscale: &[f32],
    bias: Option<&[f32]>,
    act: Activation,
    out: &mut [f32],
) {
    QPACK_BUFS.with(|cell| {
        let mut bufs = cell.borrow_mut();
        let (apack, bpack) = &mut *bufs;
        for jc in (0..n).step_by(NC) {
            let ncb = NC.min(n - jc);
            let n_jp = ncb.div_ceil(NR);
            qpack_b(wq, n, k, jc, ncb, bpack);
            for ic in (0..m).step_by(MC) {
                let mcb = MC.min(m - ic);
                let n_ip = mcb.div_ceil(MR);
                qpack_a(xq, k, ic, mcb, apack);
                for jp in 0..n_jp {
                    let jr = jp * NR;
                    let nr = NR.min(ncb - jr);
                    let bpanel = &bpack[jp * k * NR..(jp + 1) * k * NR];
                    for ip in 0..n_ip {
                        let ir = ip * MR;
                        let mr = MR.min(mcb - ir);
                        let apanel = &apack[ip * k * MR..(ip + 1) * k * MR];
                        let mut tile = [0i32; MR * NR];
                        qmicrokernel(k, apanel, bpanel, &mut tile);
                        for r in 0..mr {
                            let row = ic + ir + r;
                            let dst =
                                &mut out[row * n + jc + jr..row * n + jc + jr + nr];
                            let acc = &tile[r * NR..r * NR + nr];
                            qstore_row(
                                acc,
                                xscale[row],
                                &wscale[jc + jr..jc + jr + nr],
                                bias.map(|bs| &bs[jc + jr..jc + jr + nr]),
                                act,
                                dst,
                            );
                        }
                    }
                }
            }
        }
    });
}

/// Pack `wq[0..k, jc..jc+ncb]` into `NR`-wide int8 column micro-panels
/// (`panel[p*NR + c]`), zero-padding the final partial panel.
fn qpack_b(wq: &[i8], n: usize, k: usize, jc: usize, ncb: usize, bpack: &mut Vec<i8>) {
    let n_jp = ncb.div_ceil(NR);
    bpack.clear();
    bpack.resize(n_jp * k * NR, 0);
    for p in 0..k {
        let wrow = &wq[p * n + jc..p * n + jc + ncb];
        for jp in 0..n_jp {
            let jr = jp * NR;
            let nr = NR.min(ncb - jr);
            let dst = (jp * k + p) * NR;
            bpack[dst..dst + nr].copy_from_slice(&wrow[jr..jr + nr]);
        }
    }
}

/// Pack `xq[ic..ic+mcb, 0..k]` into `MR`-tall k-major int8 row micro-panels
/// (`panel[p*MR + r]`), zero-padding the final partial panel. Padded rows
/// contribute zero products into lanes the store mask discards.
fn qpack_a(xq: &[i8], k: usize, ic: usize, mcb: usize, apack: &mut Vec<i8>) {
    let n_ip = mcb.div_ceil(MR);
    apack.clear();
    apack.resize(n_ip * k * MR, 0);
    for ip in 0..n_ip {
        let ir = ip * MR;
        let mr = MR.min(mcb - ir);
        for r in 0..mr {
            let xrow = &xq[(ic + ir + r) * k..(ic + ir + r) * k + k];
            let base = ip * k * MR + r;
            for (p, &v) in xrow.iter().enumerate() {
                apack[base + p * MR] = v;
            }
        }
    }
}

/// The int8 register microkernel: `tile(MR,NR) += apanel ᵀ-major @ bpanel`
/// with widening i8→i32 multiply-adds over the full k extent.
#[inline(always)]
fn qmicrokernel(k: usize, apanel: &[i8], bpanel: &[i8], tile: &mut [i32; MR * NR]) {
    debug_assert!(apanel.len() >= k * MR);
    debug_assert!(bpanel.len() >= k * NR);
    for p in 0..k {
        let av: &[i8; MR] = apanel[p * MR..p * MR + MR].try_into().unwrap();
        let bv: &[i8; NR] = bpanel[p * NR..p * NR + NR].try_into().unwrap();
        for (r, &ar) in av.iter().enumerate() {
            let ar = ar as i32;
            let trow = &mut tile[r * NR..r * NR + NR];
            for (t, &bb) in trow.iter_mut().zip(bv) {
                *t += ar * bb as i32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn randv(rng: &mut Pcg64, len: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    fn assert_bits_eq(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "elem {i}: {x} vs {y}");
        }
    }

    // Module-level smoke only: the exhaustive bitwise parity matrix
    // (adversarial shapes, GEMV serial + parallel, fused epilogues,
    // concurrent submitters) lives in tests/proptest_linalg.rs.

    #[test]
    fn packed_matches_reference_bitwise() {
        let mut rng = Pcg64::seeded(11);
        for (m, k, n) in [(2, 3, 5), (13, 29, 31), (65, 257, 129)] {
            let a = randv(&mut rng, m * k);
            let b = randv(&mut rng, k * n);
            // Non-zero initial out pins the += accumulate semantics too.
            let init = randv(&mut rng, m * n);
            let mut got = init.clone();
            let mut want = init.clone();
            matmul_into(m, k, n, &a, &b, &mut got);
            matmul_into_reference(m, k, n, &a, &b, &mut want);
            assert_bits_eq(&got, &want);
        }
    }

    #[test]
    fn k_zero_is_epilogue_only() {
        let mut out = vec![1.0f32, -2.0, 3.0, -4.0];
        matmul_into(2, 0, 2, &[], &[], &mut out);
        assert_eq!(out, vec![1.0, -2.0, 3.0, -4.0]);
        let bias = [0.5f32, 0.5];
        matmul_bias_into(2, 0, 2, &[], &[], Some(&bias), Activation::Relu, &mut out);
        assert_eq!(out, vec![1.5, 0.0, 3.5, 0.0]);
    }

    fn randq(rng: &mut Pcg64, len: usize) -> Vec<i8> {
        (0..len).map(|_| (rng.below(255) as i32 - 127) as i8).collect()
    }

    // Quant smoke only: the adversarial-shape matrix (m=1, k=0, remainders,
    // pool-vs-serial, fused epilogues) lives in tests/proptest_quant.rs.
    #[test]
    fn qpacked_matches_reference_bitwise() {
        let mut rng = Pcg64::seeded(21);
        for (m, k, n) in [(2, 3, 5), (13, 29, 31), (33, 65, 33), (96, 130, 120)] {
            let xq = randq(&mut rng, m * k);
            let wq = randq(&mut rng, k * n);
            let xs: Vec<f32> = (0..m).map(|_| rng.next_f32() * 0.01 + 1e-4).collect();
            let ws: Vec<f32> = (0..n).map(|_| rng.next_f32() * 0.01 + 1e-4).collect();
            let bias: Vec<f32> = randv(&mut rng, n);
            let init = randv(&mut rng, m * n);
            let mut got = init.clone();
            let mut want = init.clone();
            qmatmul_bias_into(m, k, n, &xq, &xs, &wq, &ws, Some(&bias), Activation::Gelu, &mut got);
            qmatmul_into_reference(
                m, k, n, &xq, &xs, &wq, &ws, Some(&bias), Activation::Gelu, &mut want,
            );
            assert_bits_eq(&got, &want);
        }
    }

    #[test]
    fn qgemv_matches_reference_bitwise() {
        let mut rng = Pcg64::seeded(22);
        // Serial (small n) and pooled (macs + cols over both thresholds).
        for (k, n) in [(7, 5), (300, 2000)] {
            let xq = randq(&mut rng, k);
            let wq = randq(&mut rng, k * n);
            let xs = [rng.next_f32() * 0.01 + 1e-4];
            let ws: Vec<f32> = (0..n).map(|_| rng.next_f32() * 0.01 + 1e-4).collect();
            let mut got = vec![0.0f32; n];
            let mut want = vec![0.0f32; n];
            qmatmul_bias_into(1, k, n, &xq, &xs, &wq, &ws, None, Activation::None, &mut got);
            qmatmul_into_reference(1, k, n, &xq, &xs, &wq, &ws, None, Activation::None, &mut want);
            assert_bits_eq(&got, &want);
        }
    }

    #[test]
    fn parallel_path_matches_reference_bitwise() {
        // Big enough to cross GEMM_PARALLEL_MIN_MACS.
        let mut rng = Pcg64::seeded(14);
        let (m, k, n) = (96, 130, 120);
        assert!(m * k * n >= GEMM_PARALLEL_MIN_MACS);
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let mut got = vec![0.0f32; m * n];
        let mut want = vec![0.0f32; m * n];
        matmul_into(m, k, n, &a, &b, &mut got);
        matmul_into_reference(m, k, n, &a, &b, &mut want);
        assert_bits_eq(&got, &want);
    }
}
