//! Small symmetric positive (semi-)definite solves for SNMF's closed-form
//! A-step: A = W G (GᵀG)⁻¹. GᵀG is r×r (r ≤ a few hundred), so a Cholesky
//! with a ridge fallback is exact enough and trivially robust.

use super::Matrix;

/// Cholesky factorization of a symmetric positive-definite matrix.
/// Returns lower-triangular L with A = L Lᵀ, or None if not PD.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at(i, j) as f64;
            for k in 0..j {
                sum -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = sum.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (sum / l.at(j, j) as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Solve A X = B for symmetric positive-definite A via Cholesky, adding a
/// progressively larger ridge if A is only semi-definite (rank-deficient G
/// columns happen with SNMF on small matrices).
pub fn solve_spd(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows);
    let n = a.rows;
    let mut ridge = 0.0f32;
    let scale = (0..n).map(|i| a.at(i, i)).fold(0.0f32, f32::max).max(1e-12);
    for _ in 0..8 {
        let mut aa = a.clone();
        if ridge > 0.0 {
            for i in 0..n {
                *aa.at_mut(i, i) += ridge;
            }
        }
        if let Some(l) = cholesky(&aa) {
            return cholesky_solve(&l, b);
        }
        ridge = if ridge == 0.0 { scale * 1e-6 } else { ridge * 10.0 };
    }
    // Last resort: heavy ridge (still finite, keeps SNMF iterating).
    let mut aa = a.clone();
    for i in 0..n {
        *aa.at_mut(i, i) += scale;
    }
    let l = cholesky(&aa).expect("ridged matrix must be PD");
    cholesky_solve(&l, b)
}

/// Given L (lower, A = L Lᵀ) solve A X = B by forward+back substitution.
fn cholesky_solve(l: &Matrix, b: &Matrix) -> Matrix {
    let n = l.rows;
    let k = b.cols;
    // Forward: L Y = B.
    let mut y = Matrix::zeros(n, k);
    for i in 0..n {
        for c in 0..k {
            let mut sum = b.at(i, c) as f64;
            for j in 0..i {
                sum -= l.at(i, j) as f64 * y.at(j, c) as f64;
            }
            *y.at_mut(i, c) = (sum / l.at(i, i) as f64) as f32;
        }
    }
    // Back: Lᵀ X = Y.
    let mut x = Matrix::zeros(n, k);
    for i in (0..n).rev() {
        for c in 0..k {
            let mut sum = y.at(i, c) as f64;
            for j in i + 1..n {
                sum -= l.at(j, i) as f64 * x.at(j, c) as f64;
            }
            *x.at_mut(i, c) = (sum / l.at(i, i) as f64) as f32;
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn spd(n: usize, rng: &mut Pcg64) -> Matrix {
        let g = Matrix::randn(n + 4, n, 1.0, rng);
        g.matmul_tn(&g) // GᵀG is SPD w.p. 1
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Pcg64::seeded(40);
        let a = spd(8, &mut rng);
        let l = cholesky(&a).unwrap();
        let llt = l.matmul_nt(&l);
        for (x, y) in llt.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-2 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }

    #[test]
    fn solve_matches_direct() {
        let mut rng = Pcg64::seeded(41);
        let a = spd(10, &mut rng);
        let x_true = Matrix::randn(10, 3, 1.0, &mut rng);
        let b = a.matmul(&x_true);
        let x = solve_spd(&a, &b);
        for (u, v) in x.data.iter().zip(&x_true.data) {
            assert!((u - v).abs() < 1e-2 * (1.0 + v.abs()), "{u} vs {v}");
        }
    }

    #[test]
    fn non_pd_falls_back_to_ridge_without_panic() {
        let a = Matrix::zeros(4, 4); // semidefinite (rank 0)
        let b = Matrix::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let x = solve_spd(&a, &b);
        assert!(x.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eig -1, 3
        assert!(cholesky(&a).is_none());
    }
}
