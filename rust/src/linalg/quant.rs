//! Quantized weight containers over the [`super::gemm`] int8 kernels.
//!
//! Two storage formats for a `k×n` weight matrix (DESIGN.md §12):
//!
//! * [`QuantizedMatrix`] — per-output-channel symmetric int8: one f32 scale
//!   per output column `j` (`scale_j = maxabs(col_j) / 127`), entries
//!   `round(w / scale_j)` clamped to `[-127, 127]`. Applied through
//!   [`super::gemm::qmatmul_bias_into`]: activations are quantized per row
//!   on the fly (into thread-local scratch — zero steady-state allocation),
//!   products accumulate in i32 exactly, and one f32 multiply dequantizes
//!   each output element.
//! * [`BinaryMatrix`] — ±1 factors à la XNOR-Net / BMF (arxiv 2210.13468):
//!   sign bits packed 64-per-u64 column-major plus one f32 magnitude per
//!   column (`mean |col|`). The matvec is pure XOR + popcount:
//!   `dot = k − 2·popcount(xbits ⊕ wbits)`, scaled by the row and column
//!   magnitudes. On genuinely ±1 inputs every scale is exactly 1.0 and the
//!   integer dot is exact in f32, so the popcount path equals the f32
//!   matvec **bit for bit** (pinned by `tests/proptest_quant.rs`).
//!
//! Both `apply` entry points keep the f32 kernels' `out +=` accumulate
//! semantics and fused bias/activation epilogue.

use std::cell::RefCell;

use super::gemm::{self, Activation};

thread_local! {
    /// Per-thread activation-quantization scratch `(xq, xscale)`, reused
    /// across calls so steady-state decode does zero heap allocation.
    static QX_BUFS: RefCell<(Vec<i8>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
    /// Per-thread sign-bit scratch for binary activation rows.
    static BIN_BUFS: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

/// Symmetric int8 scale for a value range of `maxabs`: `maxabs / 127`, with
/// an all-zero range mapping to 1.0 (any scale represents zeros exactly).
#[inline]
pub fn quant_scale(maxabs: f32) -> f32 {
    let s = maxabs / 127.0;
    if s == 0.0 {
        1.0
    } else {
        s
    }
}

#[inline]
fn quantize_val(v: f32, scale: f32) -> i8 {
    // f32::round = half away from zero; clamp guards inf/NaN-free inputs
    // whose ratio still lands a hair outside ±127 (maxabs itself rounds to
    // exactly ±127 since scale divides it back).
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// Quantize `rows × k` f32 activations per row (symmetric int8) into `xq` /
/// `xscale`, reusing their capacity.
pub fn quantize_rows_into(
    rows: usize,
    k: usize,
    x: &[f32],
    xq: &mut Vec<i8>,
    xscale: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), rows * k);
    xq.clear();
    xq.resize(rows * k, 0);
    xscale.clear();
    xscale.resize(rows, 0.0);
    for i in 0..rows {
        let xrow = &x[i * k..(i + 1) * k];
        let maxabs = xrow.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = quant_scale(maxabs);
        xscale[i] = s;
        for (q, &v) in xq[i * k..(i + 1) * k].iter_mut().zip(xrow) {
            *q = quantize_val(v, s);
        }
    }
}

/// A `k×n` weight matrix stored as per-output-channel symmetric int8.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    k: usize,
    n: usize,
    q: Vec<i8>,
    scales: Vec<f32>,
}

impl QuantizedMatrix {
    /// Quantize a row-major `k×n` f32 matrix, one symmetric scale per
    /// output column.
    pub fn from_f32(k: usize, n: usize, w: &[f32]) -> Self {
        assert_eq!(w.len(), k * n, "QuantizedMatrix: shape/data mismatch");
        let mut scales = vec![0.0f32; n];
        for j in 0..n {
            let mut maxabs = 0.0f32;
            for p in 0..k {
                maxabs = maxabs.max(w[p * n + j].abs());
            }
            scales[j] = quant_scale(maxabs);
        }
        let mut q = vec![0i8; k * n];
        for p in 0..k {
            for j in 0..n {
                q[p * n + j] = quantize_val(w[p * n + j], scales[j]);
            }
        }
        QuantizedMatrix { k, n, q, scales }
    }

    /// Input dimension (rows of the weight).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output dimension (columns / channels).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Per-output-channel scales (length `n`).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// The int8 entries, row-major `k×n`.
    pub fn values(&self) -> &[i8] {
        &self.q
    }

    /// Storage footprint in bytes (entries + scales).
    pub fn bytes(&self) -> usize {
        self.q.len() + self.scales.len() * 4
    }

    /// Dequantized f32 copy (`q[p,j] * scale_j`) — for tests and error
    /// reporting, not the hot path.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut w = vec![0.0f32; self.k * self.n];
        for p in 0..self.k {
            for j in 0..self.n {
                w[p * self.n + j] = self.q[p * self.n + j] as f32 * self.scales[j];
            }
        }
        w
    }

    /// `out(rows,n) = act(out + dequant(quant(x) @ self) + bias)`: quantize
    /// the f32 activations per row into thread-local scratch, then run the
    /// int8 kernel with fused dequant + epilogue.
    pub fn apply(
        &self,
        rows: usize,
        x: &[f32],
        bias: Option<&[f32]>,
        act: Activation,
        out: &mut [f32],
    ) {
        debug_assert_eq!(x.len(), rows * self.k);
        debug_assert_eq!(out.len(), rows * self.n);
        QX_BUFS.with(|cell| {
            let mut bufs = cell.borrow_mut();
            let (xq, xscale) = &mut *bufs;
            quantize_rows_into(rows, self.k, x, xq, xscale);
            gemm::qmatmul_bias_into(
                rows,
                self.k,
                self.n,
                xq,
                xscale,
                &self.q,
                &self.scales,
                bias,
                act,
                out,
            );
        });
    }
}

/// A `k×n` weight matrix reduced to ±1 sign bits plus one f32 magnitude per
/// output column (`mean |col|`).
///
/// Bit `p` of column `j` is set iff `w[p,j] < 0`; zero (and positive)
/// entries encode +1. Sign words are column-major so the matvec walks each
/// column's `k/64` words contiguously.
#[derive(Clone, Debug)]
pub struct BinaryMatrix {
    k: usize,
    n: usize,
    words_per_col: usize,
    bits: Vec<u64>,
    scales: Vec<f32>,
}

impl BinaryMatrix {
    /// Binarize a row-major `k×n` f32 matrix.
    pub fn from_f32(k: usize, n: usize, w: &[f32]) -> Self {
        assert_eq!(w.len(), k * n, "BinaryMatrix: shape/data mismatch");
        let words_per_col = k.div_ceil(64);
        let mut bits = vec![0u64; n * words_per_col];
        let mut scales = vec![0.0f32; n];
        for j in 0..n {
            let mut sumabs = 0.0f32;
            let col = &mut bits[j * words_per_col..(j + 1) * words_per_col];
            for p in 0..k {
                let v = w[p * n + j];
                sumabs += v.abs();
                if v < 0.0 {
                    col[p / 64] |= 1u64 << (p % 64);
                }
            }
            scales[j] = if k == 0 { 1.0 } else { sumabs / k as f32 };
        }
        BinaryMatrix {
            k,
            n,
            words_per_col,
            bits,
            scales,
        }
    }

    /// Input dimension.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output dimension.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Per-column magnitudes (length `n`).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Storage footprint in bytes (sign words + scales).
    pub fn bytes(&self) -> usize {
        self.bits.len() * 8 + self.scales.len() * 4
    }

    /// `out(n) = act(out + xscale·(k − 2·popcount(xbits ⊕ colbits))·scale_j
    /// + bias)` — the XOR/popcount matvec against one pre-binarized
    /// activation row. Tail bits beyond `k` are zero in both operands, so
    /// they never perturb the count.
    pub fn matvec(
        &self,
        xbits: &[u64],
        xscale: f32,
        bias: Option<&[f32]>,
        act: Activation,
        out: &mut [f32],
    ) {
        debug_assert_eq!(xbits.len(), self.words_per_col);
        debug_assert_eq!(out.len(), self.n);
        for j in 0..self.n {
            let col = &self.bits[j * self.words_per_col..(j + 1) * self.words_per_col];
            let mut ham = 0u32;
            for (&xw, &cw) in xbits.iter().zip(col) {
                ham += (xw ^ cw).count_ones();
            }
            let dot = self.k as i32 - 2 * ham as i32;
            out[j] += dot as f32 * (xscale * self.scales[j]);
        }
        gemm::apply_epilogue(out, bias, act);
    }

    /// `out(rows,n) = act(out + binarize(x) @ self + bias)`: binarize each
    /// f32 activation row (magnitude `mean |row|`, sign bits) into
    /// thread-local scratch and run the popcount matvec per row.
    pub fn apply(
        &self,
        rows: usize,
        x: &[f32],
        bias: Option<&[f32]>,
        act: Activation,
        out: &mut [f32],
    ) {
        debug_assert_eq!(x.len(), rows * self.k);
        debug_assert_eq!(out.len(), rows * self.n);
        BIN_BUFS.with(|cell| {
            let xbits = &mut *cell.borrow_mut();
            for i in 0..rows {
                let xrow = &x[i * self.k..(i + 1) * self.k];
                let xscale = binarize_row_into(xrow, xbits);
                self.matvec(
                    xbits,
                    xscale,
                    bias,
                    act,
                    &mut out[i * self.n..(i + 1) * self.n],
                );
            }
        });
    }
}

/// Binarize one activation row: sign bits into `xbits` (bit set iff
/// negative; reused capacity, tail zeroed) and the returned magnitude
/// `mean |x|` (1.0 for an empty or all-zero row, matching
/// [`quant_scale`]'s zero-range convention).
pub fn binarize_row_into(x: &[f32], xbits: &mut Vec<u64>) -> f32 {
    let k = x.len();
    xbits.clear();
    xbits.resize(k.div_ceil(64), 0);
    let mut sumabs = 0.0f32;
    for (p, &v) in x.iter().enumerate() {
        sumabs += v.abs();
        if v < 0.0 {
            xbits[p / 64] |= 1u64 << (p % 64);
        }
    }
    if k == 0 || sumabs == 0.0 {
        1.0
    } else {
        sumabs / k as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matmul_bias_into;
    use crate::util::Pcg64;

    #[test]
    fn roundtrip_error_within_half_scale() {
        let mut rng = Pcg64::seeded(31);
        let (k, n) = (17, 9);
        let mut w = vec![0.0f32; k * n];
        rng.fill_normal(&mut w, 0.5);
        let qm = QuantizedMatrix::from_f32(k, n, &w);
        let deq = qm.dequantize();
        for j in 0..n {
            let half = qm.scales()[j] * 0.5 * (1.0 + 1e-5);
            for p in 0..k {
                let err = (w[p * n + j] - deq[p * n + j]).abs();
                assert!(err <= half, "({p},{j}): err {err} > scale/2 {half}");
            }
        }
    }

    #[test]
    fn binary_matvec_exact_on_pm1() {
        let mut rng = Pcg64::seeded(32);
        let (k, n) = (130, 7); // crosses a u64 word boundary
        let w: Vec<f32> =
            (0..k * n).map(|_| if rng.below(2) == 0 { 1.0 } else { -1.0 }).collect();
        let x: Vec<f32> = (0..k).map(|_| if rng.below(2) == 0 { 1.0 } else { -1.0 }).collect();
        let bm = BinaryMatrix::from_f32(k, n, &w);
        let mut got = vec![0.0f32; n];
        bm.apply(1, &x, None, Activation::None, &mut got);
        let mut want = vec![0.0f32; n];
        matmul_bias_into(1, k, n, &x, &w, None, Activation::None, &mut want);
        for (g, wv) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), wv.to_bits(), "{g} vs {wv}");
        }
    }

    #[test]
    fn zero_column_uses_unit_scale() {
        let w = vec![0.0f32; 6];
        let qm = QuantizedMatrix::from_f32(3, 2, &w);
        assert_eq!(qm.scales(), &[1.0, 1.0]);
        assert!(qm.dequantize().iter().all(|&v| v == 0.0));
    }
}
