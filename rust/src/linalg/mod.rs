//! Dense linear-algebra substrate, from scratch (no BLAS/LAPACK dependency).
//!
//! The post-training factorization path runs entirely in Rust, so the three
//! Greenformer solvers need a numerical core:
//!
//! * [`gemm`] — the kernel layer: packed, cache-tiled GEMM with an 8×8
//!   register microkernel, column-split parallel GEMV for the batch-1
//!   decode step, and fused bias/GELU/ReLU epilogues (DESIGN.md §11).
//! * [`quant`] — int8 per-output-channel and bit-packed ±1 weight
//!   containers over the quantized [`gemm`] kernels (DESIGN.md §12).
//! * [`pool`] — lazily-initialized persistent worker pool the parallel
//!   kernels dispatch on (replaces per-call thread spawn/join).
//! * [`workspace`] — checkout/checkin scratch arena the interpreters
//!   thread through their hot paths for zero steady-state allocation.
//! * [`matrix`] — row-major `Matrix` over the [`gemm`] kernels,
//!   transposes, norms.
//! * [`qr`] — Householder thin QR (orthonormal bases for the randomized
//!   range finder).
//! * [`svd`] — one-sided Jacobi SVD (exact; used directly on small
//!   matrices and as the inner solver of the randomized path).
//! * [`rsvd`] — Halko–Martinsson–Tropp randomized truncated SVD for the
//!   large (e.g. 768×3072) layers where full Jacobi would be wasteful.
//! * [`snmf`] — Semi-NMF multiplicative updates (Ding, Li & Jordan 2010).
//! * [`solve`] — small symmetric-positive solves (Cholesky) for SNMF's
//!   closed-form A step.
//!
//! Contracts (reconstruction-error bounds, orthogonality, non-negativity)
//! mirror `python/tests/test_solvers.py`; property tests live with each
//! module and in `rust/tests/proptest_linalg.rs`.

pub mod gemm;
pub mod matrix;
pub mod pool;
pub mod qr;
pub mod quant;
pub mod rsvd;
pub mod snmf;
pub mod solve;
pub mod svd;
pub mod workspace;

pub use gemm::{
    matmul_bias_into, matmul_into, matmul_into_reference, qmatmul_bias_into,
    qmatmul_into_reference, Activation,
};
pub use matrix::Matrix;
pub use quant::{quantize_rows_into, BinaryMatrix, QuantizedMatrix};
pub use qr::thin_qr;
pub use workspace::Workspace;
pub use rsvd::randomized_svd;
pub use snmf::snmf_factorize;
pub use svd::{factors_from_svd, jacobi_svd, svd_factorize, Svd};
