//! Lazily-initialized persistent worker pool for the parallel kernels.
//!
//! The pre-PR-5 GEMM spawned and joined OS threads on *every* call
//! (`std::thread::scope`), which put a ~100 µs floor under every parallel
//! matmul and made fine-grained parallelism (the batch-1 decode GEMV) a
//! guaranteed loss. This pool spawns `available_parallelism() - 1` workers
//! once, on first use, and then dispatches jobs with a condvar wake — cheap
//! enough that kernels in the 100 µs range profit from splitting.
//!
//! Design (see DESIGN.md §11):
//!
//! * **One job at a time.** A job is a lifetime-erased `&dyn Fn(usize)`
//!   task closure plus a task count. Workers and the submitting thread
//!   drain a shared atomic task counter, so load-balancing is automatic.
//! * **Submitter participates.** The caller runs tasks too; with no
//!   workers (single-core, spawn failure) everything still completes.
//! * **Busy or nested ⇒ serial.** If the pool is occupied (another thread
//!   is mid-job) or the caller *is* a pool worker, [`run`] simply executes
//!   the tasks inline. That makes the pool deadlock-free under nesting and
//!   correct under concurrent submitters without a job queue.
//! * **Completion is a hard barrier.** [`run`] returns only after every
//!   task has finished *and* every worker has left the job, which is what
//!   makes the lifetime erasure of the task closure sound.
//!
//! Numerics are unaffected by the pool: tasks own disjoint output regions
//! and every kernel's per-element accumulation order is independent of the
//! task split (the invariant `tests/proptest_linalg.rs` pins bitwise).

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// Cap on spawned workers (callers participate, so the effective parallel
/// width is `workers + 1`). Far above the shard counts our kernels use.
const MAX_WORKERS: usize = 31;

thread_local! {
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

type TaskFn = dyn Fn(usize) + Sync;

/// Lifetime-erased pointer to the job's task closure. Only dereferenced
/// between job publication and the completion barrier, while the submitter
/// keeps the closure alive.
#[derive(Clone, Copy)]
struct JobPtr(*const TaskFn);

// SAFETY: the pointer is only dereferenced under the job protocol (see
// `run_tasks`); the type is shared across threads as an opaque value.
unsafe impl Send for JobPtr {}

#[derive(Clone, Copy)]
struct Job {
    f: JobPtr,
    n_tasks: usize,
    epoch: u64,
}

struct State {
    job: Option<Job>,
    epoch: u64,
    /// Workers currently inside the published job.
    active: usize,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Next task index to claim (reset per job, under the state lock).
    next_task: AtomicUsize,
    /// Tasks not yet completed (reset per job, under the state lock).
    remaining: AtomicUsize,
    /// Set when a task panicked; the submitter re-raises after the barrier.
    poisoned: AtomicBool,
}

struct Pool {
    shared: &'static Shared,
    workers: usize,
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let shared: &'static Shared = Box::leak(Box::new(Shared {
            state: Mutex::new(State { job: None, epoch: 0, active: 0 }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next_task: AtomicUsize::new(0),
            remaining: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
        }));
        let want = parallelism();
        let mut workers = 0;
        for _ in 1..want {
            let ok = std::thread::Builder::new()
                .name("gf-kernel-worker".into())
                .spawn(move || worker_loop(shared))
                .is_ok();
            if ok {
                workers += 1;
            }
        }
        Pool { shared, workers }
    })
}

/// Parallel width the kernels plan for: `available_parallelism()` capped at
/// the pool's worker limit. Cached after the first call (the OS query can
/// itself allocate, and the kernel dispatch consults this on every GEMM);
/// does not spawn the pool — dispatch thresholds check this before deciding
/// to go parallel at all.
pub fn parallelism() -> usize {
    static WIDTH: OnceLock<usize> = OnceLock::new();
    *WIDTH.get_or_init(|| {
        std::thread::available_parallelism().map_or(1, |p| p.get()).min(MAX_WORKERS + 1)
    })
}

/// Run `f(0..n_tasks)` across the worker pool, returning once every task
/// has completed. Tasks may run on pool workers and/or the calling thread,
/// each index exactly once, in no particular order — callers must make
/// tasks independent (disjoint output regions).
///
/// Falls back to inline serial execution when the pool is busy, when called
/// from inside a pool task (nesting), or when no workers could be spawned.
///
/// # Panics
///
/// If a task panics, the panic is captured, the job still runs to
/// completion (remaining tasks execute), and `run` panics on the calling
/// thread afterwards — workers survive.
pub fn run(n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
    if n_tasks == 0 {
        return;
    }
    if n_tasks == 1 || IS_POOL_WORKER.with(|w| w.get()) {
        for i in 0..n_tasks {
            f(i);
        }
        return;
    }
    let p = pool();
    if p.workers == 0 || !p.try_run(n_tasks, f) {
        for i in 0..n_tasks {
            f(i);
        }
    }
}

impl Pool {
    /// Publish a job and help drain it. Returns false (without running
    /// anything) if the pool is unavailable; the caller then runs serially.
    fn try_run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) -> bool {
        let job = {
            let mut st = match self.shared.state.try_lock() {
                Ok(st) => st,
                Err(_) => return false,
            };
            if st.job.is_some() || st.active > 0 {
                return false;
            }
            // No worker is inside `run_tasks` (active == 0), so the
            // counters can be reset without racing a stale job.
            self.shared.next_task.store(0, Ordering::SeqCst);
            self.shared.remaining.store(n_tasks, Ordering::SeqCst);
            self.shared.poisoned.store(false, Ordering::SeqCst);
            st.epoch += 1;
            // SAFETY: lifetime erasure. `try_run` does not return until
            // every task has completed and every worker has left the job
            // (the barrier below), so the closure strictly outlives every
            // dereference of this pointer.
            let f_static: &'static TaskFn =
                unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static TaskFn>(f) };
            let job = Job { f: JobPtr(f_static as *const TaskFn), n_tasks, epoch: st.epoch };
            st.job = Some(job);
            self.shared.work_cv.notify_all();
            job
        };

        // The submitter drains tasks alongside the workers.
        run_tasks(self.shared, job);

        // Barrier: all tasks done AND all workers out of the job. The
        // second condition is what lets the closure be dropped safely and
        // the counters be reset by the next submission.
        {
            let mut st = self.shared.state.lock().unwrap();
            while self.shared.remaining.load(Ordering::SeqCst) != 0 || st.active > 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
        }
        if self.shared.poisoned.load(Ordering::SeqCst) {
            panic!("kernel pool task panicked");
        }
        true
    }
}

/// Claim and execute tasks from the shared counter until exhausted.
fn run_tasks(shared: &Shared, job: Job) {
    // SAFETY: see `try_run` — the closure is alive for the whole job.
    let f: &TaskFn = unsafe { &*job.f.0 };
    loop {
        let i = shared.next_task.fetch_add(1, Ordering::SeqCst);
        if i >= job.n_tasks {
            break;
        }
        if catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
            shared.poisoned.store(true, Ordering::SeqCst);
        }
        if shared.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            // Last task overall: wake the submitter (lock pairs the wake
            // with its condition check so the notification cannot be lost).
            let _st = shared.state.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: &'static Shared) {
    IS_POOL_WORKER.with(|w| w.set(true));
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                match st.job {
                    Some(job) if job.epoch != seen_epoch => {
                        seen_epoch = job.epoch;
                        st.active += 1;
                        break job;
                    }
                    _ => st = shared.work_cv.wait(st).unwrap(),
                }
            }
        };
        run_tasks(shared, job);
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_exactly_once() {
        for n in [1usize, 2, 3, 7, 16, 61] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            run(n, &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "task {i} of {n}");
            }
        }
    }

    #[test]
    fn nested_run_completes_serially() {
        let total = AtomicU64::new(0);
        run(4, &|_| {
            // Nested call must not deadlock; it runs inline.
            run(8, &|j| {
                total.fetch_add(j as u64 + 1, Ordering::SeqCst);
            });
        });
        assert_eq!(total.load(Ordering::SeqCst), 4 * (1 + 8) * 8 / 2);
    }

    #[test]
    fn concurrent_submitters_all_complete() {
        let sums: Vec<u64> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    scope.spawn(|| {
                        let acc = AtomicU64::new(0);
                        run(32, &|i| {
                            acc.fetch_add(i as u64, Ordering::SeqCst);
                        });
                        acc.load(Ordering::SeqCst)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for s in sums {
            assert_eq!(s, (0..32).sum::<u64>());
        }
    }

    #[test]
    fn pool_survives_a_panicking_task() {
        let result = std::panic::catch_unwind(|| {
            run(4, &|i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        });
        assert!(result.is_err(), "panic must propagate to the submitter");
        // The pool must still work afterwards.
        let acc = AtomicU64::new(0);
        run(8, &|i| {
            acc.fetch_add(i as u64, Ordering::SeqCst);
        });
        assert_eq!(acc.load(Ordering::SeqCst), (0..8).sum::<u64>());
    }

    #[test]
    fn parallelism_is_positive() {
        assert!(parallelism() >= 1);
    }
}
