//! Semi-NMF (Ding, Li & Jordan 2010) — Greenformer's SNMF solver.
//!
//! W ≈ A B with B ≥ 0 elementwise and A unconstrained ("B is strictly
//! nonnegative yet A has no restriction on signs" — paper §Design).
//! Multiplicative updates on G = Bᵀ with the closed-form A-step
//! A = W G (GᵀG)⁻¹ each iteration. Mirrors `python/compile/solvers.py`.

use super::{solve::solve_spd, Matrix};
use crate::util::Pcg64;

/// Factorize `w` (m×n) into (A: m×r, B: r×n) with B ≥ 0.
/// `num_iter` is the paper's `num_iter` auto_fact argument.
pub fn snmf_factorize(w: &Matrix, r: usize, num_iter: usize, seed: u64) -> (Matrix, Matrix) {
    let (m, n) = (w.rows, w.cols);
    let r = r.min(m.min(n)).max(1);
    let mut rng = Pcg64::new(seed, 7);
    // G = Bᵀ: (n, r), strictly positive init.
    let mut g = Matrix::from_fn(n, r, |_, _| rng.normal_f32().abs() + 0.1);
    let eps = 1e-9f32;

    for _ in 0..num_iter {
        // A = W G (GᵀG)⁻¹  — solve (GᵀG) Xᵀ = (W G)ᵀ.
        let wg = w.matmul(&g); // (m, r)
        let gtg = g.matmul_tn(&g); // (r, r)
        let a = solve_spd(&gtg, &wg.transpose()).transpose(); // (m, r)

        // Multiplicative update:
        // G <- G ∘ sqrt( ((WᵀA)⁺ + G (AᵀA)⁻) / ((WᵀA)⁻ + G (AᵀA)⁺) ).
        let wta = w.matmul_tn(&a); // (n, r)
        let ata = a.matmul_tn(&a); // (r, r)
        let mut ata_pos = ata.clone();
        let mut ata_neg = ata;
        for (p, q) in ata_pos.data.iter_mut().zip(ata_neg.data.iter_mut()) {
            let v = *p;
            *p = v.max(0.0);
            *q = (-v).max(0.0);
        }
        let g_ata_neg = g.matmul(&ata_neg);
        let g_ata_pos = g.matmul(&ata_pos);
        for i in 0..n {
            for j in 0..r {
                let x = wta.at(i, j);
                let num = x.max(0.0) + g_ata_neg.at(i, j);
                let den = (-x).max(0.0) + g_ata_pos.at(i, j) + eps;
                let factor = (num / den).max(0.0).sqrt();
                *g.at_mut(i, j) *= factor;
            }
        }
    }
    // Final A for the final G.
    let wg = w.matmul(&g);
    let gtg = g.matmul_tn(&g);
    let a = solve_spd(&gtg, &wg.transpose()).transpose();
    (a, g.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn b_is_nonnegative() {
        let mut rng = Pcg64::seeded(50);
        let w = Matrix::randn(20, 14, 1.0, &mut rng);
        let (_, b) = snmf_factorize(&w, 5, 30, 0);
        assert!(b.data.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn error_decreases_with_iterations() {
        let mut rng = Pcg64::seeded(51);
        let w = Matrix::randn(24, 18, 1.0, &mut rng);
        let err = |iters| {
            let (a, b) = snmf_factorize(&w, 6, iters, 0);
            w.sub(&a.matmul(&b)).fro_norm()
        };
        let (e3, e60) = (err(3), err(60));
        assert!(e60 <= e3 * 1.01, "e3={e3} e60={e60}");
        assert!(e60 < w.fro_norm(), "must actually approximate");
    }

    #[test]
    fn bounded_below_by_svd_error() {
        let mut rng = Pcg64::seeded(52);
        let w = Matrix::randn(22, 16, 1.0, &mut rng);
        let r = 6;
        let (sa, sb) = crate::linalg::svd_factorize(&w, r);
        let esvd = w.sub(&sa.matmul(&sb)).fro_norm();
        let (na, nb) = snmf_factorize(&w, r, 80, 0);
        let esn = w.sub(&na.matmul(&nb)).fro_norm();
        assert!(esn >= esvd * 0.999, "SNMF cannot beat optimal: {esn} < {esvd}");
    }

    #[test]
    fn handles_nonnegative_input_well() {
        // On an already-nonnegative low-rank matrix SNMF should get close.
        let mut rng = Pcg64::seeded(53);
        let u = Matrix::from_fn(16, 3, |_, _| rng.next_f32() + 0.05);
        let v = Matrix::from_fn(3, 12, |_, _| rng.next_f32() + 0.05);
        let w = u.matmul(&v);
        let (a, b) = snmf_factorize(&w, 3, 200, 1);
        let rel = w.sub(&a.matmul(&b)).fro_norm() / w.fro_norm();
        assert!(rel < 0.05, "rel={rel}");
    }

    #[test]
    fn deterministic_for_seed() {
        let mut rng = Pcg64::seeded(54);
        let w = Matrix::randn(10, 8, 1.0, &mut rng);
        let (a1, b1) = snmf_factorize(&w, 3, 10, 9);
        let (a2, b2) = snmf_factorize(&w, 3, 10, 9);
        assert_eq!(a1.data, a2.data);
        assert_eq!(b1.data, b2.data);
    }
}
