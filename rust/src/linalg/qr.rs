//! Householder thin QR: A (m×n, m ≥ n) = Q (m×n, orthonormal cols) R (n×n).
//!
//! Used by the randomized SVD's range finder, where only Q matters; R is
//! returned for completeness and testing.

use super::Matrix;

/// Thin QR via Householder reflections. Requires `a.rows >= a.cols`.
pub fn thin_qr(a: &Matrix) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "thin_qr needs rows >= cols, got {m}x{n}");
    let mut r = a.clone();
    // Store the Householder vectors in-place below the diagonal; betas aside.
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n);
    for j in 0..n {
        // Build the reflector for column j from rows j..m.
        let mut norm2 = 0.0f64;
        for i in j..m {
            let x = r.at(i, j) as f64;
            norm2 += x * x;
        }
        let norm = norm2.sqrt() as f32;
        let mut v = vec![0.0f32; m - j];
        if norm == 0.0 {
            vs.push(v);
            continue;
        }
        let x0 = r.at(j, j);
        let alpha = if x0 >= 0.0 { -norm } else { norm };
        v[0] = x0 - alpha;
        for i in j + 1..m {
            v[i - j] = r.at(i, j);
        }
        let vnorm2: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if vnorm2 > 0.0 {
            // Apply (I - 2 v v^T / ||v||^2) to R[j.., j..].
            for col in j..n {
                let mut dot = 0.0f64;
                for i in j..m {
                    dot += v[i - j] as f64 * r.at(i, col) as f64;
                }
                let s = (2.0 * dot / vnorm2) as f32;
                for i in j..m {
                    *r.at_mut(i, col) -= s * v[i - j];
                }
            }
        }
        vs.push(v);
    }
    // Zero strictly-lower part of R (rounding residue) and take top n rows.
    let mut r_out = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            *r_out.at_mut(i, j) = r.at(i, j);
        }
    }
    // Form Q by applying reflectors to the first n columns of I, in reverse.
    let mut q = Matrix::zeros(m, n);
    for j in 0..n {
        *q.at_mut(j, j) = 1.0;
    }
    for j in (0..n).rev() {
        let v = &vs[j];
        let vnorm2: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if vnorm2 == 0.0 {
            continue;
        }
        for col in 0..n {
            let mut dot = 0.0f64;
            for i in j..m {
                dot += v[i - j] as f64 * q.at(i, col) as f64;
            }
            let s = (2.0 * dot / vnorm2) as f32;
            for i in j..m {
                *q.at_mut(i, col) -= s * v[i - j];
            }
        }
    }
    (q, r_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn check_orthonormal(q: &Matrix, tol: f32) {
        let qtq = q.matmul_tn(q);
        for i in 0..qtq.rows {
            for j in 0..qtq.cols {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (qtq.at(i, j) - want).abs() < tol,
                    "QtQ[{i}][{j}] = {}",
                    qtq.at(i, j)
                );
            }
        }
    }

    #[test]
    fn qr_reconstructs_and_q_orthonormal() {
        let mut rng = Pcg64::seeded(10);
        for (m, n) in [(5, 5), (20, 7), (64, 32), (100, 1)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let (q, r) = thin_qr(&a);
            check_orthonormal(&q, 1e-3);
            let qr = q.matmul(&r);
            for (x, y) in qr.data.iter().zip(&a.data) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y} ({m}x{n})");
            }
        }
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Pcg64::seeded(11);
        let a = Matrix::randn(12, 6, 1.0, &mut rng);
        let (_, r) = thin_qr(&a);
        for i in 0..r.rows {
            for j in 0..i {
                assert_eq!(r.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn qr_of_rank_deficient_matrix_does_not_nan() {
        let mut a = Matrix::zeros(8, 4);
        for i in 0..8 {
            *a.at_mut(i, 0) = 1.0;
            *a.at_mut(i, 2) = 2.0; // col2 = 2*col0, col1 = col3 = 0
        }
        let (q, r) = thin_qr(&a);
        assert!(q.data.iter().all(|x| x.is_finite()));
        assert!(r.data.iter().all(|x| x.is_finite()));
    }
}
