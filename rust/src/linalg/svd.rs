//! One-sided Jacobi SVD — the exact solver behind Greenformer's SVD option.
//!
//! One-sided Jacobi orthogonalizes pairs of *columns* of a working copy of A
//! with Givens rotations, accumulating them into V; at convergence the
//! column norms are the singular values and the normalized columns are U.
//! It is simple, numerically robust, and exact enough to pin the
//! Eckart–Young bound in tests. Cost is O(m n² · sweeps) — fine for the
//! layer sizes the models emit directly; the randomized path ([`super::rsvd`])
//! handles large layers by reducing to a small Jacobi problem.

use super::Matrix;

/// A thin SVD `A = U · diag(s) · Vᵀ`.
pub struct Svd {
    /// (m, k) with orthonormal columns, k = min(m, n).
    pub u: Matrix,
    /// Singular values, descending.
    pub s: Vec<f32>,
    /// (k, n): right singular vectors as rows (V^T).
    pub vt: Matrix,
}

/// Full (thin) SVD via one-sided Jacobi. Handles any m, n.
pub fn jacobi_svd(a: &Matrix) -> Svd {
    if a.rows < a.cols {
        // Work on the transpose and swap factors: A^T = U' S V'^T
        // => A = V' S U'^T.
        let t = jacobi_svd(&a.transpose());
        return Svd {
            u: t.vt.transpose(),
            s: t.s,
            vt: t.u.transpose(),
        };
    }
    let (m, n) = (a.rows, a.cols);
    // Column-major working copy: columns contiguous for the rotation loop.
    let mut w: Vec<f64> = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            w[j * m + i] = a.at(i, j) as f64;
        }
    }
    let mut v = vec![0.0f64; n * n];
    for j in 0..n {
        v[j * n + j] = 1.0;
    }

    let eps = 1e-10;
    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                let (colp, colq) = (&w[p * m..(p + 1) * m], &w[q * m..(q + 1) * m]);
                for i in 0..m {
                    app += colp[i] * colp[i];
                    aqq += colq[i] * colq[i];
                    apq += colp[i] * colq[i];
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off += apq * apq;
                // Jacobi rotation zeroing the (p,q) entry of W^T W.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // Rotate columns p, q of W and of V.
                let (head, tail) = w.split_at_mut(q * m);
                let colp = &mut head[p * m..(p + 1) * m];
                let colq = &mut tail[..m];
                for i in 0..m {
                    let (xp, xq) = (colp[i], colq[i]);
                    colp[i] = c * xp - s * xq;
                    colq[i] = s * xp + c * xq;
                }
                let (vh, vt_) = v.split_at_mut(q * n);
                let vp = &mut vh[p * n..(p + 1) * n];
                let vq = &mut vt_[..n];
                for i in 0..n {
                    let (xp, xq) = (vp[i], vq[i]);
                    vp[i] = c * xp - s * xq;
                    vq[i] = s * xp + c * xq;
                }
            }
        }
        if off.sqrt() < 1e-14 {
            break;
        }
    }

    // Extract singular values (column norms) and sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| w[j * m + i] * w[j * m + i]).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&a, &b| norms[b].total_cmp(&norms[a]));

    let mut u = Matrix::zeros(m, n);
    let mut s = Vec::with_capacity(n);
    let mut vt = Matrix::zeros(n, n);
    for (rank, &j) in order.iter().enumerate() {
        let sigma = norms[j];
        s.push(sigma as f32);
        if sigma > 1e-30 {
            for i in 0..m {
                *u.at_mut(i, rank) = (w[j * m + i] / sigma) as f32;
            }
        }
        for i in 0..n {
            *vt.at_mut(rank, i) = v[j * n + i] as f32;
        }
    }
    Svd { u, s, vt }
}

/// Greenformer SVD solver: W ≈ A B with A = U_r √Σ_r, B = √Σ_r V_r^T.
///
/// The √Σ split balances factor norms — identical to the Python side
/// (`solvers.svd_factorize`), so by-design and post-training factors are
/// interchangeable between the two languages.
pub fn svd_factorize(w: &Matrix, r: usize) -> (Matrix, Matrix) {
    let r = r.min(w.rows.min(w.cols));
    // Large layers: randomized range finder reduces to a small Jacobi
    // problem with controlled error; small layers: direct Jacobi.
    let svd = if should_randomize(w.rows, w.cols, r) {
        super::rsvd::randomized_svd(w, r, 10, 2)
    } else {
        jacobi_svd(w)
    };
    factors_from_svd(&svd, r)
}

/// Split a (possibly truncated) SVD into balanced (A, B) factors.
pub fn factors_from_svd(svd: &Svd, r: usize) -> (Matrix, Matrix) {
    let r = r.min(svd.s.len());
    let m = svd.u.rows;
    let n = svd.vt.cols;
    let mut a = Matrix::zeros(m, r);
    let mut b = Matrix::zeros(r, n);
    for j in 0..r {
        let sq = svd.s[j].max(0.0).sqrt();
        for i in 0..m {
            *a.at_mut(i, j) = svd.u.at(i, j) * sq;
        }
        for i in 0..n {
            *b.at_mut(j, i) = sq * svd.vt.at(j, i);
        }
    }
    (a, b)
}

/// Heuristic: randomized SVD wins when the target rank is far below the full
/// spectrum on a big matrix. Exact Jacobi is O(mn²·sweeps); rSVD is
/// O(mn(r+p)) plus a small Jacobi.
fn should_randomize(m: usize, n: usize, r: usize) -> bool {
    let small = m.min(n);
    small > 160 && r + 10 < small / 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn reconstruct(svd: &Svd) -> Matrix {
        let k = svd.s.len();
        let mut us = svd.u.clone();
        for j in 0..k {
            for i in 0..us.rows {
                *us.at_mut(i, j) *= svd.s[j];
            }
        }
        us.matmul(&svd.vt)
    }

    #[test]
    fn svd_reconstructs_exactly() {
        let mut rng = Pcg64::seeded(20);
        for (m, n) in [(6, 6), (12, 5), (5, 12), (40, 17)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let svd = jacobi_svd(&a);
            let err = a.sub(&reconstruct(&svd)).fro_norm() / a.fro_norm();
            assert!(err < 1e-5, "recon err {err} for {m}x{n}");
        }
    }

    #[test]
    fn singular_values_descending_nonnegative() {
        let mut rng = Pcg64::seeded(21);
        let a = Matrix::randn(20, 13, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(svd.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn u_and_v_orthonormal() {
        let mut rng = Pcg64::seeded(22);
        let a = Matrix::randn(15, 9, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        let utu = svd.u.matmul_tn(&svd.u);
        let vvt = svd.vt.matmul_nt(&svd.vt);
        for i in 0..9 {
            for j in 0..9 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((utu.at(i, j) - want).abs() < 1e-4);
                assert!((vvt.at(i, j) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn known_diagonal_spectrum() {
        let a = Matrix::from_vec(3, 3, vec![3.0, 0., 0., 0., -5.0, 0., 0., 0., 1.0]);
        let svd = jacobi_svd(&a);
        let want = [5.0, 3.0, 1.0];
        for (s, w) in svd.s.iter().zip(want) {
            assert!((s - w).abs() < 1e-5, "{s} vs {w}");
        }
    }

    #[test]
    fn truncation_satisfies_eckart_young() {
        let mut rng = Pcg64::seeded(23);
        let a = Matrix::randn(24, 18, 1.0, &mut rng);
        let svd = jacobi_svd(&a);
        let r = 6;
        let (fa, fb) = factors_from_svd(&svd, r);
        let err2 = {
            let d = a.sub(&fa.matmul(&fb));
            let n = d.fro_norm();
            n * n
        };
        let tail2: f64 = svd.s[r..].iter().map(|&s| (s as f64) * (s as f64)).sum();
        assert!(
            (err2 - tail2).abs() < 1e-3 * (1.0 + tail2),
            "err2={err2} tail2={tail2}"
        );
    }

    #[test]
    fn factorize_balances_norms() {
        let mut rng = Pcg64::seeded(24);
        let a = Matrix::randn(32, 24, 1.0, &mut rng);
        let (fa, fb) = svd_factorize(&a, 8);
        let (na, nb) = (fa.fro_norm(), fb.fro_norm());
        assert!((na - nb).abs() / na < 1e-3, "norms {na} vs {nb}");
    }

    #[test]
    fn exactly_low_rank_matrix_recovered() {
        let mut rng = Pcg64::seeded(25);
        let u = Matrix::randn(30, 4, 1.0, &mut rng);
        let v = Matrix::randn(4, 20, 1.0, &mut rng);
        let w = u.matmul(&v);
        let (fa, fb) = svd_factorize(&w, 4);
        let err = w.sub(&fa.matmul(&fb)).fro_norm() / w.fro_norm();
        assert!(err < 1e-4, "err={err}");
    }
}
