//! Row-major dense matrix with a blocked, multithreaded GEMM.
//!
//! The GEMM is the hot path of every solver (and of the `table_solvers` /
//! `kernel_speedup` benches): i-k-j loop order over B-transposed-free layout
//! with 64-wide j-blocks keeps the inner loop vectorizable by LLVM, and row
//! blocks are distributed over `std::thread::scope` workers above a size
//! threshold. See EXPERIMENTS.md §Perf for the measured roofline.

use std::fmt;

use crate::util::Pcg64;

#[derive(Clone, PartialEq)]
/// Row-major f32 matrix — the substrate every solver computes on.
pub struct Matrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major elements, `rows * cols` long.
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

/// Below this many scalar multiply-adds, threading overhead dominates.
const PARALLEL_FLOP_THRESHOLD: usize = 1 << 21;

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Wrap row-major `data` (must be exactly `rows * cols` long).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "matrix shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Build element-wise from `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// The n×n identity.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Gaussian random matrix (used by the random solver and rSVD sketches).
    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Pcg64) -> Self {
        let mut data = vec![0.0f32; rows * cols];
        rng.fill_normal(&mut data, sigma);
        Matrix { rows, cols, data }
    }

    #[inline]
    /// Element (i, j).
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    /// Mutable element (i, j).
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    /// Row i as a contiguous slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    /// Mutable row i.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Materialized transpose (cache-blocked).
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Frobenius norm, accumulated in f64.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// In-place scalar multiply.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Element-wise difference `self - other` (shapes must match).
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// C = A @ B. Parallel blocked GEMM.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul {}x{} @ {}x{}", self.rows, self.cols, b.rows, b.cols);
        let mut out = Matrix::zeros(self.rows, b.cols);
        matmul_into(
            self.rows,
            self.cols,
            b.cols,
            &self.data,
            &b.data,
            &mut out.data,
        );
        out
    }

    /// C = A^T @ B without materializing A^T.
    pub fn matmul_tn(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.rows, b.rows, "matmul_tn shape mismatch");
        let (m, k, n) = (self.cols, self.rows, b.cols);
        let mut out = Matrix::zeros(m, n);
        // out[i][j] = sum_p a[p][i] * b[p][j] — i-p-j order keeps b row-contiguous.
        for p in 0..k {
            let arow = self.row(p);
            let brow = b.row(p);
            for i in 0..m {
                let a = arow[i];
                if a != 0.0 {
                    let orow = &mut out.data[i * n..(i + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += a * bv;
                    }
                }
            }
        }
        out
    }

    /// C = A @ B^T without materializing B^T.
    pub fn matmul_nt(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, b.rows);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            let orow = &mut out.data[i * n..(i + 1) * n];
            for j in 0..n {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (a, bb) in arow.iter().zip(brow) {
                    acc += a * bb;
                }
                orow[j] = acc;
            }
        }
        out
    }
}

/// Core GEMM: out(m,n) += a(m,k) @ b(k,n), all row-major, out zero on entry.
///
/// i-k-j ordering: the inner j loop streams both `b`'s row and `out`'s row
/// contiguously, which LLVM auto-vectorizes. Row-blocks are sharded across
/// threads when the problem is big enough to amortize spawn cost.
pub fn matmul_into(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);

    let flops = m * k * n;
    let threads = if flops < PARALLEL_FLOP_THRESHOLD {
        1
    } else {
        std::thread::available_parallelism().map_or(1, |p| p.get()).min(m.max(1))
    };

    if threads <= 1 {
        matmul_rows(0, m, k, n, a, b, out);
        return;
    }

    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        // Split `out` into disjoint row chunks; each worker owns its slice.
        let mut rest = out;
        let mut handles = Vec::new();
        let mut start = 0usize;
        while start < m {
            let rows = rows_per.min(m - start);
            let taken = std::mem::take(&mut rest);
            let (chunk, tail) = taken.split_at_mut(rows * n);
            rest = tail;
            let a_chunk = &a[start * k..(start + rows) * k];
            handles.push(scope.spawn(move || {
                matmul_rows(0, rows, k, n, a_chunk, b, chunk);
            }));
            start += rows;
        }
        for h in handles {
            h.join().expect("gemm worker panicked");
        }
    });
}

fn matmul_rows(i0: usize, i1: usize, k: usize, n: usize, a: &[f32], b: &[f32], out: &mut [f32]) {
    for i in i0..i1 {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av != 0.0 {
                let brow = &b[p * n..(p + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow) {
                    *o += av * bv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for p in 0..a.cols {
                    s += a.at(i, p) as f64 * b.at(p, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive_small() {
        let mut rng = Pcg64::seeded(1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 9, 23), (64, 64, 64)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            assert_close(&a.matmul(&b), &naive_matmul(&a, &b), 1e-4);
        }
    }

    #[test]
    fn matmul_parallel_path_matches() {
        let mut rng = Pcg64::seeded(2);
        // big enough to cross PARALLEL_FLOP_THRESHOLD
        let a = Matrix::randn(256, 128, 1.0, &mut rng);
        let b = Matrix::randn(128, 256, 1.0, &mut rng);
        assert_close(&a.matmul(&b), &naive_matmul(&a, &b), 1e-3);
    }

    #[test]
    fn matmul_tn_and_nt_match_explicit_transpose() {
        let mut rng = Pcg64::seeded(3);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let b = Matrix::randn(20, 8, 1.0, &mut rng);
        assert_close(&a.matmul_tn(&b), &a.transpose().matmul(&b), 1e-4);
        let c = Matrix::randn(7, 12, 1.0, &mut rng);
        assert_close(&a.matmul_nt(&c), &a.matmul(&c.transpose()), 1e-4);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(4);
        let a = Matrix::randn(33, 65, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn eye_is_identity_for_matmul() {
        let mut rng = Pcg64::seeded(5);
        let a = Matrix::randn(10, 10, 1.0, &mut rng);
        assert_close(&a.matmul(&Matrix::eye(10)), &a, 1e-6);
        assert_close(&Matrix::eye(10).matmul(&a), &a, 1e-6);
    }

    #[test]
    fn fro_norm_known_value() {
        let m = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn mismatched_matmul_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
