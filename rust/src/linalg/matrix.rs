//! Row-major dense matrix over the [`super::gemm`] kernel layer.
//!
//! The GEMM is the hot path of every solver (and of the `table_solvers` /
//! `kernel_speedup` benches). Since PR 5 the heavy lifting lives in
//! [`super::gemm`]: a packed, cache-tiled, pool-parallel kernel with a
//! column-split GEMV for the `m = 1` case — `Matrix::matmul`,
//! `matmul_tn` and `matmul_nt` all route through it. See DESIGN.md §11
//! for the kernel design and the measured roofline.

use std::fmt;

use crate::util::Pcg64;

pub use super::gemm::matmul_into;

#[derive(Clone, PartialEq)]
/// Row-major f32 matrix — the substrate every solver computes on.
pub struct Matrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major elements, `rows * cols` long.
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Wrap row-major `data` (must be exactly `rows * cols` long).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(rows * cols, data.len(), "matrix shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Build element-wise from `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// The n×n identity.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Gaussian random matrix (used by the random solver and rSVD sketches).
    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Pcg64) -> Self {
        let mut data = vec![0.0f32; rows * cols];
        rng.fill_normal(&mut data, sigma);
        Matrix { rows, cols, data }
    }

    #[inline]
    /// Element (i, j).
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    /// Mutable element (i, j).
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    /// Row i as a contiguous slice.
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    /// Mutable row i.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Materialized transpose (cache-blocked).
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Frobenius norm, accumulated in f64.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// In-place scalar multiply.
    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    /// Element-wise difference `self - other` (shapes must match).
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    /// C = A @ B. Parallel blocked GEMM.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul {}x{} @ {}x{}", self.rows, self.cols, b.rows, b.cols);
        let mut out = Matrix::zeros(self.rows, b.cols);
        matmul_into(
            self.rows,
            self.cols,
            b.cols,
            &self.data,
            &b.data,
            &mut out.data,
        );
        out
    }

    /// C = A^T @ B. Materializes the (cache-blocked) transpose of A and
    /// runs the packed parallel GEMM — per output element the k-sum is the
    /// same ascending-order chain the old fused loop produced, so results
    /// are unchanged while gradient/attention-path transposed products now
    /// parallelize like every other GEMM.
    pub fn matmul_tn(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.rows, b.rows, "matmul_tn shape mismatch");
        self.transpose().matmul(b)
    }

    /// C = A @ B^T. Same strategy as [`Matrix::matmul_tn`]: one blocked
    /// transpose, then the packed parallel GEMM.
    pub fn matmul_nt(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.cols, "matmul_nt shape mismatch");
        self.matmul(&b.transpose())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for p in 0..a.cols {
                    s += a.at(i, p) as f64 * b.at(p, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    fn assert_close(a: &Matrix, b: &Matrix, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive_small() {
        let mut rng = Pcg64::seeded(1);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 9, 23), (64, 64, 64)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            assert_close(&a.matmul(&b), &naive_matmul(&a, &b), 1e-4);
        }
    }

    #[test]
    fn matmul_parallel_path_matches() {
        let mut rng = Pcg64::seeded(2);
        // big enough to cross the pool-parallel dispatch threshold
        let a = Matrix::randn(256, 128, 1.0, &mut rng);
        let b = Matrix::randn(128, 256, 1.0, &mut rng);
        assert_close(&a.matmul(&b), &naive_matmul(&a, &b), 1e-3);
    }

    #[test]
    fn matmul_tn_and_nt_match_explicit_transpose() {
        let mut rng = Pcg64::seeded(3);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let b = Matrix::randn(20, 8, 1.0, &mut rng);
        assert_close(&a.matmul_tn(&b), &a.transpose().matmul(&b), 1e-4);
        let c = Matrix::randn(7, 12, 1.0, &mut rng);
        assert_close(&a.matmul_nt(&c), &a.matmul(&c.transpose()), 1e-4);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(4);
        let a = Matrix::randn(33, 65, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn eye_is_identity_for_matmul() {
        let mut rng = Pcg64::seeded(5);
        let a = Matrix::randn(10, 10, 1.0, &mut rng);
        assert_close(&a.matmul(&Matrix::eye(10)), &a, 1e-6);
        assert_close(&Matrix::eye(10).matmul(&a), &a, 1e-6);
    }

    #[test]
    fn fro_norm_known_value() {
        let m = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn mismatched_matmul_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
