//! Randomized truncated SVD (Halko, Martinsson & Tropp 2011).
//!
//! For the big layers (LM head 192×512, BERT-scale 768×3072 in the
//! kernel-speedup bench), exact Jacobi on the full matrix is wasteful when
//! only rank r ≪ min(m, n) is needed. The randomized range finder sketches
//! Y = (A Aᵀ)^q A Ω with a Gaussian Ω (n, r+p), orthonormalizes Y, and runs
//! exact Jacobi on the small projected matrix B = Qᵀ A.

use super::{jacobi_svd, thin_qr, Matrix, Svd};
use crate::util::Pcg64;

/// Truncated SVD of rank `r` with `oversample` extra sketch columns and
/// `power_iters` subspace iterations (2 is plenty for weight matrices).
pub fn randomized_svd(a: &Matrix, r: usize, oversample: usize, power_iters: usize) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let k = (r + oversample).min(m.min(n));
    // Deterministic sketch: seeded from the problem size so repeated
    // factorizations of the same layer reproduce bit-identically.
    let mut rng = Pcg64::new(0x5eed ^ ((m as u64) << 20) ^ (n as u64), r as u64);
    let omega = Matrix::randn(n, k, 1.0, &mut rng);
    let mut y = a.matmul(&omega); // (m, k)
    // Power iterations with re-orthonormalization for spectral accuracy.
    for _ in 0..power_iters {
        let (q, _) = thin_qr(&y);
        let z = a.matmul_tn(&q); // A^T Q: (n, k)
        let (qz, _) = thin_qr(&z);
        y = a.matmul(&qz); // (m, k)
    }
    let (q, _) = thin_qr(&y); // (m, k) orthonormal
    let b = q.matmul_tn(a); // wrong orientation; fix below

    // q.matmul_tn(a) computes q^T a only if rows match: q is (m,k), a is
    // (m,n) -> (k,n). That is exactly B.
    let small = jacobi_svd(&b); // B = U_b S V^T, U_b: (k, k)
    let u = q.matmul(&small.u); // (m, k)
    let take = r.min(small.s.len());
    // Truncate to r.
    let mut ut = Matrix::zeros(m, take);
    for i in 0..m {
        for j in 0..take {
            *ut.at_mut(i, j) = u.at(i, j);
        }
    }
    let mut vt = Matrix::zeros(take, n);
    for i in 0..take {
        vt.row_mut(i).copy_from_slice(small.vt.row(i));
    }
    Svd {
        u: ut,
        s: small.s[..take].to_vec(),
        vt,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::factors_from_svd;

    #[test]
    fn recovers_exactly_low_rank() {
        let mut rng = Pcg64::seeded(30);
        let u = Matrix::randn(120, 6, 1.0, &mut rng);
        let v = Matrix::randn(6, 300, 1.0, &mut rng);
        let a = u.matmul(&v);
        let svd = randomized_svd(&a, 6, 8, 2);
        let (fa, fb) = factors_from_svd(&svd, 6);
        let err = a.sub(&fa.matmul(&fb)).fro_norm() / a.fro_norm();
        assert!(err < 1e-4, "err={err}");
    }

    #[test]
    fn near_optimal_on_full_rank_noise() {
        let mut rng = Pcg64::seeded(31);
        let a = Matrix::randn(100, 80, 1.0, &mut rng);
        let r = 20;
        let exact = jacobi_svd(&a);
        let tail2: f64 = exact.s[r..].iter().map(|&s| (s as f64) * (s as f64)).sum();
        let approx = randomized_svd(&a, r, 10, 2);
        let (fa, fb) = factors_from_svd(&approx, r);
        let err2 = {
            let d = a.sub(&fa.matmul(&fb)).fro_norm();
            d * d
        };
        // Within 5% of the optimal truncation error.
        assert!(err2 <= tail2 * 1.05, "err2={err2} optimal={tail2}");
    }

    #[test]
    fn singular_values_close_to_exact() {
        let mut rng = Pcg64::seeded(32);
        let a = Matrix::randn(90, 70, 1.0, &mut rng);
        let exact = jacobi_svd(&a);
        let approx = randomized_svd(&a, 10, 10, 2);
        for j in 0..10 {
            let rel = (exact.s[j] - approx.s[j]).abs() / exact.s[j];
            assert!(rel < 0.02, "sigma_{j}: exact={} approx={}", exact.s[j], approx.s[j]);
        }
    }

    #[test]
    fn deterministic_given_same_inputs() {
        let mut rng = Pcg64::seeded(33);
        let a = Matrix::randn(60, 50, 1.0, &mut rng);
        let s1 = randomized_svd(&a, 8, 6, 1);
        let s2 = randomized_svd(&a, 8, 6, 1);
        assert_eq!(s1.s, s2.s);
        assert_eq!(s1.u.data, s2.u.data);
    }
}
