//! Reusable scratch arena for the native interpreters.
//!
//! The forward, backward and decode interpreters need a dozen-odd `Vec<f32>`
//! activation/scratch buffers per op; allocating them fresh on every call
//! put the allocator on the per-token hot path. A [`Workspace`] is a
//! checkout/checkin pool of buffers: `take_zeroed` hands out an owned,
//! zero-filled `Vec<f32>` (reusing a retired buffer's capacity whenever one
//! fits), `give` retires it for reuse. Because buffers are *owned* while
//! checked out there is no lifetime entanglement — the arena only holds the
//! free list.
//!
//! Steady-state contract: once a request/step shape has been seen, every
//! subsequent identical step is allocation-free (the decode interpreter
//! sizes its attention scratch by the session's `max_seq`, so every
//! post-prefill step requests identical lengths). [`Workspace::alloc_misses`]
//! counts takes that had to grow — `tests/decode_alloc_steady.rs` pins it at
//! zero across steady-state decode steps, alongside a counting-allocator
//! check of the whole step.
//!
//! Ownership of the [`Workspace`] follows the execution context: each
//! [`crate::backend::DecodeSession`] owns one (sessions migrate between
//! dispatcher threads), while the forward, training and *batched decode*
//! interpreters share a per-thread arena via [`with_thread_ws`] — the
//! continuous-batching sweep's stacked activations are sized by the live
//! batch, which belongs to the dispatcher thread, not to any one session.

use std::cell::RefCell;

/// Checkout/checkin pool of `f32` scratch buffers. See the module docs.
#[derive(Debug, Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
    takes: usize,
    misses: usize,
}

impl Workspace {
    /// Empty arena (no buffers retained yet).
    pub fn new() -> Self {
        Self::default()
    }

    /// Pop the smallest retired buffer whose capacity fits `len` (best fit
    /// keeps big buffers available for big requests), cleared and ready to
    /// fill; allocates (and counts a miss) when nothing fits.
    fn pop_fit(&mut self, len: usize) -> Vec<f32> {
        self.takes += 1;
        let mut best: Option<usize> = None;
        for (i, buf) in self.free.iter().enumerate() {
            if buf.capacity() < len {
                continue;
            }
            match best {
                Some(j) if self.free[j].capacity() <= buf.capacity() => {}
                _ => best = Some(i),
            }
        }
        let mut buf = match best {
            Some(i) => self.free.swap_remove(i),
            None => {
                if len > 0 {
                    self.misses += 1;
                }
                Vec::with_capacity(len)
            }
        };
        buf.clear();
        buf
    }

    /// Check out a zero-filled buffer of exactly `len` elements (the form
    /// GEMM accumulator targets and scatter-written buffers need).
    pub fn take_zeroed(&mut self, len: usize) -> Vec<f32> {
        let mut buf = self.pop_fit(len);
        buf.resize(len, 0.0);
        buf
    }

    /// Check out a buffer initialized as a copy of `src` — a single write
    /// pass, skipping the zero fill `take_zeroed` would immediately have
    /// overwritten.
    pub fn take_copied(&mut self, src: &[f32]) -> Vec<f32> {
        let mut buf = self.pop_fit(src.len());
        buf.extend_from_slice(src);
        buf
    }

    /// Retire a buffer for reuse. Order is irrelevant; zero-capacity
    /// buffers are dropped instead of retained.
    pub fn give(&mut self, buf: Vec<f32>) {
        if buf.capacity() > 0 {
            self.free.push(buf);
        }
    }

    /// Retire a batch of buffers — the interpreter epilogues return their
    /// whole scratch set in one call.
    pub fn give_all(&mut self, bufs: impl IntoIterator<Item = Vec<f32>>) {
        for buf in bufs {
            self.give(buf);
        }
    }

    /// Takes served since construction (or [`Workspace::reset_stats`]).
    pub fn takes(&self) -> usize {
        self.takes
    }

    /// Takes that had to allocate because no retired buffer fit. Zero
    /// across identical steps ⇒ the arena is in steady state.
    pub fn alloc_misses(&self) -> usize {
        self.misses
    }

    /// Reset the `takes`/`alloc_misses` counters (buffers are kept).
    pub fn reset_stats(&mut self) {
        self.takes = 0;
        self.misses = 0;
    }
}

impl Clone for Workspace {
    /// Cloning yields a fresh, empty arena: scratch capacity is an
    /// execution-context resource, not data, so a cloned
    /// [`crate::backend::DecodeSession`] warms its own.
    fn clone(&self) -> Self {
        Workspace::new()
    }
}

thread_local! {
    static THREAD_WS: RefCell<Workspace> = RefCell::new(Workspace::new());
}

/// Run `f` with this thread's shared [`Workspace`] (created on first use,
/// retained for the thread's lifetime so repeated interpreter calls on the
/// same thread — the serving dispatcher, the training loop — reuse their
/// buffers).
///
/// # Panics
///
/// Nested calls on the same thread panic (`RefCell` double borrow); callers
/// borrow once at the interpreter entry point and pass `&mut Workspace`
/// down.
pub fn with_thread_ws<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
    THREAD_WS.with(|cell| f(&mut cell.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuses_capacity_after_give() {
        let mut ws = Workspace::new();
        let a = ws.take_zeroed(128);
        assert_eq!(a.len(), 128);
        assert_eq!(ws.alloc_misses(), 1);
        ws.give(a);
        let b = ws.take_zeroed(64);
        assert!(b.capacity() >= 128, "should reuse the retired buffer");
        assert_eq!(ws.alloc_misses(), 1, "steady take must not miss");
        assert!(b.iter().all(|&v| v == 0.0));
        ws.give(b);
        assert_eq!(ws.takes(), 2);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient_buffer() {
        let mut ws = Workspace::new();
        let big = ws.take_zeroed(1024);
        let small = ws.take_zeroed(32);
        ws.give(big);
        ws.give(small);
        let got = ws.take_zeroed(16);
        assert!(got.capacity() < 1024, "picked the big buffer for a tiny take");
        ws.give(got);
        let got = ws.take_zeroed(512);
        assert!(got.capacity() >= 1024, "big take must get the big buffer");
    }

    #[test]
    fn zeroes_previous_contents() {
        let mut ws = Workspace::new();
        let mut a = ws.take_zeroed(8);
        a.iter_mut().for_each(|v| *v = 7.0);
        ws.give(a);
        let b = ws.take_zeroed(8);
        assert!(b.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn take_copied_reuses_and_copies_exactly() {
        let mut ws = Workspace::new();
        ws.give(vec![9.0f32; 32]);
        let src = [1.0f32, -2.0, 3.0];
        let buf = ws.take_copied(&src);
        assert_eq!(buf, vec![1.0, -2.0, 3.0]);
        assert!(buf.capacity() >= 32, "should reuse the retired buffer");
        assert_eq!(ws.alloc_misses(), 0);
    }

    #[test]
    fn clone_is_fresh_and_stats_reset() {
        let mut ws = Workspace::new();
        ws.give(ws_buf());
        let mut c = ws.clone();
        assert_eq!(c.takes(), 0);
        // A clone has no retained buffers: first take misses.
        let _ = c.take_zeroed(4);
        assert_eq!(c.alloc_misses(), 1);
        ws.reset_stats();
        assert_eq!(ws.takes(), 0);
        assert_eq!(ws.alloc_misses(), 0);
    }

    fn ws_buf() -> Vec<f32> {
        vec![1.0; 16]
    }

    #[test]
    fn thread_ws_is_reused_across_calls() {
        let cap = with_thread_ws(|ws| {
            let buf = ws.take_zeroed(256);
            let cap = buf.capacity();
            ws.give(buf);
            cap
        });
        let misses = with_thread_ws(|ws| {
            ws.reset_stats();
            let buf = ws.take_zeroed(cap.min(256));
            let m = ws.alloc_misses();
            ws.give(buf);
            m
        });
        assert_eq!(misses, 0);
    }
}
