//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute forever.
//!
//! The `xla` crate wraps the PJRT C API: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`. HLO
//! *text* is the interchange format (xla_extension 0.5.1 rejects jax ≥ 0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids).
//!
//! Python builds the artifacts once (`make artifacts`); this module is the
//! only place the process touches XLA, and nothing here ever calls back into
//! Python.

pub mod engine;
pub mod manifest;

pub use engine::Engine;
pub use manifest::{GraphSpec, Manifest, TensorSpec};
