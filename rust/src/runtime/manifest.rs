//! `artifacts/manifest.json` — the machine-readable index the AOT exporter
//! writes and the runtime trusts. One `GraphSpec` per lowered HLO module.
//! Parsed with the in-tree JSON codec ([`crate::util::json`]).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context as _};

use crate::tensor::Dtype;
use crate::util::Json;
use crate::Result;

/// Shape + dtype of one graph input/output/parameter.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Parameter / input / output name.
    pub name: String,
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Dtype tag (`"f32"` / `"i32"`).
    pub dtype: String,
}

impl TensorSpec {
    /// Parsed [`Dtype`] of this spec.
    pub fn dtype(&self) -> Result<Dtype> {
        Dtype::from_tag(&self.dtype)
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(TensorSpec {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: v.req("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-lowered graph: fwd or train, for one (model, variant, batch).
#[derive(Clone, Debug)]
pub struct GraphSpec {
    /// Unique graph name (e.g. `text_dense_fwd_b8`).
    pub name: String,
    /// HLO-text file, relative to the artifacts dir (empty for synthesized).
    pub file: String,
    /// Model family (`text` / `image` / `lm`).
    pub model: String,
    /// Variant name (`dense`, `led_r25`, …).
    pub variant: String,
    /// "fwd" | "train"
    pub kind: String,
    /// Static batch size the graph was lowered for.
    pub batch: usize,
    /// Parameter order — the flatten_params contract with Python.
    pub params: Vec<TensorSpec>,
    /// Runtime inputs (tokens / pixels / labels).
    pub inputs: Vec<TensorSpec>,
    /// Graph outputs (logits or loss).
    pub outputs: Vec<TensorSpec>,
    /// Resolved rank per factorized layer (layer prefix -> r).
    pub ranks: BTreeMap<String, usize>,
    /// Total scalar parameter count.
    pub n_params: usize,
    /// Model config (vocab/seq/d/... depending on model).
    pub config: BTreeMap<String, usize>,
    /// First 16 hex chars of the HLO file's sha256 (empty for synthesized).
    pub sha256_16: String,
}

impl GraphSpec {
    /// Total literal count the executable expects:
    /// fwd: params + inputs; train: 3*params (params, m, v) + step + inputs.
    pub fn expected_arg_count(&self) -> usize {
        match self.kind.as_str() {
            "train" => 3 * self.params.len() + 1 + self.inputs.len(),
            _ => self.params.len() + self.inputs.len(),
        }
    }

    /// Required integer config entry (vocab/seq/d/heads/…).
    pub fn config_usize(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .copied()
            .ok_or_else(|| anyhow!("graph {} config missing key {key:?}", self.name))
    }

    /// Fail-closed integrity check of HLO bytes against the manifest's
    /// `sha256_16` pin (first 16 hex chars of the file's sha256, written by
    /// the AOT exporter). An empty pin means the graph was synthesized
    /// in-process — there is no file to verify, so it passes. A non-empty
    /// pin that does not match is an error: the runtime must not compile a
    /// tampered or truncated artifact.
    pub fn verify_hlo_bytes(&self, bytes: &[u8]) -> Result<()> {
        if self.sha256_16.is_empty() {
            return Ok(());
        }
        let full = crate::util::sha256_hex(bytes);
        let actual = &full[..16];
        if !self.sha256_16.eq_ignore_ascii_case(actual) {
            bail!(
                "HLO integrity check failed for graph {} ({}): manifest pins sha256_16 {}, \
                 file hashes to {actual}",
                self.name,
                self.file,
                self.sha256_16
            );
        }
        Ok(())
    }

    fn from_json(v: &Json) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.req(key)?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        let mut ranks = BTreeMap::new();
        if let Some(r) = v.get("ranks") {
            for (k, rv) in r.as_obj()? {
                ranks.insert(k.clone(), rv.as_usize()?);
            }
        }
        let mut config = BTreeMap::new();
        if let Some(c) = v.get("config") {
            for (k, cv) in c.as_obj()? {
                if let Ok(u) = cv.as_usize() {
                    config.insert(k.clone(), u);
                }
            }
        }
        Ok(GraphSpec {
            name: v.req("name")?.as_str()?.to_string(),
            file: v.req("file")?.as_str()?.to_string(),
            model: v.req("model")?.as_str()?.to_string(),
            variant: v.req("variant")?.as_str()?.to_string(),
            kind: v.req("kind")?.as_str()?.to_string(),
            batch: v.req("batch")?.as_usize()?,
            params: specs("params")?,
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            ranks,
            n_params: v.usize_or("n_params", 0),
            config,
            sha256_16: v.str_or("sha256_16", ""),
        })
    }
}

/// One exported init checkpoint (model, variant) → GTZ file.
#[derive(Clone, Debug)]
pub struct CheckpointSpec {
    /// Model family.
    pub model: String,
    /// Variant name.
    pub variant: String,
    /// GTZ file, relative to the artifacts dir.
    pub file: String,
    /// Total scalar parameter count.
    pub n_params: usize,
}

/// The parsed `manifest.json`: every lowered graph + exported checkpoint.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Manifest format version (1).
    pub format: usize,
    /// All lowered graphs.
    pub graphs: Vec<GraphSpec>,
    /// All exported init checkpoints.
    pub checkpoints: Vec<CheckpointSpec>,
    /// Directory the manifest was loaded from (file paths are relative).
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?} — run `make artifacts`?"))?;
        let mut m = Self::parse(&text).context("parsing manifest.json")?;
        m.dir = dir.to_path_buf();
        Ok(m)
    }

    /// Parse manifest JSON text (the `dir` field is left empty).
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let format = v.req("format")?.as_usize()?;
        if format != 1 {
            bail!("unsupported manifest format {format}");
        }
        let graphs = v
            .req("graphs")?
            .as_arr()?
            .iter()
            .map(GraphSpec::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut checkpoints = Vec::new();
        if let Some(cs) = v.get("checkpoints") {
            for c in cs.as_arr()? {
                checkpoints.push(CheckpointSpec {
                    model: c.req("model")?.as_str()?.to_string(),
                    variant: c.req("variant")?.as_str()?.to_string(),
                    file: c.req("file")?.as_str()?.to_string(),
                    n_params: c.usize_or("n_params", 0),
                });
            }
        }
        Ok(Manifest {
            format,
            graphs,
            checkpoints,
            dir: PathBuf::new(),
        })
    }

    /// Graph by exact name.
    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs
            .iter()
            .find(|g| g.name == name)
            .ok_or_else(|| anyhow!("graph {name:?} not in manifest ({} graphs)", self.graphs.len()))
    }

    /// Find a graph by (model, variant, kind) with the largest batch <= cap
    /// (or the largest available when `cap` is None).
    pub fn find(
        &self,
        model: &str,
        variant: &str,
        kind: &str,
        cap: Option<usize>,
    ) -> Result<&GraphSpec> {
        self.graphs
            .iter()
            .filter(|g| g.model == model && g.variant == variant && g.kind == kind)
            .filter(|g| match cap {
                Some(c) => g.batch <= c,
                None => true,
            })
            .max_by_key(|g| g.batch)
            .ok_or_else(|| {
                anyhow!("no graph for model={model} variant={variant} kind={kind} cap={cap:?}")
            })
    }

    /// All distinct variants available for a model.
    pub fn variants(&self, model: &str) -> Vec<String> {
        let mut vs: Vec<String> = self
            .graphs
            .iter()
            .filter(|g| g.model == model)
            .map(|g| g.variant.clone())
            .collect();
        vs.sort();
        vs.dedup();
        vs
    }

    /// Absolute path of the init checkpoint for (model, variant).
    pub fn checkpoint(&self, model: &str, variant: &str) -> Result<PathBuf> {
        self.checkpoints
            .iter()
            .find(|c| c.model == model && c.variant == variant)
            .map(|c| self.dir.join(&c.file))
            .ok_or_else(|| anyhow!("no init checkpoint for {model}/{variant}"))
    }

    /// Absolute path of a graph's HLO-text file.
    pub fn graph_path(&self, g: &GraphSpec) -> PathBuf {
        self.dir.join(&g.file)
    }

    /// Read a graph's HLO file and verify it against the manifest pin
    /// ([`GraphSpec::verify_hlo_bytes`]); returns the verified bytes.
    pub fn verify_graph_file(&self, g: &GraphSpec) -> Result<Vec<u8>> {
        let path = self.graph_path(g);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading HLO text {path:?}"))?;
        g.verify_hlo_bytes(&bytes)?;
        Ok(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_manifest() -> Manifest {
        Manifest::parse(
            r#"{
            "format": 1,
            "graphs": [
                {"name": "m_dense_fwd_b1", "file": "a.hlo.txt", "model": "m",
                 "variant": "dense", "kind": "fwd", "batch": 1,
                 "params": [{"name": "w", "shape": [2,2], "dtype": "f32"}],
                 "inputs": [{"name": "x", "shape": [1,2], "dtype": "f32"}],
                 "outputs": [{"name": "out", "shape": [1,2], "dtype": "f32"}],
                 "ranks": {"fc": 8},
                 "n_params": 4, "config": {"d": 64}},
                {"name": "m_dense_fwd_b8", "file": "b.hlo.txt", "model": "m",
                 "variant": "dense", "kind": "fwd", "batch": 8,
                 "params": [{"name": "w", "shape": [2,2], "dtype": "f32"}],
                 "inputs": [{"name": "x", "shape": [8,2], "dtype": "f32"}],
                 "outputs": [{"name": "out", "shape": [8,2], "dtype": "f32"}],
                 "n_params": 4},
                {"name": "m_dense_train_b8", "file": "c.hlo.txt", "model": "m",
                 "variant": "dense", "kind": "train", "batch": 8,
                 "params": [{"name": "w", "shape": [2,2], "dtype": "f32"}],
                 "inputs": [{"name": "x", "shape": [8,2], "dtype": "f32"},
                             {"name": "y", "shape": [8], "dtype": "i32"}],
                 "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}],
                 "n_params": 4}
            ],
            "checkpoints": [
                {"model": "m", "variant": "dense", "file": "init/m.gtz", "n_params": 4}
            ]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn find_prefers_largest_batch_under_cap() {
        let m = toy_manifest();
        assert_eq!(m.find("m", "dense", "fwd", None).unwrap().batch, 8);
        assert_eq!(m.find("m", "dense", "fwd", Some(4)).unwrap().batch, 1);
        assert!(m.find("m", "dense", "fwd", Some(0)).is_err());
        assert!(m.find("m", "led_r25", "fwd", None).is_err());
    }

    #[test]
    fn arg_count_formula() {
        let m = toy_manifest();
        assert_eq!(m.graph("m_dense_fwd_b1").unwrap().expected_arg_count(), 2);
        // train: 3*1 params + step + 2 inputs
        assert_eq!(m.graph("m_dense_train_b8").unwrap().expected_arg_count(), 6);
    }

    #[test]
    fn ranks_and_config_parse() {
        let m = toy_manifest();
        let g = m.graph("m_dense_fwd_b1").unwrap();
        assert_eq!(g.ranks["fc"], 8);
        assert_eq!(g.config_usize("d").unwrap(), 64);
        assert!(g.config_usize("missing").is_err());
    }

    #[test]
    fn variants_and_checkpoints() {
        let m = toy_manifest();
        assert_eq!(m.variants("m"), vec!["dense".to_string()]);
        assert!(m.checkpoint("m", "dense").is_ok());
        assert!(m.checkpoint("m", "led_r10").is_err());
    }

    #[test]
    fn rejects_unknown_format() {
        assert!(Manifest::parse(r#"{"format": 9, "graphs": []}"#).is_err());
    }

    #[test]
    fn hlo_integrity_pin_fails_closed() {
        let mut g = toy_manifest().graph("m_dense_fwd_b1").unwrap().clone();
        // No pin (synthesized graph): anything passes.
        assert!(g.verify_hlo_bytes(b"whatever").is_ok());

        let body = b"HloModule m_dense_fwd_b1";
        g.sha256_16 = crate::util::sha256_hex(body)[..16].to_string();
        assert!(g.verify_hlo_bytes(body).is_ok());
        // Uppercase pins compare case-insensitively.
        g.sha256_16 = g.sha256_16.to_ascii_uppercase();
        assert!(g.verify_hlo_bytes(body).is_ok());

        let err = g.verify_hlo_bytes(b"HloModule tampered").unwrap_err();
        assert!(format!("{err:#}").contains("integrity check failed"));
    }

    #[test]
    fn tensor_spec_dtype() {
        let s = TensorSpec { name: "x".into(), shape: vec![2, 3], dtype: "f32".into() };
        assert_eq!(s.dtype().unwrap(), Dtype::F32);
        assert_eq!(s.numel(), 6);
        let bad = TensorSpec { name: "x".into(), shape: vec![], dtype: "f64".into() };
        assert!(bad.dtype().is_err());
    }
}
