//! The execution engine: compiled-executable cache + literal marshalling.
//!
//! One `Engine` per process. Executables compile lazily on first use and are
//! cached for the process lifetime (compilation of the larger train graphs
//! takes seconds; execution takes milliseconds — never recompile on the hot
//! path). All methods take `&self`; the cache is behind a `Mutex`, execution
//! itself runs outside the lock so independent graphs can run concurrently
//! from the coordinator's worker tasks.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context as _};

use super::manifest::{GraphSpec, Manifest, TensorSpec};
use crate::tensor::{Data, Dtype, ParamStore, Tensor};
use crate::Result;

/// The PJRT execution engine: client + manifest + compiled-executable
/// cache. Everything artifact-backed runs through here.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU PJRT client and load the artifact manifest from `dir`.
    pub fn load(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Self {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// Engine over the default artifacts directory (see [`crate::artifacts_dir`]).
    pub fn load_default() -> Result<Self> {
        Self::load(crate::artifacts_dir())
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for a graph.
    pub fn executable(&self, graph: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(graph) {
            return Ok(exe.clone());
        }
        // Compile outside the lock: first touches of different graphs
        // shouldn't serialize behind one compilation.
        let spec = self.manifest.graph(graph)?;
        let path = self.manifest.graph_path(spec);
        // Fail-closed: never hand a tampered/truncated artifact to the
        // compiler (the manifest pins each HLO file's sha256_16).
        self.manifest.verify_graph_file(spec)?;
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing HLO text {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {graph}: {e}"))?,
        );
        self.cache
            .lock()
            .unwrap()
            .entry(graph.to_string())
            .or_insert_with(|| exe.clone());
        Ok(exe)
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Pre-compile a set of graphs (the coordinator warms its variants up
    /// front so first requests don't pay compile latency).
    pub fn warmup(&self, graphs: &[&str]) -> Result<()> {
        for g in graphs {
            self.executable(g)?;
        }
        Ok(())
    }

    // -- marshalling --------------------------------------------------------

    /// Marshal a [`Tensor`] into a PJRT literal (zero-copy from raw bytes).
    pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
        let ty = match t.dtype() {
            Dtype::F32 => xla::ElementType::F32,
            Dtype::I32 => xla::ElementType::S32,
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, t.raw_bytes())
            .map_err(|e| anyhow!("literal from tensor shape {:?}: {e}", t.shape))
    }

    /// Marshal a PJRT literal back into a [`Tensor`].
    pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => Data::F32(
                lit.to_vec::<f32>().map_err(|e| anyhow!("literal to f32 vec: {e}"))?,
            ),
            xla::ElementType::S32 => Data::I32(
                lit.to_vec::<i32>().map_err(|e| anyhow!("literal to i32 vec: {e}"))?,
            ),
            other => bail!("unsupported literal element type {other:?}"),
        };
        Ok(Tensor { shape: dims, data })
    }

    fn check_spec(t: &Tensor, spec: &TensorSpec, what: &str) -> Result<()> {
        if t.shape != spec.shape {
            bail!(
                "{what} {:?}: shape {:?} does not match spec {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
        }
        if t.dtype() != spec.dtype()? {
            bail!("{what} {:?}: dtype mismatch", spec.name);
        }
        Ok(())
    }

    /// Execute a graph and decompose the (tupled) result into tensors.
    fn execute(&self, graph: &GraphSpec, args: &[xla::Literal]) -> Result<Vec<Tensor>> {
        if args.len() != graph.expected_arg_count() {
            bail!(
                "graph {} expects {} args, got {}",
                graph.name,
                graph.expected_arg_count(),
                args.len()
            );
        }
        let exe = self.executable(&graph.name)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {}: {e}", graph.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {}: {e}", graph.name))?;
        // Graphs are lowered with return_tuple=True: decompose host-side.
        let parts = lit
            .to_tuple()
            .map_err(|e| anyhow!("decomposing result tuple of {}: {e}", graph.name))?;
        parts.iter().map(Self::literal_to_tensor).collect()
    }

    /// Run a forward graph: `outputs = f(params, inputs)`.
    ///
    /// `params` must match the graph's param list (names, order, shapes) —
    /// the flatten_params contract. Returns the graph outputs.
    pub fn run_fwd(
        &self,
        graph: &GraphSpec,
        params: &ParamStore,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        if graph.kind != "fwd" {
            bail!("run_fwd on non-fwd graph {}", graph.name);
        }
        let mut args = Vec::with_capacity(graph.expected_arg_count());
        self.marshal_params(graph, params, &mut args)?;
        if inputs.len() != graph.inputs.len() {
            bail!(
                "graph {} wants {} inputs, got {}",
                graph.name,
                graph.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&graph.inputs) {
            Self::check_spec(t, spec, "input")?;
            args.push(Self::tensor_to_literal(t)?);
        }
        self.execute(graph, &args)
    }

    /// Run one fused train step:
    /// `(params', m', v', loss) = step(params, m, v, step_no, batch...)`.
    ///
    /// Updates `params`, `m`, `v` in place and returns the loss.
    pub fn run_train_step(
        &self,
        graph: &GraphSpec,
        params: &mut ParamStore,
        m: &mut ParamStore,
        v: &mut ParamStore,
        step_no: f32,
        batch: &[Tensor],
    ) -> Result<f32> {
        if graph.kind != "train" {
            bail!("run_train_step on non-train graph {}", graph.name);
        }
        let np = graph.params.len();
        let mut args = Vec::with_capacity(graph.expected_arg_count());
        self.marshal_params(graph, params, &mut args)?;
        self.marshal_params(graph, m, &mut args)?;
        self.marshal_params(graph, v, &mut args)?;
        args.push(Self::tensor_to_literal(&Tensor::scalar_f32(step_no))?);
        if batch.len() != graph.inputs.len() {
            bail!(
                "graph {} wants {} batch tensors, got {}",
                graph.name,
                graph.inputs.len(),
                batch.len()
            );
        }
        for (t, spec) in batch.iter().zip(&graph.inputs) {
            Self::check_spec(t, spec, "batch input")?;
            args.push(Self::tensor_to_literal(t)?);
        }
        let mut out = self.execute(graph, &args)?;
        if out.len() != 3 * np + 1 {
            bail!(
                "train graph {} returned {} tensors, expected {}",
                graph.name,
                out.len(),
                3 * np + 1
            );
        }
        let loss_t = out.pop().unwrap();
        let loss = loss_t.as_f32()?[0];
        // Write back in flat order: params, m, v.
        for (dst_store, chunk) in [(&mut *params, 0), (&mut *m, 1), (&mut *v, 2)] {
            for (i, spec) in graph.params.iter().enumerate() {
                let t = std::mem::replace(
                    &mut out[chunk * np + i],
                    Tensor::zeros(&[], Dtype::F32),
                );
                debug_assert_eq!(t.shape, spec.shape, "update for {}", spec.name);
                dst_store.insert(spec.name.clone(), t);
            }
        }
        Ok(loss)
    }

    fn marshal_params(
        &self,
        graph: &GraphSpec,
        params: &ParamStore,
        args: &mut Vec<xla::Literal>,
    ) -> Result<()> {
        if params.len() != graph.params.len() {
            bail!(
                "graph {} wants {} params, store has {}",
                graph.name,
                graph.params.len(),
                params.len()
            );
        }
        for spec in &graph.params {
            let t = params
                .get(&spec.name)
                .ok_or_else(|| anyhow!("param {:?} missing for graph {}", spec.name, graph.name))?;
            Self::check_spec(t, spec, "param")
                .with_context(|| format!("marshalling params for {}", graph.name))?;
            args.push(Self::tensor_to_literal(t)?);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = Engine::tensor_to_literal(&t).unwrap();
        let back = Engine::literal_to_tensor(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = Tensor::from_i32(&[4], vec![-1, 0, 7, 42]);
        let lit = Engine::tensor_to_literal(&t).unwrap();
        assert_eq!(Engine::literal_to_tensor(&lit).unwrap(), t);
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let t = Tensor::scalar_f32(3.25);
        let lit = Engine::tensor_to_literal(&t).unwrap();
        assert_eq!(Engine::literal_to_tensor(&lit).unwrap(), t);
    }
}
