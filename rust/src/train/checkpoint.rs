//! Checkpoint management: named GTZ snapshots under a run directory.

use std::path::{Path, PathBuf};

use crate::tensor::ParamStore;
use crate::Result;

/// Save `params` as `<dir>/<name>.gtz`, creating directories as needed.
pub fn save(dir: impl AsRef<Path>, name: &str, params: &ParamStore) -> Result<PathBuf> {
    let path = dir.as_ref().join(format!("{name}.gtz"));
    params.save_gtz(&path)?;
    Ok(path)
}

/// Load `<dir>/<name>.gtz`.
pub fn load(dir: impl AsRef<Path>, name: &str) -> Result<ParamStore> {
    ParamStore::load_gtz(dir.as_ref().join(format!("{name}.gtz")))
}

/// List checkpoint names in a directory (without the .gtz suffix).
pub fn list(dir: impl AsRef<Path>) -> Vec<String> {
    let Ok(entries) = std::fs::read_dir(dir.as_ref()) else {
        return vec![];
    };
    let mut names: Vec<String> = entries
        .filter_map(|e| e.ok())
        .filter_map(|e| {
            let name = e.file_name().into_string().ok()?;
            name.strip_suffix(".gtz").map(String::from)
        })
        .collect();
    names.sort();
    names
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Dtype, Tensor};

    #[test]
    fn save_load_list() {
        let dir = std::env::temp_dir().join(format!("gf_ckpt_{}", std::process::id()));
        let mut p = ParamStore::new();
        p.insert("w", Tensor::zeros(&[2, 2], Dtype::F32));
        save(&dir, "step100", &p).unwrap();
        save(&dir, "step200", &p).unwrap();
        assert_eq!(list(&dir), vec!["step100", "step200"]);
        let back = load(&dir, "step100").unwrap();
        assert_eq!(back.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn list_missing_dir_is_empty() {
        assert!(list("/nonexistent/path/xyz").is_empty());
    }
}
