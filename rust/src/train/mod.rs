//! Training driver: the Rust loop around the fused `train_step` artifacts.
//!
//! The AOT graph does everything numeric (fwd + bwd through the Pallas
//! custom VJPs + Adam); this module owns the loop: data iteration, step
//! counting, loss logging, and GTZ checkpointing. By-design training is
//! just: load the `led_rXX` init checkpoint, drive its train graph.

pub mod checkpoint;

use crate::data::{batch, Dataset, Split};
use crate::runtime::{Engine, GraphSpec};
use crate::tensor::{Dtype, ParamStore, Tensor};
use crate::Result;

/// Loss history entry.
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub seconds: f64,
}

/// Training state for one (model, variant).
pub struct Trainer<'e> {
    engine: &'e Engine,
    graph: GraphSpec,
    pub params: ParamStore,
    m: ParamStore,
    v: ParamStore,
    pub step: usize,
    pub history: Vec<StepLog>,
}

impl<'e> Trainer<'e> {
    /// Start from a checkpoint (usually the JAX-exported init).
    pub fn new(engine: &'e Engine, graph: &GraphSpec, mut params: ParamStore) -> Result<Self> {
        let order: Vec<String> = graph.params.iter().map(|p| p.name.clone()).collect();
        params.reorder_to(&order)?;
        let zeros = |store: &ParamStore| {
            let mut z = ParamStore::new();
            for (name, t) in store.iter() {
                z.insert(name, Tensor::zeros(&t.shape, Dtype::F32));
            }
            z
        };
        let m = zeros(&params);
        let v = zeros(&params);
        Ok(Self {
            engine,
            graph: graph.clone(),
            params,
            m,
            v,
            step: 0,
            history: Vec::new(),
        })
    }

    /// Load the manifest's init checkpoint for (model, variant) and build a
    /// trainer on its train graph.
    pub fn from_init(engine: &'e Engine, model: &str, variant: &str) -> Result<Self> {
        let graph = engine.manifest().find(model, variant, "train", None)?.clone();
        let ckpt = engine.manifest().checkpoint(model, variant)?;
        let params = ParamStore::load_gtz(ckpt)?;
        Self::new(engine, &graph, params)
    }

    pub fn graph(&self) -> &GraphSpec {
        &self.graph
    }

    pub fn batch_size(&self) -> usize {
        self.graph.batch
    }

    /// One optimizer step on a batch; returns the loss.
    pub fn train_step(&mut self, batch: &[Tensor]) -> Result<f32> {
        self.step += 1;
        let t0 = std::time::Instant::now();
        let loss = self.engine.run_train_step(
            &self.graph,
            &mut self.params,
            &mut self.m,
            &mut self.v,
            self.step as f32,
            batch,
        )?;
        self.history.push(StepLog {
            step: self.step,
            loss,
            seconds: t0.elapsed().as_secs_f64(),
        });
        Ok(loss)
    }

    /// Train a classifier for `steps` over a dataset, streaming fresh
    /// synthetic batches (no epoch structure needed — infinite data).
    pub fn train_classifier(
        &mut self,
        ds: &dyn Dataset,
        steps: usize,
        image_hw: Option<(usize, usize, usize)>,
        mut log: impl FnMut(&StepLog),
    ) -> Result<()> {
        let bsz = self.batch_size();
        for i in 0..steps {
            let (x, y) = batch(ds, Split::Train, i * bsz, bsz, image_hw);
            self.train_step(&[x, y])?;
            log(self.history.last().unwrap());
        }
        Ok(())
    }

    /// Pretrain the causal LM on the ICL corpus (single-tensor batches).
    pub fn train_lm(
        &mut self,
        corpus: &crate::data::lm::LmCorpus,
        steps: usize,
        mut log: impl FnMut(&StepLog),
    ) -> Result<()> {
        let bsz = self.batch_size();
        for i in 0..steps {
            let x = corpus.batch(i * bsz, bsz);
            self.train_step(&[x])?;
            log(self.history.last().unwrap());
        }
        Ok(())
    }

    /// Mean loss over the last `n` steps (resilience to step noise).
    pub fn recent_loss(&self, n: usize) -> f32 {
        let tail = &self.history[self.history.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|l| l.loss).sum::<f32>() / tail.len() as f32
    }
}
