//! Training driver: the Rust loop around a [`Backend`]'s train step.
//!
//! The loop is backend-generic: the PJRT engine executes one fused AOT
//! `train_step` graph (fwd + bwd through the Pallas custom VJPs + Adam),
//! while the native backend runs the pure-Rust interpreter in
//! [`crate::backend::grad`] — same contract, no artifacts. This module owns
//! everything around the step: data iteration, step counting, loss logging,
//! optimizer-state allocation, and GTZ checkpointing. By-design training is
//! just: load (or synthesize) the `led_rXX` init checkpoint, drive its train
//! graph.

pub mod checkpoint;

use anyhow::{anyhow, bail};

use crate::backend::native::synth_train_graph;
use crate::backend::{Backend, NativeBackend};
use crate::data::{batch, Dataset, Split};
use crate::runtime::{Engine, GraphSpec};
use crate::tensor::{Dtype, ParamStore, Tensor};
use crate::Result;

/// Loss history entry.
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    /// 1-based optimizer step number.
    pub step: usize,
    /// Training loss at this step.
    pub loss: f32,
    /// Wall time of the step, seconds.
    pub seconds: f64,
}

/// Training state for one (model, variant).
pub struct Trainer<'e> {
    backend: &'e dyn Backend,
    graph: GraphSpec,
    /// Current model parameters (updated in place every step).
    pub params: ParamStore,
    m: ParamStore,
    v: ParamStore,
    /// Optimizer steps taken so far.
    pub step: usize,
    /// Per-step loss/time log.
    pub history: Vec<StepLog>,
}

impl<'e> Trainer<'e> {
    /// Start from a checkpoint (a JAX-exported init, a random
    /// [`crate::backend::native::init_text_params`], or any trained store).
    ///
    /// The checkpoint must carry exactly the graph's declared trainable
    /// parameters: optimizer state (`m`/`v`) is allocated strictly from
    /// `graph.params`, every tensor is checked against its spec's shape and
    /// dtype, and entries the graph does not declare are an error — a
    /// misaligned store must fail loudly here, not train silently.
    pub fn new(
        backend: &'e dyn Backend,
        graph: &GraphSpec,
        mut params: ParamStore,
    ) -> Result<Self> {
        let mut ordered = ParamStore::new();
        let mut m = ParamStore::new();
        let mut v = ParamStore::new();
        for spec in &graph.params {
            let t = params.remove(&spec.name).ok_or_else(|| {
                anyhow!(
                    "trainable param {:?} required by graph {} is missing from the checkpoint",
                    spec.name,
                    graph.name
                )
            })?;
            if t.shape != spec.shape {
                bail!(
                    "trainable param {:?}: checkpoint shape {:?} does not match graph {} \
                     spec {:?}",
                    spec.name,
                    t.shape,
                    graph.name,
                    spec.shape
                );
            }
            if t.dtype() != spec.dtype()? {
                bail!(
                    "trainable param {:?}: checkpoint dtype does not match graph {} spec {:?}",
                    spec.name,
                    graph.name,
                    spec.dtype
                );
            }
            m.insert(spec.name.clone(), Tensor::zeros(&spec.shape, Dtype::F32));
            v.insert(spec.name.clone(), Tensor::zeros(&spec.shape, Dtype::F32));
            ordered.insert(spec.name.clone(), t);
        }
        if !params.is_empty() {
            bail!(
                "checkpoint entries not declared trainable by graph {}: {:?} \
                 (train the matching variant, or strip them first)",
                graph.name,
                params.names()
            );
        }
        Ok(Self {
            backend,
            graph: graph.clone(),
            params: ordered,
            m,
            v,
            step: 0,
            history: Vec::new(),
        })
    }

    /// Load the manifest's init checkpoint for (model, variant) and build a
    /// trainer on its AOT train graph (the PJRT path).
    pub fn from_init(engine: &'e Engine, model: &str, variant: &str) -> Result<Self> {
        let graph = engine.manifest().find(model, variant, "train", None)?.clone();
        let ckpt = engine.manifest().checkpoint(model, variant)?;
        let params = ParamStore::load_gtz(ckpt)?;
        Self::new(engine, &graph, params)
    }

    /// Build a trainer over a checkpoint on the native backend, synthesizing
    /// the train graph from the parameters themselves — fully artifact-free.
    ///
    /// The synthesized graph carries the model-zoo default head count
    /// (text = 4, lm = 6); a non-default count is not recoverable from the
    /// parameters, so construct the graph yourself (`synth_train_graph` +
    /// `config["heads"]` override, as `experiments::FigEnv` does) and use
    /// [`Trainer::new`] when you need one.
    pub fn native(
        backend: &'e NativeBackend,
        model: &str,
        variant: &str,
        batch: usize,
        params: ParamStore,
    ) -> Result<Self> {
        let graph = synth_train_graph(model, variant, batch, &params)?;
        Self::new(backend, &graph, params)
    }

    /// The train graph this trainer executes.
    pub fn graph(&self) -> &GraphSpec {
        &self.graph
    }

    /// The graph's static batch size.
    pub fn batch_size(&self) -> usize {
        self.graph.batch
    }

    /// One optimizer step on a batch; returns the loss.
    pub fn train_step(&mut self, batch: &[Tensor]) -> Result<f32> {
        self.step += 1;
        let t0 = std::time::Instant::now();
        let loss = self.backend.run_train_step(
            &self.graph,
            &mut self.params,
            &mut self.m,
            &mut self.v,
            self.step as f32,
            batch,
        )?;
        self.history.push(StepLog {
            step: self.step,
            loss,
            seconds: t0.elapsed().as_secs_f64(),
        });
        Ok(loss)
    }

    /// Train a classifier for `steps` over a dataset, streaming fresh
    /// synthetic batches (no epoch structure needed — infinite data).
    pub fn train_classifier(
        &mut self,
        ds: &dyn Dataset,
        steps: usize,
        image_hw: Option<(usize, usize, usize)>,
        mut log: impl FnMut(&StepLog),
    ) -> Result<()> {
        let bsz = self.batch_size();
        for i in 0..steps {
            let (x, y) = batch(ds, Split::Train, i * bsz, bsz, image_hw);
            self.train_step(&[x, y])?;
            log(self.history.last().unwrap());
        }
        Ok(())
    }

    /// Pretrain the causal LM on the ICL corpus (single-tensor batches).
    pub fn train_lm(
        &mut self,
        corpus: &crate::data::lm::LmCorpus,
        steps: usize,
        mut log: impl FnMut(&StepLog),
    ) -> Result<()> {
        let bsz = self.batch_size();
        for i in 0..steps {
            let x = corpus.batch(i * bsz, bsz);
            self.train_step(&[x])?;
            log(self.history.last().unwrap());
        }
        Ok(())
    }

    /// Mean loss over the last `n` steps (resilience to step noise).
    /// NaN when no steps have run yet.
    pub fn recent_loss(&self, n: usize) -> f32 {
        let tail = &self.history[self.history.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f32::NAN;
        }
        tail.iter().map(|l| l.loss).sum::<f32>() / tail.len() as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{init_text_params, TextModelCfg};
    use crate::data::text::PolarityTask;

    const BACKEND: NativeBackend = NativeBackend;

    fn tiny_cfg() -> TextModelCfg {
        TextModelCfg {
            vocab: 512,
            seq: 16,
            d: 16,
            heads: 2,
            layers: 1,
            ff: 32,
            classes: 4,
        }
    }

    fn tiny_trainer() -> Trainer<'static> {
        let params = init_text_params(&tiny_cfg(), 11);
        Trainer::native(&BACKEND, "text", "dense", 4, params).unwrap()
    }

    #[test]
    fn recent_loss_is_nan_with_no_history() {
        let t = tiny_trainer();
        assert!(t.recent_loss(5).is_nan());
        assert!(t.recent_loss(0).is_nan());
    }

    #[test]
    fn train_classifier_step_accounting() {
        let mut t = tiny_trainer();
        let ds = PolarityTask::new(16, 0);
        let mut seen = Vec::new();
        t.train_classifier(&ds, 3, None, |log| seen.push(log.step)).unwrap();
        assert_eq!(t.step, 3);
        assert_eq!(t.history.len(), 3);
        assert_eq!(seen, vec![1, 2, 3]);
        assert!(t.history.iter().all(|l| l.loss.is_finite()));
        assert!(!t.recent_loss(2).is_nan());
        // recent_loss(n > history) averages what exists.
        let all: f32 = t.history.iter().map(|l| l.loss).sum::<f32>() / 3.0;
        assert!((t.recent_loss(100) - all).abs() < 1e-6);
    }

    #[test]
    fn train_lm_step_accounting() {
        let cfg = TextModelCfg {
            vocab: 512,
            seq: 32,
            d: 12,
            heads: 6,
            layers: 1,
            ff: 24,
            classes: 512,
        };
        let params = init_text_params(&cfg, 12);
        let mut t = Trainer::native(&BACKEND, "lm", "dense", 2, params).unwrap();
        let corpus = crate::data::lm::LmCorpus::new(32, 0);
        t.train_lm(&corpus, 2, |_| {}).unwrap();
        assert_eq!(t.step, 2);
        assert_eq!(t.history.len(), 2);
        assert!(t.history.iter().all(|l| l.loss.is_finite() && l.loss > 0.0));
    }

    #[test]
    fn new_rejects_undeclared_checkpoint_entries() {
        let mut params = init_text_params(&tiny_cfg(), 13);
        let graph = synth_train_graph("text", "dense", 4, &params).unwrap();
        params.insert("rogue/buffer", Tensor::zeros(&[4], Dtype::F32));
        let err = Trainer::new(&BACKEND, &graph, params).unwrap_err().to_string();
        assert!(err.contains("rogue/buffer"), "{err}");
        assert!(err.contains("not declared trainable"), "{err}");
    }

    #[test]
    fn new_rejects_shape_mismatch() {
        let params = init_text_params(&tiny_cfg(), 14);
        let graph = synth_train_graph("text", "dense", 4, &params).unwrap();
        let mut bad = params.clone();
        bad.insert("head/bias", Tensor::zeros(&[7], Dtype::F32));
        let err = Trainer::new(&BACKEND, &graph, bad).unwrap_err().to_string();
        assert!(err.contains("head/bias"), "{err}");
        assert!(err.contains("shape"), "{err}");
        // Missing param errors clearly too.
        let mut missing = params.clone();
        missing.remove("ln_f/g");
        let err = Trainer::new(&BACKEND, &graph, missing).unwrap_err().to_string();
        assert!(err.contains("ln_f/g"), "{err}");
        assert!(err.contains("missing"), "{err}");
    }

    #[test]
    fn optimizer_state_matches_graph_params_exactly() {
        let t = tiny_trainer();
        assert_eq!(t.m.len(), t.graph.params.len());
        assert_eq!(t.v.len(), t.graph.params.len());
        assert_eq!(t.params.len(), t.graph.params.len());
        // Store order follows the graph's declared order.
        let want: Vec<&str> = t.graph.params.iter().map(|p| p.name.as_str()).collect();
        let got: Vec<&str> = t.params.names().iter().map(String::as_str).collect();
        assert_eq!(got, want);
    }
}
