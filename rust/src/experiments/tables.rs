//! Table harnesses: the cost-model table (E5) and the solver comparison (E6).

use crate::factorize::{auto_fact, rank_for, AutoFactConfig, Rank, Solver};
use crate::flops::{dense_linear_flops, led_linear_flops, roofline};
use crate::linalg::Matrix;
use crate::model::classify;
use crate::tensor::ParamStore;
use crate::util::Pcg64;
use crate::Result;

/// One row of the params/FLOPs/speedup table (E5).
#[derive(Clone, Debug)]
pub struct CostRow {
    /// Layer label (shape family).
    pub layer: String,
    /// Weight rows.
    pub m: usize,
    /// Weight cols.
    pub n: usize,
    /// Requested rank ratio.
    pub ratio: f64,
    /// Resolved rank (None = Eq.-1 gate rejected).
    pub rank: Option<usize>,
    /// Dense parameter count (m·n).
    pub dense_params: usize,
    /// Factorized parameter count (r·(m+n), or m·n when rejected).
    pub fact_params: usize,
    /// Theoretical FLOPs speedup of the factorization.
    pub flops_speedup: f64,
    /// MXU-utilization-discounted TPU estimate (DESIGN.md §4).
    pub tpu_speedup_est: f64,
    /// LED working-set VMEM estimate, bytes.
    pub vmem_bytes: usize,
}

/// Cost table over the canonical layer shapes (model-zoo linears plus the
/// BERT-base shapes the paper's audience expects).
pub fn cost_table(ratios: &[f64]) -> Vec<CostRow> {
    let shapes: &[(&str, usize, usize)] = &[
        ("text d->d (attn)", 128, 128),
        ("text d->ff", 128, 512),
        ("text ff->d", 512, 128),
        ("lm d->ff", 192, 768),
        ("lm head", 192, 512),
        ("bert-base attn", 768, 768),
        ("bert-base ffn", 768, 3072),
        ("conv2 (3x3x16->32)", 144, 32),
    ];
    let mut rows = Vec::new();
    for &(name, m, n) in shapes {
        for &ratio in ratios {
            let rank = rank_for(m, n, ratio);
            let fact_params = rank.map_or(m * n, |r| r * (m + n));
            rows.push(CostRow {
                layer: name.into(),
                m,
                n,
                ratio,
                rank,
                dense_params: m * n,
                fact_params,
                flops_speedup: rank.map_or(1.0, |r| {
                    dense_linear_flops(1, m, n) as f64 / led_linear_flops(1, m, n, r) as f64
                }),
                tpu_speedup_est: rank.map_or(1.0, |r| {
                    roofline::led_tpu_speedup_estimate(256, m, r, n)
                }),
                vmem_bytes: rank.map_or(0, |r| roofline::led_vmem_bytes(128, m, r, n, 4)),
            });
        }
    }
    rows
}

/// Render [`cost_table`] rows as the aligned text table the CLI prints.
pub fn render_cost_table(rows: &[CostRow]) -> String {
    let mut s = String::from(
        "layer                 m     n   ratio  rank  params(dense->fact)  flops-speedup  tpu-est  vmem(KiB)\n",
    );
    for r in rows {
        s.push_str(&format!(
            "{:<20} {:>5} {:>5}  {:>4.2}  {:>4}  {:>9} -> {:<9} {:>7.2}x  {:>6.2}x  {:>8.1}\n",
            r.layer,
            r.m,
            r.n,
            r.ratio,
            r.rank.map_or("--".into(), |x| x.to_string()),
            r.dense_params,
            r.fact_params,
            r.flops_speedup,
            r.tpu_speedup_est,
            r.vmem_bytes as f64 / 1024.0,
        ));
    }
    s
}

/// One row of the solver comparison (E6): reconstruction quality per solver
/// at a given ratio, on a trained-like (decaying-spectrum) weight matrix.
#[derive(Clone, Debug)]
pub struct SolverRow {
    /// Which solver produced the factors.
    pub solver: Solver,
    /// Requested rank ratio.
    pub ratio: f64,
    /// Resolved rank.
    pub rank: usize,
    /// ‖W − AB‖_F / ‖W‖_F.
    pub recon_error: f64,
    /// Solver wall-clock, seconds.
    pub seconds: f64,
}

/// Build a matrix with power-law singular values — the spectrum shape of
/// trained network weights (what makes post-training factorization viable).
pub fn trained_like_matrix(m: usize, n: usize, decay: f64, seed: u64) -> Matrix {
    let mut rng = Pcg64::new(seed, 40);
    let k = m.min(n);
    let u = Matrix::randn(m, k, 1.0, &mut rng);
    let (qu, _) = crate::linalg::thin_qr(&u);
    let v = Matrix::randn(n, k, 1.0, &mut rng);
    let (qv, _) = crate::linalg::thin_qr(&v);
    // Scale qu's columns by sigma_i = (i+1)^-decay.
    let mut us = qu;
    for j in 0..k {
        let s = ((j + 1) as f64).powf(-decay) as f32;
        for i in 0..m {
            *us.at_mut(i, j) *= s;
        }
    }
    us.matmul_nt(&qv)
}

/// E6: all three solvers across ratios on a trained-like 128×512 layer.
pub fn solver_table(ratios: &[f64], num_iter: usize) -> Vec<SolverRow> {
    let w = trained_like_matrix(128, 512, 1.0, 7);
    let mut rows = Vec::new();
    for &ratio in ratios {
        let Some(rank) = rank_for(w.rows, w.cols, ratio) else {
            continue;
        };
        for solver in [Solver::Random, Solver::Svd, Solver::Snmf] {
            let t0 = std::time::Instant::now();
            let (a, b) = solver.factorize(&w, rank, num_iter, 11);
            let seconds = t0.elapsed().as_secs_f64();
            let recon_error = w.sub(&a.matmul(&b)).fro_norm() / w.fro_norm();
            rows.push(SolverRow {
                solver,
                ratio,
                rank,
                recon_error,
                seconds,
            });
        }
    }
    rows
}

/// Render [`solver_table`] rows as the aligned text table the CLI prints.
pub fn render_solver_table(rows: &[SolverRow]) -> String {
    let mut s = String::from("solver   ratio  rank  recon-error  seconds\n");
    for r in rows {
        s.push_str(&format!(
            "{:<7} {:>5.2}  {:>4}  {:>10.4}  {:>7.4}\n",
            r.solver.to_string(),
            r.ratio,
            r.rank,
            r.recon_error,
            r.seconds
        ));
    }
    s
}

/// Convenience: auto_fact a checkpoint and summarize compression (used by
/// the CLI `report-cost` and the quickstart example).
pub fn compression_report(params: &ParamStore, ratio: f64, solver: Solver) -> Result<String> {
    let mut p = params.clone();
    let report = auto_fact(
        &mut p,
        &AutoFactConfig {
            rank: Rank::Ratio(ratio),
            solver,
            num_iter: 20,
            submodules: None,
            ..Default::default()
        },
    )?;
    let layers = classify(&p);
    let cost = crate::flops::summarize(&layers);
    Ok(format!(
        "{report}\nfactorized cost: {} weight params, {} flops/token\n",
        cost.weight_params, cost.flops_per_token
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_table_rows_consistent() {
        let rows = cost_table(&[0.25, 0.5]);
        assert!(!rows.is_empty());
        for r in &rows {
            if let Some(rank) = r.rank {
                assert_eq!(r.fact_params, rank * (r.m + r.n));
                assert!(r.fact_params < r.dense_params, "{:?}", r);
                assert!(r.flops_speedup > 1.0);
                assert!(r.vmem_bytes < roofline::VMEM_BUDGET);
            }
        }
        assert!(render_cost_table(&rows).contains("bert-base"));
    }

    #[test]
    fn trained_like_matrix_has_decaying_spectrum() {
        let w = trained_like_matrix(48, 32, 1.0, 3);
        let svd = crate::linalg::jacobi_svd(&w);
        // sigma_1/sigma_8 should be ~8 under decay=1.
        let ratio = svd.s[0] / svd.s[7];
        assert!(ratio > 4.0 && ratio < 16.0, "ratio={ratio}");
    }

    #[test]
    fn solver_table_orders_svd_best() {
        let rows = solver_table(&[0.5], 30);
        let err = |s: Solver| rows.iter().find(|r| r.solver == s).unwrap().recon_error;
        assert!(err(Solver::Svd) <= err(Solver::Snmf) + 1e-9);
        assert!(err(Solver::Snmf) < err(Solver::Random));
        assert!(err(Solver::Random) > 0.8, "random must not approximate");
        assert!(render_solver_table(&rows).contains("svd"));
    }
}
