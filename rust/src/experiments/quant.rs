//! Quantized-serving panel (DESIGN.md §12): accuracy-vs-speed across weight
//! precisions, in the style of a Figure-2 panel.
//!
//! Rank truncation (the paper's axis) trades accuracy for FLOPs; weight
//! quantization trades it for bytes — and the decode path is memory-bound,
//! so the two multiply. This harness pins the combined picture on the
//! native LM decode path: for each [`WeightPrecision`] it measures greedy
//! decode throughput, agreement of the greedy token streams with the f32
//! reference over seeded prompts, weight-storage compression, and (from the
//! [`crate::factorize::QuantReport`]) the propagated worst-case logit-error
//! bound.

use crate::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
use crate::backend::{generate_with_session, DecodeSession, NativeBackend, SamplingCfg};
use crate::eval::measure_decode_latency_prec;
use crate::factorize::{
    auto_fact, quantize_led_params, AutoFactConfig, Rank, Solver, WeightPrecision,
};
use crate::util::Pcg64;
use crate::Result;

/// RNG stream for the panel's prompt draws (shared with
/// `tests/proptest_quant.rs` so the two exercise the same prompt family).
const PROMPT_STREAM: u64 = 11;

/// Scale knobs for [`quant_panel`].
#[derive(Clone, Debug)]
pub struct QuantPanelCfg {
    /// LM dimensions (head width = vocab).
    pub lm: TextModelCfg,
    /// Rank ratio for the LED factorization pass (Eq. 1 gated).
    pub ratio: f64,
    /// Factorization solver.
    pub solver: Solver,
    /// Init / prompt seed.
    pub seed: u64,
    /// Seeded prompts per precision for the agreement measurement.
    pub prompts: usize,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Greedy tokens generated per prompt (also the latency step count).
    pub new_tokens: usize,
    /// Discarded warmup iterations per latency measurement.
    pub warmup: usize,
    /// Timed iterations per latency measurement.
    pub iters: usize,
}

impl Default for QuantPanelCfg {
    fn default() -> Self {
        Self {
            lm: TextModelCfg {
                vocab: 512,
                seq: 96,
                d: 96,
                heads: 6,
                layers: 2,
                ff: 384,
                classes: 512,
            },
            ratio: 0.5,
            solver: Solver::Svd,
            seed: 42,
            prompts: 8,
            prompt_len: 8,
            new_tokens: 24,
            warmup: 1,
            iters: 8,
        }
    }
}

impl QuantPanelCfg {
    /// Small preset for tests and the CI bench quick mode.
    pub fn quick() -> Self {
        Self {
            lm: TextModelCfg {
                vocab: 64,
                seq: 24,
                d: 48,
                heads: 4,
                layers: 1,
                ff: 96,
                classes: 64,
            },
            prompts: 4,
            prompt_len: 4,
            new_tokens: 8,
            warmup: 1,
            iters: 3,
            ..Self::default()
        }
    }
}

/// One precision's measurements.
#[derive(Clone, Debug)]
pub struct QuantPoint {
    /// Weight precision of this row.
    pub precision: WeightPrecision,
    /// Greedy decode throughput, tokens/sec.
    pub tokens_per_sec: f64,
    /// tokens_per_sec / the f32 row's tokens_per_sec.
    pub speedup: f64,
    /// Fraction of seeded prompts whose full greedy token stream equals the
    /// f32 stream (1.0 for f32 by construction).
    pub agreement: f64,
    /// Bytes of the (quantized) linear weights.
    pub bytes: usize,
    /// bytes / f32 bytes of the same weights (1.0 for f32).
    pub compression: f64,
    /// Propagated worst-case |Δlogit| bound (None for f32).
    pub logit_bound: Option<f64>,
}

/// The panel: one [`QuantPoint`] per precision over one factorized LM.
#[derive(Clone, Debug)]
pub struct QuantPanel {
    /// f32 / int8 / binary rows, in that order.
    pub points: Vec<QuantPoint>,
    /// Prompts per agreement measurement.
    pub prompts: usize,
    /// Greedy tokens per prompt.
    pub new_tokens: usize,
}

impl QuantPanel {
    /// Render as the aligned text table the CLI and bench print.
    pub fn render(&self) -> String {
        let mut s = format!(
            "== Quantized decode (agreement over {} prompts x {} greedy tokens) ==\n",
            self.prompts, self.new_tokens
        );
        s.push_str("precision  tok/s      speedup  agreement  bytes      compress  |dlogit| bound\n");
        for p in &self.points {
            s.push_str(&format!(
                "{:<9} {:>9.1}  {:>6.2}x  {:>8.2}  {:>9}  {:>7.3}  {}\n",
                p.precision.to_string(),
                p.tokens_per_sec,
                p.speedup,
                p.agreement,
                p.bytes,
                p.compression,
                p.logit_bound.map(|b| format!("{b:.3e}")).unwrap_or_else(|| "-".into()),
            ));
        }
        s
    }
}

/// Seeded prompt `i`: `prompt_len` tokens drawn from the panel's dedicated
/// RNG stream, reproducible across precisions and runs.
fn prompt_for(cfg: &QuantPanelCfg, i: usize) -> Vec<i32> {
    let mut rng = Pcg64::new(cfg.seed ^ i as u64, PROMPT_STREAM);
    (0..cfg.prompt_len).map(|_| rng.below(cfg.lm.vocab) as i32).collect()
}

/// Build the factorized LM once, then measure every precision against it.
pub fn quant_panel(cfg: &QuantPanelCfg) -> Result<QuantPanel> {
    let mut params = init_text_params(&cfg.lm, cfg.seed);
    auto_fact(
        &mut params,
        &AutoFactConfig {
            rank: Rank::Ratio(cfg.ratio),
            solver: cfg.solver,
            ..Default::default()
        },
    )?;
    let mut graph = synth_fwd_graph("lm", "led", 1, &params)?;
    // synth_fwd_graph pins the zoo-default head count; honor the cfg's.
    graph.config.insert("heads".to_string(), cfg.lm.heads);
    let backend = NativeBackend::new();
    let greedy = SamplingCfg::greedy();

    // f32 reference: token streams + throughput baseline.
    let mut f32_streams = Vec::with_capacity(cfg.prompts);
    for i in 0..cfg.prompts {
        let mut session = DecodeSession::new(&graph, &params)?;
        let out = generate_with_session(
            &backend,
            &graph,
            &params,
            &mut session,
            &prompt_for(cfg, i),
            cfg.new_tokens,
            &greedy,
            |_, _| {},
        )?;
        f32_streams.push(out.tokens);
    }
    let prompt0 = prompt_for(cfg, 0);
    let mut points = Vec::new();
    let mut f32_tps = 0.0;
    let mut bytes_f32 = 0usize;
    for precision in [WeightPrecision::F32, WeightPrecision::Int8, WeightPrecision::Binary] {
        let lat = measure_decode_latency_prec(
            &backend,
            &graph,
            &params,
            precision,
            &prompt0,
            cfg.new_tokens,
            cfg.warmup,
            cfg.iters,
        )?;
        // Agreement vs the f32 greedy streams (exact stream match).
        let agreement = if precision == WeightPrecision::F32 {
            1.0
        } else {
            let mut matches = 0usize;
            for (i, want) in f32_streams.iter().enumerate() {
                let mut session = DecodeSession::new_with_precision(&graph, &params, precision)?;
                let out = generate_with_session(
                    &backend,
                    &graph,
                    &params,
                    &mut session,
                    &prompt_for(cfg, i),
                    cfg.new_tokens,
                    &greedy,
                    |_, _| {},
                )?;
                if &out.tokens == want {
                    matches += 1;
                }
            }
            matches as f64 / cfg.prompts.max(1) as f64
        };
        // Int8's report also prices the f32 baseline bytes.
        let report = quantize_led_params(
            &params,
            if precision == WeightPrecision::F32 { WeightPrecision::Int8 } else { precision },
        )?
        .1;
        if precision == WeightPrecision::F32 {
            f32_tps = lat.tokens_per_sec;
            bytes_f32 = report.bytes_f32;
        }
        let bytes = if precision == WeightPrecision::F32 { bytes_f32 } else { report.bytes_quant };
        points.push(QuantPoint {
            precision,
            tokens_per_sec: lat.tokens_per_sec,
            speedup: lat.tokens_per_sec / f32_tps.max(1e-12),
            agreement,
            bytes,
            compression: bytes as f64 / bytes_f32.max(1) as f64,
            logit_bound: if precision == WeightPrecision::F32 { None } else { report.logit_bound },
        });
    }
    Ok(QuantPanel { points, prompts: cfg.prompts, new_tokens: cfg.new_tokens })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_panel_has_three_rows_and_f32_baseline() {
        let panel = quant_panel(&QuantPanelCfg::quick()).unwrap();
        assert_eq!(panel.points.len(), 3);
        let f32_row = &panel.points[0];
        assert_eq!(f32_row.precision, WeightPrecision::F32);
        assert_eq!(f32_row.agreement, 1.0);
        assert!((f32_row.speedup - 1.0).abs() < 1e-9);
        assert!((f32_row.compression - 1.0).abs() < 1e-9);
        assert!(f32_row.logit_bound.is_none());
        // int8 stores ~1/4 the bytes, binary fewer still.
        assert!(panel.points[1].compression < 0.5);
        assert!(panel.points[2].compression < panel.points[1].compression);
        assert!(panel.points[1].logit_bound.unwrap().is_finite());
        let text = panel.render();
        assert!(text.contains("int8") && text.contains("binary"));
    }
}
