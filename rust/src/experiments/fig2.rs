//! Figure 2 — the paper's central result, regenerated panel by panel.
//!
//! Each panel plots, against rank ratio, (a) relative performance vs the
//! uncompressed model averaged over tasks and (b) speedup ratio. The three
//! panels differ in *when* factorization happens:
//!
//! * left  (`by_design`)      — factorize at init, then train.
//! * center(`post_training`)  — train dense, factorize the checkpoint, eval.
//! * right (`icl`)            — pretrain an LM once, factorize, few-shot eval.

use std::collections::BTreeMap;

use crate::data::image::{all_image_tasks, HW};
use crate::data::lm::LmCorpus;
use crate::data::text::all_text_tasks;
use crate::data::{batch, Dataset, Split};
use crate::eval::{eval_classifier, eval_icl, measure_latency};
use crate::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use crate::runtime::Engine;
use crate::tensor::ParamStore;
use crate::train::Trainer;
use crate::Result;

use super::ExpParams;

/// One (task, variant) measurement.
#[derive(Clone, Debug)]
pub struct Fig2Point {
    pub task: String,
    pub variant: String,
    pub ratio: Option<f64>,
    pub accuracy: f64,
    /// accuracy / dense accuracy on the same task.
    pub rel_performance: f64,
    /// Median fwd latency, seconds.
    pub latency: f64,
    /// dense latency / this latency.
    pub speedup: f64,
    pub n_params: usize,
}

/// A panel: points plus the per-ratio averages the figure actually plots.
#[derive(Clone, Debug, Default)]
pub struct Fig2Result {
    pub use_case: String,
    pub points: Vec<Fig2Point>,
}

impl Fig2Result {
    /// (ratio-or-dense, mean rel-performance, mean speedup) rows, averaged
    /// across tasks — the purple and green lines of Figure 2.
    pub fn averaged(&self) -> Vec<(String, f64, f64)> {
        let mut groups: BTreeMap<String, Vec<&Fig2Point>> = BTreeMap::new();
        for p in &self.points {
            groups.entry(p.variant.clone()).or_default().push(p);
        }
        groups
            .into_iter()
            .map(|(v, ps)| {
                let n = ps.len() as f64;
                (
                    v,
                    ps.iter().map(|p| p.rel_performance).sum::<f64>() / n,
                    ps.iter().map(|p| p.speedup).sum::<f64>() / n,
                )
            })
            .collect()
    }

    pub fn render(&self) -> String {
        let mut s = format!("== Figure 2 ({}) ==\n", self.use_case);
        s.push_str("task         variant    acc    rel-perf  latency(ms)  speedup  params\n");
        for p in &self.points {
            s.push_str(&format!(
                "{:<12} {:<10} {:.3}  {:>7.3}   {:>9.2}   {:>6.2}x  {}\n",
                p.task,
                p.variant,
                p.accuracy,
                p.rel_performance,
                p.latency * 1e3,
                p.speedup,
                p.n_params
            ));
        }
        s.push_str("-- averaged across tasks --\n");
        for (v, perf, speed) in self.averaged() {
            s.push_str(&format!("{v:<12} rel-perf={perf:.3} speedup={speed:.2}x\n"));
        }
        s
    }
}

fn text_tasks(seed: u64) -> Vec<Box<dyn Dataset>> {
    all_text_tasks(64, seed)
}

fn latency_inputs(
    engine: &Engine,
    model: &str,
    variant: &str,
    ds: &dyn Dataset,
    image: bool,
    seed: u64,
) -> Result<(crate::runtime::GraphSpec, Vec<crate::tensor::Tensor>)> {
    // Latency is measured on the largest fwd batch (throughput-optimal
    // configuration, mirrors the paper's GPU batched timing).
    let graph = engine.manifest().find(model, variant, "fwd", None)?.clone();
    let hw = image.then_some((HW, HW, 1usize));
    let (x, _) = batch(ds, Split::Eval, 0, graph.batch, hw);
    let _ = seed;
    Ok((graph, vec![x]))
}

/// Panel 1: factorization-by-design over 3 text + 2 image tasks.
pub fn by_design(engine: &Engine, params: &ExpParams) -> Result<Fig2Result> {
    let mut result = Fig2Result {
        use_case: "by-design".into(),
        ..Default::default()
    };

    // (model, dataset, image?) tuples for all five tasks.
    let mut workloads: Vec<(&str, Box<dyn Dataset>, bool)> = Vec::new();
    for ds in text_tasks(params.seed) {
        workloads.push(("text", ds, false));
    }
    for ds in all_image_tasks(params.seed) {
        workloads.push(("image", ds, true));
    }

    for (model, ds, is_image) in &workloads {
        let hw = is_image.then_some((HW, HW, 1usize));
        let mut dense_acc = 0.0;
        let mut dense_latency = 0.0;
        let mut variants = vec!["dense".to_string()];
        variants.extend(params.ratios.iter().map(|&r| ExpParams::variant_for(r)));
        for variant in &variants {
            // Train from the exported init (random-init LED for by-design;
            // the init checkpoints were factorized at init by the exporter).
            let mut trainer = Trainer::from_init(engine, model, variant)?;
            trainer.train_classifier(ds.as_ref(), params.steps, hw, |_| {})?;
            let fwd = engine.manifest().find(model, variant, "fwd", None)?.clone();
            let ev = eval_classifier(
                engine,
                &fwd,
                &trainer.params,
                ds.as_ref(),
                params.eval_examples,
                hw,
            )?;
            let (lg, li) =
                latency_inputs(engine, model, variant, ds.as_ref(), *is_image, params.seed)?;
            let lat = measure_latency(engine, &lg, &trainer.params, &li, 2, params.latency_iters)?
                / lg.batch as f64;
            if variant == "dense" {
                dense_acc = ev.accuracy();
                dense_latency = lat;
            }
            result.points.push(Fig2Point {
                task: ds.name().to_string(),
                variant: variant.clone(),
                ratio: ratio_of(variant),
                accuracy: ev.accuracy(),
                rel_performance: ev.accuracy() / dense_acc.max(1e-9),
                latency: lat,
                speedup: dense_latency / lat.max(1e-12),
                n_params: trainer.params.n_params(),
            });
        }
    }
    Ok(result)
}

/// Panel 2: post-training factorization (train dense once per task, then
/// factorize the trained checkpoint at each ratio with `solver`).
pub fn post_training(engine: &Engine, params: &ExpParams, solver: Solver) -> Result<Fig2Result> {
    let mut result = Fig2Result {
        use_case: format!("post-training ({solver})"),
        ..Default::default()
    };

    let mut workloads: Vec<(&str, Box<dyn Dataset>, bool)> = Vec::new();
    for ds in text_tasks(params.seed) {
        workloads.push(("text", ds, false));
    }
    for ds in all_image_tasks(params.seed) {
        workloads.push(("image", ds, true));
    }

    for (model, ds, is_image) in &workloads {
        let hw = is_image.then_some((HW, HW, 1usize));
        // 1. Train the dense model.
        let mut trainer = Trainer::from_init(engine, model, "dense")?;
        trainer.train_classifier(ds.as_ref(), params.steps, hw, |_| {})?;
        let dense_params = trainer.params.clone();
        let fwd_dense = engine.manifest().find(model, "dense", "fwd", None)?.clone();
        let ev = eval_classifier(
            engine,
            &fwd_dense,
            &dense_params,
            ds.as_ref(),
            params.eval_examples,
            hw,
        )?;
        let dense_acc = ev.accuracy();
        let (lg, li) = latency_inputs(engine, model, "dense", ds.as_ref(), *is_image, params.seed)?;
        let dense_latency =
            measure_latency(engine, &lg, &dense_params, &li, 2, params.latency_iters)?
                / lg.batch as f64;
        result.points.push(Fig2Point {
            task: ds.name().to_string(),
            variant: "dense".into(),
            ratio: None,
            accuracy: dense_acc,
            rel_performance: 1.0,
            latency: dense_latency,
            speedup: 1.0,
            n_params: dense_params.n_params(),
        });

        // 2. Factorize the trained checkpoint at each ratio.
        for &ratio in &params.ratios {
            let variant = ExpParams::variant_for(ratio);
            let mut fact = dense_params.clone();
            auto_fact(
                &mut fact,
                &AutoFactConfig {
                    rank: Rank::Ratio(ratio),
                    solver,
                    num_iter: 50,
                    submodules: None,
                },
            )?;
            let fwd = engine.manifest().find(model, &variant, "fwd", None)?.clone();
            let ev = eval_classifier(engine, &fwd, &fact, ds.as_ref(), params.eval_examples, hw)?;
            let (lg, li) =
                latency_inputs(engine, model, &variant, ds.as_ref(), *is_image, params.seed)?;
            let lat = measure_latency(engine, &lg, &fact, &li, 2, params.latency_iters)?
                / lg.batch as f64;
            result.points.push(Fig2Point {
                task: ds.name().to_string(),
                variant,
                ratio: Some(ratio),
                accuracy: ev.accuracy(),
                rel_performance: ev.accuracy() / dense_acc.max(1e-9),
                latency: lat,
                speedup: dense_latency / lat.max(1e-12),
                n_params: fact.n_params(),
            });
        }
    }
    Ok(result)
}

/// Panel 3: in-context-learning factorization. Pretrains (or reuses) the LM,
/// factorizes it, and runs k-shot eval on the three text tasks.
///
/// Pass a pretrained `lm_params` to skip the expensive pretraining (the
/// `icl_serving` example and the bench share one pretrained checkpoint).
pub fn icl(
    engine: &Engine,
    params: &ExpParams,
    lm_params: Option<ParamStore>,
    pretrain_steps: usize,
) -> Result<Fig2Result> {
    let mut result = Fig2Result {
        use_case: "in-context learning".into(),
        ..Default::default()
    };

    // 1. Obtain the dense pretrained LM.
    let dense_params = match lm_params {
        Some(p) => p,
        None => {
            let mut trainer = Trainer::from_init(engine, "lm", "dense")?;
            let corpus = LmCorpus::new(128, params.seed);
            trainer.train_lm(&corpus, pretrain_steps, |_| {})?;
            trainer.params
        }
    };

    let tasks = text_tasks(params.seed);
    let fwd_dense = engine.manifest().find("lm", "dense", "fwd", None)?.clone();

    // Dense baseline per task.
    let mut dense_acc = BTreeMap::new();
    let mut dense_lat = 0.0;
    for ds in &tasks {
        let ev = eval_icl(
            engine,
            &fwd_dense,
            &dense_params,
            ds.as_ref(),
            params.k_shots,
            params.eval_examples,
            params.seed,
        )?;
        dense_acc.insert(ds.name().to_string(), ev.accuracy());
        dense_lat = ev.sec_per_batch / fwd_dense.batch as f64;
        result.points.push(Fig2Point {
            task: ds.name().to_string(),
            variant: "dense".into(),
            ratio: None,
            accuracy: ev.accuracy(),
            rel_performance: 1.0,
            latency: dense_lat,
            speedup: 1.0,
            n_params: dense_params.n_params(),
        });
    }

    // Factorized variants: SVD post-training factorization of the LM
    // (the paper's ICL use case applies factorization to the pretrained
    // model; Random would destroy it — see table_solvers).
    for &ratio in &params.ratios {
        let variant = ExpParams::variant_for(ratio);
        let mut fact = dense_params.clone();
        auto_fact(
            &mut fact,
            &AutoFactConfig {
                rank: Rank::Ratio(ratio),
                solver: Solver::Svd,
                num_iter: 50,
                submodules: None,
            },
        )?;
        let fwd = engine.manifest().find("lm", &variant, "fwd", None)?.clone();
        for ds in &tasks {
            let ev = eval_icl(
                engine,
                &fwd,
                &fact,
                ds.as_ref(),
                params.k_shots,
                params.eval_examples,
                params.seed,
            )?;
            let lat = ev.sec_per_batch / fwd.batch as f64;
            result.points.push(Fig2Point {
                task: ds.name().to_string(),
                variant: variant.clone(),
                ratio: Some(ratio),
                accuracy: ev.accuracy(),
                rel_performance: ev.accuracy() / dense_acc[ds.name()].max(1e-9),
                latency: lat,
                speedup: dense_lat / lat.max(1e-12),
                n_params: fact.n_params(),
            });
        }
    }
    Ok(result)
}

fn ratio_of(variant: &str) -> Option<f64> {
    variant
        .strip_prefix("led_r")
        .and_then(|s| s.parse::<f64>().ok())
        .map(|p| p / 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averaged_groups_by_variant() {
        let r = Fig2Result {
            use_case: "t".into(),
            points: vec![
                Fig2Point {
                    task: "a".into(),
                    variant: "dense".into(),
                    ratio: None,
                    accuracy: 0.9,
                    rel_performance: 1.0,
                    latency: 0.01,
                    speedup: 1.0,
                    n_params: 10,
                },
                Fig2Point {
                    task: "b".into(),
                    variant: "dense".into(),
                    ratio: None,
                    accuracy: 0.8,
                    rel_performance: 1.0,
                    latency: 0.01,
                    speedup: 1.0,
                    n_params: 10,
                },
                Fig2Point {
                    task: "a".into(),
                    variant: "led_r25".into(),
                    ratio: Some(0.25),
                    accuracy: 0.81,
                    rel_performance: 0.9,
                    latency: 0.005,
                    speedup: 2.0,
                    n_params: 5,
                },
            ],
        };
        let avg = r.averaged();
        assert_eq!(avg.len(), 2);
        let dense = avg.iter().find(|(v, _, _)| v == "dense").unwrap();
        assert!((dense.1 - 1.0).abs() < 1e-12);
        let led = avg.iter().find(|(v, _, _)| v == "led_r25").unwrap();
        assert!((led.2 - 2.0).abs() < 1e-12);
        assert!(r.render().contains("led_r25"));
    }

    #[test]
    fn ratio_parse() {
        assert_eq!(ratio_of("led_r25"), Some(0.25));
        assert_eq!(ratio_of("dense"), None);
    }
}
