//! Figure 2 — the paper's central result, regenerated panel by panel.
//!
//! Each panel plots, against rank ratio, (a) relative performance vs the
//! uncompressed model averaged over tasks and (b) speedup ratio. The three
//! panels differ in *when* factorization happens:
//!
//! * left  (`by_design`)      — factorize at init, then train.
//! * center(`post_training`)  — train dense, factorize the checkpoint, eval.
//! * right (`icl`)            — pretrain an LM once, factorize, few-shot eval.
//!
//! The harnesses are backend-generic through [`FigEnv`]: the PJRT
//! environment trains/evals the AOT graphs from the manifest, while the
//! native environment synthesizes graphs and random inits on the pure-Rust
//! interpreter — so every panel runs end-to-end on a fresh checkout with no
//! artifacts (`fig2 --backend native`). Use small step budgets there: the
//! interpreter is an order of magnitude slower than compiled XLA.

use std::collections::BTreeMap;

use anyhow::bail;

use crate::backend::native::{
    init_image_params, init_text_params, synth_fwd_graph, synth_train_graph, ImageModelCfg,
    TextModelCfg,
};
use crate::backend::{Backend, NativeBackend};
use crate::data::image::{all_image_tasks, HW};
use crate::data::lm::LmCorpus;
use crate::data::text::all_text_tasks;
use crate::data::{batch, Dataset, Split};
use crate::eval::{eval_classifier, eval_icl, measure_latency};
use crate::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use crate::runtime::{Engine, GraphSpec};
use crate::tensor::ParamStore;
use crate::train::Trainer;
use crate::Result;

use super::ExpParams;

const NATIVE: NativeBackend = NativeBackend;

/// Model-zoo configuration for the artifact-free environment. Text and
/// image default to the AOT zoo dimensions; the LM is deliberately smaller
/// than the zoo's (d=192, 4 layers) because the native interpreter pretrains
/// it from scratch — the full-scale ICL panel stays a PJRT workload
/// (DESIGN.md §9).
#[derive(Clone, Copy, Debug)]
pub struct NativeFigCfg {
    /// Text-classifier dims.
    pub text: TextModelCfg,
    /// CNN-classifier dims.
    pub image: ImageModelCfg,
    /// Causal-LM dims (head width = vocab).
    pub lm: TextModelCfg,
    /// Train and eval batch size for the synthesized graphs.
    pub batch: usize,
    /// Init seed (per-model streams are derived from it).
    pub seed: u64,
    /// Solver for factorization-at-init (by-design variants).
    pub solver: Solver,
}

impl Default for NativeFigCfg {
    fn default() -> Self {
        Self {
            text: TextModelCfg::default(),
            image: ImageModelCfg::default(),
            lm: TextModelCfg {
                vocab: 512,
                seq: 128,
                d: 96,
                heads: 6,
                layers: 2,
                ff: 384,
                classes: 512, // head width = vocab for the LM
            },
            batch: 8,
            seed: 42,
            solver: Solver::Svd,
        }
    }
}

impl NativeFigCfg {
    /// Init checkpoint for (model, variant): random dense init, factorized
    /// at init for `led_rXX` variants (factorization-by-design). Layers the
    /// Eq.-1 gate rejects stay dense — same policy as the AOT exporter.
    fn init_params(&self, model: &str, variant: &str) -> Result<ParamStore> {
        let mut params = match model {
            "text" => init_text_params(&self.text, self.seed),
            "lm" => init_text_params(&self.lm, self.seed ^ 0x4c4d),
            "image" => {
                // Text seq is configurable (tasks generate at any length via
                // task_seq), but the image tasks render at a fixed size.
                if self.image.hw != HW {
                    bail!(
                        "native fig2 env: image tasks are generated at the fixed {HW}x{HW}; \
                         cfg.image.hw = {} cannot match them",
                        self.image.hw
                    );
                }
                init_image_params(&self.image, self.seed ^ 0x494d47)
            }
            other => bail!("native fig2 env has no model {other:?}"),
        };
        if variant == "dense" {
            return Ok(params);
        }
        let Some(ratio) = ratio_of(variant) else {
            bail!("cannot derive a rank ratio from variant {variant:?}");
        };
        auto_fact(
            &mut params,
            &AutoFactConfig {
                rank: Rank::Ratio(ratio),
                solver: self.solver,
                num_iter: 50,
                submodules: None,
                ..Default::default()
            },
        )?;
        Ok(params)
    }

    /// Synthesized graphs default `config["heads"]` to the model-zoo value
    /// (it is not recoverable from the parameters); stamp this env's actual
    /// head count so non-default `TextModelCfg::heads` are honored.
    fn override_heads(&self, model: &str, graph: &mut GraphSpec) {
        let heads = match model {
            "text" => Some(self.text.heads),
            "lm" => Some(self.lm.heads),
            _ => None,
        };
        if let Some(h) = heads {
            graph.config.insert("heads".to_string(), h);
        }
    }
}

/// Where a Figure-2 harness gets graphs, init checkpoints and execution.
pub enum FigEnv<'a> {
    /// AOT manifest + PJRT engine (compiled graphs, exported inits).
    Pjrt(&'a Engine),
    /// Hermetic: synthesized graphs + random inits on the native backend.
    Native(NativeFigCfg),
}

impl FigEnv<'_> {
    /// The executor this environment runs on.
    pub fn backend(&self) -> &dyn Backend {
        match self {
            FigEnv::Pjrt(engine) => *engine,
            FigEnv::Native(_) => &NATIVE,
        }
    }

    /// A trainer over the (model, variant) init checkpoint.
    pub fn trainer(&self, model: &str, variant: &str) -> Result<Trainer<'_>> {
        match self {
            FigEnv::Pjrt(engine) => Trainer::from_init(engine, model, variant),
            FigEnv::Native(cfg) => {
                let params = cfg.init_params(model, variant)?;
                let mut graph = synth_train_graph(model, variant, cfg.batch, &params)?;
                cfg.override_heads(model, &mut graph);
                Trainer::new(&NATIVE, &graph, params)
            }
        }
    }

    /// The fwd graph a checkpoint evaluates through. PJRT reads the
    /// manifest; native synthesizes the spec from the parameters (which is
    /// what lets post-training factorized stores — whose shapes the manifest
    /// never saw — evaluate immediately).
    pub fn fwd_graph(&self, model: &str, variant: &str, params: &ParamStore) -> Result<GraphSpec> {
        match self {
            FigEnv::Pjrt(engine) => {
                Ok(engine.manifest().find(model, variant, "fwd", None)?.clone())
            }
            FigEnv::Native(cfg) => {
                let mut graph = synth_fwd_graph(model, variant, cfg.batch, params)?;
                cfg.override_heads(model, &mut graph);
                Ok(graph)
            }
        }
    }

    /// Sequence length the text-task generators must run at: the text
    /// model's context (the AOT zoo is lowered at 64; the native env reads
    /// its configured `text.seq`, so shrunken-interpreter configs work).
    fn task_seq(&self) -> usize {
        match self {
            FigEnv::Pjrt(_) => 64,
            FigEnv::Native(cfg) => cfg.text.seq,
        }
    }
}

/// One (task, variant) measurement.
#[derive(Clone, Debug)]
pub struct Fig2Point {
    /// Task name.
    pub task: String,
    /// Variant name (`dense` or `led_rXX`).
    pub variant: String,
    /// Rank ratio (None for dense).
    pub ratio: Option<f64>,
    /// Held-out accuracy.
    pub accuracy: f64,
    /// accuracy / dense accuracy on the same task.
    pub rel_performance: f64,
    /// Median fwd latency, seconds.
    pub latency: f64,
    /// dense latency / this latency.
    pub speedup: f64,
    /// Total parameter count of the measured checkpoint.
    pub n_params: usize,
}

/// A panel: points plus the per-ratio averages the figure actually plots.
#[derive(Clone, Debug, Default)]
pub struct Fig2Result {
    /// Which panel (`by-design` / `post-training` / `icl`).
    pub use_case: String,
    /// All measured (task, variant) points.
    pub points: Vec<Fig2Point>,
}

impl Fig2Result {
    /// (ratio-or-dense, mean rel-performance, mean speedup) rows, averaged
    /// across tasks — the purple and green lines of Figure 2.
    pub fn averaged(&self) -> Vec<(String, f64, f64)> {
        let mut groups: BTreeMap<String, Vec<&Fig2Point>> = BTreeMap::new();
        for p in &self.points {
            groups.entry(p.variant.clone()).or_default().push(p);
        }
        groups
            .into_iter()
            .map(|(v, ps)| {
                let n = ps.len() as f64;
                (
                    v,
                    ps.iter().map(|p| p.rel_performance).sum::<f64>() / n,
                    ps.iter().map(|p| p.speedup).sum::<f64>() / n,
                )
            })
            .collect()
    }

    /// Render the panel as the aligned text table the CLI prints.
    pub fn render(&self) -> String {
        let mut s = format!("== Figure 2 ({}) ==\n", self.use_case);
        s.push_str("task         variant    acc    rel-perf  latency(ms)  speedup  params\n");
        for p in &self.points {
            s.push_str(&format!(
                "{:<12} {:<10} {:.3}  {:>7.3}   {:>9.2}   {:>6.2}x  {}\n",
                p.task,
                p.variant,
                p.accuracy,
                p.rel_performance,
                p.latency * 1e3,
                p.speedup,
                p.n_params
            ));
        }
        s.push_str("-- averaged across tasks --\n");
        for (v, perf, speed) in self.averaged() {
            s.push_str(&format!("{v:<12} rel-perf={perf:.3} speedup={speed:.2}x\n"));
        }
        s
    }
}

fn text_tasks(env: &FigEnv, seed: u64) -> Vec<Box<dyn Dataset>> {
    all_text_tasks(env.task_seq(), seed)
}

/// Latency measurement inputs: the fwd graph plus one batch-shaped input
/// (throughput-optimal configuration, mirrors the paper's batched timing).
fn latency_inputs(
    env: &FigEnv,
    model: &str,
    variant: &str,
    store: &ParamStore,
    ds: &dyn Dataset,
    image: bool,
) -> Result<(GraphSpec, Vec<crate::tensor::Tensor>)> {
    let graph = env.fwd_graph(model, variant, store)?;
    let hw = image.then_some((HW, HW, 1usize));
    let (x, _) = batch(ds, Split::Eval, 0, graph.batch, hw);
    Ok((graph, vec![x]))
}

/// Panel 1: factorization-by-design over 3 text + 2 image tasks.
pub fn by_design(env: &FigEnv, params: &ExpParams) -> Result<Fig2Result> {
    let mut result = Fig2Result {
        use_case: "by-design".into(),
        ..Default::default()
    };

    // (model, dataset, image?) tuples for all five tasks.
    let mut workloads: Vec<(&str, Box<dyn Dataset>, bool)> = Vec::new();
    for ds in text_tasks(env, params.seed) {
        workloads.push(("text", ds, false));
    }
    for ds in all_image_tasks(params.seed) {
        workloads.push(("image", ds, true));
    }

    for (model, ds, is_image) in &workloads {
        let hw = is_image.then_some((HW, HW, 1usize));
        let mut dense_acc = 0.0;
        let mut dense_latency = 0.0;
        let mut variants = vec!["dense".to_string()];
        variants.extend(params.ratios.iter().map(|&r| ExpParams::variant_for(r)));
        for variant in &variants {
            // Train from the init (random-init LED for by-design; the init
            // checkpoints were factorized at init).
            let mut trainer = env.trainer(model, variant)?;
            trainer.train_classifier(ds.as_ref(), params.steps, hw, |_| {})?;
            let fwd = env.fwd_graph(model, variant, &trainer.params)?;
            let ev = eval_classifier(
                env.backend(),
                &fwd,
                &trainer.params,
                ds.as_ref(),
                params.eval_examples,
                hw,
            )?;
            let (lg, li) =
                latency_inputs(env, model, variant, &trainer.params, ds.as_ref(), *is_image)?;
            let lat =
                measure_latency(env.backend(), &lg, &trainer.params, &li, 2, params.latency_iters)?
                    / lg.batch as f64;
            if variant == "dense" {
                dense_acc = ev.accuracy();
                dense_latency = lat;
            }
            result.points.push(Fig2Point {
                task: ds.name().to_string(),
                variant: variant.clone(),
                ratio: ratio_of(variant),
                accuracy: ev.accuracy(),
                rel_performance: ev.accuracy() / dense_acc.max(1e-9),
                latency: lat,
                speedup: dense_latency / lat.max(1e-12),
                n_params: trainer.params.n_params(),
            });
        }
    }
    Ok(result)
}

/// Panel 2: post-training factorization (train dense once per task, then
/// factorize the trained checkpoint at each ratio with `solver`).
pub fn post_training(env: &FigEnv, params: &ExpParams, solver: Solver) -> Result<Fig2Result> {
    let mut result = Fig2Result {
        use_case: format!("post-training ({solver})"),
        ..Default::default()
    };

    let mut workloads: Vec<(&str, Box<dyn Dataset>, bool)> = Vec::new();
    for ds in text_tasks(env, params.seed) {
        workloads.push(("text", ds, false));
    }
    for ds in all_image_tasks(params.seed) {
        workloads.push(("image", ds, true));
    }

    for (model, ds, is_image) in &workloads {
        let hw = is_image.then_some((HW, HW, 1usize));
        // 1. Train the dense model.
        let mut trainer = env.trainer(model, "dense")?;
        trainer.train_classifier(ds.as_ref(), params.steps, hw, |_| {})?;
        let dense_params = trainer.params.clone();
        let fwd_dense = env.fwd_graph(model, "dense", &dense_params)?;
        let ev = eval_classifier(
            env.backend(),
            &fwd_dense,
            &dense_params,
            ds.as_ref(),
            params.eval_examples,
            hw,
        )?;
        let dense_acc = ev.accuracy();
        let (lg, li) = latency_inputs(env, model, "dense", &dense_params, ds.as_ref(), *is_image)?;
        let dense_latency =
            measure_latency(env.backend(), &lg, &dense_params, &li, 2, params.latency_iters)?
                / lg.batch as f64;
        result.points.push(Fig2Point {
            task: ds.name().to_string(),
            variant: "dense".into(),
            ratio: None,
            accuracy: dense_acc,
            rel_performance: 1.0,
            latency: dense_latency,
            speedup: 1.0,
            n_params: dense_params.n_params(),
        });

        // 2. Factorize the trained checkpoint at each ratio.
        for &ratio in &params.ratios {
            let variant = ExpParams::variant_for(ratio);
            let mut fact = dense_params.clone();
            auto_fact(
                &mut fact,
                &AutoFactConfig {
                    rank: Rank::Ratio(ratio),
                    solver,
                    num_iter: 50,
                    submodules: None,
                    ..Default::default()
                },
            )?;
            let fwd = env.fwd_graph(model, &variant, &fact)?;
            let ev = eval_classifier(
                env.backend(),
                &fwd,
                &fact,
                ds.as_ref(),
                params.eval_examples,
                hw,
            )?;
            let (lg, li) = latency_inputs(env, model, &variant, &fact, ds.as_ref(), *is_image)?;
            let lat = measure_latency(env.backend(), &lg, &fact, &li, 2, params.latency_iters)?
                / lg.batch as f64;
            result.points.push(Fig2Point {
                task: ds.name().to_string(),
                variant,
                ratio: Some(ratio),
                accuracy: ev.accuracy(),
                rel_performance: ev.accuracy() / dense_acc.max(1e-9),
                latency: lat,
                speedup: dense_latency / lat.max(1e-12),
                n_params: fact.n_params(),
            });
        }
    }
    Ok(result)
}

/// Panel 3: in-context-learning factorization. Pretrains (or reuses) the LM,
/// factorizes it, and runs k-shot eval on the three text tasks.
///
/// Pass a pretrained `lm_params` to skip the expensive pretraining (the
/// `icl_serving` example and the bench share one pretrained checkpoint).
pub fn icl(
    env: &FigEnv,
    params: &ExpParams,
    lm_params: Option<ParamStore>,
    pretrain_steps: usize,
) -> Result<Fig2Result> {
    let mut result = Fig2Result {
        use_case: "in-context learning".into(),
        ..Default::default()
    };

    // 1. Obtain the dense pretrained LM.
    let dense_params = match lm_params {
        Some(p) => p,
        None => {
            let mut trainer = env.trainer("lm", "dense")?;
            let corpus = LmCorpus::new(trainer.graph().inputs[0].shape[1], params.seed);
            trainer.train_lm(&corpus, pretrain_steps, |_| {})?;
            trainer.params
        }
    };

    let tasks = text_tasks(env, params.seed);
    let fwd_dense = env.fwd_graph("lm", "dense", &dense_params)?;

    // Dense baseline per task.
    let mut dense_acc = BTreeMap::new();
    let mut dense_lat = 0.0;
    for ds in &tasks {
        let ev = eval_icl(
            env.backend(),
            &fwd_dense,
            &dense_params,
            ds.as_ref(),
            params.k_shots,
            params.eval_examples,
            params.seed,
        )?;
        dense_acc.insert(ds.name().to_string(), ev.accuracy());
        dense_lat = ev.sec_per_batch / fwd_dense.batch as f64;
        result.points.push(Fig2Point {
            task: ds.name().to_string(),
            variant: "dense".into(),
            ratio: None,
            accuracy: ev.accuracy(),
            rel_performance: 1.0,
            latency: dense_lat,
            speedup: 1.0,
            n_params: dense_params.n_params(),
        });
    }

    // Factorized variants: SVD post-training factorization of the LM
    // (the paper's ICL use case applies factorization to the pretrained
    // model; Random would destroy it — see table_solvers).
    for &ratio in &params.ratios {
        let variant = ExpParams::variant_for(ratio);
        let mut fact = dense_params.clone();
        auto_fact(
            &mut fact,
            &AutoFactConfig {
                rank: Rank::Ratio(ratio),
                solver: Solver::Svd,
                num_iter: 50,
                submodules: None,
                ..Default::default()
            },
        )?;
        let fwd = env.fwd_graph("lm", &variant, &fact)?;
        for ds in &tasks {
            let ev = eval_icl(
                env.backend(),
                &fwd,
                &fact,
                ds.as_ref(),
                params.k_shots,
                params.eval_examples,
                params.seed,
            )?;
            let lat = ev.sec_per_batch / fwd.batch as f64;
            result.points.push(Fig2Point {
                task: ds.name().to_string(),
                variant: variant.clone(),
                ratio: Some(ratio),
                accuracy: ev.accuracy(),
                rel_performance: ev.accuracy() / dense_acc[ds.name()].max(1e-9),
                latency: lat,
                speedup: dense_lat / lat.max(1e-12),
                n_params: fact.n_params(),
            });
        }
    }
    Ok(result)
}

fn ratio_of(variant: &str) -> Option<f64> {
    variant
        .strip_prefix("led_r")
        .and_then(|s| s.parse::<f64>().ok())
        .map(|p| p / 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn averaged_groups_by_variant() {
        let r = Fig2Result {
            use_case: "t".into(),
            points: vec![
                Fig2Point {
                    task: "a".into(),
                    variant: "dense".into(),
                    ratio: None,
                    accuracy: 0.9,
                    rel_performance: 1.0,
                    latency: 0.01,
                    speedup: 1.0,
                    n_params: 10,
                },
                Fig2Point {
                    task: "b".into(),
                    variant: "dense".into(),
                    ratio: None,
                    accuracy: 0.8,
                    rel_performance: 1.0,
                    latency: 0.01,
                    speedup: 1.0,
                    n_params: 10,
                },
                Fig2Point {
                    task: "a".into(),
                    variant: "led_r25".into(),
                    ratio: Some(0.25),
                    accuracy: 0.81,
                    rel_performance: 0.9,
                    latency: 0.005,
                    speedup: 2.0,
                    n_params: 5,
                },
            ],
        };
        let avg = r.averaged();
        assert_eq!(avg.len(), 2);
        let dense = avg.iter().find(|(v, _, _)| v == "dense").unwrap();
        assert!((dense.1 - 1.0).abs() < 1e-12);
        let led = avg.iter().find(|(v, _, _)| v == "led_r25").unwrap();
        assert!((led.2 - 2.0).abs() < 1e-12);
        assert!(r.render().contains("led_r25"));
    }

    #[test]
    fn ratio_parse() {
        assert_eq!(ratio_of("led_r25"), Some(0.25));
        assert_eq!(ratio_of("dense"), None);
    }

    #[test]
    fn native_env_builds_by_design_inits() {
        let cfg = NativeFigCfg {
            text: TextModelCfg {
                vocab: 64,
                seq: 12,
                d: 32,
                heads: 4,
                layers: 1,
                ff: 64,
                classes: 3,
            },
            solver: Solver::Random, // instant (shapes are what this pins)
            ..Default::default()
        };
        let dense = cfg.init_params("text", "dense").unwrap();
        let led = cfg.init_params("text", "led_r50").unwrap();
        assert!(led.n_params() < dense.n_params());
        assert!(led.get("block0/fc1/a").is_some());
        assert!(cfg.init_params("text", "weird").is_err());
        assert!(cfg.init_params("vision", "dense").is_err());
    }

    #[test]
    fn native_env_trainer_and_fwd_graph_agree_on_batch() {
        let cfg = NativeFigCfg {
            text: TextModelCfg {
                vocab: 64,
                seq: 12,
                d: 16,
                heads: 4,
                layers: 1,
                ff: 32,
                classes: 3,
            },
            batch: 4,
            ..Default::default()
        };
        let env = FigEnv::Native(cfg);
        let trainer = env.trainer("text", "dense").unwrap();
        assert_eq!(trainer.batch_size(), 4);
        assert_eq!(trainer.graph().kind, "train");
        let g = env.fwd_graph("text", "dense", &trainer.params).unwrap();
        assert_eq!(g.batch, 4);
        assert_eq!(g.kind, "fwd");
    }

    #[test]
    fn native_env_honors_non_default_head_count() {
        // synth_*_graph defaults heads to the zoo value (4 for text); the
        // env must stamp its cfg's actual count onto both graph kinds.
        let cfg = NativeFigCfg {
            text: TextModelCfg {
                vocab: 64,
                seq: 12,
                d: 16,
                heads: 8,
                layers: 1,
                ff: 32,
                classes: 3,
            },
            batch: 2,
            ..Default::default()
        };
        let env = FigEnv::Native(cfg);
        let trainer = env.trainer("text", "dense").unwrap();
        assert_eq!(trainer.graph().config["heads"], 8);
        let g = env.fwd_graph("text", "dense", &trainer.params).unwrap();
        assert_eq!(g.config["heads"], 8);
    }
}
