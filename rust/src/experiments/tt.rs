//! TT-factorization panel (DESIGN.md §13): dense vs LED vs TT serving on a
//! Kronecker-structured LM, in the style of a Figure-2 panel.
//!
//! Rank truncation compresses layers whose *flat* spectrum is concentrated;
//! the TT family compresses layers whose weight is (near-)separable across
//! factorized mode dims — kron(A, B) is exactly TT-rank-1 while its flat
//! spectrum is full-rank, so LED's Eq.-1 gate can never win on it. This
//! harness builds an LM whose linear weights carry that structure, runs
//! `auto_fact` with the LED and TT solvers against the same checkpoint, and
//! measures greedy decode throughput, agreement with the dense token
//! streams, and serialized weight bytes per variant
//! (`benches/native_tt.rs` prints the `BENCH_TT` line from it).

use crate::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
use crate::backend::{generate_with_session, DecodeSession, NativeBackend, SamplingCfg};
use crate::eval::measure_decode_latency;
use crate::factorize::tt::mode_dims;
use crate::factorize::{auto_fact, AutoFactConfig, Rank, Solver, TtConfig};
use crate::linalg::Matrix;
use crate::model::classify;
use crate::tensor::ParamStore;
use crate::util::Pcg64;
use crate::Result;

/// RNG stream for the panel's prompt draws.
const PROMPT_STREAM: u64 = 13;

/// RNG stream for the Kronecker weight factors.
const KRON_STREAM: u64 = 14;

/// The panel factors every linear over two modes — matching the two-factor
/// Kronecker structure the builder plants.
const PANEL_MODES: usize = 2;

/// Scale knobs for [`tt_panel`].
#[derive(Clone, Debug)]
pub struct TtPanelCfg {
    /// LM dimensions (head width = vocab). Pick dims with balanced
    /// two-mode factorizations (powers of two work best).
    pub lm: TextModelCfg,
    /// Retained energy τ for the TT sweep (and the chooser's LED budget).
    pub energy: f64,
    /// Rank ratio for the LED comparison row.
    pub led_ratio: f64,
    /// Init / prompt seed.
    pub seed: u64,
    /// Seeded prompts per variant for the agreement measurement.
    pub prompts: usize,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Greedy tokens generated per prompt (also the latency step count).
    pub new_tokens: usize,
    /// Discarded warmup iterations per latency measurement.
    pub warmup: usize,
    /// Timed iterations per latency measurement.
    pub iters: usize,
}

impl Default for TtPanelCfg {
    fn default() -> Self {
        Self {
            lm: TextModelCfg {
                vocab: 512,
                seq: 96,
                d: 128,
                heads: 8,
                layers: 2,
                ff: 512,
                classes: 512,
            },
            energy: 0.99,
            led_ratio: 0.5,
            seed: 42,
            prompts: 8,
            prompt_len: 8,
            new_tokens: 24,
            warmup: 1,
            iters: 8,
        }
    }
}

impl TtPanelCfg {
    /// Small preset for tests and the CI bench quick mode.
    pub fn quick() -> Self {
        Self {
            lm: TextModelCfg {
                vocab: 64,
                seq: 24,
                d: 32,
                heads: 4,
                layers: 1,
                ff: 64,
                classes: 64,
            },
            prompts: 4,
            prompt_len: 4,
            new_tokens: 8,
            warmup: 1,
            iters: 3,
            ..Self::default()
        }
    }
}

/// One variant's measurements.
#[derive(Clone, Debug)]
pub struct TtPoint {
    /// Row label: `dense`, `led_rNN`, or `tt`.
    pub variant: String,
    /// Greedy decode throughput, tokens/sec.
    pub tokens_per_sec: f64,
    /// tokens_per_sec / the dense row's tokens_per_sec.
    pub speedup: f64,
    /// Fraction of seeded prompts whose full greedy token stream equals the
    /// dense stream (1.0 for dense by construction).
    pub agreement: f64,
    /// Serialized checkpoint bytes (f32).
    pub bytes: usize,
    /// bytes / dense bytes (1.0 for dense).
    pub compression: f64,
}

/// The panel: one [`TtPoint`] per variant over one structured LM.
#[derive(Clone, Debug)]
pub struct TtPanel {
    /// dense / led / tt rows, in that order.
    pub points: Vec<TtPoint>,
    /// Prompts per agreement measurement.
    pub prompts: usize,
    /// Greedy tokens per prompt.
    pub new_tokens: usize,
}

impl TtPanel {
    /// Render as the aligned text table the CLI and bench print.
    pub fn render(&self) -> String {
        let mut s = format!(
            "== TT decode (agreement over {} prompts x {} greedy tokens) ==\n",
            self.prompts, self.new_tokens
        );
        s.push_str("variant    tok/s      speedup  agreement  bytes      compress\n");
        for p in &self.points {
            s.push_str(&format!(
                "{:<9} {:>9.1}  {:>6.2}x  {:>8.2}  {:>9}  {:>7.3}\n",
                p.variant, p.tokens_per_sec, p.speedup, p.agreement, p.bytes, p.compression,
            ));
        }
        s
    }
}

/// `kron(A, B)` sized so the two-mode TT of the `(m, n)` weight is exactly
/// rank-1: A is `(m1, n1)`, B `(m2, n2)` over [`mode_dims`]`(·, 2)`, and
/// `W[i1·m2+i2, j1·n2+j2] = A[i1,j1]·B[i2,j2]`. Per-factor σ is the fourth
/// root of the glorot variance so the product matches a dense init's scale.
fn kron_weight(m: usize, n: usize, rng: &mut Pcg64) -> Vec<f32> {
    let (md, nd) = (mode_dims(m, PANEL_MODES), mode_dims(n, PANEL_MODES));
    let (m1, m2, n1, n2) = (md[0], md[1], nd[0], nd[1]);
    let sigma = (2.0 / (m + n) as f64).sqrt().sqrt() as f32;
    let a = Matrix::randn(m1, n1, sigma, rng);
    let b = Matrix::randn(m2, n2, sigma, rng);
    let mut w = vec![0.0f32; m * n];
    for i1 in 0..m1 {
        for i2 in 0..m2 {
            for j1 in 0..n1 {
                for j2 in 0..n2 {
                    w[(i1 * m2 + i2) * n + (j1 * n2 + j2)] =
                        a.data[i1 * n1 + j1] * b.data[i2 * n2 + j2];
                }
            }
        }
    }
    w
}

/// Init an LM and overwrite every linear weight with a Kronecker-structured
/// matrix — the separable regime where TT wins and LED cannot.
pub fn kron_structured_lm(cfg: &TextModelCfg, seed: u64) -> Result<ParamStore> {
    let mut params = init_text_params(cfg, seed);
    let mut rng = Pcg64::new(seed, KRON_STREAM);
    let linears: Vec<String> = classify(&params)
        .into_iter()
        .filter(|l| matches!(l.kind, crate::model::LayerKind::Linear))
        .map(|l| l.name)
        .collect();
    for name in linears {
        let wname = if name.is_empty() { "w".to_string() } else { format!("{name}/w") };
        let t = params
            .get_mut(&wname)
            .ok_or_else(|| anyhow::anyhow!("classified linear lost its weight {wname:?}"))?;
        let (m, n) = (t.shape[0], t.shape[1]);
        t.as_f32_mut()?.copy_from_slice(&kron_weight(m, n, &mut rng));
    }
    Ok(params)
}

/// Seeded prompt `i`, reproducible across variants and runs.
fn prompt_for(cfg: &TtPanelCfg, i: usize) -> Vec<i32> {
    let mut rng = Pcg64::new(cfg.seed ^ i as u64, PROMPT_STREAM);
    (0..cfg.prompt_len).map(|_| rng.below(cfg.lm.vocab) as i32).collect()
}

/// Build the structured LM once, factorize it with the LED and TT solvers,
/// and measure all three variants.
pub fn tt_panel(cfg: &TtPanelCfg) -> Result<TtPanel> {
    let dense = kron_structured_lm(&cfg.lm, cfg.seed)?;

    let mut led = dense.clone();
    auto_fact(
        &mut led,
        &AutoFactConfig {
            rank: Rank::Ratio(cfg.led_ratio),
            solver: Solver::Svd,
            ..Default::default()
        },
    )?;
    let mut tt = dense.clone();
    auto_fact(
        &mut tt,
        &AutoFactConfig {
            solver: Solver::Tt,
            tt: TtConfig {
                modes: PANEL_MODES,
                energy: cfg.energy,
                max_rank: None,
            },
            ..Default::default()
        },
    )?;

    let led_variant = format!("led_r{:02}", (cfg.led_ratio * 100.0).round() as usize);
    let variants: [(&str, &ParamStore); 3] =
        [("dense", &dense), (led_variant.as_str(), &led), ("tt", &tt)];

    let backend = NativeBackend;
    let greedy = SamplingCfg::greedy();
    let prompt0 = prompt_for(cfg, 0);
    let mut dense_streams: Vec<Vec<i32>> = Vec::new();
    let mut dense_tps = 0.0;
    let mut dense_bytes = 0usize;
    let mut points = Vec::new();
    for (variant, params) in variants {
        let mut graph = synth_fwd_graph("lm", variant, 1, params)?;
        // synth_fwd_graph pins the zoo-default head count; honor the cfg's.
        graph.config.insert("heads".to_string(), cfg.lm.heads);
        let lat = measure_decode_latency(
            &backend,
            &graph,
            params,
            &prompt0,
            cfg.new_tokens,
            cfg.warmup,
            cfg.iters,
        )?;
        let mut matches = 0usize;
        for i in 0..cfg.prompts {
            let mut session = DecodeSession::new(&graph, params)?;
            let out = generate_with_session(
                &backend,
                &graph,
                params,
                &mut session,
                &prompt_for(cfg, i),
                cfg.new_tokens,
                &greedy,
                |_, _| {},
            )?;
            if variant == "dense" {
                dense_streams.push(out.tokens);
                matches += 1;
            } else if dense_streams.get(i).is_some_and(|want| want == &out.tokens) {
                matches += 1;
            }
        }
        let bytes = params.iter().map(|(_, t)| t.raw_bytes().len()).sum::<usize>();
        if variant == "dense" {
            dense_tps = lat.tokens_per_sec;
            dense_bytes = bytes;
        }
        points.push(TtPoint {
            variant: variant.to_string(),
            tokens_per_sec: lat.tokens_per_sec,
            speedup: lat.tokens_per_sec / dense_tps.max(1e-12),
            agreement: matches as f64 / cfg.prompts.max(1) as f64,
            bytes,
            compression: bytes as f64 / dense_bytes.max(1) as f64,
        });
    }
    Ok(TtPanel { points, prompts: cfg.prompts, new_tokens: cfg.new_tokens })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_panel_tt_beats_led_on_bytes() {
        let panel = tt_panel(&TtPanelCfg::quick()).unwrap();
        assert_eq!(panel.points.len(), 3);
        let dense = &panel.points[0];
        assert_eq!(dense.variant, "dense");
        assert_eq!(dense.agreement, 1.0);
        assert!((dense.speedup - 1.0).abs() < 1e-9);
        assert!((dense.compression - 1.0).abs() < 1e-9);
        let (led, tt) = (&panel.points[1], &panel.points[2]);
        assert_eq!(tt.variant, "tt");
        // The separable regime: TT compresses below both dense and LED.
        assert!(led.compression < 1.0, "led={}", led.compression);
        assert!(tt.compression < led.compression, "tt={} led={}", tt.compression, led.compression);
        // Exactly-rank-1 structure at τ=0.99 reconstructs ~losslessly, so
        // the TT streams should track dense closely.
        assert!(tt.agreement >= 0.5, "tt agreement {}", tt.agreement);
        let text = panel.render();
        assert!(text.contains("tt") && text.contains("dense"), "{text}");
    }
}
