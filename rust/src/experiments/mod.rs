//! Experiment harnesses — one per paper artifact (DESIGN.md §6).
//!
//! Each harness regenerates a Figure-2 panel or a table row set and returns
//! structured results; the criterion benches and the CLI print them. Scale
//! knobs (`ExpParams`) let the same harness run as a CI smoke test or as the
//! full reproduction (env `GREENFORMER_STEPS` / `GREENFORMER_EVAL` override).

pub mod fig2;
pub mod quant;
pub mod tables;
pub mod tt;

pub use fig2::{by_design, icl, post_training, Fig2Point, Fig2Result, FigEnv, NativeFigCfg};
pub use quant::{quant_panel, QuantPanel, QuantPanelCfg, QuantPoint};
pub use tables::{cost_table, solver_table, CostRow, SolverRow};
pub use tt::{kron_structured_lm, tt_panel, TtPanel, TtPanelCfg, TtPoint};

/// Scale parameters shared by the harnesses.
#[derive(Clone, Debug)]
pub struct ExpParams {
    /// Training steps per (task, variant).
    pub steps: usize,
    /// Held-out examples per accuracy eval.
    pub eval_examples: usize,
    /// Rank ratios to sweep (the x-axis of Figure 2).
    pub ratios: Vec<f64>,
    /// Latency measurement iterations.
    pub latency_iters: usize,
    /// Exemplars per ICL prompt.
    pub k_shots: usize,
    /// Seed for data/inits across the harness.
    pub seed: u64,
}

impl Default for ExpParams {
    fn default() -> Self {
        Self {
            steps: 300,
            eval_examples: 256,
            ratios: vec![0.10, 0.25, 0.50, 0.75],
            latency_iters: 20,
            k_shots: 4,
            seed: 42,
        }
    }
}

impl ExpParams {
    /// Quick preset for tests/benches; env vars override.
    pub fn quick() -> Self {
        let mut p = Self {
            steps: 60,
            eval_examples: 96,
            ratios: vec![0.25, 0.50],
            latency_iters: 8,
            k_shots: 4,
            seed: 42,
        };
        p.apply_env();
        p
    }

    /// Full-reproduction preset; env vars override.
    pub fn full() -> Self {
        let mut p = Self::default();
        p.apply_env();
        p
    }

    /// Apply `GREENFORMER_STEPS` / `GREENFORMER_EVAL` overrides.
    pub fn apply_env(&mut self) {
        if let Ok(s) = std::env::var("GREENFORMER_STEPS") {
            if let Ok(v) = s.parse() {
                self.steps = v;
            }
        }
        if let Ok(s) = std::env::var("GREENFORMER_EVAL") {
            if let Ok(v) = s.parse() {
                self.eval_examples = v;
            }
        }
    }

    /// Artifact variant name for a ratio (contract with aot.py).
    pub fn variant_for(ratio: f64) -> String {
        format!("led_r{:02}", (ratio * 100.0).round() as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_naming_contract() {
        assert_eq!(ExpParams::variant_for(0.10), "led_r10");
        assert_eq!(ExpParams::variant_for(0.25), "led_r25");
        assert_eq!(ExpParams::variant_for(0.75), "led_r75");
    }

    #[test]
    fn quick_smaller_than_full() {
        let q = ExpParams::quick();
        let f = ExpParams::default();
        assert!(q.steps < f.steps);
        assert!(q.ratios.len() <= f.ratios.len());
    }
}
