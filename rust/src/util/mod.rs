//! Small shared utilities: deterministic RNG, timing helpers, and the
//! offline replacements for unavailable crates (JSON codec with schema
//! validation, SHA-256, retry/backoff, bench harness).

pub mod bench;
pub mod json;
pub mod retry;
pub mod rng;
pub mod sha256;
pub mod timer;

pub use bench::Bench;
pub use json::Value as Json;
pub use retry::{try_with_backoff, BackoffCfg};
pub use rng::Pcg64;
pub use sha256::sha256_hex;
pub use timer::Stopwatch;
