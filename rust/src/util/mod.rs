//! Small shared utilities: deterministic RNG, timing helpers, and the
//! offline replacements for unavailable crates (JSON codec, bench harness).

pub mod bench;
pub mod json;
pub mod rng;
pub mod timer;

pub use bench::Bench;
pub use json::Value as Json;
pub use rng::Pcg64;
pub use timer::Stopwatch;
