//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs each `[[bench]]` binary with `harness = false`; those
//! binaries use this module: warmup, timed iterations with outlier-robust
//! statistics, and a stable text report (`name  median ± iqr  mean  n`).
//! Honors the standard `--bench <filter>` arguments cargo passes through.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// `group/case` label.
    pub name: String,
    /// Timed iterations.
    pub n: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// 25th-percentile seconds.
    pub p25_s: f64,
    /// 75th-percentile seconds.
    pub p75_s: f64,
    /// Fastest iteration, seconds.
    pub min_s: f64,
}

impl BenchStats {
    /// One formatted report row.
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12}  min {:>12}  (n={})",
            self.name,
            fmt_secs(self.median_s),
            fmt_secs(self.mean_s),
            fmt_secs(self.min_s),
            self.n
        )
    }
}

/// Human-scale duration formatting (ns/µs/ms/s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// A bench group; collects stats and prints the report on drop.
pub struct Bench {
    group: String,
    filter: Option<String>,
    results: Vec<BenchStats>,
    /// Target measurement budget per case, seconds.
    pub budget_s: f64,
    /// Max iterations per case.
    pub max_iters: usize,
    /// Min iterations per case.
    pub min_iters: usize,
}

impl Bench {
    /// Bench group named `group`, honoring cargo's trailing filter arg.
    pub fn new(group: &str) -> Self {
        // cargo bench passes e.g. `--bench` plus user filters; take the last
        // non-flag argument as a substring filter.
        let filter = std::env::args().skip(1).filter(|a| !a.starts_with('-')).next_back();
        Self {
            group: group.to_string(),
            filter,
            results: Vec::new(),
            budget_s: 3.0,
            max_iters: 100,
            min_iters: 5,
        }
    }

    /// Time `f`, reporting under `name`. Returns the stats (also stored).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Option<BenchStats> {
        let full = format!("{}/{}", self.group, name);
        if let Some(flt) = &self.filter {
            if !full.contains(flt.as_str()) {
                return None;
            }
        }
        // Warmup + calibration.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.budget_s / once) as usize).clamp(self.min_iters, self.max_iters);

        let mut laps = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            laps.push(t.elapsed().as_secs_f64());
        }
        laps.sort_by(|a, b| a.total_cmp(b));
        let last = laps.len() - 1;
        let pct = |p: f64| laps[((p * last as f64).round() as usize).min(last)];
        let stats = BenchStats {
            name: full,
            n: iters,
            mean_s: laps.iter().sum::<f64>() / iters as f64,
            median_s: pct(0.50),
            p25_s: pct(0.25),
            p75_s: pct(0.75),
            min_s: laps[0],
        };
        println!("{}", stats.report_line());
        self.results.push(stats.clone());
        Some(stats)
    }

    /// All stats recorded so far.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// Median-ratio helper: time(a)/time(b) from recorded results.
    pub fn speedup(&self, slow: &str, fast: &str) -> Option<f64> {
        let find = |n: &str| {
            self.results
                .iter()
                .find(|r| r.name.ends_with(n))
                .map(|r| r.median_s)
        };
        Some(find(slow)? / find(fast)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut b = Bench::new("test");
        b.budget_s = 0.01;
        b.max_iters = 8;
        let s = b.bench("noop", || std::hint::black_box(1 + 1)).unwrap();
        assert!(s.n >= 5);
        assert!(s.median_s >= 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn speedup_ratio() {
        let mut b = Bench::new("test");
        b.budget_s = 0.01;
        b.max_iters = 6;
        b.bench("slow", || std::thread::sleep(std::time::Duration::from_micros(300)));
        b.bench("fast", || std::thread::sleep(std::time::Duration::from_micros(50)));
        let s = b.speedup("slow", "fast").unwrap();
        assert!(s > 1.5, "speedup={s}");
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(5e-9).contains("ns"));
        assert!(fmt_secs(5e-6).contains("µs"));
        assert!(fmt_secs(5e-3).contains("ms"));
        assert!(fmt_secs(5.0).contains(" s"));
    }
}
