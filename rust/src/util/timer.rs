//! Wall-clock measurement helpers used by the experiment harnesses.

use std::time::{Duration, Instant};

/// A stopwatch that accumulates laps; reports mean/median/p95 in seconds.
#[derive(Debug, Default)]
pub struct Stopwatch {
    laps: Vec<Duration>,
    start: Option<Instant>,
}

impl Stopwatch {
    /// Empty stopwatch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Begin a lap.
    pub fn start(&mut self) {
        self.start = Some(Instant::now());
    }

    /// Stop the current lap and record it. Returns the lap duration.
    pub fn lap(&mut self) -> Duration {
        let d = self.start.take().expect("lap() without start()").elapsed();
        self.laps.push(d);
        d
    }

    /// Time a closure as one lap and pass its value through.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let v = f();
        self.lap();
        v
    }

    /// Number of recorded laps.
    pub fn count(&self) -> usize {
        self.laps.len()
    }

    /// Sum of all laps, seconds.
    pub fn total_secs(&self) -> f64 {
        self.laps.iter().map(Duration::as_secs_f64).sum()
    }

    /// Mean lap, seconds.
    pub fn mean_secs(&self) -> f64 {
        if self.laps.is_empty() {
            0.0
        } else {
            self.total_secs() / self.laps.len() as f64
        }
    }

    /// Median lap, seconds.
    pub fn median_secs(&self) -> f64 {
        self.percentile_secs(50.0)
    }

    /// 95th-percentile lap, seconds.
    pub fn p95_secs(&self) -> f64 {
        self.percentile_secs(95.0)
    }

    /// Arbitrary-percentile lap (nearest-rank), seconds.
    pub fn percentile_secs(&self, p: f64) -> f64 {
        if self.laps.is_empty() {
            return 0.0;
        }
        let mut xs: Vec<f64> = self.laps.iter().map(Duration::as_secs_f64).collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
        xs[idx.min(xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        for _ in 0..3 {
            sw.time(|| std::hint::black_box(1 + 1));
        }
        assert_eq!(sw.count(), 3);
        assert!(sw.mean_secs() >= 0.0);
        assert!(sw.p95_secs() >= sw.median_secs() || sw.count() < 20);
    }

    #[test]
    #[should_panic]
    fn lap_without_start_panics() {
        Stopwatch::new().lap();
    }
}
