//! Minimal JSON parser/serializer (RFC 8259 subset sufficient for the
//! artifact manifest, experiment configs, and the HTTP serving surface).
//!
//! The build environment is offline with no serde in the crate cache, so the
//! manifest contract with `python/compile/aot.py` is implemented directly:
//! a recursive-descent parser into a [`Value`] tree plus typed accessors.
//! Unsupported: \u escapes beyond BMP surrogate pairs are passed through
//! losslessly; numbers parse as f64 (integers up to 2^53, plenty for shapes).
//!
//! The parse is **fail-closed** — this codec sits on the trust boundary of
//! the HTTP front end and the model registry, so anything ambiguous is an
//! error rather than a guess: trailing bytes after the top-level value,
//! duplicate object keys, and non-finite numbers (`1e999`) are all
//! rejected.
//!
//! On top of the tree sit two composable halves (the read/write split):
//!
//! * [`Schema`] — a declarative validator for request/manifest objects.
//!   Unknown fields, missing required fields, and type mismatches produce a
//!   typed, path-bearing [`ValidationError`] (`body.tokens[3]: expected
//!   non-negative integer`) that maps directly onto a structured 400.
//! * [`ObjBuilder`] — a fluent object composer for building response and
//!   manifest JSON without hand-assembling `BTreeMap`s.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
/// A parsed JSON value.
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number, held as f64.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object (keys sorted, which the codec round-trips canonically).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    /// Parse a complete JSON document from raw bytes (e.g. an HTTP body),
    /// rejecting invalid UTF-8 up front.
    pub fn parse_bytes(bytes: &[u8]) -> Result<Value> {
        let text = std::str::from_utf8(bytes).map_err(|e| anyhow!("body is not UTF-8: {e}"))?;
        Value::parse(text)
    }

    // -- typed accessors ----------------------------------------------------

    /// Object field by key (None when absent or not an object).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field by key, erroring when absent.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// This value as a string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// This value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// This value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    /// This value as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// This value as an array slice.
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// This value as an object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Optional string with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str().ok())
            .unwrap_or(default)
            .to_string()
    }

    /// Field as usize, with a default when absent or invalid.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize().ok()).unwrap_or(default)
    }

    /// Field as f64 when present and numeric.
    pub fn f64_opt(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64().ok())
    }

    // -- serialization ------------------------------------------------------

    /// Serialize back to compact JSON text.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_json_string(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            if m.contains_key(&key) {
                // Fail-closed: RFC 8259 leaves duplicate-key semantics to the
                // implementation, and "last one wins" silently drops data —
                // unacceptable on the request/manifest trust boundary.
                bail!("duplicate object key {key:?} at byte {}", self.pos);
            }
            m.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos..self.pos + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // Surrogate pair support.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        self.bytes
                                            .get(self.pos + 2..self.pos + 6)
                                            .ok_or_else(|| anyhow!("bad surrogate"))?,
                                    )?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.pos += 6;
                                    char::from_u32(
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                    )
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("invalid codepoint"))?);
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                b if b < 0x80 => s.push(b as char),
                _ => {
                    // Multi-byte UTF-8: back up and decode.
                    self.pos -= 1;
                    let rest = &self.bytes[self.pos..];
                    let st = std::str::from_utf8(&rest[..rest.len().min(4)])
                        .map_or_else(|e| {
                            std::str::from_utf8(&rest[..e.valid_up_to()]).unwrap_or("")
                        }, |s| s);
                    let ch = st.chars().next().ok_or_else(|| anyhow!("bad utf8 in string"))?;
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        let n = text
            .parse::<f64>()
            .map_err(|e| anyhow!("bad number {text:?} at byte {start}: {e}"))?;
        // `"1e999".parse::<f64>()` happily returns infinity; JSON has no
        // non-finite numbers, so overflowing literals are a parse error.
        if !n.is_finite() {
            bail!("non-finite number {text:?} at byte {start}");
        }
        Ok(Value::Num(n))
    }
}

// ---------------------------------------------------------------------------
// Declarative validation (the read half of the composer/validator split).
// ---------------------------------------------------------------------------

/// A typed, path-bearing validation failure: which field broke
/// (`body.checkpoints[1].sha256`) and how. Implements `std::error::Error`,
/// so `?` converts it into the crate error type while callers that need the
/// structure (the HTTP 400 path) can keep the typed form.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ValidationError {
    /// Dotted/indexed path from the schema root to the offending value.
    pub path: String,
    /// What was wrong at `path`.
    pub message: String,
}

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.path, self.message)
    }
}

impl std::error::Error for ValidationError {}

/// Expected shape of one schema field.
#[derive(Clone, Debug)]
pub enum Kind {
    /// A JSON string.
    Str,
    /// Any finite JSON number.
    Num,
    /// A non-negative integer (no fraction, ≤ 2^53).
    UInt,
    /// `true` / `false`.
    Bool,
    /// An array whose elements all match the inner kind.
    Arr(Box<Kind>),
    /// A nested object validated by its own schema.
    Obj(Box<Schema>),
    /// Any value (presence/absence is still checked).
    Any,
}

impl Kind {
    fn describe(&self) -> &'static str {
        match self {
            Kind::Str => "string",
            Kind::Num => "number",
            Kind::UInt => "non-negative integer",
            Kind::Bool => "bool",
            Kind::Arr(_) => "array",
            Kind::Obj(_) => "object",
            Kind::Any => "value",
        }
    }
}

/// A declarative object schema: required/optional fields, each with a
/// [`Kind`]. Validation is fail-closed — fields not named by the schema are
/// errors, not silently ignored (a typo'd knob must not be a no-op).
///
/// Schemas compose: [`Kind::Obj`] nests one schema inside another and
/// [`Kind::Arr`] lifts any kind over arrays, so one `validate` call checks
/// an entire manifest tree and reports the exact failing path.
#[derive(Clone, Debug)]
pub struct Schema {
    name: String,
    fields: Vec<(String, Kind, bool)>,
}

impl Schema {
    /// New empty schema; `name` roots the error paths (e.g. `"body"`).
    pub fn new(name: &str) -> Self {
        Schema { name: name.to_string(), fields: Vec::new() }
    }

    /// Add a field that must be present.
    pub fn required(mut self, key: &str, kind: Kind) -> Self {
        self.fields.push((key.to_string(), kind, true));
        self
    }

    /// Add a field that may be absent (but must match `kind` when present).
    pub fn optional(mut self, key: &str, kind: Kind) -> Self {
        self.fields.push((key.to_string(), kind, false));
        self
    }

    /// Validate `v` against this schema. `Ok(())` means every required
    /// field is present, every present field matches its kind, and no
    /// unknown fields exist.
    pub fn validate(&self, v: &Value) -> std::result::Result<(), ValidationError> {
        self.validate_at(v, &self.name)
    }

    fn validate_at(&self, v: &Value, path: &str) -> std::result::Result<(), ValidationError> {
        let obj = match v {
            Value::Obj(m) => m,
            other => {
                return Err(ValidationError {
                    path: path.to_string(),
                    message: format!("expected object, got {}", kind_name(other)),
                })
            }
        };
        for key in obj.keys() {
            if !self.fields.iter().any(|(k, _, _)| k == key) {
                return Err(ValidationError {
                    path: format!("{path}.{key}"),
                    message: "unknown field".to_string(),
                });
            }
        }
        for (key, kind, required) in &self.fields {
            match obj.get(key) {
                Some(val) => check_kind(val, kind, &format!("{path}.{key}"))?,
                None if *required => {
                    return Err(ValidationError {
                        path: format!("{path}.{key}"),
                        message: "missing required field".to_string(),
                    })
                }
                None => {}
            }
        }
        Ok(())
    }
}

fn kind_name(v: &Value) -> &'static str {
    match v {
        Value::Null => "null",
        Value::Bool(_) => "bool",
        Value::Num(_) => "number",
        Value::Str(_) => "string",
        Value::Arr(_) => "array",
        Value::Obj(_) => "object",
    }
}

fn check_kind(v: &Value, kind: &Kind, path: &str) -> std::result::Result<(), ValidationError> {
    let fail = |msg: String| {
        Err(ValidationError { path: path.to_string(), message: msg })
    };
    match kind {
        Kind::Any => Ok(()),
        Kind::Str => match v {
            Value::Str(_) => Ok(()),
            other => fail(format!("expected string, got {}", kind_name(other))),
        },
        Kind::Bool => match v {
            Value::Bool(_) => Ok(()),
            other => fail(format!("expected bool, got {}", kind_name(other))),
        },
        Kind::Num => match v {
            Value::Num(_) => Ok(()),
            other => fail(format!("expected number, got {}", kind_name(other))),
        },
        Kind::UInt => match v {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9.0e15 => Ok(()),
            Value::Num(n) => fail(format!("expected non-negative integer, got {n}")),
            other => fail(format!(
                "expected {}, got {}",
                kind.describe(),
                kind_name(other)
            )),
        },
        Kind::Arr(inner) => match v {
            Value::Arr(items) => {
                for (i, item) in items.iter().enumerate() {
                    check_kind(item, inner, &format!("{path}[{i}]"))?;
                }
                Ok(())
            }
            other => fail(format!("expected array, got {}", kind_name(other))),
        },
        Kind::Obj(schema) => schema.validate_at(v, path),
    }
}

// ---------------------------------------------------------------------------
// Fluent composition (the write half).
// ---------------------------------------------------------------------------

/// Fluent JSON object composer — the write-side counterpart of [`Schema`].
/// Builds a [`Value::Obj`] without hand-assembling maps; used by the HTTP
/// response paths and the registry manifest writer.
#[derive(Clone, Debug, Default)]
pub struct ObjBuilder {
    m: BTreeMap<String, Value>,
}

impl ObjBuilder {
    /// Empty object.
    pub fn new() -> Self {
        Self::default()
    }

    /// Set `key` to an arbitrary value (later sets of the same key win).
    pub fn set(mut self, key: &str, v: Value) -> Self {
        self.m.insert(key.to_string(), v);
        self
    }

    /// Set a string field.
    pub fn str(self, key: &str, s: &str) -> Self {
        self.set(key, Value::Str(s.to_string()))
    }

    /// Set a numeric field.
    pub fn num(self, key: &str, n: f64) -> Self {
        self.set(key, Value::Num(n))
    }

    /// Set a non-negative integer field.
    pub fn uint(self, key: &str, n: u64) -> Self {
        self.set(key, Value::Num(n as f64))
    }

    /// Set a boolean field.
    pub fn boolean(self, key: &str, b: bool) -> Self {
        self.set(key, Value::Bool(b))
    }

    /// Set an array field from already-built values.
    pub fn arr(self, key: &str, items: Vec<Value>) -> Self {
        self.set(key, Value::Arr(items))
    }

    /// Set an array field from token ids.
    pub fn arr_i32(self, key: &str, xs: &[i32]) -> Self {
        self.set(
            key,
            Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect()),
        )
    }

    /// Set an array field from f32 values (logits).
    pub fn arr_f32(self, key: &str, xs: &[f32]) -> Self {
        self.set(
            key,
            Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect()),
        )
    }

    /// Finish building, yielding the composed [`Value`].
    pub fn build(self) -> Value {
        Value::Obj(self.m)
    }

    /// Finish and serialize to compact JSON text.
    pub fn render(self) -> String {
        self.build().render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"format": 1, "graphs": [{"name": "a", "shape": [2, 64], "ok": true, "x": null, "r": 0.25}], "s": "hi\nthere"}"#;
        let v = Value::parse(doc).unwrap();
        assert_eq!(v.req("format").unwrap().as_usize().unwrap(), 1);
        let g = &v.req("graphs").unwrap().as_arr().unwrap()[0];
        assert_eq!(g.req("name").unwrap().as_str().unwrap(), "a");
        assert_eq!(g.req("shape").unwrap().as_arr().unwrap()[1].as_usize().unwrap(), 64);
        assert!(g.req("ok").unwrap().as_bool().unwrap());
        assert_eq!(g.req("x").unwrap(), &Value::Null);
        assert!((g.req("r").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "hi\nthere");
    }

    #[test]
    fn roundtrips_through_render() {
        let doc = r#"{"a": [1, 2.5, "x", {"b": false}], "c": null}"#;
        let v = Value::parse(doc).unwrap();
        let v2 = Value::parse(&v.render()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,]", "{\"a\" 1}", "tru", "1 2", "\"unterminated"] {
            assert!(Value::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn escapes_in_strings() {
        let v = Value::parse(r#""tab\there Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "tab\there Aé");
        let v = Value::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — 世界");
    }

    #[test]
    fn accessor_defaults() {
        let v = Value::parse(r#"{"n": 5}"#).unwrap();
        assert_eq!(v.usize_or("n", 1), 5);
        assert_eq!(v.usize_or("missing", 7), 7);
        assert_eq!(v.str_or("missing", "d"), "d");
        assert!(v.f64_opt("missing").is_none());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let v = Value::parse("[-3, 1e3, -2.5e-2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), -3.0);
        assert_eq!(a[1].as_f64().unwrap(), 1000.0);
        assert!((a[2].as_f64().unwrap() + 0.025).abs() < 1e-12);
        assert!(a[0].as_usize().is_err());
    }

    #[test]
    fn rejects_trailing_bytes_after_top_level_value() {
        for bad in ["{} x", "1 2", "[1]{}", "null,", "true false"] {
            let e = Value::parse(bad).unwrap_err();
            assert!(format!("{e:#}").contains("trailing"), "{bad:?}: {e:#}");
        }
        // Pure trailing whitespace stays fine.
        assert!(Value::parse("{\"a\": 1}  \n").is_ok());
    }

    #[test]
    fn rejects_duplicate_object_keys() {
        let e = Value::parse(r#"{"a": 1, "a": 2}"#).unwrap_err();
        assert!(format!("{e:#}").contains("duplicate object key \"a\""), "{e:#}");
        // Nested duplicates are caught too; same key in *different* objects
        // is of course fine.
        assert!(Value::parse(r#"{"o": {"k": 1, "k": 2}}"#).is_err());
        assert!(Value::parse(r#"[{"k": 1}, {"k": 2}]"#).is_ok());
    }

    #[test]
    fn rejects_non_finite_numbers() {
        for bad in ["1e999", "-1e999", "[1, 2e9999]"] {
            assert!(Value::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn parse_bytes_rejects_invalid_utf8() {
        assert!(Value::parse_bytes(b"{\"a\": 1}").is_ok());
        assert!(Value::parse_bytes(&[0x7b, 0xff, 0xfe, 0x7d]).is_err());
    }

    #[test]
    fn schema_accepts_valid_objects() {
        let schema = Schema::new("body")
            .required("tokens", Kind::Arr(Box::new(Kind::UInt)))
            .optional("model", Kind::Str)
            .optional("tier", Kind::Str);
        let v = Value::parse(r#"{"tokens": [1, 2, 3], "tier": "fast"}"#).unwrap();
        schema.validate(&v).unwrap();
    }

    #[test]
    fn schema_rejects_unknown_fields_with_path() {
        let schema = Schema::new("body").required("tokens", Kind::Arr(Box::new(Kind::UInt)));
        let v = Value::parse(r#"{"tokens": [1], "bogus": 1}"#).unwrap();
        let e = schema.validate(&v).unwrap_err();
        assert_eq!(e.path, "body.bogus");
        assert_eq!(e.message, "unknown field");
    }

    #[test]
    fn schema_rejects_missing_and_mistyped_fields() {
        let schema = Schema::new("body")
            .required("tokens", Kind::Arr(Box::new(Kind::UInt)))
            .optional("temperature", Kind::Num);
        let e = schema.validate(&Value::parse("{}").unwrap()).unwrap_err();
        assert_eq!(e.path, "body.tokens");
        assert!(e.message.contains("missing"));

        let v = Value::parse(r#"{"tokens": [1, -2]}"#).unwrap();
        let e = schema.validate(&v).unwrap_err();
        assert_eq!(e.path, "body.tokens[1]");
        assert!(e.message.contains("non-negative integer"), "{e}");

        let v = Value::parse(r#"{"tokens": [], "temperature": "hot"}"#).unwrap();
        let e = schema.validate(&v).unwrap_err();
        assert_eq!(e.path, "body.temperature");
        assert!(e.message.contains("expected number"), "{e}");

        let e = schema.validate(&Value::parse("[1]").unwrap()).unwrap_err();
        assert_eq!(e.path, "body");
        assert!(e.message.contains("expected object"), "{e}");
    }

    #[test]
    fn schema_nesting_reports_deep_paths() {
        let ckpt = Schema::new("checkpoint")
            .required("name", Kind::Str)
            .required("sha256", Kind::Str);
        let schema = Schema::new("manifest")
            .required("format", Kind::UInt)
            .required("checkpoints", Kind::Arr(Box::new(Kind::Obj(Box::new(ckpt)))));
        let ok = Value::parse(
            r#"{"format": 1, "checkpoints": [{"name": "dense", "sha256": "ab"}]}"#,
        )
        .unwrap();
        schema.validate(&ok).unwrap();

        let bad = Value::parse(
            r#"{"format": 1, "checkpoints": [{"name": "dense", "sha256": 7}]}"#,
        )
        .unwrap();
        let e = schema.validate(&bad).unwrap_err();
        assert_eq!(e.path, "manifest.checkpoints[0].sha256");
    }

    #[test]
    fn obj_builder_composes_and_roundtrips() {
        let v = ObjBuilder::new()
            .str("variant", "dense")
            .uint("label", 3)
            .boolean("ok", true)
            .arr_i32("tokens", &[1, 2, 3])
            .arr_f32("logits", &[0.5, -1.25])
            .build();
        let text = v.render();
        let back = Value::parse(&text).unwrap();
        assert_eq!(back.req("variant").unwrap().as_str().unwrap(), "dense");
        assert_eq!(back.req("label").unwrap().as_usize().unwrap(), 3);
        assert!(back.req("ok").unwrap().as_bool().unwrap());
        assert_eq!(back.req("tokens").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(back.req("logits").unwrap().as_arr().unwrap()[1].as_f64().unwrap(), -1.25);
        // The composer's output always passes a matching schema.
        let schema = Schema::new("resp")
            .required("variant", Kind::Str)
            .required("label", Kind::UInt)
            .required("ok", Kind::Bool)
            .required("tokens", Kind::Arr(Box::new(Kind::UInt)))
            .required("logits", Kind::Arr(Box::new(Kind::Num)));
        schema.validate(&back).unwrap();
    }
}
