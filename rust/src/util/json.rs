//! Minimal JSON parser/serializer (RFC 8259 subset sufficient for the
//! artifact manifest and experiment configs).
//!
//! The build environment is offline with no serde in the crate cache, so the
//! manifest contract with `python/compile/aot.py` is implemented directly:
//! a recursive-descent parser into a [`Value`] tree plus typed accessors.
//! Unsupported: \u escapes beyond BMP surrogate pairs are passed through
//! losslessly; numbers parse as f64 (integers up to 2^53, plenty for shapes).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
/// A parsed JSON value.
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number, held as f64.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object (keys sorted, which the codec round-trips canonically).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    /// Object field by key (None when absent or not an object).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field by key, erroring when absent.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// This value as a string.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// This value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// This value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    /// This value as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// This value as an array slice.
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// This value as an object map.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Optional string with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str().ok())
            .unwrap_or(default)
            .to_string()
    }

    /// Field as usize, with a default when absent or invalid.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize().ok()).unwrap_or(default)
    }

    /// Field as f64 when present and numeric.
    pub fn f64_opt(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64().ok())
    }

    // -- serialization ------------------------------------------------------

    /// Serialize back to compact JSON text.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_json_string(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_string(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_json_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(key, v);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Value::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Value::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos..self.pos + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // Surrogate pair support.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        self.bytes
                                            .get(self.pos + 2..self.pos + 6)
                                            .ok_or_else(|| anyhow!("bad surrogate"))?,
                                    )?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.pos += 6;
                                    char::from_u32(
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                    )
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow!("invalid codepoint"))?);
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                b if b < 0x80 => s.push(b as char),
                _ => {
                    // Multi-byte UTF-8: back up and decode.
                    self.pos -= 1;
                    let rest = &self.bytes[self.pos..];
                    let st = std::str::from_utf8(&rest[..rest.len().min(4)])
                        .map_or_else(|e| {
                            std::str::from_utf8(&rest[..e.valid_up_to()]).unwrap_or("")
                        }, |s| s);
                    let ch = st.chars().next().ok_or_else(|| anyhow!("bad utf8 in string"))?;
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"format": 1, "graphs": [{"name": "a", "shape": [2, 64], "ok": true, "x": null, "r": 0.25}], "s": "hi\nthere"}"#;
        let v = Value::parse(doc).unwrap();
        assert_eq!(v.req("format").unwrap().as_usize().unwrap(), 1);
        let g = &v.req("graphs").unwrap().as_arr().unwrap()[0];
        assert_eq!(g.req("name").unwrap().as_str().unwrap(), "a");
        assert_eq!(g.req("shape").unwrap().as_arr().unwrap()[1].as_usize().unwrap(), 64);
        assert!(g.req("ok").unwrap().as_bool().unwrap());
        assert_eq!(g.req("x").unwrap(), &Value::Null);
        assert!((g.req("r").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-12);
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "hi\nthere");
    }

    #[test]
    fn roundtrips_through_render() {
        let doc = r#"{"a": [1, 2.5, "x", {"b": false}], "c": null}"#;
        let v = Value::parse(doc).unwrap();
        let v2 = Value::parse(&v.render()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["{", "[1,]", "{\"a\" 1}", "tru", "1 2", "\"unterminated"] {
            assert!(Value::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn escapes_in_strings() {
        let v = Value::parse(r#""tab\there Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "tab\there Aé");
        let v = Value::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — 世界");
    }

    #[test]
    fn accessor_defaults() {
        let v = Value::parse(r#"{"n": 5}"#).unwrap();
        assert_eq!(v.usize_or("n", 1), 5);
        assert_eq!(v.usize_or("missing", 7), 7);
        assert_eq!(v.str_or("missing", "d"), "d");
        assert!(v.f64_opt("missing").is_none());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let v = Value::parse("[-3, 1e3, -2.5e-2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_f64().unwrap(), -3.0);
        assert_eq!(a[1].as_f64().unwrap(), 1000.0);
        assert!((a[2].as_f64().unwrap() + 0.025).abs() < 1e-12);
        assert!(a[0].as_usize().is_err());
    }
}
