//! Bounded, deterministic exponential backoff for overload-shed requests.
//!
//! The serving front end sheds load with typed `Overloaded` errors carrying
//! a retry hint; this module is the client-side half: a small retry driver
//! that callers use instead of hand-rolling loops. Determinism matters — the
//! delay schedule is a pure function of [`BackoffCfg`] and the attempt
//! index (no jitter source baked in), and the sleep is injected, so tests
//! drive it with a fake clock and assert the exact schedule.

use std::time::Duration;

/// Retry policy: how many attempts, and the delay curve between them.
///
/// The delay before retry `i` (0-based) is `base * multiplier^i`, capped at
/// `max_delay`; a per-error server hint (e.g. `Retry-After`) can only
/// lengthen a delay, never shorten it below the curve.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BackoffCfg {
    /// Total attempts including the first (must be ≥ 1; 1 = no retries).
    pub attempts: usize,
    /// Delay before the first retry.
    pub base: Duration,
    /// Per-retry delay multiplier.
    pub multiplier: u32,
    /// Upper bound on any single delay.
    pub max_delay: Duration,
}

impl Default for BackoffCfg {
    fn default() -> Self {
        BackoffCfg {
            attempts: 4,
            base: Duration::from_millis(25),
            multiplier: 2,
            max_delay: Duration::from_millis(400),
        }
    }
}

impl BackoffCfg {
    /// The deterministic delay before retry `attempt` (0-based):
    /// `min(base * multiplier^attempt, max_delay)`.
    pub fn delay(&self, attempt: usize) -> Duration {
        let mut d = self.base;
        for _ in 0..attempt {
            d = d.saturating_mul(self.multiplier);
            if d >= self.max_delay {
                return self.max_delay;
            }
        }
        d.min(self.max_delay)
    }
}

/// Run `op` until it succeeds, retries are exhausted, or an error is not
/// retryable.
///
/// * `op(attempt)` — the fallible operation; `attempt` is 0-based.
/// * `retry_after(&err)` — `Some(hint)` marks the error retryable (the hint
///   may be zero); `None` aborts immediately with that error. The effective
///   delay is `max(cfg.delay(attempt), hint)` — a server's explicit
///   `Retry-After` can stretch the curve but never undercut it.
/// * `sleep(d)` — injected so tests substitute a recording fake for
///   `std::thread::sleep`.
///
/// Returns the first success, or the last error once `cfg.attempts` runs
/// out.
pub fn try_with_backoff<T, E>(
    cfg: &BackoffCfg,
    mut op: impl FnMut(usize) -> std::result::Result<T, E>,
    mut retry_after: impl FnMut(&E) -> Option<Duration>,
    mut sleep: impl FnMut(Duration),
) -> std::result::Result<T, E> {
    let attempts = cfg.attempts.max(1);
    let mut attempt = 0;
    loop {
        match op(attempt) {
            Ok(v) => return Ok(v),
            Err(e) => {
                if attempt + 1 >= attempts {
                    return Err(e);
                }
                match retry_after(&e) {
                    Some(hint) => sleep(cfg.delay(attempt).max(hint)),
                    None => return Err(e),
                }
                attempt += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn delay_curve_is_capped_geometric() {
        let cfg = BackoffCfg::default();
        assert_eq!(cfg.delay(0), ms(25));
        assert_eq!(cfg.delay(1), ms(50));
        assert_eq!(cfg.delay(2), ms(100));
        assert_eq!(cfg.delay(3), ms(200));
        assert_eq!(cfg.delay(4), ms(400));
        assert_eq!(cfg.delay(50), ms(400), "cap holds without overflow");
    }

    #[test]
    fn succeeds_after_transient_failures_with_exact_schedule() {
        let cfg = BackoffCfg::default();
        let slept = RefCell::new(Vec::new());
        let out = try_with_backoff(
            &cfg,
            |attempt| if attempt < 2 { Err("busy") } else { Ok(attempt) },
            |_| Some(Duration::ZERO),
            |d| slept.borrow_mut().push(d),
        );
        assert_eq!(out.unwrap(), 2);
        assert_eq!(*slept.borrow(), vec![ms(25), ms(50)]);
    }

    #[test]
    fn exhausts_attempts_and_returns_last_error() {
        let cfg = BackoffCfg { attempts: 3, ..BackoffCfg::default() };
        let slept = RefCell::new(Vec::new());
        let calls = RefCell::new(0usize);
        let out: Result<(), &str> = try_with_backoff(
            &cfg,
            |_| {
                *calls.borrow_mut() += 1;
                Err("still busy")
            },
            |_| Some(Duration::ZERO),
            |d| slept.borrow_mut().push(d),
        );
        assert_eq!(out.unwrap_err(), "still busy");
        assert_eq!(*calls.borrow(), 3, "attempts bounds the op calls");
        assert_eq!(*slept.borrow(), vec![ms(25), ms(50)]);
    }

    #[test]
    fn non_retryable_error_aborts_without_sleeping() {
        let cfg = BackoffCfg::default();
        let slept = RefCell::new(Vec::new());
        let out: Result<(), &str> = try_with_backoff(
            &cfg,
            |_| Err("malformed"),
            |_| None,
            |d| slept.borrow_mut().push(d),
        );
        assert_eq!(out.unwrap_err(), "malformed");
        assert!(slept.borrow().is_empty());
    }

    #[test]
    fn server_hint_stretches_but_never_undercuts_the_curve() {
        let cfg = BackoffCfg::default();
        let slept = RefCell::new(Vec::new());
        let out: Result<(), &str> = try_with_backoff(
            &cfg,
            |_| Err("busy"),
            |_| Some(ms(80)),
            |d| slept.borrow_mut().push(d),
        );
        assert!(out.is_err());
        // attempt 0: max(25, 80) = 80; attempt 1: max(50, 80) = 80;
        // attempt 2: max(100, 80) = 100.
        assert_eq!(*slept.borrow(), vec![ms(80), ms(80), ms(100)]);
    }

    #[test]
    fn single_attempt_never_sleeps() {
        let cfg = BackoffCfg { attempts: 1, ..BackoffCfg::default() };
        let slept = RefCell::new(Vec::new());
        let out: Result<(), &str> = try_with_backoff(
            &cfg,
            |_| Err("busy"),
            |_| Some(Duration::ZERO),
            |d| slept.borrow_mut().push(d),
        );
        assert!(out.is_err());
        assert!(slept.borrow().is_empty());
    }
}
