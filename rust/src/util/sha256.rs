//! Pure-Rust SHA-256 (FIPS 180-4): the checkpoint-integrity primitive
//! behind the fail-closed model registry.
//!
//! The offline build has no crypto crate in its cache, so the registry's
//! per-checkpoint hash verification is implemented directly: a plain
//! single-block compressor with a streaming state. Throughput is more than
//! enough for hashing checkpoints at load time. This is an integrity check
//! against corrupt or tampered at-rest files, not a constant-time
//! authentication primitive.

use std::fmt::Write as _;

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

fn compress(h: &mut [u32; 8], block: &[u8; 64]) {
    let mut w = [0u32; 64];
    for (wi, chunk) in w.iter_mut().zip(block.chunks_exact(4)) {
        *wi = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
    for i in 16..64 {
        let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
        let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
        w[i] = w[i - 16]
            .wrapping_add(s0)
            .wrapping_add(w[i - 7])
            .wrapping_add(s1);
    }
    let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = *h;
    for (&ki, &wi) in K.iter().zip(w.iter()) {
        let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
        let ch = (e & f) ^ (!e & g);
        let t1 = hh
            .wrapping_add(s1)
            .wrapping_add(ch)
            .wrapping_add(ki)
            .wrapping_add(wi);
        let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
        let maj = (a & b) ^ (a & c) ^ (b & c);
        let t2 = s0.wrapping_add(maj);
        hh = g;
        g = f;
        f = e;
        e = d.wrapping_add(t1);
        d = c;
        c = b;
        b = a;
        a = t1.wrapping_add(t2);
    }
    for (acc, v) in h.iter_mut().zip([a, b, c, d, e, f, g, hh]) {
        *acc = acc.wrapping_add(v);
    }
}

/// Streaming SHA-256 state: feed bytes with [`Sha256::update`], close with
/// [`Sha256::finish`].
pub struct Sha256 {
    h: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    /// Fresh hash state.
    pub fn new() -> Self {
        Sha256 { h: H0, buf: [0; 64], buf_len: 0, total_len: 0 }
    }

    /// Absorb `data` (any length, any number of calls).
    pub fn update(&mut self, data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        let mut rest = data;
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(rest.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&rest[..take]);
            self.buf_len += take;
            rest = &rest[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                compress(&mut self.h, &block);
                self.buf_len = 0;
            }
        }
        let chunks = rest.chunks_exact(64);
        let tail = chunks.remainder();
        for chunk in chunks {
            let mut block = [0u8; 64];
            block.copy_from_slice(chunk);
            compress(&mut self.h, &block);
        }
        if !tail.is_empty() {
            self.buf[..tail.len()].copy_from_slice(tail);
            self.buf_len = tail.len();
        }
    }

    /// Pad, run the final block(s), and return the 32-byte digest.
    pub fn finish(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        self.update(&bit_len.to_be_bytes());
        debug_assert_eq!(self.buf_len, 0, "final block must have flushed");
        let mut out = [0u8; 32];
        for (slot, word) in out.chunks_exact_mut(4).zip(self.h) {
            slot.copy_from_slice(&word.to_be_bytes());
        }
        out
    }
}

/// One-shot SHA-256 of `data` as 64 lowercase hex characters — the exact
/// form registry manifests carry per checkpoint.
pub fn sha256_hex(data: &[u8]) -> String {
    let mut state = Sha256::new();
    state.update(data);
    let mut s = String::with_capacity(64);
    for b in state.finish() {
        let _ = write!(s, "{b:02x}");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    // FIPS 180-4 / NIST CAVP reference vectors.
    #[test]
    fn empty_input() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn abc() {
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn two_block_message() {
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn million_a() {
        assert_eq!(
            sha256_hex(&vec![b'a'; 1_000_000]),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn streaming_matches_one_shot_at_every_split() {
        let data: Vec<u8> = (0..300u32).map(|i| (i * 7 % 256) as u8).collect();
        let whole = sha256_hex(&data);
        for split in [0, 1, 63, 64, 65, 128, 200, 299, 300] {
            let mut s = Sha256::new();
            s.update(&data[..split]);
            s.update(&data[split..]);
            let mut hex = String::new();
            for b in s.finish() {
                use std::fmt::Write as _;
                let _ = write!(hex, "{b:02x}");
            }
            assert_eq!(hex, whole, "split at {split}");
        }
    }

    #[test]
    fn padding_boundaries() {
        // Lengths straddling the 55/56-byte padding boundary exercise the
        // two-block finalization path.
        assert_eq!(
            sha256_hex(&[0u8; 55]),
            "02779466cdec163811d078815c633f21901413081449002f24aa3e80f0b88ef7"
        );
        assert_eq!(
            sha256_hex(&[0u8; 56]),
            "d4817aa5497628e7c77e6b606107042bbba3130888c5f47a375e6179be789fbb"
        );
        assert_eq!(
            sha256_hex(&[0u8; 64]),
            "f5a5fd42d16a20302798ef6ed309979b43003d2320d9f0e8ea9831a92759fb4b"
        );
    }
}
