//! PCG64 (XSL-RR) — deterministic, seedable RNG for everything random in the
//! toolkit: the Random solver, randomized SVD sketches, synthetic datasets,
//! and data shuffling. No external dependency so results are reproducible
//! byte-for-byte across machines.

/// PCG-XSL-RR 128/64 generator (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct streams are
    /// independent — datasets, solvers and shuffles each get their own.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's method without bias correction is fine for our n << 2^64,
        // but keep it exact via rejection to protect property tests.
        let zone = u64::MAX - (u64::MAX % n as u64);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n as u64) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-12 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(1, 2);
        let mut b = Pcg64::new(1, 2);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(1, 0);
        let mut b = Pcg64::new(1, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::seeded(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(6);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
