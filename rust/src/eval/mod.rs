//! Evaluation harnesses: classifier accuracy and in-context-learning
//! accuracy, plus the latency instrumentation Figure 2's speedup axis needs.
//!
//! All harnesses execute through [`Backend`], so the same evaluation runs on
//! the PJRT engine (artifacts present) or the native CPU interpreter
//! (hermetic checkouts) — `&Engine` call sites coerce unchanged.

use crate::backend::{
    generate, generate_speculative, sample_token, Backend, DecodeSession, SamplingCfg, SpecConfig,
};
use crate::data::lm::{compose_prompt, IclPrompt};
use crate::data::{batch, vocab, Dataset, Split};
use crate::runtime::GraphSpec;
use crate::tensor::{ParamStore, Tensor};
use crate::util::Stopwatch;
use crate::Result;

/// Accuracy + timing of one evaluation run.
#[derive(Clone, Debug)]
pub struct EvalResult {
    /// Correctly classified examples.
    pub correct: usize,
    /// Examples scored.
    pub total: usize,
    /// Seconds per forward batch (median).
    pub sec_per_batch: f64,
    /// End-to-end examples/second.
    pub throughput: f64,
}

impl EvalResult {
    /// correct / total.
    pub fn accuracy(&self) -> f64 {
        self.correct as f64 / self.total.max(1) as f64
    }
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Evaluate a classifier graph on `examples` held-out examples.
/// `image_hw` selects the image collation path.
pub fn eval_classifier(
    backend: &dyn Backend,
    graph: &GraphSpec,
    params: &ParamStore,
    ds: &dyn Dataset,
    examples: usize,
    image_hw: Option<(usize, usize, usize)>,
) -> Result<EvalResult> {
    let bsz = graph.batch;
    // The graph's logit width is the model's class capacity (e.g. 4); the
    // task may use fewer classes (e.g. binary polarity). Stride by the
    // graph width, argmax over the task's classes only.
    let width = *graph.outputs[0]
        .shape
        .last()
        .ok_or_else(|| anyhow::anyhow!("classifier graph without class dim"))?;
    let classes = ds.num_classes().min(width);
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut sw = Stopwatch::new();
    let batches = examples.div_ceil(bsz);
    for bi in 0..batches {
        let (x, y) = batch(ds, Split::Eval, bi * bsz, bsz, image_hw);
        let out = sw.time(|| backend.run_fwd(graph, params, &[x]))?;
        let logits = out[0].as_f32()?;
        let labels = y.as_i32()?;
        let take = (examples - total).min(bsz);
        for i in 0..take {
            let row = &logits[i * width..i * width + classes];
            if argmax(row) == labels[i] as usize {
                correct += 1;
            }
        }
        total += take;
    }
    let sec = sw.median_secs();
    Ok(EvalResult {
        correct,
        total,
        sec_per_batch: sec,
        throughput: bsz as f64 / sec.max(1e-12),
    })
}

/// Score one composed ICL prompt from LM logits: argmax over the label-token
/// logits at the predict position.
pub fn score_prompt(logits: &Tensor, row: usize, prompt: &IclPrompt) -> Result<usize> {
    let (vocab_size, seq) = {
        let s = &logits.shape;
        (s[2], s[1])
    };
    debug_assert!(prompt.predict_pos < seq);
    let data = logits.as_f32()?;
    let base = (row * seq + prompt.predict_pos) * vocab_size;
    let label_logits: Vec<f32> = (0..prompt.num_classes)
        .map(|c| data[base + (vocab::LABEL_BASE as usize) + c])
        .collect();
    Ok(argmax(&label_logits))
}

/// Few-shot evaluation of the causal LM on a text task.
pub fn eval_icl(
    backend: &dyn Backend,
    graph: &GraphSpec,
    params: &ParamStore,
    task: &dyn Dataset,
    k_shots: usize,
    examples: usize,
    seed: u64,
) -> Result<EvalResult> {
    let bsz = graph.batch;
    let seq = graph.inputs[0].shape[1];
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut sw = Stopwatch::new();
    let batches = examples.div_ceil(bsz);
    for bi in 0..batches {
        let prompts: Vec<IclPrompt> = (0..bsz)
            .map(|i| compose_prompt(task, k_shots, bi * bsz + i, seq, seed))
            .collect();
        let mut toks = Vec::with_capacity(bsz * seq);
        for p in &prompts {
            toks.extend_from_slice(&p.tokens);
        }
        let x = Tensor::from_i32(&[bsz, seq], toks);
        let out = sw.time(|| backend.run_fwd(graph, params, &[x]))?;
        let take = (examples - total).min(bsz);
        for (i, p) in prompts.iter().take(take).enumerate() {
            if score_prompt(&out[0], i, p)? == p.label {
                correct += 1;
            }
        }
        total += take;
    }
    let sec = sw.median_secs();
    Ok(EvalResult {
        correct,
        total,
        sec_per_batch: sec,
        throughput: bsz as f64 / sec.max(1e-12),
    })
}

/// Latency profile of KV-cached autoregressive decoding: the prefill cost
/// and the per-token step distribution — the two numbers that price a
/// generation server, reported separately because factorization moves them
/// differently (prefill is GEMM-bound like training, decode steps are
/// matvec-bound).
#[derive(Clone, Copy, Debug)]
pub struct DecodeLatency {
    /// Median prefill wall time (seconds) over the prompt.
    pub prefill_s: f64,
    /// Median single-token decode step (seconds).
    pub per_token_p50_s: f64,
    /// 95th-percentile single-token decode step (seconds).
    pub per_token_p95_s: f64,
    /// Aggregate decode throughput: generated tokens / total step time.
    pub tokens_per_sec: f64,
    /// Prompt length each iteration prefilled.
    pub prefill_tokens: usize,
    /// Tokens generated per iteration.
    pub new_tokens: usize,
}

/// Measure KV-cached decode latency on `graph`/`params`: each iteration
/// opens a fresh [`DecodeSession`], prefills `prompt`, then generates
/// `new_tokens` greedily, timing the prefill and every single-token step
/// (`warmup` whole iterations are discarded). Requires a backend that
/// implements [`Backend::run_decode_step`] — i.e. the native interpreter.
pub fn measure_decode_latency(
    backend: &dyn Backend,
    graph: &GraphSpec,
    params: &ParamStore,
    prompt: &[i32],
    new_tokens: usize,
    warmup: usize,
    iters: usize,
) -> Result<DecodeLatency> {
    measure_decode_latency_prec(
        backend,
        graph,
        params,
        crate::factorize::WeightPrecision::F32,
        prompt,
        new_tokens,
        warmup,
        iters,
    )
}

/// [`measure_decode_latency`] with a weight-precision axis: sessions are
/// opened at `precision`, so int8 / binary serving is timed over the same
/// prompt/step schedule as f32. The one-off quantization pass runs once per
/// measurement (not per iteration) — the pre-packed store is cloned into
/// each fresh session behind an `Arc`.
#[allow(clippy::too_many_arguments)]
pub fn measure_decode_latency_prec(
    backend: &dyn Backend,
    graph: &GraphSpec,
    params: &ParamStore,
    precision: crate::factorize::WeightPrecision,
    prompt: &[i32],
    new_tokens: usize,
    warmup: usize,
    iters: usize,
) -> Result<DecodeLatency> {
    if prompt.is_empty() || new_tokens == 0 || iters == 0 {
        anyhow::bail!("measure_decode_latency needs a prompt, new_tokens >= 1 and iters >= 1");
    }
    // Quantize once, outside the timed region; each iteration's fresh
    // session shares the packed store behind the Arc.
    let quant = if precision == crate::factorize::WeightPrecision::F32 {
        None
    } else {
        Some(std::sync::Arc::new(crate::factorize::quantize_led_params(params, precision)?.0))
    };
    let greedy = SamplingCfg::greedy();
    let mut rng = greedy.rng();
    let mut sw_prefill = Stopwatch::new();
    let mut sw_step = Stopwatch::new();
    for it in 0..warmup + iters {
        let measured = it >= warmup;
        let mut session = match &quant {
            Some(store) => DecodeSession::with_quant_store(graph, params, store.clone())?,
            None => DecodeSession::new(graph, params)?,
        };
        let mut logits = if measured {
            sw_prefill.time(|| backend.run_decode_step(graph, params, &mut session, prompt))?
        } else {
            backend.run_decode_step(graph, params, &mut session, prompt)?
        };
        for _ in 0..new_tokens {
            if session.remaining() == 0 {
                anyhow::bail!(
                    "prompt {} + new_tokens {new_tokens} exceeds the model's seq capacity {}",
                    prompt.len(),
                    session.max_seq()
                );
            }
            let tok = sample_token(logits.as_f32()?, &greedy, &mut rng) as i32;
            logits = if measured {
                sw_step.time(|| backend.run_decode_step(graph, params, &mut session, &[tok]))?
            } else {
                backend.run_decode_step(graph, params, &mut session, &[tok])?
            };
        }
    }
    Ok(DecodeLatency {
        prefill_s: sw_prefill.median_secs(),
        per_token_p50_s: sw_step.median_secs(),
        per_token_p95_s: sw_step.p95_secs(),
        tokens_per_sec: (iters * new_tokens) as f64 / sw_step.total_secs().max(1e-12),
        prefill_tokens: prompt.len(),
        new_tokens,
    })
}

/// Aggregate throughput of decoding several concurrent streams, measured
/// under the two scheduling policies the coordinator has known: round-robin
/// (each stream advanced by its own solo [`Backend::run_decode_step`], the
/// pre-continuous-batching dispatcher) and stacked (all streams advanced by
/// one [`Backend::run_decode_step_batched`] call per token step).
#[derive(Clone, Copy, Debug)]
pub struct BatchedDecodeThroughput {
    /// Concurrent streams decoded.
    pub sessions: usize,
    /// Tokens generated per stream per iteration.
    pub new_tokens: usize,
    /// Aggregate tokens/sec with all streams stacked into one batched step.
    pub batched_tps: f64,
    /// Aggregate tokens/sec advancing each stream with its own solo step.
    pub roundrobin_tps: f64,
}

impl BatchedDecodeThroughput {
    /// Stacked throughput over round-robin throughput (> 1.0 when the
    /// packed GEMM wins).
    pub fn speedup(&self) -> f64 {
        self.batched_tps / self.roundrobin_tps.max(1e-12)
    }
}

/// Measure continuous-batching decode throughput: each iteration prefills
/// one fresh [`DecodeSession`] per prompt, then generates `new_tokens`
/// greedily per stream — once advancing every stream with solo steps
/// (round-robin) and once advancing all of them with stacked batched steps.
/// Only the post-prefill token steps are timed (prefill cost is
/// [`measure_decode_latency`]'s number). The two schedules are
/// value-identical by construction, and this harness re-checks that: it
/// fails if the token streams diverge. Requires a backend with a native
/// decode path; `warmup` whole iterations are discarded.
///
/// # Examples
///
/// ```
/// use greenformer::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
/// use greenformer::backend::NativeBackend;
/// use greenformer::eval::measure_batched_decode;
///
/// let cfg = TextModelCfg { vocab: 48, seq: 12, d: 24, heads: 6, layers: 1, ff: 32, classes: 48 };
/// let params = init_text_params(&cfg, 7);
/// let graph = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
/// let prompts = vec![vec![1, 2, 3], vec![4, 5, 6]];
/// let t = measure_batched_decode(&NativeBackend::new(), &graph, &params, &prompts, 4, 0, 1)
///     .unwrap();
/// assert_eq!(t.sessions, 2);
/// assert!(t.batched_tps > 0.0 && t.roundrobin_tps > 0.0);
/// ```
pub fn measure_batched_decode(
    backend: &dyn Backend,
    graph: &GraphSpec,
    params: &ParamStore,
    prompts: &[Vec<i32>],
    new_tokens: usize,
    warmup: usize,
    iters: usize,
) -> Result<BatchedDecodeThroughput> {
    if prompts.is_empty() || new_tokens == 0 || iters == 0 {
        anyhow::bail!("measure_batched_decode needs prompts, new_tokens >= 1 and iters >= 1");
    }
    let m = prompts.len();
    let greedy = SamplingCfg::greedy();
    let mut rng = greedy.rng();
    // Prefill all streams and sample each one's first next-token.
    let prefill = |sessions: &mut Vec<DecodeSession>,
                   last: &mut Vec<i32>,
                   rng: &mut crate::util::Pcg64|
     -> Result<()> {
        sessions.clear();
        last.clear();
        for prompt in prompts {
            let mut s = DecodeSession::new(graph, params)?;
            let logits = backend.run_decode_step(graph, params, &mut s, prompt)?;
            if s.remaining() < new_tokens {
                anyhow::bail!(
                    "prompt {} + new_tokens {new_tokens} exceeds the model's seq capacity {}",
                    prompt.len(),
                    s.max_seq()
                );
            }
            last.push(sample_token(logits.as_f32()?, &greedy, rng) as i32);
            sessions.push(s);
        }
        Ok(())
    };

    let mut sw_rr = Stopwatch::new();
    let mut sw_batched = Stopwatch::new();
    let mut sessions: Vec<DecodeSession> = Vec::with_capacity(m);
    let mut last: Vec<i32> = Vec::with_capacity(m);
    let mut rr_streams: Vec<Vec<i32>> = Vec::new();
    let mut batched_streams: Vec<Vec<i32>> = Vec::new();
    for it in 0..warmup + iters {
        let measured = it >= warmup;

        // Round-robin schedule: one solo step per stream per token.
        prefill(&mut sessions, &mut last, &mut rng)?;
        rr_streams = last.iter().map(|&t| vec![t]).collect();
        for _ in 0..new_tokens {
            for (i, s) in sessions.iter_mut().enumerate() {
                let tok = last[i];
                let logits = if measured {
                    sw_rr.time(|| backend.run_decode_step(graph, params, s, &[tok]))?
                } else {
                    backend.run_decode_step(graph, params, s, &[tok])?
                };
                last[i] = sample_token(logits.as_f32()?, &greedy, &mut rng) as i32;
                rr_streams[i].push(last[i]);
            }
        }

        // Stacked schedule: one batched step over all streams per token.
        prefill(&mut sessions, &mut last, &mut rng)?;
        batched_streams = last.iter().map(|&t| vec![t]).collect();
        for _ in 0..new_tokens {
            let step = {
                let mut refs: Vec<&mut DecodeSession> = sessions.iter_mut().collect();
                if measured {
                    sw_batched
                        .time(|| backend.run_decode_step_batched(graph, params, &mut refs, &last))?
                } else {
                    backend.run_decode_step_batched(graph, params, &mut refs, &last)?
                }
            };
            for (i, logits) in step.iter().enumerate() {
                last[i] = sample_token(logits.as_f32()?, &greedy, &mut rng) as i32;
                batched_streams[i].push(last[i]);
            }
        }
    }
    // Greedy decoding + value-identical steps ⇒ the schedules must agree.
    anyhow::ensure!(
        rr_streams == batched_streams,
        "batched decode diverged from round-robin decode"
    );
    let total = (iters * m * new_tokens) as f64;
    Ok(BatchedDecodeThroughput {
        sessions: m,
        new_tokens,
        batched_tps: total / sw_batched.total_secs().max(1e-12),
        roundrobin_tps: total / sw_rr.total_secs().max(1e-12),
    })
}

/// Throughput of speculative decoding (LED draft proposes, dense target
/// verifies) against plain single-token decoding of the same target — the
/// numbers that price factorization as a draft/verify serving lever: how
/// much faster the stream runs, and what fraction of cheap drafts the
/// target accepted (the paper's accuracy-retention claim, operationalized).
#[derive(Clone, Copy, Debug)]
pub struct SpecDecodeReport {
    /// Tokens generated per iteration (same for both schedules).
    pub new_tokens: usize,
    /// Aggregate tokens/sec of the speculative draft+verify loop.
    pub spec_tps: f64,
    /// Aggregate tokens/sec of plain greedy decoding of the target.
    pub plain_tps: f64,
    /// Fraction of drafted tokens the target accepted, over all measured
    /// iterations.
    pub acceptance_rate: f64,
    /// Total draft tokens proposed across measured iterations.
    pub drafted: u64,
    /// Total draft tokens accepted across measured iterations.
    pub accepted: u64,
}

impl SpecDecodeReport {
    /// Speculative throughput over plain throughput (> 1.0 when drafting
    /// pays for itself).
    pub fn speedup(&self) -> f64 {
        self.spec_tps / self.plain_tps.max(1e-12)
    }
}

/// Measure speculative-decode throughput: each iteration generates
/// `new_tokens` greedily from `prompt` twice — once with plain
/// [`generate`] on the target checkpoint, once with
/// [`generate_speculative`] over the `draft` checkpoint (built by
/// [`crate::backend::build_draft_params`]; it shares the target's graph) —
/// timing each full loop. `warmup` whole iterations are discarded.
///
/// Greedy speculative decoding is token-for-token identical to plain
/// greedy decoding by construction (see [`crate::backend::spec`]), and
/// this harness re-checks that: it fails if the streams diverge, so a
/// throughput number can never come from a decode that changed the output.
///
/// # Examples
///
/// ```
/// use greenformer::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
/// use greenformer::backend::{build_draft_params, NativeBackend, SpecConfig};
/// use greenformer::eval::measure_spec_decode;
///
/// let cfg = TextModelCfg { vocab: 48, seq: 12, d: 24, heads: 6, layers: 1, ff: 32, classes: 48 };
/// let params = init_text_params(&cfg, 7);
/// let draft = build_draft_params(&params, 0.5).unwrap();
/// let graph = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
/// let spec = SpecConfig { k: 2, ..Default::default() };
/// let r = measure_spec_decode(
///     &NativeBackend::new(), &graph, &params, &draft, &[1, 2, 3], 4, &spec, 0, 1,
/// )
/// .unwrap();
/// assert_eq!(r.new_tokens, 4);
/// assert!(r.spec_tps > 0.0 && r.plain_tps > 0.0);
/// ```
#[allow(clippy::too_many_arguments)]
pub fn measure_spec_decode(
    backend: &dyn Backend,
    graph: &GraphSpec,
    target: &ParamStore,
    draft: &ParamStore,
    prompt: &[i32],
    new_tokens: usize,
    spec: &SpecConfig,
    warmup: usize,
    iters: usize,
) -> Result<SpecDecodeReport> {
    if prompt.is_empty() || new_tokens == 0 || iters == 0 {
        anyhow::bail!("measure_spec_decode needs a prompt, new_tokens >= 1 and iters >= 1");
    }
    spec.validate()?;
    let greedy = SamplingCfg::greedy();
    let mut sw_plain = Stopwatch::new();
    let mut sw_spec = Stopwatch::new();
    let mut drafted = 0u64;
    let mut accepted = 0u64;
    let mut emitted = 0usize;
    for it in 0..warmup + iters {
        let measured = it >= warmup;
        let plain = if measured {
            sw_plain.time(|| generate(backend, graph, target, prompt, new_tokens, &greedy, |_, _| {}))?
        } else {
            generate(backend, graph, target, prompt, new_tokens, &greedy, |_, _| {})?
        };
        let run_spec = || {
            generate_speculative(
                backend, graph, target, graph, draft, prompt, new_tokens, &greedy, spec, |_, _| {},
            )
        };
        let spec_out = if measured { sw_spec.time(run_spec)? } else { run_spec()? };
        anyhow::ensure!(
            plain.tokens == spec_out.tokens,
            "speculative greedy stream diverged from plain greedy stream"
        );
        if measured {
            drafted += spec_out.drafted;
            accepted += spec_out.accepted;
            emitted += spec_out.tokens.len();
        }
    }
    Ok(SpecDecodeReport {
        new_tokens,
        spec_tps: emitted as f64 / sw_spec.total_secs().max(1e-12),
        plain_tps: emitted as f64 / sw_plain.total_secs().max(1e-12),
        acceptance_rate: if drafted == 0 { 0.0 } else { accepted as f64 / drafted as f64 },
        drafted,
        accepted,
    })
}

/// Median latency (seconds) of a single forward pass of `graph`, after
/// `warmup` discarded runs — the speedup axis of Figure 2.
pub fn measure_latency(
    backend: &dyn Backend,
    graph: &GraphSpec,
    params: &ParamStore,
    inputs: &[Tensor],
    warmup: usize,
    iters: usize,
) -> Result<f64> {
    for _ in 0..warmup {
        backend.run_fwd(graph, params, inputs)?;
    }
    let mut sw = Stopwatch::new();
    for _ in 0..iters {
        sw.time(|| backend.run_fwd(graph, params, inputs))?;
    }
    Ok(sw.median_secs())
}

/// Throughput/latency of the HTTP front end as measured through a real
/// socket (the `BENCH_HTTP` numbers).
#[derive(Clone, Debug)]
pub struct HttpServingThroughput {
    /// Requests that completed with a 2xx.
    pub ok: usize,
    /// Requests that came back non-2xx (sheds count here).
    pub rejected: usize,
    /// End-to-end requests per second over the whole run.
    pub rps: f64,
    /// Median per-request wall time, microseconds.
    pub p50_us: u64,
    /// 95th-percentile per-request wall time, microseconds.
    pub p95_us: u64,
}

/// Drive `requests` classify POSTs at `/v1/classify` through `clients`
/// concurrent connections against a live [`crate::serve_http::HttpServer`],
/// measuring through the real socket (connect + parse + serve + close per
/// request, `Connection: close` semantics — exactly what an external
/// client pays).
pub fn measure_http_serving(
    addr: std::net::SocketAddr,
    body: &str,
    requests: usize,
    clients: usize,
) -> Result<HttpServingThroughput> {
    use crate::serve_http::client;
    use std::time::{Duration, Instant};

    let clients = clients.max(1);
    let started = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let n = requests / clients + usize::from(c < requests % clients);
        let body = body.to_string();
        joins.push(std::thread::spawn(move || -> Result<(usize, usize, Vec<u64>)> {
            let mut ok = 0;
            let mut rejected = 0;
            let mut lat = Vec::with_capacity(n);
            for _ in 0..n {
                let t0 = Instant::now();
                let reply =
                    client::request(addr, "/v1/classify", Some(&body), Duration::from_secs(10))?;
                lat.push(t0.elapsed().as_micros() as u64);
                if reply.status == 200 {
                    ok += 1;
                } else {
                    rejected += 1;
                }
            }
            Ok((ok, rejected, lat))
        }));
    }
    let mut ok = 0;
    let mut rejected = 0;
    let mut lat = Vec::with_capacity(requests);
    for j in joins {
        let (o, r, l) = j.join().expect("http client thread")?;
        ok += o;
        rejected += r;
        lat.extend(l);
    }
    let elapsed = started.elapsed().as_secs_f64().max(1e-9);
    lat.sort_unstable();
    let pct = |p: f64| -> u64 {
        if lat.is_empty() {
            return 0;
        }
        let idx = ((lat.len() as f64 - 1.0) * p / 100.0).round() as usize;
        lat[idx.min(lat.len() - 1)]
    };
    Ok(HttpServingThroughput {
        ok,
        rejected,
        rps: (ok + rejected) as f64 / elapsed,
        p50_us: pct(50.0),
        p95_us: pct(95.0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }

    #[test]
    fn eval_result_accuracy() {
        let r = EvalResult {
            correct: 3,
            total: 4,
            sec_per_batch: 0.1,
            throughput: 80.0,
        };
        assert!((r.accuracy() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn score_prompt_reads_label_slot() {
        // logits: (1, 4, 16) with a peak at LABEL_BASE+1 at position 2.
        let seq = 4;
        let v = 16;
        let mut data = vec![0.0f32; seq * v];
        data[2 * v + (vocab::LABEL_BASE as usize) + 1] = 9.0;
        let logits = Tensor::from_f32(&[1, seq, v], data);
        let p = IclPrompt {
            tokens: vec![0; seq],
            label: 1,
            predict_pos: 2,
            num_classes: 3,
        };
        assert_eq!(score_prompt(&logits, 0, &p).unwrap(), 1);
    }
}
