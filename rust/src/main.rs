//! `greenformer` — CLI launcher for the factorization toolkit.
//!
//! Subcommands map 1:1 onto the library's public API; see `README.md` for a
//! tour. Everything runs against the AOT artifacts built by `make artifacts`.
//! (Arg parsing is hand-rolled: the offline build has no clap.)

use std::collections::HashMap;
use std::path::PathBuf;

use greenformer::backend::native::{demo_variants, init_text_params, synth_fwd_graph, TextModelCfg};
use greenformer::backend::{
    build_draft_params, generate as lm_generate, generate_batched as lm_generate_batched,
    generate_speculative as lm_generate_speculative, generate_with_session, DecodeSession,
    NativeBackend, SamplingCfg, SpecConfig,
};
use greenformer::config::ExperimentConfig;
use greenformer::coordinator::{
    serve_classifier, serve_classifier_native, RoutePolicy, Router, ServeConfig, Tier,
};
use greenformer::data::image::{all_image_tasks, HW};
use greenformer::data::text::all_text_tasks;
use greenformer::data::Dataset;
use greenformer::experiments::{self, ExpParams};
use greenformer::factorize::{auto_fact, quantize_led_params, Solver, WeightPrecision};
use greenformer::registry::ModelRegistry;
use greenformer::runtime::Engine;
use greenformer::serve_http::{HttpConfig, HttpServer};
use greenformer::tensor::ParamStore;
use greenformer::train::{checkpoint, Trainer};
use greenformer::Result;

const USAGE: &str = "\
greenformer — factorization toolkit for efficient DNNs (paper reproduction)

USAGE: greenformer [--artifacts DIR] [--backend auto|native|pjrt] <command> [options]

COMMANDS:
  info                                  show the artifact manifest summary
  factorize --input F --output F        auto_fact a GTZ checkpoint
            [--ratio 0.25] [--rank N] [--solver svd|snmf|random|tt|auto]
            [--tt-modes 3] [--tt-energy 0.9] [--tt-max-rank N]
            (tt replaces linears with TT core chains when the cores beat
            dense on bytes; auto picks dense|LED|TT per layer by bytes at
            the shared --tt-energy budget)
            [--num-iter 50] [--submodule S]...
            [--precision f32|int8|binary] report the post-SVD quantization
            pass (bytes + worst-case logit bound; checkpoint stays f32)
  train     [--model text] [--variant dense] [--task polarity]
            [--steps 300] [--out-dir runs]
  eval      --ckpt F [--model text] [--variant dense] [--task polarity]
            [--examples 256] [--batch 8]
  run       --config F                  config-driven experiment (JSON)
  fig2      [--use-case by-design|post-training|icl] [--quick] [--steps N]
  report-cost                           cost-model table (E5)
  report-solvers                        solver comparison table (E6)
  report-quant [--quick]                quantized-decode panel: tok/s,
            greedy agreement vs f32, bytes and |dlogit| bound per precision
  serve-demo [--requests 200] [--train-steps 60] [--max-sessions 64]
  serve-http [--addr 127.0.0.1:8790] [--registry manifest.json]
            [--max-connections 64] [--max-sessions 64]
            hardened HTTP front end over the fail-closed model registry
            (SERVING.md): GET /v1/healthz /v1/models /v1/metrics, POST
            /v1/classify, POST /v1/generate (chunked ndjson token stream).
            Without --registry, installs a demo registry (text-demo +
            lm-demo) so the server is exercisable artifact-free.
  registry-hash --file F                print a file's sha256 hex (for
            authoring registry-manifest checkpoint pins)
  generate  [--max-new 32] [--temperature 0.0] [--top-k 0] [--seed 42]
            [--prompt "3,17,42" | --prompt-len 16] [--ratio 0.25]
            [--model-seed 42] [--stats] [--sessions 1]
            [--precision f32|int8|binary]
            [--speculative [--draft-ratio 0.25] [-k 4] [--adaptive-k]]
            KV-cached autoregressive decoding on a synthetic LM
            (artifact-free; random init, factorized when --ratio is given).
            --sessions N decodes N staggered prompts concurrently through
            the continuous-batching stacked step (see SERVING.md).
            --precision packs the LED/dense linears into int8 or binary
            once per session and decodes through the quantized kernels
            (DESIGN.md §12); --stats then profiles at that precision.
            --speculative drafts -k tokens per round on an LED rank-cut
            copy (SVD at --draft-ratio) and verifies them in one stacked
            target pass; greedy output is identical to the plain stream

Backends: pjrt executes the AOT artifacts; native is the pure-Rust CPU
interpreter (no artifacts needed — it trains too, via the grad module, and
decodes incrementally via the KV cache). eval, fig2, serve-demo and
generate honor --backend; train/run need pjrt artifacts; generate is
native-only (AOT fwd graphs have no cache inputs).
Native fig2 runs artifact-free end to end; keep step budgets small
(--quick / --steps / GREENFORMER_STEPS) — it is interpreted, not compiled.

Tasks: polarity | topic | matching (text), shapes | blobs (image).
Env: GREENFORMER_ARTIFACTS, GREENFORMER_STEPS, GREENFORMER_EVAL.";

/// Tiny argv helper: `--key value` flags, `--flag` booleans, repeatables.
struct Args {
    argv: Vec<String>,
}

impl Args {
    fn get(&self, key: &str) -> Option<String> {
        self.argv
            .iter()
            .position(|a| a == key)
            .and_then(|i| self.argv.get(i + 1).cloned())
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn has(&self, key: &str) -> bool {
        self.argv.iter().any(|a| a == key)
    }

    fn all(&self, key: &str) -> Vec<String> {
        self.argv
            .iter()
            .enumerate()
            .filter(|(_, a)| *a == key)
            .filter_map(|(i, _)| self.argv.get(i + 1).cloned())
            .collect()
    }

    fn required(&self, key: &str) -> Result<String> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required flag {key}\n\n{USAGE}"))
    }
}

fn artifacts_dir_arg(args: &Args) -> PathBuf {
    args.get("--artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(greenformer::artifacts_dir)
}

fn engine(args: &Args) -> Result<Engine> {
    Engine::load(artifacts_dir_arg(args))
}

/// Resolved `--backend` choice (auto = pjrt when a manifest exists).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BackendChoice {
    Native,
    Pjrt,
}

fn backend_choice(args: &Args) -> Result<BackendChoice> {
    match args.get_or("--backend", "auto").as_str() {
        "native" => Ok(BackendChoice::Native),
        "pjrt" => Ok(BackendChoice::Pjrt),
        "auto" => {
            // Probe the whole PJRT path: artifacts may exist while the
            // runtime is the offline stub — auto must fall back to native
            // then, matching serve_classifier's documented behavior. (The
            // probe engine is discarded; a second load at use time is an
            // accepted one-off CLI startup cost.)
            let dir = artifacts_dir_arg(args);
            if dir.join("manifest.json").exists() && Engine::load(dir).is_ok() {
                Ok(BackendChoice::Pjrt)
            } else {
                Ok(BackendChoice::Native)
            }
        }
        other => anyhow::bail!("unknown backend {other:?} (native|pjrt|auto)"),
    }
}

fn find_task(name: &str, seed: u64) -> Result<(Box<dyn Dataset>, bool)> {
    for t in all_text_tasks(64, seed) {
        if t.name() == name {
            return Ok((t, false));
        }
    }
    for t in all_image_tasks(seed) {
        if t.name() == name {
            return Ok((t, true));
        }
    }
    anyhow::bail!("unknown task {name:?} (polarity|topic|matching|shapes|blobs)")
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        println!("{USAGE}");
        return Ok(());
    };
    let args = Args {
        argv: argv[1..].to_vec(),
    };

    match cmd.as_str() {
        "info" => {
            let eng = engine(&args)?;
            let m = eng.manifest();
            println!("platform: {}", eng.platform());
            println!("graphs: {}", m.graphs.len());
            for g in &m.graphs {
                println!(
                    "  {:<28} kind={:<5} batch={:<3} params={} ({} tensors)",
                    g.name,
                    g.kind,
                    g.batch,
                    g.n_params,
                    g.params.len()
                );
            }
            println!("checkpoints: {}", m.checkpoints.len());
        }
        "factorize" => {
            let input = PathBuf::from(args.required("--input")?);
            let output = PathBuf::from(args.required("--output")?);
            let solver: Solver = args
                .get_or("--solver", "svd")
                .parse()
                .map_err(|e: String| anyhow::anyhow!(e))?;
            let rank = match args.get("--rank") {
                Some(r) => greenformer::factorize::Rank::Fixed(r.parse()?),
                None => greenformer::factorize::Rank::Ratio(args.parse_or("--ratio", 0.25)),
            };
            let submodules = args.all("--submodule");
            let precision: WeightPrecision = args.get_or("--precision", "f32").parse()?;
            let tt = greenformer::factorize::TtConfig {
                modes: args.parse_or("--tt-modes", 3usize),
                energy: args.parse_or("--tt-energy", 0.9f64),
                max_rank: match args.get("--tt-max-rank") {
                    Some(r) => Some(r.parse()?),
                    None => None,
                },
            };
            let mut params = ParamStore::load_gtz(&input)?;
            let report = auto_fact(
                &mut params,
                &greenformer::factorize::AutoFactConfig {
                    rank,
                    solver,
                    num_iter: args.parse_or("--num-iter", 50),
                    submodules: (!submodules.is_empty()).then_some(submodules),
                    tt,
                    precision,
                },
            )?;
            print!("{report}");
            params.save_gtz(&output)?;
            println!("wrote {output:?}");
        }
        "train" => {
            let eng = engine(&args)?;
            let model = args.get_or("--model", "text");
            let variant = args.get_or("--variant", "dense");
            let task = args.get_or("--task", "polarity");
            let steps = args.parse_or("--steps", 300usize);
            let out_dir = PathBuf::from(args.get_or("--out-dir", "runs"));
            let (ds, is_image) = find_task(&task, 42)?;
            let hw = is_image.then_some((HW, HW, 1usize));
            let mut trainer = Trainer::from_init(&eng, &model, &variant)?;
            println!(
                "training {model}/{variant} on {task}: {} params, batch {}",
                trainer.params.n_params(),
                trainer.batch_size()
            );
            trainer.train_classifier(ds.as_ref(), steps, hw, |log| {
                if log.step % 20 == 0 || log.step == 1 {
                    println!(
                        "  step {:>4}  loss {:.4}  ({:.0} ms)",
                        log.step,
                        log.loss,
                        log.seconds * 1e3
                    );
                }
            })?;
            let name = format!("{model}_{variant}_{task}");
            let path = checkpoint::save(&out_dir, &name, &trainer.params)?;
            println!("saved {path:?}");
        }
        "eval" => {
            let model = args.get_or("--model", "text");
            let variant = args.get_or("--variant", "dense");
            let task = args.get_or("--task", "polarity");
            let ckpt = PathBuf::from(args.required("--ckpt")?);
            let examples = args.parse_or("--examples", 256usize);
            let (ds, is_image) = find_task(&task, 42)?;
            let hw = is_image.then_some((HW, HW, 1usize));
            let mut params = ParamStore::load_gtz(&ckpt)?;
            let choice = backend_choice(&args)?;
            let ev = match choice {
                BackendChoice::Pjrt => {
                    let eng = engine(&args)?;
                    let graph = eng.manifest().find(&model, &variant, "fwd", None)?.clone();
                    params.reorder_to(
                        &graph.params.iter().map(|p| p.name.clone()).collect::<Vec<_>>(),
                    )?;
                    greenformer::eval::eval_classifier(
                        &eng,
                        &graph,
                        &params,
                        ds.as_ref(),
                        examples,
                        hw,
                    )?
                }
                BackendChoice::Native => {
                    let batch = args.parse_or("--batch", 8usize);
                    let graph = synth_fwd_graph(&model, &variant, batch, &params)?;
                    greenformer::eval::eval_classifier(
                        &NativeBackend::new(),
                        &graph,
                        &params,
                        ds.as_ref(),
                        examples,
                        hw,
                    )?
                }
            };
            println!(
                "{model}/{variant} on {task} [{:?}]: acc {:.3} ({}/{})  {:.2} ms/batch  {:.0} ex/s",
                choice,
                ev.accuracy(),
                ev.correct,
                ev.total,
                ev.sec_per_batch * 1e3,
                ev.throughput
            );
        }
        "run" => {
            let cfg = ExperimentConfig::load(args.required("--config")?)?;
            let eng = engine(&args)?;
            run_config(&eng, &cfg)?;
        }
        "fig2" => {
            let quick = args.has("--quick");
            let mut params = if quick {
                ExpParams::quick()
            } else {
                ExpParams::full()
            };
            if let Some(steps) = args.get("--steps") {
                params.steps = steps.parse()?;
            }
            let eng;
            let env = match backend_choice(&args)? {
                BackendChoice::Pjrt => {
                    eng = engine(&args)?;
                    experiments::FigEnv::Pjrt(&eng)
                }
                BackendChoice::Native => {
                    println!("native backend: synthesized graphs, random inits, CPU interpreter");
                    experiments::FigEnv::Native(experiments::NativeFigCfg::default())
                }
            };
            // Accept both spellings: by-design / by_design etc.
            let use_case = args.get_or("--use-case", "post-training").replace('_', "-");
            // An explicit --steps budget also caps the ICL LM pretrain, so
            // `--backend native --steps N` stays N-step cheap end to end.
            let pretrain = args.parse_or("--steps", if quick { 150 } else { 600 });
            let result = match use_case.as_str() {
                "by-design" => experiments::by_design(&env, &params)?,
                "post-training" => experiments::post_training(&env, &params, Solver::Svd)?,
                "icl" => experiments::icl(&env, &params, None, pretrain)?,
                other => anyhow::bail!("unknown use case {other:?}"),
            };
            print!("{}", result.render());
        }
        "report-cost" => {
            let rows = experiments::cost_table(&[0.10, 0.25, 0.50, 0.75]);
            print!("{}", experiments::tables::render_cost_table(&rows));
        }
        "report-solvers" => {
            let rows = experiments::solver_table(&[0.10, 0.25, 0.50, 0.75], 50);
            print!("{}", experiments::tables::render_solver_table(&rows));
        }
        "report-quant" => {
            let cfg = if args.has("--quick") {
                experiments::QuantPanelCfg::quick()
            } else {
                experiments::QuantPanelCfg::default()
            };
            print!("{}", experiments::quant_panel(&cfg)?.render());
        }
        "serve-demo" => {
            serve_demo(
                &args,
                args.parse_or("--requests", 200usize),
                args.parse_or("--train-steps", 60usize),
            )?;
        }
        "serve-http" => serve_http_cmd(&args)?,
        "registry-hash" => {
            let file = PathBuf::from(args.required("--file")?);
            let bytes = std::fs::read(&file)
                .map_err(|e| anyhow::anyhow!("reading {}: {e}", file.display()))?;
            println!("{}", greenformer::util::sha256_hex(&bytes));
        }
        "generate" => generate_cmd(&args)?,
        "help" | "--help" | "-h" => println!("{USAGE}"),
        other => {
            anyhow::bail!("unknown command {other:?}\n\n{USAGE}");
        }
    }
    Ok(())
}

fn run_config(eng: &Engine, cfg: &ExperimentConfig) -> Result<()> {
    let (ds, is_image) = find_task(&cfg.experiment.task, cfg.experiment.seed)?;
    let hw = is_image.then_some((HW, HW, 1usize));
    let model = &cfg.experiment.model;
    let variant = cfg.factorize.variant_name();

    println!("== {} ==", cfg.experiment.name);
    // by-design: train the factorized variant directly from its init.
    let mut trainer = Trainer::from_init(eng, model, &variant)?;
    trainer.train_classifier(ds.as_ref(), cfg.train.steps, hw, |log| {
        if log.step % cfg.train.log_every == 0 {
            println!("  step {:>4}  loss {:.4}", log.step, log.loss);
        }
    })?;
    let graph = eng.manifest().find(model, &variant, "fwd", None)?.clone();
    let ev = greenformer::eval::eval_classifier(
        eng,
        &graph,
        &trainer.params,
        ds.as_ref(),
        cfg.train.eval_examples,
        hw,
    )?;
    println!(
        "{model}/{variant} on {}: acc {:.3}  ({:.2} ms/batch)",
        cfg.experiment.task,
        ev.accuracy(),
        ev.sec_per_batch * 1e3
    );
    Ok(())
}

/// `serve-http`: stand up the hardened HTTP front end over a model
/// registry — either loaded fail-closed from a `--registry` manifest
/// (checkpoint hashes verified), or an artifact-free demo registry with a
/// classifier (`text-demo`) and a generator (`lm-demo`). Blocks until
/// killed.
fn serve_http_cmd(args: &Args) -> Result<()> {
    let addr = args.get_or("--addr", "127.0.0.1:8790");
    let serve_cfg = ServeConfig {
        max_sessions: args.parse_or("--max-sessions", ServeConfig::default().max_sessions),
        ..ServeConfig::default()
    };
    let registry = std::sync::Arc::new(ModelRegistry::with_serve_config(serve_cfg));

    if let Some(path) = args.get("--registry") {
        let report = registry.load_and_apply(std::path::Path::new(&path))?;
        for name in &report.installed {
            println!("installed {name}");
        }
        for (name, err) in &report.rejected {
            eprintln!("REJECTED {name}: {err}");
        }
        if registry.is_empty() {
            anyhow::bail!("no model installed from {path}");
        }
    } else {
        let cfg =
            TextModelCfg { vocab: 512, seq: 64, d: 64, heads: 4, layers: 2, ff: 128, classes: 4 };
        let (dense, led) = demo_variants(&cfg, 42, 0.25)?;
        let mut variants = HashMap::new();
        variants.insert("dense".to_string(), dense);
        variants.insert("led_r25".to_string(), led);
        registry.install_local(
            "text-demo",
            "text",
            "demo",
            "dense",
            variants,
            Some(RoutePolicy::Tiered {
                quality: "dense".into(),
                balanced: "dense".into(),
                fast: "led_r25".into(),
            }),
        )?;
        let lm_cfg =
            TextModelCfg { vocab: 256, seq: 96, d: 64, heads: 4, layers: 2, ff: 128, classes: 4 };
        let mut lm_variants = HashMap::new();
        lm_variants.insert("dense".to_string(), init_text_params(&lm_cfg, 7));
        registry.install_local("lm-demo", "lm", "demo", "dense", lm_variants, None)?;
        println!("demo registry: text-demo (classify) + lm-demo (generate)");
    }

    let http_cfg = HttpConfig {
        max_connections: args.parse_or("--max-connections", HttpConfig::default().max_connections),
        ..HttpConfig::default()
    };
    let server = HttpServer::bind(&addr, registry.clone(), http_cfg)?;
    println!("listening on http://{}", server.local_addr());
    println!("endpoints: GET /v1/healthz /v1/models /v1/metrics | POST /v1/classify /v1/generate");
    for m in registry.models() {
        println!(
            "  model {} family={} version={} epoch={} seq={} variants={:?}",
            m.name, m.family, m.version, m.epoch, m.seq, m.variants
        );
    }
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// `generate`: KV-cached autoregressive decoding on a synthetic LM —
/// artifact-free, streaming each sampled token to stdout as it exists.
/// Native-only: the PJRT AOT fwd graphs are fixed-shape full-sequence
/// executables with no cache inputs, so `--backend pjrt` is refused.
fn generate_cmd(args: &Args) -> Result<()> {
    if backend_choice(args)? == BackendChoice::Pjrt {
        anyhow::bail!(
            "generate needs --backend native: KV-cached decoding is native-only \
             (AOT fwd graphs have no cache inputs)"
        );
    }
    let max_new = args.parse_or("--max-new", 32usize);
    let sampling = SamplingCfg {
        temperature: args.parse_or("--temperature", 0.0f32),
        top_k: args.parse_or("--top-k", 0usize),
        seed: args.parse_or("--seed", 42u64),
    };
    let precision: WeightPrecision = args.get_or("--precision", "f32").parse()?;
    let cfg = TextModelCfg::lm_default();
    let mut params = init_text_params(&cfg, args.parse_or("--model-seed", 42u64));
    let mut variant = "dense".to_string();
    if let Some(r) = args.get("--ratio") {
        let ratio: f64 = r.parse()?;
        let report = greenformer::factorize::auto_fact(
            &mut params,
            &greenformer::factorize::AutoFactConfig {
                rank: greenformer::factorize::Rank::Ratio(ratio),
                solver: Solver::Random,
                num_iter: 0,
                submodules: None,
                tt: greenformer::factorize::TtConfig::default(),
                // The session packs its own quant store below; keep the
                // factorization pass itself precision-free.
                precision: WeightPrecision::F32,
            },
        )?;
        variant = format!("led_r{}", (ratio * 100.0).round() as usize);
        println!("factorized {} layers at ratio {ratio} (Random solver)", report.n_factorized());
    }
    let graph = synth_fwd_graph("lm", &variant, 1, &params)?;
    // Pack the quantized side-table once; sessions share it behind the Arc.
    let quant_store = if precision == WeightPrecision::F32 {
        None
    } else {
        let (store, qreport) = quantize_led_params(&params, precision)?;
        print!("{qreport}");
        Some(std::sync::Arc::new(store))
    };
    let prompt: Vec<i32> = match args.get("--prompt") {
        Some(s) => s
            .split(',')
            .map(|t| t.trim().parse::<i32>())
            .collect::<std::result::Result<_, _>>()?,
        None => {
            let n = args.parse_or("--prompt-len", 16usize).max(1);
            let mut rng = greenformer::util::Pcg64::new(sampling.seed, 11);
            (0..n).map(|_| rng.below(cfg.vocab) as i32).collect()
        }
    };
    println!(
        "lm/{variant} (native): d={} layers={} vocab={} seq={} | prompt {} tokens, max_new {}",
        cfg.d,
        cfg.layers,
        cfg.vocab,
        cfg.seq,
        prompt.len(),
        max_new
    );
    let be = NativeBackend::new();
    let sessions = args.parse_or("--sessions", 1usize).max(1);
    if args.has("--speculative") {
        if sessions > 1 {
            anyhow::bail!(
                "--speculative decodes one stream; drop --sessions (the serving layer runs \
                 speculative sessions concurrently — see ServeConfig.spec in SERVING.md)"
            );
        }
        if precision != WeightPrecision::F32 {
            anyhow::bail!(
                "--speculative runs f32 only: draft/target agreement is calibrated against \
                 f32 logits; drop --precision"
            );
        }
        return generate_speculative_cmd(args, &be, &graph, &params, &prompt, max_new, &sampling);
    }
    if sessions > 1 && precision != WeightPrecision::F32 {
        anyhow::bail!(
            "--sessions with --precision is not wired through generate_batched yet; \
             decode one quantized stream at a time"
        );
    }
    if sessions > 1 {
        // Continuous-batching path: decode N streams concurrently, one
        // stacked GEMM step per token. Streams get distinct prompts (the
        // base prompt plus per-stream random ones) so the printout shows
        // genuinely independent generations.
        let mut rng = greenformer::util::Pcg64::new(sampling.seed, 23);
        let mut prompts = vec![prompt.clone()];
        for _ in 1..sessions {
            let n = prompt.len().max(1);
            prompts.push((0..n).map(|_| rng.below(cfg.vocab) as i32).collect());
        }
        let cfgs = vec![sampling; sessions];
        let t0 = std::time::Instant::now();
        let outs = lm_generate_batched(&be, &graph, &params, &prompts, max_new, &cfgs)?;
        let secs = t0.elapsed().as_secs_f64();
        let mut total = 0usize;
        for (i, out) in outs.iter().enumerate() {
            total += out.tokens.len();
            let shown: Vec<String> = out.tokens.iter().map(|t| t.to_string()).collect();
            println!("stream {i}: {}", shown.join(" "));
        }
        println!(
            "{sessions} streams x {max_new} tokens: {total} tokens in {secs:.3}s \
             ({:.1} tok/s aggregate, stacked steps)",
            total as f64 / secs.max(1e-12)
        );
        return Ok(());
    }
    let t0 = std::time::Instant::now();
    print!("generated:");
    let stream = |_: usize, t: i32| {
        print!(" {t}");
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
    };
    let out = match &quant_store {
        Some(store) => {
            let mut session = DecodeSession::with_quant_store(&graph, &params, store.clone())?;
            generate_with_session(
                &be, &graph, &params, &mut session, &prompt, max_new, &sampling, stream,
            )?
        }
        None => lm_generate(&be, &graph, &params, &prompt, max_new, &sampling, stream)?,
    };
    println!();
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "{} tokens in {:.3}s ({:.1} tok/s end to end, {} positions cached, {} weights)",
        out.tokens.len(),
        secs,
        out.tokens.len() as f64 / secs.max(1e-12),
        out.positions_used,
        precision
    );
    if args.has("--stats") {
        let room = cfg.seq.saturating_sub(prompt.len());
        if room == 0 {
            println!("(prompt fills the context; no per-token profile to measure)");
            return Ok(());
        }
        let budget = room.min(max_new);
        let lat = greenformer::eval::measure_decode_latency_prec(
            &be, &graph, &params, precision, &prompt, budget, 1, 3,
        )?;
        println!(
            "decode profile ({precision}): prefill {:.2} ms ({} tok), per-token p50 {:.3} ms \
             p95 {:.3} ms, {:.1} tok/s steady-state",
            lat.prefill_s * 1e3,
            lat.prefill_tokens,
            lat.per_token_p50_s * 1e3,
            lat.per_token_p95_s * 1e3,
            lat.tokens_per_sec
        );
    }
    Ok(())
}

/// `generate --speculative`: draft on an LED rank-cut copy of the model,
/// verify each round in one stacked multi-row target pass, stream the
/// accepted tokens. Greedy output is token-for-token identical to the
/// plain `generate` stream — speculation changes speed, never content.
fn generate_speculative_cmd(
    args: &Args,
    be: &NativeBackend,
    graph: &greenformer::runtime::GraphSpec,
    params: &ParamStore,
    prompt: &[i32],
    max_new: usize,
    sampling: &SamplingCfg,
) -> Result<()> {
    let spec = SpecConfig {
        draft_ratio: args.parse_or("--draft-ratio", 0.25f64),
        k: args.parse_or("-k", args.parse_or("--spec-k", 4usize)),
        adaptive_k: args.has("--adaptive-k"),
    };
    spec.validate()?;
    let draft = build_draft_params(params, spec.draft_ratio)?;
    println!(
        "speculative: LED draft at ratio {} (SVD), k={}{}",
        spec.draft_ratio,
        spec.k,
        if spec.adaptive_k { " (adaptive)" } else { "" }
    );
    let t0 = std::time::Instant::now();
    print!("generated:");
    let out = lm_generate_speculative(
        be, graph, params, graph, &draft, prompt, max_new, sampling, &spec, |_, t| {
            print!(" {t}");
            use std::io::Write as _;
            let _ = std::io::stdout().flush();
        },
    )?;
    println!();
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "{} tokens in {:.3}s ({:.1} tok/s end to end): drafted {}, accepted {} \
         (acceptance {:.2}), {} rollbacks over {} rounds",
        out.tokens.len(),
        secs,
        out.tokens.len() as f64 / secs.max(1e-12),
        out.drafted,
        out.accepted,
        out.acceptance_rate(),
        out.rollbacks,
        out.steps
    );
    if args.has("--stats") {
        let seq = graph.config_usize("seq").unwrap_or(prompt.len() + max_new);
        let room = seq.saturating_sub(prompt.len());
        if room == 0 {
            println!("(prompt fills the context; no throughput profile to measure)");
            return Ok(());
        }
        let budget = room.min(max_new);
        let r = greenformer::eval::measure_spec_decode(
            be, graph, params, &draft, prompt, budget, &spec, 1, 3,
        )?;
        println!(
            "spec profile: {:.1} tok/s speculative vs {:.1} tok/s plain ({:.2}x), \
             acceptance {:.2} ({}/{} drafts)",
            r.spec_tps,
            r.plain_tps,
            r.speedup(),
            r.acceptance_rate,
            r.accepted,
            r.drafted
        );
    }
    Ok(())
}

fn serve_demo(args: &Args, requests: usize, train_steps: usize) -> Result<()> {
    let art_dir = artifacts_dir_arg(args);
    let choice = backend_choice(args)?;
    let (ds, _) = find_task("polarity", 42)?;

    let mut stores = HashMap::new();
    match choice {
        BackendChoice::Pjrt => {
            // Train dense + one factorized variant briefly so routing has a
            // quality/speed ladder.
            let eng = engine(args)?;
            println!("preparing variants (training {train_steps} steps each)...");
            for variant in ["dense", "led_r25"] {
                let mut t = Trainer::from_init(&eng, "text", variant)?;
                t.train_classifier(ds.as_ref(), train_steps, None, |_| {})?;
                stores.insert(variant.to_string(), t.params);
            }
        }
        BackendChoice::Native => {
            // Hermetic demo: random-init dense + a factorized variant (see
            // demo_variants for the Random-solver rationale). The routing/
            // batching/metrics path is identical; accuracy is meaningless
            // without training.
            println!("native backend: serving random-init checkpoints (no training)");
            let (dense, led) = demo_variants(&TextModelCfg::default(), 42, 0.25)?;
            stores.insert("dense".to_string(), dense);
            stores.insert("led_r25".to_string(), led);
        }
    }

    let router = Router::new(
        RoutePolicy::Adaptive {
            quality: "dense".into(),
            balanced: "dense".into(),
            fast: "led_r25".into(),
            low: 4,
            high: 8,
        },
        stores.keys().cloned().collect(),
    )?;

    let cfg = ServeConfig {
        max_sessions: args.parse_or("--max-sessions", ServeConfig::default().max_sessions),
        ..ServeConfig::default()
    };
    let handle = match choice {
        BackendChoice::Pjrt => serve_classifier(art_dir, "text", stores, router, cfg)?,
        BackendChoice::Native => serve_classifier_native("text", stores, router, cfg)?,
    };

    let mut joins = Vec::new();
    for i in 0..requests {
        let h = handle.clone();
        let ex = ds.example(greenformer::data::Split::Eval, i);
        joins.push(std::thread::spawn(move || {
            let tier = if i % 3 == 0 { Tier::Fast } else { Tier::Quality };
            let resp = h.classify(ex.tokens, tier)?;
            Ok::<(bool, String), anyhow::Error>((resp.label == ex.label, resp.variant))
        }));
    }
    let mut correct = 0usize;
    let mut by_variant: HashMap<String, usize> = HashMap::new();
    for j in joins {
        let (ok, variant) = j.join().expect("client thread")?;
        correct += ok as usize;
        *by_variant.entry(variant).or_insert(0) += 1;
    }
    println!("served {requests} requests: {correct} correct");
    println!("variant mix: {by_variant:?}");
    println!("metrics: {}", handle.metrics.summary());
    Ok(())
}
