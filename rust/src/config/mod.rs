//! JSON experiment configuration — the launcher's input format.
//!
//! `configs/*.json` drive the CLI (`greenformer run --config configs/x.json`)
//! and the experiment harnesses. Every field has a default so `{}` is a
//! valid config. (JSON rather than TOML: the offline build uses the in-tree
//! codec — see `util::json`.)

use std::path::Path;

use anyhow::anyhow;

use crate::factorize::{AutoFactConfig, Rank, Solver};
use crate::util::Json;
use crate::Result;

/// A full experiment description: what to train, how to factorize, how to
/// evaluate and serve.
#[derive(Clone, Debug, Default)]
pub struct ExperimentConfig {
    /// Identity: name, model family, task, seed.
    pub experiment: Experiment,
    /// Training budget and logging cadence.
    pub train: TrainConfig,
    /// Factorization policy (ratio/rank, solver, filter).
    pub factorize: FactorizeConfig,
    /// Evaluation budget.
    pub eval: EvalConfig,
    /// Serving/batching limits.
    pub serve: ServeConfig,
}

/// Experiment identity block.
#[derive(Clone, Debug)]
pub struct Experiment {
    /// Human-readable experiment name.
    pub name: String,
    /// "text" | "image" | "lm"
    pub model: String,
    /// Task name: polarity | topic | matching | shapes | blobs
    pub task: String,
    /// Seed for data generation and inits.
    pub seed: u64,
}

impl Default for Experiment {
    fn default() -> Self {
        Self {
            name: "experiment".into(),
            model: "text".into(),
            task: "polarity".into(),
            seed: 42,
        }
    }
}

/// Training budget.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Optimizer steps to run.
    pub steps: usize,
    /// Batch size (must match an available train graph).
    pub batch: usize,
    /// Print a loss line every this many steps.
    pub log_every: usize,
    /// Evaluate on this many held-out examples after training.
    pub eval_examples: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps: 300,
            batch: 32,
            log_every: 20,
            eval_examples: 256,
        }
    }
}

/// Factorization policy.
#[derive(Clone, Debug)]
pub struct FactorizeConfig {
    /// Rank ratio in (0, 1]; `rank` takes precedence when set.
    pub ratio: Option<f64>,
    /// Fixed integer rank.
    pub rank: Option<usize>,
    /// Solver name (`random` / `svd` / `snmf` / `tt` / `auto`).
    pub solver: String,
    /// SNMF iteration budget.
    pub num_iter: usize,
    /// Submodule filter (substring match), empty = all.
    pub submodules: Vec<String>,
    /// Serving-time weight precision (`f32` / `int8` / `binary`).
    pub precision: String,
}

impl Default for FactorizeConfig {
    fn default() -> Self {
        Self {
            ratio: Some(0.25),
            rank: None,
            solver: "svd".into(),
            num_iter: 50,
            submodules: vec![],
            precision: "f32".into(),
        }
    }
}

impl FactorizeConfig {
    /// Resolve into the [`AutoFactConfig`] the library call takes.
    pub fn to_auto_fact(&self) -> Result<AutoFactConfig> {
        let rank = match (self.rank, self.ratio) {
            (Some(r), _) => Rank::Fixed(r),
            (None, Some(ratio)) => Rank::Ratio(ratio),
            (None, None) => Rank::Ratio(0.25),
        };
        let solver: Solver = self.solver.parse().map_err(|e: String| anyhow!(e))?;
        Ok(AutoFactConfig {
            rank,
            solver,
            num_iter: self.num_iter,
            submodules: if self.submodules.is_empty() {
                None
            } else {
                Some(self.submodules.clone())
            },
            tt: Default::default(),
            precision: self.precision.parse()?,
        })
    }

    /// The artifact variant name this config's ratio maps to (graph naming
    /// contract with aot.py: led_r10/r25/r50/r75, dense otherwise).
    pub fn variant_name(&self) -> String {
        match self.ratio {
            Some(r) => format!("led_r{:02}", (r * 100.0).round() as usize),
            None => "dense".into(),
        }
    }
}

/// Evaluation budget.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Held-out examples to score.
    pub examples: usize,
    /// Exemplars per ICL prompt (LM experiments).
    pub k_shots: usize,
}

impl Default for EvalConfig {
    fn default() -> Self {
        Self {
            examples: 256,
            k_shots: 4,
        }
    }
}

/// Serving/batching limits.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Max requests per dynamic batch (padded to the artifact batch size).
    pub max_batch: usize,
    /// Batch assembly deadline in milliseconds.
    pub max_wait_ms: u64,
    /// Dispatcher queue capacity (submits block when full).
    pub queue_capacity: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait_ms: 5,
            queue_capacity: 1024,
        }
    }
}

impl ExperimentConfig {
    /// Load and parse a config file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| anyhow!("reading config {:?}: {e}", path.as_ref()))?;
        Self::parse(&text)
    }

    /// Parse config JSON; absent fields keep their defaults.
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let mut cfg = ExperimentConfig::default();
        if let Some(e) = v.get("experiment") {
            cfg.experiment.name = e.str_or("name", &cfg.experiment.name);
            cfg.experiment.model = e.str_or("model", &cfg.experiment.model);
            cfg.experiment.task = e.str_or("task", &cfg.experiment.task);
            cfg.experiment.seed = e.usize_or("seed", cfg.experiment.seed as usize) as u64;
        }
        if let Some(t) = v.get("train") {
            cfg.train.steps = t.usize_or("steps", cfg.train.steps);
            cfg.train.batch = t.usize_or("batch", cfg.train.batch);
            cfg.train.log_every = t.usize_or("log_every", cfg.train.log_every);
            cfg.train.eval_examples = t.usize_or("eval_examples", cfg.train.eval_examples);
        }
        if let Some(f) = v.get("factorize") {
            cfg.factorize.ratio = f.f64_opt("ratio").or(cfg.factorize.ratio);
            if f.get("ratio") == Some(&Json::Null) {
                cfg.factorize.ratio = None;
            }
            cfg.factorize.rank = f.get("rank").and_then(|r| r.as_usize().ok());
            cfg.factorize.solver = f.str_or("solver", &cfg.factorize.solver);
            cfg.factorize.num_iter = f.usize_or("num_iter", cfg.factorize.num_iter);
            cfg.factorize.precision = f.str_or("precision", &cfg.factorize.precision);
            if let Some(subs) = f.get("submodules") {
                cfg.factorize.submodules = subs
                    .as_arr()?
                    .iter()
                    .map(|s| Ok(s.as_str()?.to_string()))
                    .collect::<Result<_>>()?;
            }
        }
        if let Some(e) = v.get("eval") {
            cfg.eval.examples = e.usize_or("examples", cfg.eval.examples);
            cfg.eval.k_shots = e.usize_or("k_shots", cfg.eval.k_shots);
        }
        if let Some(s) = v.get("serve") {
            cfg.serve.max_batch = s.usize_or("max_batch", cfg.serve.max_batch);
            cfg.serve.max_wait_ms =
                s.usize_or("max_wait_ms", cfg.serve.max_wait_ms as usize) as u64;
            cfg.serve.queue_capacity = s.usize_or("queue_capacity", cfg.serve.queue_capacity);
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_config_is_all_defaults() {
        let cfg = ExperimentConfig::parse("{}").unwrap();
        assert_eq!(cfg.experiment.name, "experiment");
        assert_eq!(cfg.train.steps, 300);
        assert_eq!(cfg.factorize.solver, "svd");
        assert_eq!(cfg.serve.max_batch, 8);
    }

    #[test]
    fn partial_config_overrides() {
        let cfg = ExperimentConfig::parse(
            r#"{"experiment": {"name": "x", "task": "topic"},
                "train": {"steps": 50},
                "factorize": {"ratio": 0.5, "solver": "snmf",
                               "submodules": ["attn", "fc1"]}}"#,
        )
        .unwrap();
        assert_eq!(cfg.experiment.name, "x");
        assert_eq!(cfg.experiment.task, "topic");
        assert_eq!(cfg.train.steps, 50);
        assert_eq!(cfg.train.batch, 32); // default preserved
        assert_eq!(cfg.factorize.ratio, Some(0.5));
        assert_eq!(cfg.factorize.submodules, vec!["attn", "fc1"]);
        assert_eq!(cfg.factorize.variant_name(), "led_r50");
    }

    #[test]
    fn factorize_resolution() {
        let fc = FactorizeConfig {
            ratio: Some(0.5),
            ..Default::default()
        };
        let af = fc.to_auto_fact().unwrap();
        assert_eq!(af.rank, Rank::Ratio(0.5));
        let fixed = FactorizeConfig {
            rank: Some(16),
            ratio: None,
            ..Default::default()
        };
        assert_eq!(fixed.to_auto_fact().unwrap().rank, Rank::Fixed(16));
        let bad = FactorizeConfig {
            solver: "qr".into(),
            ..Default::default()
        };
        assert!(bad.to_auto_fact().is_err());
        let quant = FactorizeConfig {
            precision: "int8".into(),
            ..Default::default()
        };
        assert_eq!(
            quant.to_auto_fact().unwrap().precision,
            crate::factorize::WeightPrecision::Int8
        );
        let bad_prec = FactorizeConfig {
            precision: "fp16".into(),
            ..Default::default()
        };
        assert!(bad_prec.to_auto_fact().is_err());
    }

    #[test]
    fn empty_submodules_is_none() {
        let fc = FactorizeConfig::default();
        assert!(fc.to_auto_fact().unwrap().submodules.is_none());
        let fc = FactorizeConfig {
            submodules: vec!["attn".into()],
            ..Default::default()
        };
        assert_eq!(
            fc.to_auto_fact().unwrap().submodules,
            Some(vec!["attn".to_string()])
        );
    }
}
