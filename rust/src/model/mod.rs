//! Module-tree reconstruction from parameter names.
//!
//! The toolkit is model-agnostic, like the PyTorch original: instead of a
//! hard-coded architecture list, the module tree is recovered from the
//! checkpoint's parameter names (`block0/attn/q/w`, `conv1/bias`, ...) and
//! each leaf group is classified by its member tensors:
//!
//! | members              | layer                    |
//! |----------------------|--------------------------|
//! | `w` (2-D) [+ `bias`] | [`LayerKind::Linear`]    |
//! | `w` (4-D) [+ `bias`] | [`LayerKind::Conv2d`]    |
//! | `a` + `b` [+ `bias`] | LED / CED (factorized)   |
//! | `tt0`.. [+ `bias`]   | [`LayerKind::TtLinear`]  |
//! | `table`              | [`LayerKind::Embedding`] |
//! | `g` + `bias`         | [`LayerKind::LayerNorm`] |
//!
//! `auto_fact` consumes this classification to decide what to replace; the
//! FLOPs model consumes it to cost a checkpoint without running it.

use crate::tensor::ParamStore;

/// What a parameter group is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Dense linear layer (`w` + optional `bias`).
    Linear,
    /// Dense 2-D convolution (HWIO `w`).
    Conv2d,
    /// Already-factorized linear (LED).
    LedLinear,
    /// Already-factorized conv (CED).
    CedConv2d,
    /// Tensor-train-factorized linear (`tt0..ttK` cores, DESIGN.md §13).
    TtLinear,
    /// Lookup table (`embed/table`, `pos/table`).
    Embedding,
    /// LayerNorm gain + bias.
    LayerNorm,
    /// Anything unrecognized (left untouched by auto_fact).
    Other,
}

/// One classified layer (parameter group).
#[derive(Clone, Debug)]
pub struct LayerInfo {
    /// Group prefix, e.g. `block0/attn/q` (empty for root-level tensors).
    pub name: String,
    /// Classified kind.
    pub kind: LayerKind,
    /// For Linear/LED: (in, out). For Conv/CED: (kh·kw·cin, cout) — the
    /// paper's rearrangement. For Embedding: (vocab, dim).
    pub in_dim: usize,
    /// Output dimension.
    pub out_dim: usize,
    /// Conv spatial kernel (kh, kw) when applicable.
    pub kernel: Option<(usize, usize)>,
    /// Factor rank for LED/CED layers (max internal rank for TT).
    pub rank: Option<usize>,
    /// TT mode/rank structure when `kind == TtLinear`.
    pub tt: Option<TtInfo>,
}

/// Mode dims and rank chain of a TT-factorized linear — enough to count
/// its parameters and cost its contraction without re-reading the store.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TtInfo {
    /// Input mode dims (`∏` = in_dim).
    pub m_dims: Vec<usize>,
    /// Output mode dims (`∏` = out_dim).
    pub n_dims: Vec<usize>,
    /// Full rank chain `r_0..r_d` (boundaries are 1).
    pub ranks: Vec<usize>,
}

impl TtInfo {
    /// Total stored core elements: Σ_k r_{k-1}·m_k·n_k·r_k.
    pub fn n_params(&self) -> usize {
        (0..self.m_dims.len())
            .map(|k| self.ranks[k] * self.m_dims[k] * self.n_dims[k] * self.ranks[k + 1])
            .sum()
    }

    /// Exact MACs of the interpreter's per-token TT contraction: at step k
    /// the GEMM is (P·S, r_{k-1}·m_k, n_k·r_k) with P = ∏_{l<k} n_l and
    /// S = ∏_{l>k} m_l.
    pub fn macs_per_token(&self) -> u64 {
        let d = self.m_dims.len();
        let mut total = 0u64;
        for k in 0..d {
            let p: u64 = self.n_dims[..k].iter().map(|&v| v as u64).product();
            let s: u64 = self.m_dims[k + 1..].iter().map(|&v| v as u64).product();
            let ri = (self.ranks[k] * self.m_dims[k]) as u64;
            let nr = (self.n_dims[k] * self.ranks[k + 1]) as u64;
            total += p * s * ri * nr;
        }
        total
    }
}

impl LayerInfo {
    /// Parameter count of this layer's weights (excluding bias).
    pub fn weight_params(&self) -> usize {
        match self.kind {
            LayerKind::LedLinear | LayerKind::CedConv2d => {
                let r = self.rank.unwrap_or(0);
                r * (self.in_dim + self.out_dim)
            }
            LayerKind::TtLinear => self
                .tt
                .as_ref()
                .map(TtInfo::n_params)
                .unwrap_or(self.in_dim * self.out_dim),
            _ => self.in_dim * self.out_dim,
        }
    }
}

/// Group params by their prefix (everything before the last `/`) and
/// classify each group. Groups appear in the store's order.
pub fn classify(params: &ParamStore) -> Vec<LayerInfo> {
    let mut groups: Vec<(String, Vec<(&str, &crate::tensor::Tensor)>)> = Vec::new();
    for (name, t) in params.iter() {
        let (prefix, leaf) = match name.rfind('/') {
            Some(i) => (&name[..i], &name[i + 1..]),
            None => ("", name),
        };
        match groups.last_mut() {
            Some((p, members)) if p == prefix => members.push((leaf, t)),
            _ => groups.push((prefix.to_string(), vec![(leaf, t)])),
        }
    }
    groups
        .into_iter()
        .map(|(name, members)| classify_group(name, &members))
        .collect()
}

fn classify_group(name: String, members: &[(&str, &crate::tensor::Tensor)]) -> LayerInfo {
    let get = |leaf: &str| members.iter().find(|(l, _)| *l == leaf).map(|(_, t)| *t);
    let (w, a, b, table, g) = (get("w"), get("a"), get("b"), get("table"), get("g"));

    if let Some(w) = w {
        if w.ndim() == 2 {
            return LayerInfo {
                name,
                kind: LayerKind::Linear,
                in_dim: w.shape[0],
                out_dim: w.shape[1],
                kernel: None,
                rank: None,
                tt: None,
            };
        }
        if w.ndim() == 4 {
            return LayerInfo {
                name,
                kind: LayerKind::Conv2d,
                in_dim: w.shape[0] * w.shape[1] * w.shape[2],
                out_dim: w.shape[3],
                kernel: Some((w.shape[0], w.shape[1])),
                rank: None,
                tt: None,
            };
        }
    }
    if let (Some(a), Some(b)) = (a, b) {
        if a.ndim() == 2 && b.ndim() == 2 {
            return LayerInfo {
                name,
                kind: LayerKind::LedLinear,
                in_dim: a.shape[0],
                out_dim: b.shape[1],
                kernel: None,
                rank: Some(a.shape[1]),
                tt: None,
            };
        }
        if a.ndim() == 4 && b.ndim() == 4 {
            return LayerInfo {
                name,
                kind: LayerKind::CedConv2d,
                in_dim: a.shape[0] * a.shape[1] * a.shape[2],
                out_dim: b.shape[3],
                kernel: Some((a.shape[0], a.shape[1])),
                rank: Some(a.shape[3]),
                tt: None,
            };
        }
    }
    // TT group: `tt0..ttK` 4-D cores in chain order.
    let mut tt_cores: Vec<&crate::tensor::Tensor> = Vec::new();
    loop {
        let leaf = format!("tt{}", tt_cores.len());
        match members.iter().find(|(l, _)| *l == leaf) {
            Some((_, t)) if t.ndim() == 4 => tt_cores.push(t),
            _ => break,
        }
    }
    if !tt_cores.is_empty() {
        let m_dims: Vec<usize> = tt_cores.iter().map(|t| t.shape[1]).collect();
        let n_dims: Vec<usize> = tt_cores.iter().map(|t| t.shape[2]).collect();
        let mut ranks = vec![tt_cores[0].shape[0]];
        ranks.extend(tt_cores.iter().map(|t| t.shape[3]));
        let info = TtInfo { m_dims, n_dims, ranks };
        let max_rank = info.ranks.iter().copied().max().unwrap_or(1);
        return LayerInfo {
            name,
            kind: LayerKind::TtLinear,
            in_dim: info.m_dims.iter().product(),
            out_dim: info.n_dims.iter().product(),
            kernel: None,
            rank: Some(max_rank),
            tt: Some(info),
        };
    }
    if let Some(t) = table {
        return LayerInfo {
            name,
            kind: LayerKind::Embedding,
            in_dim: t.shape.first().copied().unwrap_or(0),
            out_dim: t.shape.get(1).copied().unwrap_or(0),
            kernel: None,
            rank: None,
            tt: None,
        };
    }
    if g.is_some() {
        return LayerInfo {
            name,
            kind: LayerKind::LayerNorm,
            in_dim: g.unwrap().len(),
            out_dim: g.unwrap().len(),
            kernel: None,
            rank: None,
            tt: None,
        };
    }
    LayerInfo {
        name,
        kind: LayerKind::Other,
        in_dim: 0,
        out_dim: 0,
        kernel: None,
        rank: None,
        tt: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Dtype, Tensor};

    fn store() -> ParamStore {
        let mut s = ParamStore::new();
        s.insert("block0/attn/q/w", Tensor::zeros(&[64, 64], Dtype::F32));
        s.insert("block0/attn/q/bias", Tensor::zeros(&[64], Dtype::F32));
        s.insert("block0/fc1/a", Tensor::zeros(&[64, 16], Dtype::F32));
        s.insert("block0/fc1/b", Tensor::zeros(&[16, 128], Dtype::F32));
        s.insert("block0/fc1/bias", Tensor::zeros(&[128], Dtype::F32));
        s.insert("conv1/w", Tensor::zeros(&[3, 3, 8, 16], Dtype::F32));
        s.insert("conv1/bias", Tensor::zeros(&[16], Dtype::F32));
        s.insert("conv2/a", Tensor::zeros(&[3, 3, 8, 4], Dtype::F32));
        s.insert("conv2/b", Tensor::zeros(&[1, 1, 4, 16], Dtype::F32));
        s.insert("conv2/bias", Tensor::zeros(&[16], Dtype::F32));
        s.insert("embed/table", Tensor::zeros(&[512, 64], Dtype::F32));
        s.insert("ln/g", Tensor::zeros(&[64], Dtype::F32));
        s.insert("ln/bias", Tensor::zeros(&[64], Dtype::F32));
        // TT linear: 24 = 4·6 in, 36 = 6·6 out, internal rank 3.
        s.insert("ttfc/bias", Tensor::zeros(&[36], Dtype::F32));
        s.insert("ttfc/tt0", Tensor::zeros(&[1, 4, 6, 3], Dtype::F32));
        s.insert("ttfc/tt1", Tensor::zeros(&[3, 6, 6, 1], Dtype::F32));
        s
    }

    #[test]
    fn classifies_all_kinds() {
        let layers = classify(&store());
        let by_name: std::collections::HashMap<_, _> =
            layers.iter().map(|l| (l.name.clone(), l)).collect();
        assert_eq!(by_name["block0/attn/q"].kind, LayerKind::Linear);
        assert_eq!(by_name["block0/fc1"].kind, LayerKind::LedLinear);
        assert_eq!(by_name["block0/fc1"].rank, Some(16));
        assert_eq!(by_name["conv1"].kind, LayerKind::Conv2d);
        assert_eq!(by_name["conv1"].in_dim, 72);
        assert_eq!(by_name["conv2"].kind, LayerKind::CedConv2d);
        assert_eq!(by_name["conv2"].rank, Some(4));
        assert_eq!(by_name["embed"].kind, LayerKind::Embedding);
        assert_eq!(by_name["ln"].kind, LayerKind::LayerNorm);
        let tt = &by_name["ttfc"];
        assert_eq!(tt.kind, LayerKind::TtLinear);
        assert_eq!((tt.in_dim, tt.out_dim), (24, 36));
        assert_eq!(tt.rank, Some(3));
        let info = tt.tt.as_ref().unwrap();
        assert_eq!(info.ranks, vec![1, 3, 1]);
        assert_eq!(info.m_dims, vec![4, 6]);
    }

    #[test]
    fn weight_params_formulas() {
        let layers = classify(&store());
        let by_name: std::collections::HashMap<_, _> =
            layers.iter().map(|l| (l.name.clone(), l)).collect();
        assert_eq!(by_name["block0/attn/q"].weight_params(), 64 * 64);
        assert_eq!(by_name["block0/fc1"].weight_params(), 16 * (64 + 128));
        assert_eq!(by_name["conv2"].weight_params(), 4 * (72 + 16));
        // TT: exact core elements (1·4·6·3 + 3·6·6·1), not r·(in + out).
        assert_eq!(by_name["ttfc"].weight_params(), 72 + 108);
        let info = by_name["ttfc"].tt.as_ref().unwrap();
        // Step 0: (P·S = 6, 1·4, 6·3) = 432 MACs; step 1: (P·S = 6, 3·6, 6·1) = 648.
        assert_eq!(info.macs_per_token(), 432 + 648);
    }

    #[test]
    fn root_level_params_group_to_empty_prefix() {
        let mut s = ParamStore::new();
        s.insert("w", Tensor::zeros(&[4, 4], Dtype::F32));
        let layers = classify(&s);
        assert_eq!(layers.len(), 1);
        assert_eq!(layers[0].name, "");
        assert_eq!(layers[0].kind, LayerKind::Linear);
    }

    #[test]
    fn unknown_group_is_other() {
        let mut s = ParamStore::new();
        s.insert("thing/weird", Tensor::zeros(&[4], Dtype::F32));
        assert_eq!(classify(&s)[0].kind, LayerKind::Other);
    }
}
