//! The in-context-learning corpus and prompt composition.
//!
//! Real ICL (Brown et al. 2020) emerges from language-model pretraining on
//! text that contains task-like structure. Our tiny causal LM gets the same
//! chance: the pretraining corpus is a stream of serialized classification
//! examples — `CLS tokens… SEP LABEL_k` — drawn from the three text tasks.
//! At eval time the coordinator composes k-shot prompts in exactly that
//! format and reads the LM's logit over the label tokens at the final
//! position. Factorizing the LM (the paper's third use case) then trades
//! accuracy against speed with no gradient anywhere.

use super::text::all_text_tasks;
use super::{vocab, Dataset, Split};
use crate::util::Pcg64;

/// Compress a task example into a short `snippet_len`-token snippet:
/// the CLS prefix is dropped and filler is downsampled so several
/// exemplars fit in the LM context.
fn snippet(tokens: &[i32], snippet_len: usize, rng: &mut Pcg64) -> Vec<i32> {
    // Keep all non-filler "structure" tokens (keywords live below the
    // per-task filler bases; we conservatively keep everything below the
    // highest filler base and sample the rest).
    let mut out: Vec<i32> = Vec::with_capacity(snippet_len);
    let body = &tokens[1..]; // drop CLS
    let stride = (body.len() / snippet_len).max(1);
    let offset = rng.below(stride.min(body.len()));
    for &t in body.iter().skip(offset).step_by(stride) {
        if out.len() == snippet_len {
            break;
        }
        out.push(t);
    }
    while out.len() < snippet_len {
        out.push(vocab::PAD);
    }
    out
}

/// Serialize one labelled example as `snippet… SEP LABEL`.
fn serialize(tokens: &[i32], label: usize, snippet_len: usize, rng: &mut Pcg64) -> Vec<i32> {
    let mut s = snippet(tokens, snippet_len, rng);
    s.push(vocab::SEP);
    s.push(vocab::LABEL_BASE + label as i32);
    s
}

/// Pretraining corpus: an endless deterministic stream of serialized
/// examples from all three text tasks, concatenated to `seq` tokens.
pub struct LmCorpus {
    tasks: Vec<Box<dyn Dataset>>,
    /// Tokens per pretraining sequence (the LM's context length).
    pub seq: usize,
    seed: u64,
    snippet_len: usize,
}

impl LmCorpus {
    /// Corpus of `seq`-token sequences, deterministic in `seed`.
    pub fn new(seq: usize, seed: u64) -> Self {
        Self {
            // Snippets come from the tasks' own generators at their native
            // seq; snippet() compresses them.
            tasks: all_text_tasks(64, seed),
            seq,
            seed,
            snippet_len: 12,
        }
    }

    /// The i-th pretraining sequence: (seq,) token ids.
    pub fn sequence(&self, index: usize) -> Vec<i32> {
        let mut rng = Pcg64::new(self.seed ^ (index as u64).wrapping_mul(0x2545f4914f6cdd1d), 21);
        let mut out = Vec::with_capacity(self.seq);
        let mut cursor = index * 1000;
        while out.len() < self.seq {
            let t = rng.below(self.tasks.len());
            let ds = &self.tasks[t];
            let ex = ds.example(Split::Train, cursor);
            cursor += 1;
            out.extend(serialize(&ex.tokens, ex.label, self.snippet_len, &mut rng));
        }
        out.truncate(self.seq);
        out
    }

    /// Batch of pretraining sequences as an i32 tensor (count, seq).
    pub fn batch(&self, start: usize, count: usize) -> crate::tensor::Tensor {
        let mut toks = Vec::with_capacity(count * self.seq);
        for i in 0..count {
            toks.extend(self.sequence(start + i));
        }
        crate::tensor::Tensor::from_i32(&[count, self.seq], toks)
    }
}

/// A composed k-shot prompt and its gold label.
#[derive(Clone, Debug)]
pub struct IclPrompt {
    /// (seq,) tokens, PAD-left so the query's label slot is the last token.
    pub tokens: Vec<i32>,
    /// Gold class of the query example.
    pub label: usize,
    /// Position of the token *before* the label slot (the LM predicts the
    /// label at this position's output).
    pub predict_pos: usize,
    /// Number of classes the task (and so the label-token slice) uses.
    pub num_classes: usize,
}

/// Compose a k-shot prompt for `task`: k exemplars (with labels) followed by
/// the query (label slot left empty — the LM must predict it).
pub fn compose_prompt(
    task: &dyn Dataset,
    k_shots: usize,
    query_index: usize,
    seq: usize,
    seed: u64,
) -> IclPrompt {
    let snippet_len = 12;
    let mut rng = Pcg64::new(seed ^ (query_index as u64).wrapping_mul(0x6a09e667f3bcc909), 31);
    let mut body: Vec<i32> = Vec::new();
    for s in 0..k_shots {
        // Exemplars come from the train split (disjoint from eval queries).
        let ex = task.example(Split::Train, query_index * 37 + s);
        body.extend(serialize(&ex.tokens, ex.label, snippet_len, &mut rng));
    }
    let query = task.example(Split::Eval, query_index);
    let mut q = snippet(&query.tokens, snippet_len, &mut rng);
    q.push(vocab::SEP);
    body.extend(&q);
    assert!(
        body.len() <= seq,
        "prompt ({} tokens) exceeds LM context ({seq}); reduce k_shots",
        body.len()
    );
    // predict position = index of the last real token (the SEP); the LM's
    // output there is the next-token distribution over the label slot.
    let predict_pos = body.len() - 1;
    let mut tokens = body;
    tokens.resize(seq, vocab::PAD);
    IclPrompt {
        tokens,
        label: query.label,
        predict_pos,
        num_classes: task.num_classes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::text::PolarityTask;

    #[test]
    fn sequences_have_label_structure() {
        let corpus = LmCorpus::new(128, 0);
        let s = corpus.sequence(0);
        assert_eq!(s.len(), 128);
        let labels = s
            .iter()
            .filter(|&&t| t >= vocab::LABEL_BASE && t < vocab::LABEL_BASE + vocab::NUM_LABELS)
            .count();
        let seps = s.iter().filter(|&&t| t == vocab::SEP).count();
        assert!(labels >= 3, "expected several label tokens, got {labels}");
        assert!(seps >= labels, "every label is preceded by SEP");
    }

    #[test]
    fn corpus_deterministic() {
        let c = LmCorpus::new(128, 1);
        assert_eq!(c.sequence(4), c.sequence(4));
        assert_ne!(c.sequence(4), c.sequence(5));
    }

    #[test]
    fn batch_shape() {
        let c = LmCorpus::new(128, 0);
        let b = c.batch(0, 3);
        assert_eq!(b.shape, vec![3, 128]);
    }

    #[test]
    fn prompt_fits_and_ends_with_sep_at_predict_pos() {
        let task = PolarityTask::new(64, 0);
        let p = compose_prompt(&task, 4, 7, 128, 0);
        assert_eq!(p.tokens.len(), 128);
        assert_eq!(p.tokens[p.predict_pos], vocab::SEP);
        assert!(p.label < 2);
        // 4 exemplars serialized = 4 labels in the prompt body
        let labels = p.tokens[..p.predict_pos]
            .iter()
            .filter(|&&t| t >= vocab::LABEL_BASE && t < vocab::LABEL_BASE + vocab::NUM_LABELS)
            .count();
        assert_eq!(labels, 4);
    }

    #[test]
    #[should_panic]
    fn oversized_prompt_panics() {
        let task = PolarityTask::new(64, 0);
        let _ = compose_prompt(&task, 40, 0, 128, 0);
    }
}
