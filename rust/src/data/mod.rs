//! Synthetic workload suite — the evaluation tasks (DESIGN.md §3).
//!
//! The paper evaluates on 3 text classification and 2 image classification
//! tasks plus a pretrained LM for in-context learning. Those datasets and
//! checkpoints aren't shippable, so this module generates synthetic
//! equivalents that exercise identical code paths and degrade smoothly with
//! rank — which is all Figure 2 needs:
//!
//! * [`text`] — `polarity` (sentiment-like), `topic` (4-way), `matching`
//!   (NLI-like) over a shared 512-token vocabulary.
//! * [`image`] — `shapes` (rendered geometric shapes) and `blobs`
//!   (class-conditioned Gaussian mixtures), 28×28 grayscale.
//! * [`lm`] — the ICL corpus: task examples serialized as token streams
//!   with label tokens, so a causal LM learns to complete `... -> LABEL`.
//!
//! Everything is deterministic in (seed, index): train/eval splits are
//! disjoint by construction (different streams), and examples regenerate
//! identically across processes.

pub mod image;
pub mod lm;
pub mod text;

use crate::tensor::Tensor;

/// Shared vocabulary layout (matches LMConfig.vocab = TextConfig.vocab = 512).
pub mod vocab {
    /// Vocabulary size shared by every text task and the LM.
    pub const SIZE: usize = 512;
    /// Padding token id (also the PAD-row filler in serving batches).
    pub const PAD: i32 = 0;
    /// Sequence-start marker.
    pub const CLS: i32 = 1;
    /// Segment separator (matching task, ICL example boundaries).
    pub const SEP: i32 = 2;
    /// Label tokens: LABEL_BASE + class id (up to 8 classes).
    pub const LABEL_BASE: i32 = 3;
    /// Number of reserved label-token slots.
    pub const NUM_LABELS: i32 = 8;
    /// First ordinary word id.
    pub const WORDS: i32 = LABEL_BASE + NUM_LABELS; // 11
}

/// One classification example: token sequence (or image) + class label.
#[derive(Clone, Debug)]
pub struct Example {
    /// For text: token ids (padded to seq). For images: HxWxC pixels.
    pub tokens: Vec<i32>,
    /// For images: row-major (h, w, c) pixel values; empty for text.
    pub pixels: Vec<f32>,
    /// Ground-truth class id.
    pub label: usize,
}

/// A deterministic, indexable synthetic dataset.
pub trait Dataset: Send + Sync {
    /// Task name as the CLI spells it (`polarity`, `shapes`, …).
    fn name(&self) -> &str;
    /// Number of classes the task uses.
    fn num_classes(&self) -> usize;
    /// Generate the i-th example of the given split ("train"/"eval" streams
    /// use disjoint RNG streams).
    fn example(&self, split: Split, index: usize) -> Example;
    /// True for image tasks (pixels populated instead of tokens).
    fn is_image(&self) -> bool {
        false
    }
}

/// Which disjoint example stream to draw from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// Training stream.
    Train,
    /// Held-out evaluation stream.
    Eval,
}

impl Split {
    /// The RNG stream id backing this split (disjoint by construction).
    pub fn stream(self) -> u64 {
        match self {
            Split::Train => 1,
            Split::Eval => 2,
        }
    }
}

/// Collate `count` examples starting at `start` into (x, y) tensors.
/// Text: x is (count, seq) i32; image: (count, h, w, c) f32. y is (count,) i32.
pub fn batch(
    ds: &dyn Dataset,
    split: Split,
    start: usize,
    count: usize,
    image_hw: Option<(usize, usize, usize)>,
) -> (Tensor, Tensor) {
    let mut labels = Vec::with_capacity(count);
    if let Some((h, w, c)) = image_hw {
        let mut pixels = Vec::with_capacity(count * h * w * c);
        for i in 0..count {
            let ex = ds.example(split, start + i);
            assert_eq!(ex.pixels.len(), h * w * c, "{}", ds.name());
            pixels.extend_from_slice(&ex.pixels);
            labels.push(ex.label as i32);
        }
        (
            Tensor::from_f32(&[count, h, w, c], pixels),
            Tensor::from_i32(&[count], labels),
        )
    } else {
        let ex0 = ds.example(split, start);
        let seq = ex0.tokens.len();
        let mut toks = Vec::with_capacity(count * seq);
        for i in 0..count {
            let ex = ds.example(split, start + i);
            assert_eq!(ex.tokens.len(), seq, "{}", ds.name());
            toks.extend_from_slice(&ex.tokens);
            labels.push(ex.label as i32);
        }
        (
            Tensor::from_i32(&[count, seq], toks),
            Tensor::from_i32(&[count], labels),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::text::PolarityTask;
    use super::*;

    #[test]
    fn batch_shapes() {
        let ds = PolarityTask::new(64, 0);
        let (x, y) = batch(&ds, Split::Train, 0, 4, None);
        assert_eq!(x.shape, vec![4, 64]);
        assert_eq!(y.shape, vec![4]);
    }

    #[test]
    fn splits_are_disjoint_streams() {
        let ds = PolarityTask::new(64, 0);
        let a = ds.example(Split::Train, 0);
        let b = ds.example(Split::Eval, 0);
        assert_ne!(a.tokens, b.tokens);
    }

    #[test]
    fn deterministic_by_index() {
        let ds = PolarityTask::new(64, 0);
        let a = ds.example(Split::Train, 5);
        let b = ds.example(Split::Train, 5);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.label, b.label);
    }
}
