//! The two synthetic image-classification tasks (28×28 grayscale).
//!
//! `shapes` renders one of four geometric glyphs at a random position/scale;
//! `blobs` places class-conditioned Gaussian bumps. Both add pixel noise so
//! the CNN has to learn real spatial filters — the CED factorization path
//! gets exercised on genuinely spatial weights.

use super::{Dataset, Example, Split};
use crate::util::Pcg64;

/// Image side length: every image task renders at HW×HW grayscale.
pub const HW: usize = 28;

fn rng_for(seed: u64, split: Split, index: usize) -> Pcg64 {
    Pcg64::new(seed ^ (index as u64).wrapping_mul(0x9e3779b97f4a7c15), split.stream() + 10)
}

fn noise(img: &mut [f32], rng: &mut Pcg64, sigma: f32) {
    for p in img.iter_mut() {
        *p = (*p + rng.normal_f32() * sigma).clamp(0.0, 1.0);
    }
}

/// 4 classes: 0 = square, 1 = circle, 2 = cross, 3 = triangle.
pub struct ShapesTask {
    seed: u64,
}

impl ShapesTask {
    /// Task deterministic in `seed` (images render at [`HW`]×[`HW`]).
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }
}

impl Dataset for ShapesTask {
    fn name(&self) -> &str {
        "shapes"
    }

    fn num_classes(&self) -> usize {
        4
    }

    fn is_image(&self) -> bool {
        true
    }

    fn example(&self, split: Split, index: usize) -> Example {
        let mut rng = rng_for(self.seed ^ 0x80, split, index);
        let label = rng.below(4);
        let mut img = vec![0.0f32; HW * HW];
        let size = 6 + rng.below(8); // half-extent 6..13
        let cx = size + rng.below(HW - 2 * size);
        let cy = size + rng.below(HW - 2 * size);
        let val = 0.7 + 0.3 * rng.next_f32();
        let set = |x: i64, y: i64, v: f32, img: &mut Vec<f32>| {
            if (0..HW as i64).contains(&x) && (0..HW as i64).contains(&y) {
                img[y as usize * HW + x as usize] = v;
            }
        };
        let (cx, cy, s) = (cx as i64, cy as i64, size as i64);
        match label {
            0 => {
                // square outline
                for d in -s..=s {
                    set(cx + d, cy - s, val, &mut img);
                    set(cx + d, cy + s, val, &mut img);
                    set(cx - s, cy + d, val, &mut img);
                    set(cx + s, cy + d, val, &mut img);
                }
            }
            1 => {
                // circle outline (midpoint-ish via angle sweep)
                for k in 0..64 {
                    let th = k as f64 * std::f64::consts::TAU / 64.0;
                    set(
                        cx + (s as f64 * th.cos()).round() as i64,
                        cy + (s as f64 * th.sin()).round() as i64,
                        val,
                        &mut img,
                    );
                }
            }
            2 => {
                // cross
                for d in -s..=s {
                    set(cx + d, cy, val, &mut img);
                    set(cx, cy + d, val, &mut img);
                }
            }
            _ => {
                // triangle outline
                for d in -s..=s {
                    set(cx + d, cy + s, val, &mut img); // base
                }
                for d in 0..=s {
                    // sides from apex (cx, cy - s) to base corners
                    let frac = d as f64 / s as f64;
                    let y = cy - s + (2 * d);
                    set(cx - (frac * s as f64) as i64, y.min(cy + s), val, &mut img);
                    set(cx + (frac * s as f64) as i64, y.min(cy + s), val, &mut img);
                }
            }
        }
        noise(&mut img, &mut rng, 0.08);
        Example {
            tokens: vec![],
            pixels: img,
            label,
        }
    }
}

/// 4 classes; class k places a bright Gaussian bump in quadrant k plus a
/// distractor bump anywhere.
pub struct BlobsTask {
    seed: u64,
}

impl BlobsTask {
    /// Task deterministic in `seed` (images render at [`HW`]×[`HW`]).
    pub fn new(seed: u64) -> Self {
        Self { seed }
    }

    fn bump(img: &mut [f32], cx: f64, cy: f64, sigma: f64, amp: f32) {
        for y in 0..HW {
            for x in 0..HW {
                let d2 = (x as f64 - cx).powi(2) + (y as f64 - cy).powi(2);
                img[y * HW + x] += amp * (-d2 / (2.0 * sigma * sigma)).exp() as f32;
            }
        }
    }
}

impl Dataset for BlobsTask {
    fn name(&self) -> &str {
        "blobs"
    }

    fn num_classes(&self) -> usize {
        4
    }

    fn is_image(&self) -> bool {
        true
    }

    fn example(&self, split: Split, index: usize) -> Example {
        let mut rng = rng_for(self.seed ^ 0x81, split, index);
        let label = rng.below(4);
        let mut img = vec![0.0f32; HW * HW];
        // Quadrant centers: (7,7), (21,7), (7,21), (21,21).
        let qx = if label % 2 == 0 { 7.0 } else { 21.0 };
        let qy = if label < 2 { 7.0 } else { 21.0 };
        let jitter = |rng: &mut Pcg64| (rng.next_f64() - 0.5) * 6.0;
        Self::bump(
            &mut img,
            qx + jitter(&mut rng),
            qy + jitter(&mut rng),
            2.0 + rng.next_f64() * 1.5,
            0.9,
        );
        // Distractor: dimmer, anywhere.
        Self::bump(
            &mut img,
            rng.next_f64() * HW as f64,
            rng.next_f64() * HW as f64,
            2.0,
            0.35,
        );
        noise(&mut img, &mut rng, 0.05);
        for p in img.iter_mut() {
            *p = p.clamp(0.0, 1.0);
        }
        Example {
            tokens: vec![],
            pixels: img,
            label,
        }
    }
}

/// The two image tasks at the fixed [`HW`]×[`HW`] render size.
pub fn all_image_tasks(seed: u64) -> Vec<Box<dyn Dataset>> {
    vec![Box::new(ShapesTask::new(seed)), Box::new(BlobsTask::new(seed))]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixels_in_unit_range() {
        for ds in all_image_tasks(0) {
            for i in 0..20 {
                let ex = ds.example(Split::Train, i);
                assert_eq!(ex.pixels.len(), HW * HW);
                assert!(ex.pixels.iter().all(|&p| (0.0..=1.0).contains(&p)));
                assert!(ds.is_image());
            }
        }
    }

    #[test]
    fn classes_visibly_differ() {
        // Mean images per class must differ — weak but cheap separability check.
        let ds = BlobsTask::new(0);
        let mut means = vec![vec![0.0f64; HW * HW]; 4];
        let mut counts = [0usize; 4];
        for i in 0..200 {
            let ex = ds.example(Split::Train, i);
            counts[ex.label] += 1;
            for (m, &p) in means[ex.label].iter_mut().zip(&ex.pixels) {
                *m += p as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        for a in 0..4 {
            for b in (a + 1)..4 {
                let dist: f64 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum::<f64>()
                    .sqrt();
                assert!(dist > 1.0, "classes {a},{b} too similar: {dist}");
            }
        }
    }

    #[test]
    fn shapes_deterministic() {
        let ds = ShapesTask::new(3);
        assert_eq!(
            ds.example(Split::Eval, 9).pixels,
            ds.example(Split::Eval, 9).pixels
        );
    }
}
