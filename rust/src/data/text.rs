//! The three synthetic text-classification tasks.
//!
//! Each task defines keyword structure over the shared vocabulary so that a
//! transformer must aggregate evidence across the sequence (not just read a
//! single token), giving smooth accuracy degradation under factorization —
//! the behaviour Figure 2's performance curves require.

use super::{vocab, Dataset, Example, Split};
use crate::util::Pcg64;

fn rng_for(seed: u64, split: Split, index: usize) -> Pcg64 {
    // Independent stream per (task seed, split); sequence position = index.
    Pcg64::new(seed ^ (index as u64).wrapping_mul(0x9e3779b97f4a7c15), split.stream())
}

/// Binary sentiment-like task: the label is whether positive keywords
/// outnumber negative ones. 20 keywords per class, embedded among filler.
pub struct PolarityTask {
    seq: usize,
    seed: u64,
}

impl PolarityTask {
    /// First of the 20 positive-keyword token ids.
    pub const POS_BASE: i32 = vocab::WORDS; // 20 positive keywords
    /// First of the 20 negative-keyword token ids.
    pub const NEG_BASE: i32 = vocab::WORDS + 20; // 20 negative keywords
    /// First filler (non-evidential) token id.
    pub const FILLER_BASE: i32 = vocab::WORDS + 40;

    /// Task over `seq`-token examples, deterministic in `seed`.
    pub fn new(seq: usize, seed: u64) -> Self {
        Self { seq, seed }
    }
}

impl Dataset for PolarityTask {
    fn name(&self) -> &str {
        "polarity"
    }

    fn num_classes(&self) -> usize {
        2
    }

    fn example(&self, split: Split, index: usize) -> Example {
        let mut rng = rng_for(self.seed ^ 0x70, split, index);
        let label = rng.below(2);
        // Strength of the signal varies per example: 2..6 majority keywords,
        // 0..(majority-1) minority.
        let maj = 2 + rng.below(5);
        let min_ = rng.below(maj);
        let (n_pos, n_neg) = if label == 1 { (maj, min_) } else { (min_, maj) };
        let filler_count = vocab::SIZE as i32 - Self::FILLER_BASE;
        let mut toks: Vec<i32> = (0..self.seq)
            .map(|_| Self::FILLER_BASE + rng.below(filler_count as usize) as i32)
            .collect();
        toks[0] = vocab::CLS;
        // Scatter keywords at *distinct* random positions (after CLS) so a
        // later keyword can never overwrite an earlier one and flip the
        // majority the label encodes.
        let mut positions: Vec<usize> = (1..self.seq).collect();
        rng.shuffle(&mut positions);
        for (k, &pos) in positions.iter().take(n_pos + n_neg).enumerate() {
            let tok = if k < n_pos {
                Self::POS_BASE + rng.below(20) as i32
            } else {
                Self::NEG_BASE + rng.below(20) as i32
            };
            toks[pos] = tok;
        }
        Example {
            tokens: toks,
            pixels: vec![],
            label,
        }
    }
}

/// 4-way topic classification: each topic owns 24 keywords; the example's
/// keywords are drawn mostly from the gold topic with cross-topic noise.
pub struct TopicTask {
    seq: usize,
    seed: u64,
}

impl TopicTask {
    /// First topic-keyword token id (topics own contiguous ranges).
    pub const TOPIC_BASE: i32 = vocab::WORDS + 80;
    /// Keywords per topic.
    pub const PER_TOPIC: usize = 24;
    /// First filler token id.
    pub const FILLER_BASE: i32 = Self::TOPIC_BASE + 4 * Self::PER_TOPIC as i32;

    /// Task over `seq`-token examples, deterministic in `seed`.
    pub fn new(seq: usize, seed: u64) -> Self {
        Self { seq, seed }
    }

    fn topic_word(&self, topic: usize, rng: &mut Pcg64) -> i32 {
        Self::TOPIC_BASE + (topic * Self::PER_TOPIC) as i32 + rng.below(Self::PER_TOPIC) as i32
    }
}

impl Dataset for TopicTask {
    fn name(&self) -> &str {
        "topic"
    }

    fn num_classes(&self) -> usize {
        4
    }

    fn example(&self, split: Split, index: usize) -> Example {
        let mut rng = rng_for(self.seed ^ 0x71, split, index);
        let label = rng.below(4);
        let filler_count = (vocab::SIZE as i32 - Self::FILLER_BASE) as usize;
        let mut toks: Vec<i32> = (0..self.seq)
            .map(|_| Self::FILLER_BASE + rng.below(filler_count) as i32)
            .collect();
        toks[0] = vocab::CLS;
        let n_gold = 4 + rng.below(4); // 4..7 gold keywords
        let n_noise = rng.below(3); // 0..2 keywords from other topics
        for _ in 0..n_gold {
            let pos = 1 + rng.below(self.seq - 1);
            toks[pos] = self.topic_word(label, &mut rng);
        }
        for _ in 0..n_noise {
            let pos = 1 + rng.below(self.seq - 1);
            let other = (label + 1 + rng.below(3)) % 4;
            toks[pos] = self.topic_word(other, &mut rng);
        }
        Example {
            tokens: toks,
            pixels: vec![],
            label,
        }
    }
}

/// NLI-like premise/hypothesis matching, 3 classes.
///
/// The "world" pairs subject tokens with attribute tokens. The premise
/// states `(s, a)`; the hypothesis restates it (entail), contradicts the
/// attribute (contradict), or talks about an unrelated subject (neutral).
pub struct MatchingTask {
    seq: usize,
    seed: u64,
}

impl MatchingTask {
    /// First subject token id.
    pub const SUBJ_BASE: i32 = vocab::WORDS + 200;
    /// Number of subject tokens.
    pub const NUM_SUBJ: usize = 32;
    /// First attribute token id.
    pub const ATTR_BASE: i32 = Self::SUBJ_BASE + Self::NUM_SUBJ as i32;
    /// Number of attribute tokens.
    pub const NUM_ATTR: usize = 32;
    /// First filler token id.
    pub const FILLER_BASE: i32 = Self::ATTR_BASE + Self::NUM_ATTR as i32;

    /// Label id: hypothesis restates the premise.
    pub const ENTAIL: usize = 0;
    /// Label id: hypothesis contradicts the premise's attribute.
    pub const CONTRADICT: usize = 1;
    /// Label id: hypothesis talks about an unrelated subject.
    pub const NEUTRAL: usize = 2;

    /// Task over `seq`-token examples (`seq >= 12`), deterministic in `seed`.
    pub fn new(seq: usize, seed: u64) -> Self {
        assert!(seq >= 12, "matching needs seq >= 12");
        Self { seq, seed }
    }
}

impl Dataset for MatchingTask {
    fn name(&self) -> &str {
        "matching"
    }

    fn num_classes(&self) -> usize {
        3
    }

    fn example(&self, split: Split, index: usize) -> Example {
        let mut rng = rng_for(self.seed ^ 0x72, split, index);
        let label = rng.below(3);
        let s = Self::SUBJ_BASE + rng.below(Self::NUM_SUBJ) as i32;
        let a = Self::ATTR_BASE + rng.below(Self::NUM_ATTR) as i32;
        let filler_count = (vocab::SIZE as i32 - Self::FILLER_BASE) as usize;
        let mut toks: Vec<i32> = (0..self.seq)
            .map(|_| Self::FILLER_BASE + rng.below(filler_count) as i32)
            .collect();
        toks[0] = vocab::CLS;
        let half = self.seq / 2;
        toks[half] = vocab::SEP;
        // Premise: (s, a) at random positions in the first half.
        let p1 = 1 + rng.below(half - 2);
        toks[p1] = s;
        toks[p1 + 1] = a;
        // Hypothesis in the second half.
        let h1 = half + 1 + rng.below(self.seq - half - 2);
        match label {
            Self::ENTAIL => {
                toks[h1] = s;
                toks[h1 + 1] = a;
            }
            Self::CONTRADICT => {
                let mut a2 = Self::ATTR_BASE + rng.below(Self::NUM_ATTR) as i32;
                while a2 == a {
                    a2 = Self::ATTR_BASE + rng.below(Self::NUM_ATTR) as i32;
                }
                toks[h1] = s;
                toks[h1 + 1] = a2;
            }
            _ => {
                let mut s2 = Self::SUBJ_BASE + rng.below(Self::NUM_SUBJ) as i32;
                while s2 == s {
                    s2 = Self::SUBJ_BASE + rng.below(Self::NUM_SUBJ) as i32;
                }
                let a2 = Self::ATTR_BASE + rng.below(Self::NUM_ATTR) as i32;
                toks[h1] = s2;
                toks[h1 + 1] = a2;
            }
        }
        Example {
            tokens: toks,
            pixels: vec![],
            label,
        }
    }
}

/// The three text tasks at the model's sequence length.
pub fn all_text_tasks(seq: usize, seed: u64) -> Vec<Box<dyn Dataset>> {
    vec![
        Box::new(PolarityTask::new(seq, seed)),
        Box::new(TopicTask::new(seq, seed)),
        Box::new(MatchingTask::new(seq, seed)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_range() {
        for ds in all_text_tasks(64, 0) {
            for i in 0..50 {
                let ex = ds.example(Split::Train, i);
                let in_range = ex.tokens.iter().all(|&t| t >= 0 && (t as usize) < vocab::SIZE);
                assert!(in_range, "{}", ds.name());
                assert!(ex.label < ds.num_classes());
                assert_eq!(ex.tokens.len(), 64);
            }
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        for ds in all_text_tasks(64, 0) {
            let n = 400;
            let mut counts = vec![0usize; ds.num_classes()];
            for i in 0..n {
                counts[ds.example(Split::Train, i).label] += 1;
            }
            let expect = n / ds.num_classes();
            for (c, &cnt) in counts.iter().enumerate() {
                assert!(
                    cnt > expect / 2 && cnt < expect * 2,
                    "{} class {c}: {cnt}/{n}",
                    ds.name()
                );
            }
        }
    }

    #[test]
    fn polarity_signal_is_present() {
        // Count keyword occurrences: the majority keyword class must match
        // the label (by construction) — sanity-check the generator itself.
        let ds = PolarityTask::new(64, 0);
        for i in 0..100 {
            let ex = ds.example(Split::Train, i);
            let pos = ex
                .tokens
                .iter()
                .filter(|&&t| t >= PolarityTask::POS_BASE && t < PolarityTask::NEG_BASE)
                .count();
            let neg = ex
                .tokens
                .iter()
                .filter(|&&t| t >= PolarityTask::NEG_BASE && t < PolarityTask::FILLER_BASE)
                .count();
            // Keyword scatter can overwrite earlier keywords, so allow ties,
            // but the majority direction must never flip.
            if ex.label == 1 {
                assert!(pos >= neg, "example {i}: pos={pos} neg={neg}");
            } else {
                assert!(neg >= pos, "example {i}: pos={pos} neg={neg}");
            }
        }
    }

    #[test]
    fn matching_has_sep_and_premise_pair() {
        let ds = MatchingTask::new(64, 0);
        let ex = ds.example(Split::Train, 3);
        assert_eq!(ex.tokens[32], vocab::SEP);
    }

    #[test]
    fn vocab_regions_do_not_overlap() {
        assert!(PolarityTask::FILLER_BASE <= TopicTask::TOPIC_BASE);
        assert!(TopicTask::FILLER_BASE <= MatchingTask::SUBJ_BASE);
        assert!((MatchingTask::FILLER_BASE as usize) < vocab::SIZE);
    }
}
