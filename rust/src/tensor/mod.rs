//! Tensor container + named parameter store + the GTZ checkpoint format.
//!
//! `Tensor` is deliberately simple: a shape plus row-major data in one of the
//! two dtypes the artifact graphs use (f32, i32). Heavy math lives in
//! [`crate::linalg`] on 2-D views; the runtime marshals `Tensor`s to PJRT
//! literals zero-copy from the raw bytes.

pub mod gtz;

use anyhow::{anyhow, bail};

use crate::Result;

/// Element type of a [`Tensor`]. Matches the manifest's `"f32"`/`"i32"` tags
/// and GTZ dtype codes 0/1.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer (token ids, labels).
    I32,
}

impl Dtype {
    /// GTZ dtype code (0 = f32, 1 = i32).
    pub fn code(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::I32 => 1,
        }
    }

    /// Decode a GTZ dtype code.
    pub fn from_code(c: u8) -> Result<Self> {
        match c {
            0 => Ok(Dtype::F32),
            1 => Ok(Dtype::I32),
            _ => bail!("unknown GTZ dtype code {c}"),
        }
    }

    /// Decode a manifest dtype tag (`"f32"` / `"i32"`).
    pub fn from_tag(tag: &str) -> Result<Self> {
        match tag {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => bail!("unknown dtype tag {tag:?}"),
        }
    }

    /// Bytes per element (both dtypes are 4-byte).
    pub fn size_bytes(self) -> usize {
        4
    }
}

/// Row-major dense tensor.
#[derive(Clone, Debug, PartialEq)]
pub enum Data {
    /// f32 elements.
    F32(Vec<f32>),
    /// i32 elements.
    I32(Vec<i32>),
}

/// A shaped, row-major tensor in one of the two artifact dtypes.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// The elements.
    pub data: Data,
}

impl Tensor {
    /// All-zero tensor of the given shape and dtype.
    pub fn zeros(shape: &[usize], dtype: Dtype) -> Self {
        let n = shape.iter().product();
        let data = match dtype {
            Dtype::F32 => Data::F32(vec![0.0; n]),
            Dtype::I32 => Data::I32(vec![0; n]),
        };
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    /// Wrap f32 `data` under `shape` (lengths must agree).
    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor {
            shape: shape.to_vec(),
            data: Data::F32(data),
        }
    }

    /// Wrap i32 `data` under `shape` (lengths must agree).
    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor {
            shape: shape.to_vec(),
            data: Data::I32(data),
        }
    }

    /// A 0-D f32 scalar.
    pub fn scalar_f32(v: f32) -> Self {
        Tensor {
            shape: vec![],
            data: Data::F32(vec![v]),
        }
    }

    /// Element dtype.
    pub fn dtype(&self) -> Dtype {
        match self.data {
            Data::F32(_) => Dtype::F32,
            Data::I32(_) => Dtype::I32,
        }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of dimensions.
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Elements as an f32 slice (errors on i32 tensors).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Mutable f32 elements.
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Data::F32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    /// Elements as an i32 slice (errors on f32 tensors).
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    /// Raw little-endian bytes (the in-memory layout; x86/aarch64 are LE).
    pub fn raw_bytes(&self) -> &[u8] {
        match &self.data {
            Data::F32(v) => bytemuck_cast_slice_f32(v),
            Data::I32(v) => bytemuck_cast_slice_i32(v),
        }
    }

    /// Reinterpret as a 2-D (rows, cols) view, collapsing leading dims.
    /// For a conv HWIO weight (kh, kw, cin, cout) this yields the paper's
    /// (kh*kw*cin, cout) rearrangement.
    pub fn as_matrix_2d(&self) -> Result<(usize, usize, &[f32])> {
        if self.ndim() < 2 {
            bail!("need >=2 dims, got {:?}", self.shape);
        }
        let cols = *self.shape.last().unwrap();
        let rows = self.len() / cols;
        Ok((rows, cols, self.as_f32()?))
    }

    /// Frobenius norm (f32 tensors).
    pub fn fro_norm(&self) -> f64 {
        match &self.data {
            Data::F32(v) => v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt(),
            Data::I32(v) => v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt(),
        }
    }
}

fn bytemuck_cast_slice_f32(v: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

fn bytemuck_cast_slice_i32(v: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// An ordered, named collection of tensors — a model checkpoint.
///
/// Ordering follows the Python `flatten_params` contract (depth-first,
/// key-sorted), which is also the order the AOT manifest records and the
/// order the runtime marshals literals in. `ParamStore` preserves insertion
/// order and supports name lookup.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    names: Vec<String>,
    tensors: Vec<Tensor>,
}

impl ParamStore {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert or replace the tensor under `name` (insertion order kept).
    pub fn insert(&mut self, name: impl Into<String>, t: Tensor) {
        let name = name.into();
        if let Some(i) = self.index_of(&name) {
            self.tensors[i] = t;
        } else {
            self.names.push(name);
            self.tensors.push(t);
        }
    }

    /// Remove a tensor by name, returning it.
    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        let i = self.index_of(name)?;
        self.names.remove(i);
        Some(self.tensors.remove(i))
    }

    /// Position of `name` in the store order.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    /// Tensor by name.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index_of(name).map(|i| &self.tensors[i])
    }

    /// Mutable tensor by name.
    pub fn get_mut(&mut self, name: &str) -> Option<&mut Tensor> {
        self.index_of(name).map(move |i| &mut self.tensors[i])
    }

    /// Number of named tensors.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True when the store holds nothing.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// All names, in store order.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Iterate (name, tensor) pairs in store order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.names.iter().map(String::as_str).zip(self.tensors.iter())
    }

    /// Total number of scalar parameters.
    pub fn n_params(&self) -> usize {
        self.tensors.iter().map(Tensor::len).sum()
    }

    /// Re-sort into the canonical flatten_params order (depth-first sorted
    /// keys == plain lexicographic sort on the slash-joined names, given '/'
    /// sorts below all alphanumerics used in our names).
    pub fn sort_canonical(&mut self) {
        let mut idx: Vec<usize> = (0..self.names.len()).collect();
        idx.sort_by(|&a, &b| self.names[a].cmp(&self.names[b]));
        self.names = idx.iter().map(|&i| self.names[i].clone()).collect();
        let mut tensors = Vec::with_capacity(self.tensors.len());
        // drain in index order without cloning tensor data
        let mut old: Vec<Option<Tensor>> =
            std::mem::take(&mut self.tensors).into_iter().map(Some).collect();
        for &i in &idx {
            tensors.push(old[i].take().expect("index used twice"));
        }
        self.tensors = tensors;
    }

    /// Reorder to match an explicit name list (the manifest's param order).
    pub fn reorder_to(&mut self, order: &[String]) -> Result<()> {
        if order.len() != self.names.len() {
            bail!(
                "param count mismatch: store has {}, manifest wants {}",
                self.names.len(),
                order.len()
            );
        }
        let mut new_tensors = Vec::with_capacity(order.len());
        for name in order {
            let i = self
                .index_of(name)
                .ok_or_else(|| anyhow!("param {name:?} missing from store"))?;
            new_tensors.push(self.tensors[i].clone());
        }
        self.names = order.to_vec();
        self.tensors = new_tensors;
        Ok(())
    }

    /// Load a checkpoint from a GTZ file.
    pub fn load_gtz(path: impl AsRef<std::path::Path>) -> Result<Self> {
        gtz::read(path)
    }

    /// Write the checkpoint as a GTZ file.
    pub fn save_gtz(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        gtz::write(path, self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_basics() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), Dtype::F32);
        let (r, c, d) = t.as_matrix_2d().unwrap();
        assert_eq!((r, c), (2, 3));
        assert_eq!(d[4], 5.0);
        assert_eq!(t.raw_bytes().len(), 24);
    }

    #[test]
    fn conv_weight_collapses_to_paper_rearrangement() {
        let t = Tensor::zeros(&[3, 3, 8, 16], Dtype::F32);
        let (r, c, _) = t.as_matrix_2d().unwrap();
        assert_eq!((r, c), (72, 16)); // (kh*kw*cin, cout)
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar_f32(7.0);
        assert_eq!(t.ndim(), 0);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn store_insert_get_replace() {
        let mut s = ParamStore::new();
        s.insert("a/w", Tensor::zeros(&[2, 2], Dtype::F32));
        s.insert("a/bias", Tensor::zeros(&[2], Dtype::F32));
        assert_eq!(s.len(), 2);
        s.insert("a/w", Tensor::from_f32(&[1], vec![9.0]));
        assert_eq!(s.len(), 2);
        assert_eq!(s.get("a/w").unwrap().len(), 1);
        assert!(s.remove("a/w").is_some());
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn sort_canonical_matches_python_flatten_order() {
        let mut s = ParamStore::new();
        for n in ["b/y", "a", "b/x"] {
            s.insert(n, Tensor::zeros(&[1], Dtype::F32));
        }
        s.sort_canonical();
        assert_eq!(s.names(), &["a", "b/x", "b/y"]);
    }

    #[test]
    fn reorder_to_manifest_order() {
        let mut s = ParamStore::new();
        s.insert("x", Tensor::from_f32(&[1], vec![1.0]));
        s.insert("y", Tensor::from_f32(&[1], vec![2.0]));
        s.reorder_to(&["y".into(), "x".into()]).unwrap();
        assert_eq!(s.names(), &["y", "x"]);
        assert_eq!(s.tensors[0].as_f32().unwrap()[0], 2.0);
        assert!(s.reorder_to(&["y".into()]).is_err());
        assert!(s.clone().reorder_to(&["y".into(), "z".into()]).is_err());
    }

    #[test]
    fn n_params_sums() {
        let mut s = ParamStore::new();
        s.insert("w", Tensor::zeros(&[4, 5], Dtype::F32));
        s.insert("b", Tensor::zeros(&[5], Dtype::F32));
        assert_eq!(s.n_params(), 25);
    }
}
