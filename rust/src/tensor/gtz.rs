//! GTZ checkpoint format — the byte-level contract with `python/compile/aot.py`.
//!
//! ```text
//! "GTZ1" | u32 count | repeat count times:
//!   u16 name_len | name utf8 | u8 dtype(0=f32,1=i32) | u8 ndim
//!   | ndim x u64 dims | raw little-endian data
//! ```
//!
//! All integers little-endian. `python/tests/test_aot.py::test_gtz_roundtrip`
//! pins the same layout from the Python side.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use anyhow::{anyhow, bail, Context as _};

use super::{Data, Dtype, ParamStore, Tensor};
use crate::Result;

const MAGIC: &[u8; 4] = b"GTZ1";

/// Read and parse a GTZ checkpoint file.
pub fn read(path: impl AsRef<Path>) -> Result<ParamStore> {
    let path = path.as_ref();
    let buf = fs::read(path).with_context(|| format!("reading GTZ {path:?}"))?;
    parse(&buf).with_context(|| format!("parsing GTZ {path:?}"))
}

/// Parse GTZ bytes into a [`ParamStore`] (store order = file order).
pub fn parse(buf: &[u8]) -> Result<ParamStore> {
    let mut off = 0usize;
    let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
        if *off + n > buf.len() {
            bail!("GTZ truncated at offset {} (want {n} bytes)", *off);
        }
        let s = &buf[*off..*off + n];
        *off += n;
        Ok(s)
    };

    if take(&mut off, 4)? != MAGIC {
        bail!("bad GTZ magic");
    }
    let count = u32::from_le_bytes(take(&mut off, 4)?.try_into().unwrap()) as usize;
    let mut store = ParamStore::new();
    for _ in 0..count {
        let nlen = u16::from_le_bytes(take(&mut off, 2)?.try_into().unwrap()) as usize;
        let name = std::str::from_utf8(take(&mut off, nlen)?)
            .map_err(|e| anyhow!("bad tensor name utf8: {e}"))?
            .to_string();
        let dtype = Dtype::from_code(take(&mut off, 1)?[0])?;
        let ndim = take(&mut off, 1)?[0] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u64::from_le_bytes(take(&mut off, 8)?.try_into().unwrap()) as usize);
        }
        let n: usize = shape.iter().product();
        let raw = take(&mut off, n * dtype.size_bytes())?;
        let data = match dtype {
            Dtype::F32 => Data::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            Dtype::I32 => Data::I32(
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
        };
        store.insert(name, Tensor { shape, data });
    }
    if off != buf.len() {
        bail!("GTZ has {} trailing bytes", buf.len() - off);
    }
    Ok(store)
}

/// Write `store` as a GTZ file (creating parent directories).
pub fn write(path: impl AsRef<Path>, store: &ParamStore) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        fs::create_dir_all(parent)?;
    }
    let mut f = fs::File::create(path).with_context(|| format!("creating GTZ {path:?}"))?;
    f.write_all(MAGIC)?;
    f.write_all(&(store.len() as u32).to_le_bytes())?;
    for (name, t) in store.iter() {
        let nb = name.as_bytes();
        if nb.len() > u16::MAX as usize {
            bail!("tensor name too long: {name:?}");
        }
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[t.dtype().code(), t.ndim() as u8])?;
        for &d in &t.shape {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        f.write_all(t.raw_bytes())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_store() -> ParamStore {
        let mut s = ParamStore::new();
        s.insert("block0/w", Tensor::from_f32(&[2, 3], vec![1., -2., 3.5, 0., 1e-9, 6.]));
        s.insert("block0/bias", Tensor::from_f32(&[3], vec![0.1, 0.2, 0.3]));
        s.insert("toks", Tensor::from_i32(&[4], vec![1, -5, 7, 0]));
        s.insert("step", Tensor::scalar_f32(12.0));
        s
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("gtz_test_{}", std::process::id()));
        let path = dir.join("s.gtz");
        let s = sample_store();
        write(&path, &s).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.len(), s.len());
        for ((n1, t1), (n2, t2)) in s.iter().zip(back.iter()) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse(b"NOPE\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let dir = std::env::temp_dir().join(format!("gtz_trunc_{}", std::process::id()));
        let path = dir.join("s.gtz");
        write(&path, &sample_store()).unwrap();
        let buf = std::fs::read(&path).unwrap();
        for cut in [5, 9, 12, buf.len() - 1] {
            assert!(parse(&buf[..cut]).is_err(), "cut at {cut} should fail");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_trailing_garbage() {
        let dir = std::env::temp_dir().join(format!("gtz_trail_{}", std::process::id()));
        let path = dir.join("s.gtz");
        write(&path, &sample_store()).unwrap();
        let mut buf = std::fs::read(&path).unwrap();
        buf.push(0);
        assert!(parse(&buf).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_store_roundtrips() {
        let dir = std::env::temp_dir().join(format!("gtz_empty_{}", std::process::id()));
        let path = dir.join("e.gtz");
        write(&path, &ParamStore::new()).unwrap();
        assert_eq!(read(&path).unwrap().len(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
