//! Analytical cost model: params, FLOPs, memory, and the TPU roofline
//! estimates that stand in for real-TPU measurements (DESIGN.md §4).
//!
//! This module is the quantitative backbone of the paper's claims:
//! Eq. 1's gate is "factorize only when theoretical cost drops", and the
//! `table_cost_model` bench regenerates the params/FLOPs/speedup table from
//! these formulas, then checks predicted against measured wall-clock ratios.

pub mod roofline;

use crate::model::{LayerInfo, LayerKind};

/// FLOPs of a dense GEMM y = x W with x: (tokens, m), W: (m, n).
/// Counted as 2·tokens·m·n (multiply + add).
pub fn dense_linear_flops(tokens: usize, m: usize, n: usize) -> u64 {
    2 * tokens as u64 * m as u64 * n as u64
}

/// FLOPs of the LED replacement y = (x A) B, rank r.
pub fn led_linear_flops(tokens: usize, m: usize, n: usize, r: usize) -> u64 {
    2 * tokens as u64 * r as u64 * (m as u64 + n as u64)
}

/// Predicted speedup of LED over dense at the same shape (>1 = faster).
pub fn led_speedup(m: usize, n: usize, r: usize) -> f64 {
    dense_linear_flops(1, m, n) as f64 / led_linear_flops(1, m, n, r) as f64
}

/// Cost of one classified layer for `tokens` row-vectors through it.
/// Embedding/LayerNorm are memory-bound; we count their linear work.
pub fn layer_flops(layer: &LayerInfo, tokens: usize) -> u64 {
    match layer.kind {
        LayerKind::Linear | LayerKind::Conv2d => {
            dense_linear_flops(tokens, layer.in_dim, layer.out_dim)
        }
        LayerKind::LedLinear | LayerKind::CedConv2d => led_linear_flops(
            tokens,
            layer.in_dim,
            layer.out_dim,
            layer.rank.unwrap_or(0),
        ),
        LayerKind::TtLinear => {
            // Exact contraction cost of the core chain when the classifier
            // recovered it; dense fallback otherwise (never cheaper).
            2 * tokens as u64
                * layer
                    .tt
                    .as_ref()
                    .map(crate::model::TtInfo::macs_per_token)
                    .unwrap_or(layer.in_dim as u64 * layer.out_dim as u64)
        }
        LayerKind::LayerNorm => 8 * tokens as u64 * layer.in_dim as u64,
        LayerKind::Embedding => 0, // gather, no MACs
        LayerKind::Other => 0,
    }
}

/// Whole-checkpoint totals.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostSummary {
    /// Total weight parameters (biases excluded).
    pub weight_params: usize,
    /// MAC-based FLOPs per token/position.
    pub flops_per_token: u64,
    /// Weight footprint in bytes (f32).
    pub weight_bytes: usize,
}

/// Sum the per-layer cost model over a classified checkpoint.
pub fn summarize(layers: &[LayerInfo]) -> CostSummary {
    let mut s = CostSummary::default();
    for l in layers {
        // Conv layers process (H·W) positions per "token"; the per-position
        // model is good enough for relative comparisons, which is what
        // Figure 2 plots.
        s.weight_params += l.weight_params();
        s.flops_per_token += layer_flops(l, 1);
    }
    s.weight_bytes = s.weight_params * 4;
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn linear(name: &str, m: usize, n: usize) -> LayerInfo {
        LayerInfo {
            name: name.into(),
            kind: LayerKind::Linear,
            in_dim: m,
            out_dim: n,
            kernel: None,
            rank: None,
            tt: None,
        }
    }

    fn led(name: &str, m: usize, n: usize, r: usize) -> LayerInfo {
        LayerInfo {
            name: name.into(),
            kind: LayerKind::LedLinear,
            in_dim: m,
            out_dim: n,
            kernel: None,
            rank: Some(r),
            tt: None,
        }
    }

    #[test]
    fn led_cheaper_iff_gate_accepts() {
        // r < mn/(m+n) <=> LED flops < dense flops — the Eq. 1 identity.
        for (m, n) in [(128, 128), (768, 3072), (64, 512)] {
            let rmax = crate::factorize::r_max(m, n);
            let r_ok = (rmax as usize).saturating_sub(1).max(1);
            assert!(led_linear_flops(7, m, n, r_ok) < dense_linear_flops(7, m, n));
            let r_bad = rmax.ceil() as usize + 1;
            assert!(led_linear_flops(7, m, n, r_bad) > dense_linear_flops(7, m, n));
        }
    }

    #[test]
    fn speedup_formula() {
        // 128x128 at r=32: dense 2·128·128, led 2·32·256 => 16384/8192 = 2x
        assert!((led_speedup(128, 128, 32) - 2.0).abs() < 1e-12);
        assert!((led_speedup(768, 3072, 192) - 3.2).abs() < 1e-12);
    }

    #[test]
    fn tt_flops_exact_and_fallback() {
        let tt = crate::model::TtInfo {
            m_dims: vec![4, 6],
            n_dims: vec![6, 6],
            ranks: vec![1, 3, 1],
        };
        let macs = tt.macs_per_token();
        let mut layer = linear("tt", 24, 36);
        layer.kind = LayerKind::TtLinear;
        layer.tt = Some(tt);
        assert_eq!(layer_flops(&layer, 7), 2 * 7 * macs);
        // Without the recovered chain the model falls back to dense cost.
        layer.tt = None;
        assert_eq!(layer_flops(&layer, 7), dense_linear_flops(7, 24, 36));
    }

    #[test]
    fn summary_adds_up() {
        let layers = vec![linear("a", 128, 128), led("b", 128, 512, 32)];
        let s = summarize(&layers);
        assert_eq!(s.weight_params, 128 * 128 + 32 * (128 + 512));
        assert_eq!(
            s.flops_per_token,
            dense_linear_flops(1, 128, 128) + led_linear_flops(1, 128, 512, 32)
        );
        assert_eq!(s.weight_bytes, s.weight_params * 4);
    }
}
