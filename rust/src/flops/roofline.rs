//! TPU roofline estimates for the Pallas LED kernel (DESIGN.md §4).
//!
//! We cannot execute Mosaic kernels on CPU, so TPU performance is *estimated*
//! from the kernel's structure: per-program VMEM footprint (must fit the
//! 16 MiB budget) and MXU utilization (how full the 128×128 systolic tiles
//! are for the two skinny GEMMs LED emits). These numbers are printed by
//! `benches/kernel_speedup.rs` next to the measured CPU wall-clock ratios
//! (see DESIGN.md §4 and §11).

/// VMEM per core on the modeled TPU (v4-class), bytes.
pub const VMEM_BUDGET: usize = 16 * 1024 * 1024;

/// MXU tile edge.
pub const MXU_TILE: usize = 128;

/// Per-program VMEM bytes of the fused LED kernel with row-block `bm`:
/// x-tile (bm×k) + A (k×r) + intermediate (bm×r) + B (r×n) + out (bm×n).
/// Mirrors `python/compile/kernels/led.py::vmem_bytes`.
pub fn led_vmem_bytes(bm: usize, k: usize, r: usize, n: usize, dtype_bytes: usize) -> usize {
    (bm * k + k * r + bm * r + r * n + bm * n) * dtype_bytes
}

/// Fraction of MXU lanes doing useful work for an (m × k) @ (k × n) GEMM:
/// each dimension wastes the pad up to the next multiple of 128.
pub fn mxu_utilization(m: usize, k: usize, n: usize) -> f64 {
    let eff = |d: usize| d as f64 / (d.div_ceil(MXU_TILE) * MXU_TILE) as f64;
    eff(m) * eff(k) * eff(n)
}

/// Combined MXU utilization of the two LED GEMMs, FLOP-weighted.
pub fn led_mxu_utilization(m: usize, k: usize, r: usize, n: usize) -> f64 {
    let f1 = (m * k * r) as f64;
    let f2 = (m * r * n) as f64;
    (mxu_utilization(m, k, r) * f1 + mxu_utilization(m, r, n) * f2) / (f1 + f2)
}

/// Estimated TPU-side speedup of LED vs dense for one linear layer:
/// FLOP ratio discounted by the relative MXU utilization. This is the
/// honest version of the paper's "theoretical computational cost" —
/// a rank of 8 looks 8× cheaper in FLOPs but pads to a full 128-lane tile.
pub fn led_tpu_speedup_estimate(m_tokens: usize, k: usize, r: usize, n: usize) -> f64 {
    let dense_flops = 2.0 * m_tokens as f64 * k as f64 * n as f64;
    let led_flops = 2.0 * m_tokens as f64 * r as f64 * (k + n) as f64;
    let dense_util = mxu_utilization(m_tokens, k, n).max(1e-6);
    let led_util = led_mxu_utilization(m_tokens, k, r, n).max(1e-6);
    (dense_flops / dense_util.max(1e-6)) / (led_flops / led_util)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vmem_formula_counts_all_tiles() {
        // bm=128, k=128, r=32, n=512, f32
        let b = led_vmem_bytes(128, 128, 32, 512, 4);
        assert_eq!(b, (128 * 128 + 128 * 32 + 128 * 32 + 32 * 512 + 128 * 512) * 4);
        assert!(b < VMEM_BUDGET);
    }

    #[test]
    fn model_shapes_fit_vmem() {
        // Every (k, r, n) the model zoo can emit must fit at bm=128.
        for (k, n) in [(128, 128), (128, 512), (512, 128), (192, 768), (768, 192), (192, 512)] {
            for ratio in [0.10, 0.25, 0.50, 0.75] {
                if let Some(r) = crate::factorize::rank_for(k, n, ratio) {
                    assert!(led_vmem_bytes(128, k, r, n, 4) < VMEM_BUDGET, "({k},{r},{n})");
                }
            }
        }
    }

    #[test]
    fn utilization_is_one_on_aligned_shapes() {
        assert!((mxu_utilization(128, 128, 128) - 1.0).abs() < 1e-12);
        assert!((mxu_utilization(256, 384, 512) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_penalizes_skinny_dims() {
        let u = mxu_utilization(128, 128, 8); // n=8 wastes 120/128 lanes
        assert!((u - 8.0 / 128.0).abs() < 1e-12);
    }

    #[test]
    fn tpu_estimate_below_flop_ratio_for_small_ranks() {
        // FLOP-only speedup for 768x768 @ r=8 is huge; the MXU-aware
        // estimate must be strictly smaller (padding waste).
        let flops_ratio = crate::flops::led_speedup(768, 768, 8);
        let est = led_tpu_speedup_estimate(256, 768, 8, 768);
        assert!(est < flops_ratio, "est={est} flops={flops_ratio}");
        assert!(est > 1.0, "still a win: {est}");
    }

    #[test]
    fn aligned_rank_estimate_close_to_flop_ratio() {
        let flops_ratio = crate::flops::led_speedup(768, 768, 128);
        let est = led_tpu_speedup_estimate(256, 768, 128, 768);
        assert!((est - flops_ratio).abs() / flops_ratio < 1e-9);
    }
}
