//! Speculative decoding: a rank-cut LED draft model proposes, the dense
//! target verifies — factorization as a serving throughput lever.
//!
//! The paper's claim is that a factorized model is a *faithful cheap proxy*
//! of its dense parent. Speculative decoding operationalizes that claim: a
//! [`SpecSession`] pairs a draft [`DecodeSession`] (LED/CED rank-cut
//! params, built with [`build_draft_params`]) with a target session (dense
//! params). Each [`SpecSession::step`] drafts `k` tokens autoregressively
//! on the cheap model, then verifies all of them in **one** stacked
//! multi-row pass through the target ([`Backend::run_decode_step_multi`] —
//! the same chunk machinery the batched/prefill paths use), accepts the
//! longest valid prefix, and rolls both KV caches back past any rejected
//! suffix ([`DecodeSession::truncate`]). The measured acceptance rate *is*
//! the paper's accuracy-retention claim made operational: the closer the
//! rank-cut model tracks the dense one, the more drafts survive and the
//! closer the decode loop runs to `k + 1` tokens per target pass.
//!
//! Accept rules:
//!
//! * **Greedy** (`temperature <= 0`): draft token `d_i` is accepted iff it
//!   equals the target's argmax at that position; the first mismatch is
//!   replaced by the target's own argmax, and on full acceptance the extra
//!   verify row yields a free "bonus" token. Because every emitted token is
//!   by construction the target's argmax at its prefix — and the chunked
//!   verify rows are value-identical to solo steps (see [`super::decode`])
//!   — greedy speculative output is **token-for-token identical** to plain
//!   greedy decoding of the target, at any `k`, with any draft. Pinned by
//!   `tests/proptest_spec_decode.rs`.
//! * **Sampled**: seeded rejection sampling (Leviathan-style). Draft token
//!   `d_i ~ p_draft` is accepted with probability
//!   `min(1, p_target(d_i) / p_draft(d_i))`; on rejection the replacement
//!   is drawn from the residual `max(p_target - p_draft, 0)` renormalized,
//!   which makes each emitted token exactly `p_target`-distributed. Both
//!   distributions are the post-temperature/top-k distributions
//!   [`sample_token`] draws from, and all randomness comes from the one
//!   seeded [`SamplingCfg`] stream, so a fixed seed reproduces the stream.
//!
//! The coordinator schedules speculative sessions inside its continuous-
//! batching sweep (`ServeConfig::spec`), the CLI exposes
//! `generate --speculative`, and `eval::measure_spec_decode` /
//! `benches/native_decode.rs` pin the tokens/sec + acceptance numbers.

use anyhow::bail;

use crate::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use crate::runtime::GraphSpec;
use crate::tensor::ParamStore;
use crate::util::Pcg64;
use crate::Result;

use super::decode::{argmax, sample_token, DecodeSession, SamplingCfg};
use super::Backend;

/// Speculative-decoding policy knobs, carried by `ServeConfig` and the CLI.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpecConfig {
    /// Rank ratio of the LED draft built from the target checkpoint
    /// (`0 < draft_ratio < 1`); lower is a cheaper but less faithful
    /// drafter. Consumed by [`build_draft_params`] — the step engine itself
    /// never reads it.
    pub draft_ratio: f64,
    /// Tokens drafted per speculative step (the verify pass scores `k + 1`
    /// rows). Must be at least 1.
    pub k: usize,
    /// Adapt the per-step draft length to recent acceptance: grow by one
    /// (up to `k`) after a fully-accepted step, shrink to the accepted
    /// count (floor 1) otherwise. Deterministic, so it never perturbs the
    /// greedy-equivalence contract.
    pub adaptive_k: bool,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig { draft_ratio: 0.25, k: 4, adaptive_k: false }
    }
}

impl SpecConfig {
    /// Reject out-of-range knobs with a actionable message.
    pub fn validate(&self) -> Result<()> {
        if self.k == 0 {
            bail!("SpecConfig.k must be >= 1 (k is the per-step draft length)");
        }
        if !(self.draft_ratio > 0.0 && self.draft_ratio < 1.0) {
            bail!(
                "SpecConfig.draft_ratio must be in (0, 1), got {} (it is the LED rank ratio \
                 of the draft model)",
                self.draft_ratio
            );
        }
        Ok(())
    }
}

/// Build an LED draft checkpoint from a target checkpoint: clone + SVD
/// factorization at `Rank::Ratio(draft_ratio)`.
///
/// SVD is the right solver here — the draft must *approximate* the target
/// for drafts to be accepted (the paper's LED-on-trained-weights setting).
/// Layers the Eq.-1 gate rejects (too small for the ratio to pay) stay
/// dense; if nothing factorizes at all — e.g. the target is already
/// rank-cut — the clone is returned unchanged and speculation degenerates
/// gracefully to a draft that is the target itself (every draft accepted,
/// no speedup, still correct).
pub fn build_draft_params(params: &ParamStore, draft_ratio: f64) -> Result<ParamStore> {
    if !(draft_ratio > 0.0 && draft_ratio < 1.0) {
        bail!("draft_ratio must be in (0, 1), got {draft_ratio}");
    }
    let mut draft = params.clone();
    auto_fact(
        &mut draft,
        &AutoFactConfig {
            rank: Rank::Ratio(draft_ratio),
            solver: Solver::Svd,
            num_iter: 0,
            submodules: None,
            ..Default::default()
        },
    )?;
    Ok(draft)
}

/// What one [`SpecSession::step`] emitted and spent.
#[derive(Clone, Debug)]
pub struct SpecStep {
    /// Tokens emitted by this step, in stream order: the accepted draft
    /// prefix followed by one target-sampled token (the correction at the
    /// first mismatch, or the bonus row on full acceptance). Never empty.
    pub tokens: Vec<i32>,
    /// Draft tokens proposed this step (0 for a degenerate plain step at
    /// the capacity/budget tail).
    pub drafted: usize,
    /// How many of those drafts the target accepted.
    pub accepted: usize,
    /// KV positions rolled back off the target cache (`drafted - accepted`).
    pub rolled_back: usize,
}

/// One in-flight speculative generation: a draft session and a target
/// session advancing in lockstep over the accepted token stream.
///
/// Invariant between steps: the target cache holds exactly the accepted
/// prefix (prompt + every emitted token except the newest, which — like
/// plain [`generate`](super::generate) — is sampled but not yet appended),
/// and `draft_pending` holds whatever suffix of that stream the draft cache
/// hasn't seen yet (normally just the newest token; also the final drafted
/// token after a fully-accepted step, since the draft never feeds its own
/// last proposal).
#[derive(Debug)]
pub struct SpecSession {
    target: DecodeSession,
    draft: DecodeSession,
    sampling: SamplingCfg,
    rng: Pcg64,
    /// Newest emitted token — sampled, not yet appended to the target.
    last: i32,
    /// Emitted-stream suffix the draft cache hasn't ingested yet.
    draft_pending: Vec<i32>,
    /// Configured ceiling for the per-step draft length.
    k_max: usize,
    /// Current draft length (== `k_max` unless `adaptive_k` moved it).
    k_cur: usize,
    adaptive: bool,
    drafted: u64,
    accepted: u64,
    rollbacks: u64,
    corrections: u64,
    steps: u64,
}

impl SpecSession {
    /// Open a speculative session: prefill both models on `prompt` and
    /// sample the first token from the **target's** prefill logits (exactly
    /// what plain decoding does — the draft only ever proposes, never
    /// emits). Returns the session plus that first emitted token.
    ///
    /// The prompt must be non-empty (degenerate requests are the driver's
    /// job — see [`generate_speculative`]); draft and target must agree on
    /// vocabulary width.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        backend: &dyn Backend,
        target_graph: &GraphSpec,
        target_params: &ParamStore,
        draft_graph: &GraphSpec,
        draft_params: &ParamStore,
        prompt: &[i32],
        sampling: SamplingCfg,
        spec: &SpecConfig,
    ) -> Result<(Self, i32)> {
        spec.validate()?;
        if prompt.is_empty() {
            bail!("speculative decode needs a non-empty prompt");
        }
        let mut target = DecodeSession::new(target_graph, target_params)?;
        let mut draft = DecodeSession::new(draft_graph, draft_params)?;
        if draft.vocab() != target.vocab() {
            bail!(
                "draft vocab {} != target vocab {}: the draft must be a factorization of the \
                 target family",
                draft.vocab(),
                target.vocab()
            );
        }
        if prompt.len() > target.max_seq() || prompt.len() > draft.max_seq() {
            bail!(
                "prompt length {} exceeds positional capacity (target {}, draft {})",
                prompt.len(),
                target.max_seq(),
                draft.max_seq()
            );
        }
        let logits = backend.run_decode_step(target_graph, target_params, &mut target, prompt)?;
        backend.run_decode_step(draft_graph, draft_params, &mut draft, prompt)?;
        let mut rng = sampling.rng();
        let first = sample_token(logits.as_f32()?, &sampling, &mut rng) as i32;
        Ok((
            SpecSession {
                target,
                draft,
                sampling,
                rng,
                last: first,
                draft_pending: vec![first],
                k_max: spec.k,
                k_cur: spec.k,
                adaptive: spec.adaptive_k,
                drafted: 0,
                accepted: 0,
                rollbacks: 0,
                corrections: 1, // the prefill sample is a target-emitted token
                steps: 0,
            },
            first,
        ))
    }

    /// One draft → verify → accept/rollback round, emitting between 1 and
    /// `k + 1` tokens (never more than `max_emit`, which callers set to
    /// their remaining `max_new` budget).
    ///
    /// When capacity or budget leaves no room to draft (`k_eff == 0`), the
    /// step degenerates to a plain single-token target step — same output
    /// contract, zero drafts — so the driver never needs a special tail
    /// path. Errors if the target context is already full.
    pub fn step(
        &mut self,
        backend: &dyn Backend,
        target_graph: &GraphSpec,
        target_params: &ParamStore,
        draft_graph: &GraphSpec,
        draft_params: &ParamStore,
        max_emit: usize,
    ) -> Result<SpecStep> {
        if max_emit == 0 {
            bail!("speculate step needs max_emit >= 1");
        }
        let headroom = self.target.remaining();
        if headroom == 0 {
            bail!("speculate step: target positional capacity exhausted");
        }
        // The verify chunk appends 1 + k positions to the target; the draft
        // appends its pending backlog plus k - 1 proposals. Bound k by the
        // emit budget (a step emits at most k + 1 tokens), both capacities,
        // and the (possibly adaptive) configured length.
        let draft_room =
            (self.draft.remaining() + 1).saturating_sub(self.draft_pending.len());
        let k = self
            .k_cur
            .min(max_emit.saturating_sub(1))
            .min(headroom - 1)
            .min(draft_room);
        self.steps += 1;
        let greedy = self.sampling.temperature <= 0.0;

        if k == 0 {
            // Degenerate tail: one plain target step keeps the stream
            // flowing when there is no room (or no budget) to speculate.
            let logits =
                backend.run_decode_step(target_graph, target_params, &mut self.target, &[self.last])?;
            let t = sample_token(logits.as_f32()?, &self.sampling, &mut self.rng) as i32;
            self.last = t;
            self.draft_pending.push(t);
            self.corrections += 1;
            return Ok(SpecStep { tokens: vec![t], drafted: 0, accepted: 0, rolled_back: 0 });
        }

        // --- Draft phase: k autoregressive proposals on the cheap model.
        // The first chunk flushes the pending backlog; each later chunk is
        // the previous proposal. The final proposal is never fed — the
        // verify outcome decides whether the draft ever sees it.
        let mut drafts: Vec<i32> = Vec::with_capacity(k);
        let mut draft_dists: Vec<Vec<f64>> = Vec::new();
        let mut chunk = std::mem::take(&mut self.draft_pending);
        for _ in 0..k {
            let logits_t =
                backend.run_decode_step(draft_graph, draft_params, &mut self.draft, &chunk)?;
            let logits = logits_t.as_f32()?;
            let proposal = if greedy {
                argmax(logits)
            } else {
                let dist = sampling_dist(logits, &self.sampling);
                let tok = self.rng.weighted(&dist);
                draft_dists.push(dist);
                tok
            };
            drafts.push(proposal as i32);
            chunk.clear();
            chunk.push(proposal as i32);
        }

        // --- Verify phase: one stacked (k + 1)-row pass through the
        // target. Row i is the target's next-token distribution after
        // [last, d_1, .., d_i] — row k is the bonus row.
        let base = self.target.len();
        let mut verify = Vec::with_capacity(k + 1);
        verify.push(self.last);
        verify.extend_from_slice(&drafts);
        let rows_t =
            backend.run_decode_step_multi(target_graph, target_params, &mut self.target, &verify)?;
        let rows = rows_t.as_f32()?;
        let vocab = self.target.vocab();

        // --- Accept phase.
        let mut a = 0usize; // accepted draft count
        let next: i32;
        if greedy {
            while a < k && argmax(&rows[a * vocab..(a + 1) * vocab]) as i32 == drafts[a] {
                a += 1;
            }
            // First mismatch row → the target's own argmax (the exact token
            // plain greedy decode would emit here); row k → bonus token.
            next = argmax(&rows[a * vocab..(a + 1) * vocab]) as i32;
        } else {
            let mut replacement = None;
            while a < k {
                let p_target = sampling_dist(&rows[a * vocab..(a + 1) * vocab], &self.sampling);
                let d = drafts[a] as usize;
                let (pt, pd) = (p_target[d], draft_dists[a][d]);
                // Accept with prob min(1, pt/pd); u in [0,1) makes pd == pt
                // always accept.
                if pd > 0.0 && self.rng.next_f64() * pd < pt {
                    a += 1;
                    continue;
                }
                // Rejected: draw from the residual max(p_target - p_draft, 0),
                // which keeps the emitted marginal exactly p_target.
                let residual: Vec<f64> = p_target
                    .iter()
                    .zip(&draft_dists[a])
                    .map(|(&t, &q)| (t - q).max(0.0))
                    .collect();
                let tok = if residual.iter().sum::<f64>() > 0.0 {
                    self.rng.weighted(&residual)
                } else {
                    // Identical distributions (numerically): plain draw.
                    self.rng.weighted(&p_target)
                };
                replacement = Some(tok as i32);
                break;
            }
            next = match replacement {
                Some(t) => t,
                None => {
                    let bonus = sampling_dist(&rows[k * vocab..(k + 1) * vocab], &self.sampling);
                    self.rng.weighted(&bonus) as i32
                }
            };
        }

        // --- Rollback phase: erase the rejected suffix from both caches.
        let accepted_len = base + 1 + a;
        let rolled = self.target.len() - accepted_len; // == k - a
        self.target.truncate(accepted_len);
        self.draft.truncate(accepted_len);
        debug_assert!(self.draft_pending.is_empty());
        if self.draft.len() < accepted_len {
            // Fully-accepted step: the draft never ingested its own final
            // proposal, which is now part of the accepted stream.
            debug_assert_eq!(self.draft.len() + 1, accepted_len);
            self.draft_pending.push(drafts[k - 1]);
        }
        self.draft_pending.push(next);
        self.last = next;

        let mut tokens = drafts;
        tokens.truncate(a);
        tokens.push(next);
        self.drafted += k as u64;
        self.accepted += a as u64;
        self.corrections += 1;
        if rolled > 0 {
            self.rollbacks += 1;
        }
        if self.adaptive {
            self.k_cur = if a == k { (self.k_cur + 1).min(self.k_max) } else { a.max(1) };
        }
        Ok(SpecStep { tokens, drafted: k, accepted: a, rolled_back: rolled })
    }

    /// The target-model session (holds the accepted prefix).
    pub fn target(&self) -> &DecodeSession {
        &self.target
    }

    /// The draft-model session.
    pub fn draft(&self) -> &DecodeSession {
        &self.draft
    }

    /// Total draft tokens proposed so far.
    pub fn drafted(&self) -> u64 {
        self.drafted
    }

    /// Total draft tokens the target accepted.
    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    /// Steps that had to roll back at least one rejected draft.
    pub fn rollbacks(&self) -> u64 {
        self.rollbacks
    }

    /// Target-sampled tokens emitted (prefill sample + one per step).
    /// `accepted() + corrections()` always equals the emitted-token count.
    pub fn corrections(&self) -> u64 {
        self.corrections
    }

    /// Speculative steps taken (including degenerate plain-step tails).
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Fraction of drafted tokens accepted; 0 before anything was drafted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

/// The categorical distribution [`sample_token`] draws from: temperature
/// softmax over the `top_k` highest logits (full support when `top_k` is
/// 0), as a dense probability vector over the whole vocabulary. Rejection
/// sampling needs both models' distributions over the same support.
fn sampling_dist(logits: &[f32], cfg: &SamplingCfg) -> Vec<f64> {
    debug_assert!(cfg.temperature > 0.0, "greedy mode never builds a distribution");
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if cfg.top_k > 0 && cfg.top_k < logits.len() {
        // Same deterministic support selection as sample_token.
        idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]).then(a.cmp(&b)));
        idx.truncate(cfg.top_k);
    }
    let inv_t = 1.0 / cfg.temperature;
    let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let mut dist = vec![0.0f64; logits.len()];
    let mut total = 0.0;
    for &i in &idx {
        let w = f64::from((logits[i] - max) * inv_t).exp();
        dist[i] = w;
        total += w;
    }
    for v in &mut dist {
        *v /= total;
    }
    dist
}

/// What one [`generate_speculative`] run produced: the plain
/// [`GenerateOutcome`](super::GenerateOutcome) fields plus the speculation
/// ledger.
#[derive(Clone, Debug, Default)]
pub struct SpecGenerateOutcome {
    /// Generated token ids, in order (the prompt is not repeated). Under
    /// greedy sampling this is identical to what plain
    /// [`generate`](super::generate) on the target emits.
    pub tokens: Vec<i32>,
    /// Prompt length consumed by the prefills (both models see it).
    pub prefill_tokens: usize,
    /// Positions held in the target's KV cache at the end.
    pub positions_used: usize,
    /// Draft tokens proposed across all steps.
    pub drafted: u64,
    /// Draft tokens accepted by the verify passes.
    pub accepted: u64,
    /// Steps that rolled back at least one rejected draft.
    pub rollbacks: u64,
    /// Target-sampled tokens (prefill sample + one per step);
    /// `accepted + corrections == tokens.len()`.
    pub corrections: u64,
    /// Speculative steps taken after the prefill.
    pub steps: u64,
}

impl SpecGenerateOutcome {
    /// Fraction of drafted tokens accepted; 0 when nothing was drafted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

/// Speculative counterpart of [`generate`](super::generate): prefill both
/// models, then draft/verify/rollback rounds until `max_new` tokens are out
/// or the target's positional capacity is exhausted. `on_token(index,
/// token)` fires per emitted token in stream order.
///
/// Emits exactly the token count plain `generate` would (the two stop rules
/// coincide), and under greedy sampling exactly the same *tokens* — the
/// draft model only ever changes how fast the stream is produced, never
/// what it says. Degenerate requests (empty prompt / `max_new == 0`) yield
/// a clean empty outcome, mirroring `generate`.
#[allow(clippy::too_many_arguments)]
pub fn generate_speculative(
    backend: &dyn Backend,
    target_graph: &GraphSpec,
    target_params: &ParamStore,
    draft_graph: &GraphSpec,
    draft_params: &ParamStore,
    prompt: &[i32],
    max_new: usize,
    sampling: &SamplingCfg,
    spec: &SpecConfig,
    mut on_token: impl FnMut(usize, i32),
) -> Result<SpecGenerateOutcome> {
    if prompt.is_empty() || max_new == 0 {
        return Ok(SpecGenerateOutcome::default());
    }
    let (mut session, first) = SpecSession::new(
        backend,
        target_graph,
        target_params,
        draft_graph,
        draft_params,
        prompt,
        *sampling,
        spec,
    )?;
    on_token(0, first);
    let mut tokens = vec![first];
    while tokens.len() < max_new && session.target().remaining() > 0 {
        let step = session.step(
            backend,
            target_graph,
            target_params,
            draft_graph,
            draft_params,
            max_new - tokens.len(),
        )?;
        for &t in &step.tokens {
            on_token(tokens.len(), t);
            tokens.push(t);
        }
    }
    Ok(SpecGenerateOutcome {
        tokens,
        prefill_tokens: prompt.len(),
        positions_used: session.target().len(),
        drafted: session.drafted(),
        accepted: session.accepted(),
        rollbacks: session.rollbacks(),
        corrections: session.corrections(),
        steps: session.steps(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
    use crate::backend::{generate, NativeBackend};

    fn lm_cfg() -> TextModelCfg {
        TextModelCfg { vocab: 48, seq: 12, d: 24, heads: 6, layers: 1, ff: 48, classes: 48 }
    }

    fn setup(seed: u64, ratio: f64) -> (ParamStore, ParamStore, GraphSpec) {
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, seed);
        let draft = build_draft_params(&params, ratio).unwrap();
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        (params, draft, g)
    }

    #[test]
    fn config_validation_rejects_bad_knobs() {
        assert!(SpecConfig::default().validate().is_ok());
        assert!(SpecConfig { k: 0, ..Default::default() }.validate().is_err());
        assert!(SpecConfig { draft_ratio: 0.0, ..Default::default() }.validate().is_err());
        assert!(SpecConfig { draft_ratio: 1.0, ..Default::default() }.validate().is_err());
        assert!(build_draft_params(&ParamStore::new(), 1.5).is_err());
    }

    #[test]
    fn greedy_speculative_equals_plain_greedy_smoke() {
        let be = NativeBackend::new();
        let (params, draft, g) = setup(3, 0.5);
        let sampling = SamplingCfg::greedy();
        let spec = SpecConfig { k: 3, ..Default::default() };
        let mut streamed = Vec::new();
        let out = generate_speculative(
            &be, &g, &params, &g, &draft, &[1, 2, 3], 8, &sampling, &spec, |i, t| {
                streamed.push((i, t));
            },
        )
        .unwrap();
        let plain = generate(&be, &g, &params, &[1, 2, 3], 8, &sampling, |_, _| {}).unwrap();
        assert_eq!(out.tokens, plain.tokens, "greedy spec must equal plain greedy");
        assert_eq!(out.positions_used, plain.positions_used);
        assert_eq!(out.accepted + out.corrections, out.tokens.len() as u64);
        assert_eq!(
            streamed,
            out.tokens.iter().copied().enumerate().collect::<Vec<_>>(),
            "streaming callback must see the stream in order"
        );
        assert!(out.drafted > 0);
    }

    #[test]
    fn draft_equals_target_accepts_everything() {
        let be = NativeBackend::new();
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, 5);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        // Draft == target: every greedy draft matches the verify argmax.
        let out = generate_speculative(
            &be,
            &g,
            &params,
            &g,
            &params,
            &[4, 5],
            6,
            &SamplingCfg::greedy(),
            &SpecConfig { k: 2, ..Default::default() },
            |_, _| {},
        )
        .unwrap();
        assert_eq!(out.accepted, out.drafted, "self-drafting must accept every token");
        assert_eq!(out.rollbacks, 0);
        let plain = generate(&be, &g, &params, &[4, 5], 6, &SamplingCfg::greedy(), |_, _| {})
            .unwrap();
        assert_eq!(out.tokens, plain.tokens);
    }

    #[test]
    fn degenerate_requests_yield_clean_empty_outcomes() {
        let be = NativeBackend::new();
        let (params, draft, g) = setup(7, 0.5);
        let sampling = SamplingCfg::greedy();
        let spec = SpecConfig::default();
        let a = generate_speculative(&be, &g, &params, &g, &draft, &[], 4, &sampling, &spec, |_, _| {})
            .unwrap();
        let b = generate_speculative(&be, &g, &params, &g, &draft, &[1], 0, &sampling, &spec, |_, _| {})
            .unwrap();
        for out in [a, b] {
            assert!(out.tokens.is_empty());
            assert_eq!(out.positions_used, 0);
            assert_eq!(out.drafted, 0);
        }
    }

    #[test]
    fn sampled_mode_is_seed_reproducible() {
        let be = NativeBackend::new();
        let (params, draft, g) = setup(11, 0.5);
        let sampling = SamplingCfg { temperature: 0.9, top_k: 8, seed: 42 };
        let spec = SpecConfig { k: 3, ..Default::default() };
        let a = generate_speculative(&be, &g, &params, &g, &draft, &[2, 3], 7, &sampling, &spec, |_, _| {})
            .unwrap();
        let b = generate_speculative(&be, &g, &params, &g, &draft, &[2, 3], 7, &sampling, &spec, |_, _| {})
            .unwrap();
        assert_eq!(a.tokens, b.tokens, "fixed seed must reproduce the sampled stream");
        assert_eq!(a.tokens.len(), 7);
        assert_eq!(a.accepted + a.corrections, a.tokens.len() as u64);
    }

    #[test]
    fn adaptive_k_stays_within_bounds_and_preserves_greedy_stream() {
        let be = NativeBackend::new();
        let (params, draft, g) = setup(13, 0.5);
        let sampling = SamplingCfg::greedy();
        let adaptive = SpecConfig { k: 4, adaptive_k: true, ..Default::default() };
        let out = generate_speculative(
            &be, &g, &params, &g, &draft, &[1, 2], 9, &sampling, &adaptive, |_, _| {},
        )
        .unwrap();
        let plain = generate(&be, &g, &params, &[1, 2], 9, &sampling, |_, _| {}).unwrap();
        assert_eq!(out.tokens, plain.tokens, "adaptive k must not change greedy output");
    }
}
