//! `NativeBackend` — a pure-Rust CPU interpreter for the forward pass.
//!
//! NeuralMatrix (arXiv 2305.14405) observes that entire networks reduce to
//! plain linear matrix operations; this module takes that literally: the
//! checkpoint's layer structure is recovered with [`crate::model::classify`]
//! and executed directly over the [`ParamStore`] — Embedding → transformer
//! blocks of LayerNorm / attention / Linear-or-LED → logits for the text and
//! LM models, and the Conv2d/CED im2col path for the image model. Every GEMM
//! routes through the cache-blocked, multithreaded
//! [`crate::linalg::matrix::matmul_into`], so the dense-vs-LED speedup the
//! paper prices is directly measurable on CPU (LED executes as two skinny
//! GEMMs, never materializing `a·b`).
//!
//! Because LED/CED keep each layer's I/O signature, one interpreter runs any
//! mixture of dense and factorized layers — the same dispatch-on-keys
//! contract as `python/compile/layers.py`. Graph metadata comes from the AOT
//! manifest when present, or from [`synth_fwd_graph`], which synthesizes a
//! [`GraphSpec`] for any checkpoint so the serving stack runs hermetically
//! (no `artifacts/`, no PJRT).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail};

use crate::factorize::tt::{tt_apply_ws, TtCoreView, TT_MAX_MODES};
use crate::factorize::QuantStore;
use crate::linalg::gemm::{apply_epilogue, matmul_bias_into, Activation};
use crate::linalg::matrix::matmul_into;
use crate::linalg::workspace::{with_thread_ws, Workspace};
use crate::model::classify;
use crate::runtime::{GraphSpec, TensorSpec};
use crate::tensor::{Dtype, ParamStore, Tensor};
use crate::util::Pcg64;
use crate::Result;

use super::{Backend, BackendKind};

/// Pure-Rust forward-pass interpreter. Stateless and `Send`: any thread can
/// own one (unlike the PJRT client).
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    /// The interpreter (zero-sized; construction is free).
    pub fn new() -> Self {
        NativeBackend
    }

    /// [`Backend::run_fwd`] with a weight-precision axis: linear groups
    /// present in `quant` execute through the int8 / binary kernels
    /// (DESIGN.md §12), everything else falls through to the f32 tensors.
    /// `quant: None` is bit-identical to [`Backend::run_fwd`].
    pub fn run_fwd_quant(
        &self,
        graph: &GraphSpec,
        params: &ParamStore,
        quant: Option<&QuantStore>,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        if graph.kind != "fwd" {
            bail!("native backend only executes fwd graphs, got {}", graph.kind);
        }
        if inputs.len() != 1 {
            bail!("graph {} wants 1 input, got {}", graph.name, inputs.len());
        }
        let x = &inputs[0];
        let spec = graph
            .inputs
            .first()
            .ok_or_else(|| anyhow!("graph {} has no input spec", graph.name))?;
        if x.shape != spec.shape {
            bail!(
                "input shape {:?} does not match graph {} spec {:?}",
                x.shape,
                graph.name,
                spec.shape
            );
        }
        if x.ndim() == 4 {
            return with_thread_ws(|ws| Ok(vec![image_fwd(params, quant, x, ws)?]));
        }
        if x.ndim() != 2 {
            bail!("expected (batch, seq) tokens or (b, h, w, c) pixels, got {:?}", x.shape);
        }
        let (b, s) = (x.shape[0], x.shape[1]);
        let tokens = x.as_i32()?;
        let heads = heads_for(graph);
        // LM graphs emit per-position logits (B, S, vocab); classifiers pool
        // to (B, classes). Activation buffers come from the calling thread's
        // workspace, so steady-state serving reuses them across requests.
        let causal = graph.outputs.first().is_some_and(|o| o.shape.len() == 3);
        let out = with_thread_ws(|ws| {
            if causal {
                lm_fwd(params, quant, tokens, b, s, heads, ws)
            } else {
                classifier_fwd(params, quant, tokens, b, s, heads, ws)
            }
        })?;
        Ok(vec![out])
    }
}

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        let threads = std::thread::available_parallelism().map_or(1, |p| p.get());
        format!("native-cpu ({threads} threads)")
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Native
    }

    fn supports(&self, graph: &GraphSpec) -> bool {
        graph.kind == "fwd" || graph.kind == "train"
    }

    fn run_fwd(
        &self,
        graph: &GraphSpec,
        params: &ParamStore,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        self.run_fwd_quant(graph, params, None, inputs)
    }

    fn run_train_step(
        &self,
        graph: &GraphSpec,
        params: &mut ParamStore,
        m: &mut ParamStore,
        v: &mut ParamStore,
        step_no: f32,
        batch: &[Tensor],
    ) -> Result<f32> {
        super::grad::native_train_step(
            graph,
            params,
            m,
            v,
            step_no,
            batch,
            &super::grad::AdamConfig::default(),
        )
    }

    fn run_decode_step(
        &self,
        _graph: &GraphSpec,
        params: &ParamStore,
        session: &mut super::DecodeSession,
        new_tokens: &[i32],
    ) -> Result<Tensor> {
        super::decode::native_decode_step(params, session, new_tokens)
    }

    fn run_decode_step_batched(
        &self,
        _graph: &GraphSpec,
        params: &ParamStore,
        sessions: &mut [&mut super::DecodeSession],
        tokens: &[i32],
    ) -> Result<Vec<Tensor>> {
        super::decode::native_decode_step_batched(params, sessions, tokens)
    }

    fn run_decode_step_multi(
        &self,
        _graph: &GraphSpec,
        params: &ParamStore,
        session: &mut super::DecodeSession,
        new_tokens: &[i32],
    ) -> Result<Tensor> {
        super::decode::native_decode_step_multi(params, session, new_tokens)
    }
}

/// Attention head count: the manifest's `config.heads` when recorded, else
/// the model-zoo defaults (`python/compile/model.py`).
pub(crate) fn heads_for(graph: &GraphSpec) -> usize {
    graph
        .config_usize("heads")
        .unwrap_or_else(|_| default_heads(&graph.model))
}

fn default_heads(model: &str) -> usize {
    if model == "lm" {
        6
    } else {
        4
    }
}

// ---------------------------------------------------------------------------
// Graph synthesis (hermetic serving: no manifest required)
// ---------------------------------------------------------------------------

/// Synthesize a fwd [`GraphSpec`] for a checkpoint by inspecting its layer
/// structure — the native analogue of the AOT manifest entry. Dimensions
/// (seq, d, classes, image size) are recovered from the parameters
/// themselves; `model` picks the architecture family (`"image"` is detected
/// from a `conv1` group, tokens otherwise, with `"lm"` selecting
/// per-position logits).
///
/// The attention head count is *not* recoverable from the parameters, so
/// `config.heads` is set to the model-zoo default (4 for text, 6 for lm) —
/// the same contract the AOT exporter uses. A checkpoint built with a
/// non-default head count must override `config["heads"]` on the returned
/// spec before executing it.
pub fn synth_fwd_graph(
    model: &str,
    variant: &str,
    batch: usize,
    params: &ParamStore,
) -> Result<GraphSpec> {
    if batch == 0 {
        bail!("synth_fwd_graph: batch must be positive");
    }
    let layers = classify(params);
    let find = |name: &str| layers.iter().find(|l| l.name == name);

    let tensor_specs: Vec<TensorSpec> = params
        .iter()
        .map(|(n, t)| TensorSpec {
            name: n.to_string(),
            shape: t.shape.clone(),
            dtype: match t.dtype() {
                Dtype::F32 => "f32",
                Dtype::I32 => "i32",
            }
            .to_string(),
        })
        .collect();
    let mut ranks = BTreeMap::new();
    for l in &layers {
        if let Some(r) = l.rank {
            ranks.insert(l.name.clone(), r);
        }
    }
    let mut config = BTreeMap::new();

    let (inputs, outputs) = if let Some(conv1) = find("conv1") {
        let (kh, kw) = conv1.kernel.ok_or_else(|| anyhow!("conv1 without kernel dims"))?;
        let cin = conv1.in_dim / (kh * kw).max(1);
        let c2 = find("conv2")
            .ok_or_else(|| anyhow!("image checkpoint missing conv2"))?
            .out_dim;
        let fc1 = find("fc1").ok_or_else(|| anyhow!("image checkpoint missing fc1"))?;
        let classes = find("fc2")
            .ok_or_else(|| anyhow!("image checkpoint missing fc2"))?
            .out_dim;
        // flat = (hw/4)^2 * c2 after two 2x2 pools.
        let q = fc1.in_dim / c2.max(1);
        let side = (q as f64).sqrt().round() as usize;
        if side * side != q || fc1.in_dim % c2.max(1) != 0 {
            bail!("cannot infer image size from fc1 ({}) / conv2 ({c2}) dims", fc1.in_dim);
        }
        let hw = side * 4;
        config.insert("hw".to_string(), hw);
        config.insert("classes".to_string(), classes);
        (
            vec![TensorSpec {
                name: "pixels".to_string(),
                shape: vec![batch, hw, hw, cin],
                dtype: "f32".to_string(),
            }],
            vec![TensorSpec {
                name: "logits".to_string(),
                shape: vec![batch, classes],
                dtype: "f32".to_string(),
            }],
        )
    } else {
        let embed = find("embed").ok_or_else(|| anyhow!("checkpoint missing embed/table"))?;
        let pos = find("pos").ok_or_else(|| anyhow!("checkpoint missing pos/table"))?;
        let head = find("head").ok_or_else(|| anyhow!("checkpoint missing head"))?;
        let (vocab, d, seq, width) = (embed.in_dim, embed.out_dim, pos.in_dim, head.out_dim);
        let heads = default_heads(model);
        config.insert("vocab".to_string(), vocab);
        config.insert("seq".to_string(), seq);
        config.insert("d".to_string(), d);
        config.insert("heads".to_string(), heads);
        config.insert("classes".to_string(), width);
        let out_shape = if model == "lm" {
            vec![batch, seq, width]
        } else {
            vec![batch, width]
        };
        (
            vec![TensorSpec {
                name: "tokens".to_string(),
                shape: vec![batch, seq],
                dtype: "i32".to_string(),
            }],
            vec![TensorSpec {
                name: "logits".to_string(),
                shape: out_shape,
                dtype: "f32".to_string(),
            }],
        )
    };

    Ok(GraphSpec {
        name: format!("{model}_{variant}_fwd_native_b{batch}"),
        file: String::new(),
        model: model.to_string(),
        variant: variant.to_string(),
        kind: "fwd".to_string(),
        batch,
        params: tensor_specs,
        inputs,
        outputs,
        ranks,
        n_params: params.n_params(),
        config,
        sha256_16: String::new(),
    })
}

/// Synthesize a *train* [`GraphSpec`] for a checkpoint: the native analogue
/// of the AOT fused `train_step` manifest entry. The graph shares
/// [`synth_fwd_graph`]'s inferred dimensions; its batch signature follows
/// `python/compile/aot.py`: classifiers take `(tokens|pixels, labels)`, the
/// causal LM takes tokens alone (next-token targets are the shifted input).
/// The single output is the scalar loss.
pub fn synth_train_graph(
    model: &str,
    variant: &str,
    batch: usize,
    params: &ParamStore,
) -> Result<GraphSpec> {
    let mut g = synth_fwd_graph(model, variant, batch, params)?;
    g.name = format!("{model}_{variant}_train_native_b{batch}");
    g.kind = "train".to_string();
    if model != "lm" {
        g.inputs.push(TensorSpec {
            name: "labels".to_string(),
            shape: vec![batch],
            dtype: "i32".to_string(),
        });
    }
    g.outputs = vec![TensorSpec {
        name: "loss".to_string(),
        shape: vec![],
        dtype: "f32".to_string(),
    }];
    Ok(g)
}

// ---------------------------------------------------------------------------
// Random init (hermetic tests / benches / demos without AOT checkpoints)
// ---------------------------------------------------------------------------

/// Text-classifier dimensions; the default mirrors `TextConfig` in
/// `python/compile/model.py`. Keep `heads` at the model-zoo default for the
/// model name you serve under (text = 4, lm = 6) unless you also override
/// `config["heads"]` on the synthesized graph — [`synth_fwd_graph`] cannot
/// recover the head count from the parameters.
#[derive(Clone, Copy, Debug)]
pub struct TextModelCfg {
    /// Vocabulary size (embedding rows).
    pub vocab: usize,
    /// Context length (positional-table rows).
    pub seq: usize,
    /// Residual width.
    pub d: usize,
    /// Attention heads (must divide `d`).
    pub heads: usize,
    /// Transformer blocks.
    pub layers: usize,
    /// FFN hidden width.
    pub ff: usize,
    /// Head output width (classes for classifiers, vocab for the LM).
    pub classes: usize,
}

impl Default for TextModelCfg {
    fn default() -> Self {
        Self {
            vocab: 512,
            seq: 64,
            d: 128,
            heads: 4,
            layers: 2,
            ff: 512,
            classes: 4,
        }
    }
}

impl TextModelCfg {
    /// Synthetic causal-LM dimensions for hermetic decode tests, benches and
    /// the `generate` CLI: head width = vocab (per-position next-token
    /// logits) and `heads` at the model-zoo `"lm"` default of 6, so
    /// synthesized graphs need no head-count override.
    pub fn lm_default() -> Self {
        Self {
            vocab: 512,
            seq: 96,
            d: 192,
            heads: 6,
            layers: 2,
            ff: 768,
            classes: 512,
        }
    }
}

/// Deterministic random init of a dense text classifier in the canonical
/// parameter layout (same group names as the JAX exporter, so `classify`,
/// `auto_fact` and the native interpreter all recognize it).
pub fn init_text_params(cfg: &TextModelCfg, seed: u64) -> ParamStore {
    let mut rng = Pcg64::new(seed, 7);
    let mut s = ParamStore::new();
    let glorot = |rng: &mut Pcg64, k: usize, n: usize| -> Tensor {
        let limit = (6.0 / (k + n) as f64).sqrt() as f32;
        let mut data = vec![0.0f32; k * n];
        for v in data.iter_mut() {
            *v = (rng.next_f32() * 2.0 - 1.0) * limit;
        }
        Tensor::from_f32(&[k, n], data)
    };
    let table = |rng: &mut Pcg64, rows: usize, d: usize| -> Tensor {
        let mut data = vec![0.0f32; rows * d];
        rng.fill_normal(&mut data, 0.02);
        Tensor::from_f32(&[rows, d], data)
    };
    let ones = |n: usize| Tensor::from_f32(&[n], vec![1.0; n]);

    s.insert("embed/table", table(&mut rng, cfg.vocab, cfg.d));
    s.insert("pos/table", table(&mut rng, cfg.seq, cfg.d));
    for i in 0..cfg.layers {
        for proj in ["q", "k", "v", "o"] {
            s.insert(format!("block{i}/attn/{proj}/w"), glorot(&mut rng, cfg.d, cfg.d));
            s.insert(
                format!("block{i}/attn/{proj}/bias"),
                Tensor::zeros(&[cfg.d], Dtype::F32),
            );
        }
        for ln in ["ln1", "ln2"] {
            s.insert(format!("block{i}/{ln}/g"), ones(cfg.d));
            s.insert(format!("block{i}/{ln}/bias"), Tensor::zeros(&[cfg.d], Dtype::F32));
        }
        s.insert(format!("block{i}/fc1/w"), glorot(&mut rng, cfg.d, cfg.ff));
        s.insert(format!("block{i}/fc1/bias"), Tensor::zeros(&[cfg.ff], Dtype::F32));
        s.insert(format!("block{i}/fc2/w"), glorot(&mut rng, cfg.ff, cfg.d));
        s.insert(format!("block{i}/fc2/bias"), Tensor::zeros(&[cfg.d], Dtype::F32));
    }
    s.insert("head/w", glorot(&mut rng, cfg.d, cfg.classes));
    s.insert("head/bias", Tensor::zeros(&[cfg.classes], Dtype::F32));
    s.insert("ln_f/g", ones(cfg.d));
    s.insert("ln_f/bias", Tensor::zeros(&[cfg.d], Dtype::F32));
    s.sort_canonical();
    s
}

/// CNN-classifier dimensions; the default mirrors `ImageConfig` in
/// `python/compile/model.py` (28×28 grayscale, conv1→conv2→fc1→fc2 with two
/// 2×2 max-pools).
#[derive(Clone, Copy, Debug)]
pub struct ImageModelCfg {
    /// Input image side length (must survive two 2×2 pools).
    pub hw: usize,
    /// Input channels.
    pub ch: usize,
    /// Output classes.
    pub classes: usize,
    /// conv1 output channels.
    pub c1: usize,
    /// conv2 output channels.
    pub c2: usize,
    /// fc1 hidden width.
    pub fc: usize,
}

impl Default for ImageModelCfg {
    fn default() -> Self {
        Self {
            hw: 28,
            ch: 1,
            classes: 4,
            c1: 16,
            c2: 32,
            fc: 128,
        }
    }
}

/// Deterministic random init of a dense CNN classifier in the canonical
/// parameter layout (`conv1`, `conv2`, `fc1`, `fc2` — the `image` model of
/// the zoo). Conv weights are HWIO with conv-aware Glorot fan
/// (`rf·cin`/`rf·cout`), matching `python/compile/layers.py::glorot`.
pub fn init_image_params(cfg: &ImageModelCfg, seed: u64) -> ParamStore {
    assert!(cfg.hw % 4 == 0, "image size must survive two 2x2 pools");
    let mut rng = Pcg64::new(seed, 8);
    let mut s = ParamStore::new();
    let uniform = |rng: &mut Pcg64, shape: &[usize], fan_in: usize, fan_out: usize| -> Tensor {
        let limit = (6.0 / (fan_in + fan_out) as f64).sqrt() as f32;
        let mut data = vec![0.0f32; shape.iter().product()];
        for v in data.iter_mut() {
            *v = (rng.next_f32() * 2.0 - 1.0) * limit;
        }
        Tensor::from_f32(shape, data)
    };
    let flat = (cfg.hw / 4) * (cfg.hw / 4) * cfg.c2;
    let rf = 9; // 3x3 kernels throughout, like the zoo
    s.insert("conv1/w", uniform(&mut rng, &[3, 3, cfg.ch, cfg.c1], rf * cfg.ch, rf * cfg.c1));
    s.insert("conv1/bias", Tensor::zeros(&[cfg.c1], Dtype::F32));
    s.insert("conv2/w", uniform(&mut rng, &[3, 3, cfg.c1, cfg.c2], rf * cfg.c1, rf * cfg.c2));
    s.insert("conv2/bias", Tensor::zeros(&[cfg.c2], Dtype::F32));
    s.insert("fc1/w", uniform(&mut rng, &[flat, cfg.fc], flat, cfg.fc));
    s.insert("fc1/bias", Tensor::zeros(&[cfg.fc], Dtype::F32));
    s.insert("fc2/w", uniform(&mut rng, &[cfg.fc, cfg.classes], cfg.fc, cfg.classes));
    s.insert("fc2/bias", Tensor::zeros(&[cfg.classes], Dtype::F32));
    s.sort_canonical();
    s
}

/// Hermetic dense + LED variant pair: random-init dense and its
/// `auto_fact(Rank::Ratio(ratio))` factorization with the Random solver.
/// Shared by the artifact-free serving test, bench and `serve-demo` so the
/// recipe stays in one place. The Random solver keeps construction instant
/// (SVD on the default 128×512 layers costs seconds) and serving semantics
/// depend only on factor *shapes*; LED numerics are pinned separately by
/// `tests/proptest_backend.rs`.
pub fn demo_variants(
    cfg: &TextModelCfg,
    seed: u64,
    ratio: f64,
) -> Result<(ParamStore, ParamStore)> {
    let dense = init_text_params(cfg, seed);
    let mut led = dense.clone();
    let report = crate::factorize::auto_fact(
        &mut led,
        &crate::factorize::AutoFactConfig {
            rank: crate::factorize::Rank::Ratio(ratio),
            solver: crate::factorize::Solver::Random,
            num_iter: 0,
            submodules: None,
            ..Default::default()
        },
    )?;
    if report.n_factorized() == 0 {
        bail!("no layer passed the Eq.-1 gate at ratio {ratio} for this model size");
    }
    Ok((dense, led))
}

// ---------------------------------------------------------------------------
// Layer primitives
// ---------------------------------------------------------------------------

pub(crate) fn pname(prefix: &str, leaf: &str) -> String {
    if prefix.is_empty() {
        leaf.to_string()
    } else {
        format!("{prefix}/{leaf}")
    }
}

/// Pre-resolved parameter names of one linear/conv group (`w`, `a`, `b`,
/// `tt0..ttK`, `bias` leaves). Hot paths build these once (per request, or
/// per decode *session*) so the per-op interpreter loop does zero string
/// formatting.
#[derive(Clone, Debug)]
pub(crate) struct LinearNames {
    /// The group prefix, kept for error messages.
    pub(crate) prefix: String,
    w: String,
    a: String,
    b: String,
    tt: Vec<String>,
    bias: String,
}

impl LinearNames {
    /// Resolve the leaf names under `prefix`.
    pub(crate) fn new(prefix: &str) -> Self {
        LinearNames {
            prefix: prefix.to_string(),
            w: pname(prefix, "w"),
            a: pname(prefix, "a"),
            b: pname(prefix, "b"),
            tt: (0..TT_MAX_MODES).map(|k| pname(prefix, &format!("tt{k}"))).collect(),
            bias: pname(prefix, "bias"),
        }
    }
}

/// Workspace-backed fused linear: `y(rows, n) = act(x(rows, k) @ W + bias)`,
/// dispatching dense `w` vs LED/CED `a·b` vs TT `tt0..ttK` cores on the keys
/// present (the layers.py contract). The bias add and activation run inside
/// the GEMM epilogue (bit-identical to the unfused sequence), factorized
/// layers run as two GEMMs through the rank bottleneck, TT layers contract
/// the core chain left-to-right ([`tt_apply_ws`]) and then apply the same
/// per-row epilogue, and `y` (plus intermediates) comes from `ws` — callers
/// `give` it back when done, making steady-state interpretation
/// allocation-free. Returns `(n, y)`.
pub(crate) fn apply_linear_named(
    params: &ParamStore,
    names: &LinearNames,
    rows: usize,
    k: usize,
    x: &[f32],
    act: Activation,
    ws: &mut Workspace,
) -> Result<(usize, Vec<f32>)> {
    debug_assert_eq!(x.len(), rows * k);
    let bias = match params.get(&names.bias) {
        Some(t) => Some(t.as_f32()?),
        None => None,
    };
    let check_bias = |n: usize| -> Result<()> {
        if let Some(bd) = bias {
            if bd.len() != n {
                bail!("{}: bias len {} does not match output dim {n}", names.prefix, bd.len());
            }
        }
        Ok(())
    };
    let n;
    let mut y;
    if let Some(w) = params.get(&names.w) {
        let (wk, wn, wd) = w.as_matrix_2d()?;
        if wk != k {
            bail!("{}: input dim {k} does not match weight {wk}x{wn}", names.prefix);
        }
        n = wn;
        check_bias(n)?;
        y = ws.take_zeroed(rows * n);
        matmul_bias_into(rows, k, n, x, wd, bias, act, &mut y);
    } else if let (Some(a), Some(b)) = (params.get(&names.a), params.get(&names.b)) {
        let (ak, r, ad) = a.as_matrix_2d()?;
        let (br, bn, bd) = b.as_matrix_2d()?;
        if ak != k || br != r {
            bail!(
                "{}: LED factor shapes {ak}x{r} / {br}x{bn} do not chain from dim {k}",
                names.prefix
            );
        }
        n = bn;
        check_bias(n)?;
        let mut h = ws.take_zeroed(rows * r);
        matmul_into(rows, k, r, x, ad, &mut h);
        y = ws.take_zeroed(rows * n);
        matmul_bias_into(rows, r, n, &h, bd, bias, act, &mut y);
        ws.give(h);
    } else if params.get(&names.tt[0]).is_some() {
        // TT core chain: gather `tt0..ttK` views on the stack, contract,
        // then run the shared epilogue (bit-identical to the fused path).
        let mut views = [TtCoreView::empty(); TT_MAX_MODES];
        let mut nc = 0;
        while nc < TT_MAX_MODES {
            let Some(t) = params.get(&names.tt[nc]) else {
                break;
            };
            views[nc] = TtCoreView::of_tensor(t)?;
            nc += 1;
        }
        let (tn, ty) = tt_apply_ws(rows, k, x, &views[..nc], ws)
            .map_err(|e| anyhow!("{}: {e}", names.prefix))?;
        n = tn;
        check_bias(n)?;
        y = ty;
        if bias.is_some() || !matches!(act, Activation::None) {
            for row in y.chunks_exact_mut(n) {
                apply_epilogue(row, bias, act);
            }
        }
    } else {
        bail!("no linear weights (w, a/b, or tt0..) under group {:?}", names.prefix);
    }
    Ok((n, y))
}

/// Precision-dispatching [`apply_linear_named`]: when `quant` carries an
/// entry for this group's weight(s), the GEMM runs through the int8 /
/// binary kernels (activations quantized per row into thread-local
/// scratch); otherwise — `quant` is `None`, or the group was not quantized
/// (4-D conv factors, mixed stores) — it falls through to the f32 path
/// bit-for-bit. LED groups need *both* factors quantized to take the
/// quantized route, so a CED conv whose 4-D `a` stayed f32 runs fully f32.
/// TT core groups are never quantized and always take the f32 fallthrough.
#[allow(clippy::too_many_arguments)]
pub(crate) fn apply_linear_quant(
    params: &ParamStore,
    quant: Option<&QuantStore>,
    names: &LinearNames,
    rows: usize,
    k: usize,
    x: &[f32],
    act: Activation,
    ws: &mut Workspace,
) -> Result<(usize, Vec<f32>)> {
    let Some(store) = quant else {
        return apply_linear_named(params, names, rows, k, x, act, ws);
    };
    debug_assert_eq!(x.len(), rows * k);
    let bias = match params.get(&names.bias) {
        Some(t) => Some(t.as_f32()?),
        None => None,
    };
    if let Some(qw) = store.get(&names.w) {
        if qw.k() != k {
            bail!(
                "{}: input dim {k} does not match quant weight {}x{}",
                names.prefix,
                qw.k(),
                qw.n()
            );
        }
        let n = qw.n();
        if let Some(bd) = bias {
            if bd.len() != n {
                bail!("{}: bias len {} does not match output dim {n}", names.prefix, bd.len());
            }
        }
        let mut y = ws.take_zeroed(rows * n);
        qw.apply(rows, x, bias, act, &mut y);
        return Ok((n, y));
    }
    if let (Some(qa), Some(qb)) = (store.get(&names.a), store.get(&names.b)) {
        let (r, n) = (qa.n(), qb.n());
        if qa.k() != k || qb.k() != r {
            bail!(
                "{}: quant LED factor shapes {}x{r} / {}x{n} do not chain from dim {k}",
                names.prefix,
                qa.k(),
                qb.k()
            );
        }
        if let Some(bd) = bias {
            if bd.len() != n {
                bail!("{}: bias len {} does not match output dim {n}", names.prefix, bd.len());
            }
        }
        let mut h = ws.take_zeroed(rows * r);
        qa.apply(rows, x, None, Activation::None, &mut h);
        let mut y = ws.take_zeroed(rows * n);
        qb.apply(rows, &h, bias, act, &mut y);
        ws.give(h);
        return Ok((n, y));
    }
    apply_linear_named(params, names, rows, k, x, act, ws)
}

/// `y(rows, n) = x(rows, k) @ W + bias`, dispatching dense `w` vs LED/CED
/// `a·b` vs TT `tt0..ttK` cores on the keys present (the layers.py
/// contract). Factorized layers never materialize the full product: LED
/// runs two GEMMs through the rank bottleneck, TT contracts the core chain.
/// Returns `(n, y)`.
///
/// Convenience wrapper over [`apply_linear_named`] with a throwaway
/// workspace; the interpreters call the workspace-backed form directly.
pub fn apply_linear(
    params: &ParamStore,
    prefix: &str,
    rows: usize,
    k: usize,
    x: &[f32],
) -> Result<(usize, Vec<f32>)> {
    let names = LinearNames::new(prefix);
    let mut ws = Workspace::new();
    apply_linear_named(params, &names, rows, k, x, Activation::None, &mut ws)
}

/// LayerNorm with pre-resolved gain/bias parameter names (the decode hot
/// path resolves them once per session).
pub(crate) fn layernorm_named(
    params: &ParamStore,
    gname: &str,
    bname: &str,
    d: usize,
    x: &mut [f32],
) -> Result<()> {
    let g = params
        .get(gname)
        .ok_or_else(|| anyhow!("missing layernorm gain {gname:?}"))?
        .as_f32()?;
    let bias = params
        .get(bname)
        .ok_or_else(|| anyhow!("missing layernorm bias {bname:?}"))?
        .as_f32()?;
    if g.len() != d || bias.len() != d {
        bail!("{gname}: layernorm dims {}/{} != {d}", g.len(), bias.len());
    }
    const EPS: f32 = 1e-5;
    for row in x.chunks_exact_mut(d) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * g[j] + bias[j];
        }
    }
    Ok(())
}

pub(crate) fn layernorm(params: &ParamStore, prefix: &str, d: usize, x: &mut [f32]) -> Result<()> {
    layernorm_named(params, &pname(prefix, "g"), &pname(prefix, "bias"), d, x)
}

/// tanh-approximated GELU (the JAX default the AOT graphs lower). Delegates
/// to the kernel layer's [`crate::linalg::gemm::gelu_slice`] — the same
/// code the fused epilogue runs, so fused and unfused paths agree bit for
/// bit.
pub(crate) fn gelu(x: &mut [f32]) {
    crate::linalg::gemm::gelu_slice(x);
}

pub(crate) fn relu(x: &mut [f32]) {
    crate::linalg::gemm::relu_slice(x);
}

/// In-place row softmax with max-subtraction.
pub(crate) fn softmax_rows(x: &mut [f32], cols: usize) {
    for row in x.chunks_exact_mut(cols) {
        let mut max = f32::NEG_INFINITY;
        for &v in row.iter() {
            if v > max {
                max = v;
            }
        }
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

// ---------------------------------------------------------------------------
// Transformer forward (text classifier + causal LM)
// ---------------------------------------------------------------------------

/// Token + position embedding: x(b·s, d), with `x` checked out of `ws`.
pub(crate) fn embed_ws(
    params: &ParamStore,
    tokens: &[i32],
    b: usize,
    s: usize,
    ws: &mut Workspace,
) -> Result<(usize, Vec<f32>)> {
    let table = params
        .get("embed/table")
        .ok_or_else(|| anyhow!("checkpoint missing embed/table"))?;
    let (vocab, d) = (table.shape[0], table.shape[1]);
    let td = table.as_f32()?;
    let pos = params
        .get("pos/table")
        .ok_or_else(|| anyhow!("checkpoint missing pos/table"))?;
    if pos.shape.len() != 2 || pos.shape[1] != d || pos.shape[0] < s {
        bail!("pos/table {:?} incompatible with seq {s} / d {d}", pos.shape);
    }
    let pd = pos.as_f32()?;
    let mut x = ws.take_zeroed(b * s * d);
    for bi in 0..b {
        for si in 0..s {
            let t = tokens[bi * s + si];
            if t < 0 || t as usize >= vocab {
                bail!("token id {t} out of range (vocab {vocab})");
            }
            let row = &td[t as usize * d..(t as usize + 1) * d];
            let prow = &pd[si * d..(si + 1) * d];
            let dst = &mut x[(bi * s + si) * d..(bi * s + si + 1) * d];
            for ((dv, &rv), &pv) in dst.iter_mut().zip(row).zip(prow) {
                *dv = rv + pv;
            }
        }
    }
    Ok((d, x))
}

/// Token + position embedding: x(b·s, d). Allocating wrapper over
/// [`embed_ws`] for the training tape, which owns its buffers.
pub(crate) fn embed(
    params: &ParamStore,
    tokens: &[i32],
    b: usize,
    s: usize,
) -> Result<(usize, Vec<f32>)> {
    let mut ws = Workspace::new();
    embed_ws(params, tokens, b, s, &mut ws)
}

/// Count contiguous transformer blocks, erroring if any `block*` parameter
/// lies beyond the contiguous range — a gap (pruned/renamed block, or a
/// missing `ln1/g`) would otherwise silently truncate the model and return
/// plausible-looking but wrong logits.
pub(crate) fn num_blocks(params: &ParamStore) -> Result<usize> {
    let mut n = 0;
    while params.get(&format!("block{n}/ln1/g")).is_some() {
        n += 1;
    }
    for name in params.names() {
        if let Some(rest) = name.strip_prefix("block") {
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            if let Ok(i) = digits.parse::<usize>() {
                if i >= n {
                    bail!(
                        "checkpoint has {name:?} but only {n} contiguous blocks \
                         (missing block{n}/ln1/g?)"
                    );
                }
            }
        }
    }
    Ok(n)
}

/// Multi-head self-attention over x(b·s, d); returns the o-projected context.
///
/// NOTE: `grad::attention_fwd` mirrors this op-for-op while recording a
/// tape; any numeric change here (scale placement, mask value, loop order)
/// must be made there too, or train-time and eval-time forwards diverge.
/// The same applies to `transformer_block`/`grad::block_fwd`,
/// `trunk`/`grad::trunk_fwd` and `maxpool2`/`grad::maxpool2_idx`.
#[allow(clippy::too_many_arguments)]
fn attention(
    params: &ParamStore,
    quant: Option<&QuantStore>,
    prefix: &str,
    b: usize,
    s: usize,
    d: usize,
    heads: usize,
    causal: bool,
    x: &[f32],
    ws: &mut Workspace,
) -> Result<Vec<f32>> {
    if heads == 0 || d % heads != 0 {
        bail!("{prefix}: d={d} not divisible by heads={heads}");
    }
    let dk = d / heads;
    let rows = b * s;
    let (dq, q) = apply_linear_quant(
        params,
        quant,
        &LinearNames::new(&pname(prefix, "q")),
        rows,
        d,
        x,
        Activation::None,
        ws,
    )?;
    let (dkk, kk) = apply_linear_quant(
        params,
        quant,
        &LinearNames::new(&pname(prefix, "k")),
        rows,
        d,
        x,
        Activation::None,
        ws,
    )?;
    let (dv, v) = apply_linear_quant(
        params,
        quant,
        &LinearNames::new(&pname(prefix, "v")),
        rows,
        d,
        x,
        Activation::None,
        ws,
    )?;
    if dq != d || dkk != d || dv != d {
        bail!("{prefix}: projection output dims {dq}/{dkk}/{dv} != d {d}");
    }
    let scale = 1.0 / (dk as f32).sqrt();
    let mut ctx = ws.take_zeroed(rows * d);
    let mut qh = ws.take_zeroed(s * dk);
    let mut kt = ws.take_zeroed(dk * s); // k gathered pre-transposed: (dk, s)
    let mut vh = ws.take_zeroed(s * dk);
    let mut scores = ws.take_zeroed(s * s);
    let mut oh = ws.take_zeroed(s * dk);
    for bi in 0..b {
        for h in 0..heads {
            for si in 0..s {
                let src = (bi * s + si) * d + h * dk;
                qh[si * dk..(si + 1) * dk].copy_from_slice(&q[src..src + dk]);
                vh[si * dk..(si + 1) * dk].copy_from_slice(&v[src..src + dk]);
                for ki in 0..dk {
                    kt[ki * s + si] = kk[src + ki];
                }
            }
            // scores(s, s) = qh @ kh^T * scale, with the causal mask applied
            // before softmax (masked logits pinned to -1e9, like the graphs).
            scores.fill(0.0);
            matmul_into(s, dk, s, &qh, &kt, &mut scores);
            for i in 0..s {
                let row = &mut scores[i * s..(i + 1) * s];
                for v in row.iter_mut() {
                    *v *= scale;
                }
                if causal {
                    for v in row[i + 1..].iter_mut() {
                        *v = -1e9;
                    }
                }
            }
            softmax_rows(&mut scores, s);
            oh.fill(0.0);
            matmul_into(s, s, dk, &scores, &vh, &mut oh);
            for si in 0..s {
                let dst = (bi * s + si) * d + h * dk;
                ctx[dst..dst + dk].copy_from_slice(&oh[si * dk..(si + 1) * dk]);
            }
        }
    }
    let (do_, out) = apply_linear_quant(
        params,
        quant,
        &LinearNames::new(&pname(prefix, "o")),
        rows,
        d,
        &ctx,
        Activation::None,
        ws,
    )?;
    if do_ != d {
        bail!("{prefix}: o-projection output dim {do_} != d {d}");
    }
    ws.give(q);
    ws.give(kk);
    ws.give(v);
    ws.give(ctx);
    ws.give(qh);
    ws.give(kt);
    ws.give(vh);
    ws.give(scores);
    ws.give(oh);
    Ok(out)
}

/// Pre-LN transformer block, in place: x += attn(ln1(x)); x += ffn(ln2(x)).
#[allow(clippy::too_many_arguments)]
fn transformer_block(
    params: &ParamStore,
    quant: Option<&QuantStore>,
    prefix: &str,
    b: usize,
    s: usize,
    d: usize,
    heads: usize,
    causal: bool,
    x: &mut [f32],
    ws: &mut Workspace,
) -> Result<()> {
    let rows = b * s;
    let mut xn = ws.take_copied(x);
    layernorm(params, &pname(prefix, "ln1"), d, &mut xn)?;
    let attn =
        attention(params, quant, &pname(prefix, "attn"), b, s, d, heads, causal, &xn, ws)?;
    for (v, a) in x.iter_mut().zip(&attn) {
        *v += a;
    }
    ws.give(attn);
    xn.copy_from_slice(x);
    layernorm(params, &pname(prefix, "ln2"), d, &mut xn)?;
    // fc1's GELU runs in the GEMM epilogue — no second pass over (rows, ff).
    let (ff, h) = apply_linear_quant(
        params,
        quant,
        &LinearNames::new(&pname(prefix, "fc1")),
        rows,
        d,
        &xn,
        Activation::Gelu,
        ws,
    )?;
    let (d2, y) = apply_linear_quant(
        params,
        quant,
        &LinearNames::new(&pname(prefix, "fc2")),
        rows,
        ff,
        &h,
        Activation::None,
        ws,
    )?;
    if d2 != d {
        bail!("{prefix}: fc2 output dim {d2} != d {d}");
    }
    for (v, a) in x.iter_mut().zip(&y) {
        *v += a;
    }
    ws.give(h);
    ws.give(y);
    ws.give(xn);
    Ok(())
}

/// Shared trunk: embed, blocks, final layernorm. Returns (d, x(b·s, d))
/// with `x` checked out of `ws`.
#[allow(clippy::too_many_arguments)]
fn trunk(
    params: &ParamStore,
    quant: Option<&QuantStore>,
    tokens: &[i32],
    b: usize,
    s: usize,
    heads: usize,
    causal: bool,
    ws: &mut Workspace,
) -> Result<(usize, Vec<f32>)> {
    let (d, mut x) = embed_ws(params, tokens, b, s, ws)?;
    for i in 0..num_blocks(params)? {
        transformer_block(
            params,
            quant,
            &format!("block{i}"),
            b,
            s,
            d,
            heads,
            causal,
            &mut x,
            ws,
        )?;
    }
    layernorm(params, "ln_f", d, &mut x)?;
    Ok((d, x))
}

/// Text classifier: mean-pool over tokens, then the head → (b, classes).
fn classifier_fwd(
    params: &ParamStore,
    quant: Option<&QuantStore>,
    tokens: &[i32],
    b: usize,
    s: usize,
    heads: usize,
    ws: &mut Workspace,
) -> Result<Tensor> {
    let (d, x) = trunk(params, quant, tokens, b, s, heads, false, ws)?;
    let mut pooled = ws.take_zeroed(b * d);
    for bi in 0..b {
        let dst = &mut pooled[bi * d..(bi + 1) * d];
        for si in 0..s {
            let src = &x[(bi * s + si) * d..(bi * s + si + 1) * d];
            for (dv, &sv) in dst.iter_mut().zip(src) {
                *dv += sv;
            }
        }
        let inv = 1.0 / s as f32;
        for v in dst.iter_mut() {
            *v *= inv;
        }
    }
    let (classes, logits) = apply_linear_quant(
        params,
        quant,
        &LinearNames::new("head"),
        b,
        d,
        &pooled,
        Activation::None,
        ws,
    )?;
    let out = Tensor::from_f32(&[b, classes], logits.clone());
    ws.give(logits);
    ws.give(pooled);
    ws.give(x);
    Ok(out)
}

/// Causal LM: per-position next-token logits (b, s, vocab).
fn lm_fwd(
    params: &ParamStore,
    quant: Option<&QuantStore>,
    tokens: &[i32],
    b: usize,
    s: usize,
    heads: usize,
    ws: &mut Workspace,
) -> Result<Tensor> {
    let (d, x) = trunk(params, quant, tokens, b, s, heads, true, ws)?;
    let (vocab, logits) = apply_linear_quant(
        params,
        quant,
        &LinearNames::new("head"),
        b * s,
        d,
        &x,
        Activation::None,
        ws,
    )?;
    let out = Tensor::from_f32(&[b, s, vocab], logits.clone());
    ws.give(logits);
    ws.give(x);
    Ok(out)
}

// ---------------------------------------------------------------------------
// CNN forward (image classifier, Conv2d/CED im2col path)
// ---------------------------------------------------------------------------

/// SAME-padded stride-1 im2col: (b·h·w, kh·kw·c) patches in HWIO column
/// order, matching the collapsed conv weight layout of `as_matrix_2d`, with
/// the patch buffer checked out of `ws`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn im2col_ws(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    ws: &mut Workspace,
) -> Vec<f32> {
    let (ph, pw) = (kh / 2, kw / 2);
    let cols = kh * kw * c;
    // Zero-filled: padding taps are simply never written.
    let mut out = ws.take_zeroed(b * h * w * cols);
    for bi in 0..b {
        for y in 0..h {
            for xx in 0..w {
                let row = ((bi * h + y) * w + xx) * cols;
                for ky in 0..kh {
                    let sy = y as isize + ky as isize - ph as isize;
                    if sy < 0 || sy >= h as isize {
                        continue; // zero padding
                    }
                    for kx in 0..kw {
                        let sx = xx as isize + kx as isize - pw as isize;
                        if sx < 0 || sx >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + sy as usize) * w + sx as usize) * c;
                        let dst = row + (ky * kw + kx) * c;
                        out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    out
}

/// Allocating [`im2col_ws`] wrapper for the training tape and tests.
pub(crate) fn im2col(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
) -> Vec<f32> {
    let mut ws = Workspace::new();
    im2col_ws(x, b, h, w, c, kh, kw, &mut ws)
}

/// 2×2 max pool over (b, h, w, c) row-major data. Requires even h, w.
fn maxpool2(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    ws: &mut Workspace,
) -> Result<(usize, usize, Vec<f32>)> {
    if h % 2 != 0 || w % 2 != 0 {
        bail!("maxpool2 needs even spatial dims, got {h}x{w}");
    }
    let (oh, ow) = (h / 2, w / 2);
    let mut out = ws.take_zeroed(b * oh * ow * c);
    for bi in 0..b {
        for y in 0..oh {
            for xx in 0..ow {
                let dst = ((bi * oh + y) * ow + xx) * c;
                for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let src = ((bi * h + 2 * y + dy) * w + 2 * xx + dx) * c;
                    for ci in 0..c {
                        let v = x[src + ci];
                        if (dy, dx) == (0, 0) || v > out[dst + ci] {
                            out[dst + ci] = v;
                        }
                    }
                }
            }
        }
    }
    Ok((oh, ow, out))
}

pub(crate) fn conv_kernel(params: &ParamStore, prefix: &str) -> Result<(usize, usize, usize)> {
    let t = params
        .get(&pname(prefix, "w"))
        .or_else(|| params.get(&pname(prefix, "a")))
        .ok_or_else(|| anyhow!("no conv weights under group {prefix:?}"))?;
    if t.ndim() != 4 {
        bail!("{prefix}: conv weight must be 4-D HWIO, got {:?}", t.shape);
    }
    Ok((t.shape[0], t.shape[1], t.shape[2]))
}

/// CNN classifier: conv1 → relu → pool → conv2 → relu → pool → fc1 → relu →
/// fc2 (the `image` model of the zoo). CED conv layers execute as
/// im2col · a2d · b2d — two GEMMs through the rank bottleneck; the ReLUs
/// run in the conv/fc GEMM epilogues.
fn image_fwd(
    params: &ParamStore,
    quant: Option<&QuantStore>,
    x: &Tensor,
    ws: &mut Workspace,
) -> Result<Tensor> {
    let (b, mut h, mut w, mut c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut cur = ws.take_copied(x.as_f32()?);
    for conv in ["conv1", "conv2"] {
        let (kh, kw, cin) = conv_kernel(params, conv)?;
        if cin != c {
            bail!("{conv}: input channels {c} != weight cin {cin}");
        }
        let cols = im2col_ws(&cur, b, h, w, c, kh, kw, ws);
        // Conv weights are 4-D (never quantized); CED convs keep their 4-D
        // `a`, so apply_linear_quant falls through to f32 here by design.
        let (cout, y) = apply_linear_quant(
            params,
            quant,
            &LinearNames::new(conv),
            b * h * w,
            kh * kw * c,
            &cols,
            Activation::Relu,
            ws,
        )?;
        let (oh, ow, pooled) = maxpool2(&y, b, h, w, cout, ws)?;
        ws.give(cur);
        ws.give(cols);
        ws.give(y);
        cur = pooled;
        h = oh;
        w = ow;
        c = cout;
    }
    // (b, h, w, c) row-major flattens directly to (b, h·w·c).
    let flat = h * w * c;
    let (fc, f1) = apply_linear_quant(
        params,
        quant,
        &LinearNames::new("fc1"),
        b,
        flat,
        &cur,
        Activation::Relu,
        ws,
    )?;
    let (classes, logits) = apply_linear_quant(
        params,
        quant,
        &LinearNames::new("fc2"),
        b,
        fc,
        &f1,
        Activation::None,
        ws,
    )?;
    let out = Tensor::from_f32(&[b, classes], logits.clone());
    ws.give(logits);
    ws.give(f1);
    ws.give(cur);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
    use crate::linalg::Matrix;

    fn small_cfg() -> TextModelCfg {
        TextModelCfg {
            vocab: 96,
            seq: 12,
            d: 32,
            heads: 4,
            layers: 1,
            ff: 64,
            classes: 3,
        }
    }

    fn tokens_for(cfg: &TextModelCfg, b: usize, seed: u64) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        let toks: Vec<i32> = (0..b * cfg.seq).map(|_| rng.below(cfg.vocab) as i32).collect();
        Tensor::from_i32(&[b, cfg.seq], toks)
    }

    #[test]
    fn apply_linear_dense_matches_matrix_matmul() {
        let mut rng = Pcg64::seeded(10);
        let (m, k, n) = (5, 7, 9);
        let x = Matrix::randn(m, k, 1.0, &mut rng);
        let w = Matrix::randn(k, n, 1.0, &mut rng);
        let mut s = ParamStore::new();
        s.insert("fc/w", Tensor::from_f32(&[k, n], w.data.clone()));
        s.insert("fc/bias", Tensor::zeros(&[n], Dtype::F32));
        let (nn, y) = apply_linear(&s, "fc", m, k, &x.data).unwrap();
        assert_eq!(nn, n);
        let want = x.matmul(&w);
        for (a, b) in y.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn apply_linear_led_matches_dense_of_product() {
        let mut rng = Pcg64::seeded(11);
        let (m, k, r, n) = (4, 16, 3, 10);
        let a = Matrix::randn(k, r, 0.5, &mut rng);
        let b = Matrix::randn(r, n, 0.5, &mut rng);
        let w = a.matmul(&b);
        let x = Matrix::randn(m, k, 1.0, &mut rng);
        let mut bias = vec![0.0f32; n];
        rng.fill_normal(&mut bias, 0.3);

        let mut dense = ParamStore::new();
        dense.insert("fc/w", Tensor::from_f32(&[k, n], w.data.clone()));
        dense.insert("fc/bias", Tensor::from_f32(&[n], bias.clone()));
        let mut led = ParamStore::new();
        led.insert("fc/a", Tensor::from_f32(&[k, r], a.data.clone()));
        led.insert("fc/b", Tensor::from_f32(&[r, n], b.data.clone()));
        led.insert("fc/bias", Tensor::from_f32(&[n], bias));

        let (_, yd) = apply_linear(&dense, "fc", m, k, &x.data).unwrap();
        let (_, yl) = apply_linear(&led, "fc", m, k, &x.data).unwrap();
        for (d, l) in yd.iter().zip(&yl) {
            assert!((d - l).abs() <= 1e-4 * (1.0 + d.abs()), "{d} vs {l}");
        }
    }

    #[test]
    fn text_forward_shapes_and_determinism() {
        let cfg = small_cfg();
        let params = init_text_params(&cfg, 1);
        let g = synth_fwd_graph("text", "dense", 3, &params).unwrap();
        assert_eq!(g.inputs[0].shape, vec![3, cfg.seq]);
        assert_eq!(g.outputs[0].shape, vec![3, cfg.classes]);
        let x = tokens_for(&cfg, 3, 2);
        let be = NativeBackend::new();
        let out1 = be.run_fwd(&g, &params, &[x.clone()]).unwrap();
        let out2 = be.run_fwd(&g, &params, &[x]).unwrap();
        assert_eq!(out1[0].shape, vec![3, cfg.classes]);
        assert_eq!(out1[0], out2[0]);
        let logits = out1[0].as_f32().unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn auto_fact_checkpoint_serves_through_same_interpreter() {
        // LED/CED keep the layer I/O signature: one interpreter must run the
        // SVD-factorized checkpoint unchanged. (Exact LED≡dense equivalence
        // is pinned by tests/proptest_backend.rs with exact factors.)
        let cfg = small_cfg();
        let params = init_text_params(&cfg, 3);
        let mut fact = params.clone();
        let report = auto_fact(
            &mut fact,
            &AutoFactConfig {
                rank: Rank::Ratio(0.5),
                solver: Solver::Svd,
                num_iter: 10,
                submodules: None,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(report.n_factorized() > 0);
        assert!(fact.n_params() < params.n_params());
        let gf = synth_fwd_graph("text", "led", 2, &fact).unwrap();
        let x = tokens_for(&cfg, 2, 4);
        let yf = NativeBackend::new().run_fwd(&gf, &fact, &[x]).unwrap();
        assert_eq!(yf[0].shape, vec![2, cfg.classes]);
        assert!(yf[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn lm_forward_emits_per_position_logits() {
        let cfg = TextModelCfg {
            vocab: 64,
            seq: 8,
            d: 24,
            heads: 6,
            layers: 1,
            ff: 48,
            classes: 64, // head width = vocab for the LM
        };
        let params = init_text_params(&cfg, 5);
        let g = synth_fwd_graph("lm", "dense", 2, &params).unwrap();
        assert_eq!(g.outputs[0].shape, vec![2, cfg.seq, 64]);
        let x = tokens_for(&cfg, 2, 6);
        let out = NativeBackend::new().run_fwd(&g, &params, &[x]).unwrap();
        assert_eq!(out[0].shape, vec![2, cfg.seq, 64]);
    }

    #[test]
    fn causal_lm_ignores_future_tokens() {
        let cfg = TextModelCfg {
            vocab: 64,
            seq: 8,
            d: 24,
            heads: 6,
            layers: 1,
            ff: 48,
            classes: 64,
        };
        let params = init_text_params(&cfg, 7);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        let mut a: Vec<i32> = (0..cfg.seq as i32).collect();
        let out_a = NativeBackend::new()
            .run_fwd(&g, &params, &[Tensor::from_i32(&[1, cfg.seq], a.clone())])
            .unwrap();
        // Change the last token: logits at earlier positions must not move.
        a[cfg.seq - 1] = 63;
        let out_b = NativeBackend::new()
            .run_fwd(&g, &params, &[Tensor::from_i32(&[1, cfg.seq], a)])
            .unwrap();
        let (la, lb) = (out_a[0].as_f32().unwrap(), out_b[0].as_f32().unwrap());
        let vocab = 64;
        for p in 0..cfg.seq - 1 {
            for j in 0..vocab {
                let (x, y) = (la[p * vocab + j], lb[p * vocab + j]);
                assert!((x - y).abs() < 1e-5, "pos {p}: {x} vs {y}");
            }
        }
    }

    fn tiny_image_params(seed: u64) -> ParamStore {
        // 8x8 inputs: conv1 1->4, conv2 4->8, fc1 (2*2*8)->16, fc2 16->3.
        let mut rng = Pcg64::seeded(seed);
        let mut s = ParamStore::new();
        let randn = |rng: &mut Pcg64, shape: &[usize]| {
            let mut d = vec![0.0f32; shape.iter().product()];
            rng.fill_normal(&mut d, 0.2);
            Tensor::from_f32(shape, d)
        };
        s.insert("conv1/w", randn(&mut rng, &[3, 3, 1, 4]));
        s.insert("conv1/bias", Tensor::zeros(&[4], Dtype::F32));
        s.insert("conv2/w", randn(&mut rng, &[3, 3, 4, 8]));
        s.insert("conv2/bias", Tensor::zeros(&[8], Dtype::F32));
        s.insert("fc1/w", randn(&mut rng, &[32, 16]));
        s.insert("fc1/bias", Tensor::zeros(&[16], Dtype::F32));
        s.insert("fc2/w", randn(&mut rng, &[16, 3]));
        s.insert("fc2/bias", Tensor::zeros(&[3], Dtype::F32));
        s.sort_canonical();
        s
    }

    #[test]
    fn image_forward_shapes_and_synth_inference() {
        let params = tiny_image_params(8);
        let g = synth_fwd_graph("image", "dense", 2, &params).unwrap();
        assert_eq!(g.inputs[0].shape, vec![2, 8, 8, 1]);
        assert_eq!(g.outputs[0].shape, vec![2, 3]);
        let mut rng = Pcg64::seeded(9);
        let mut px = vec![0.0f32; 2 * 8 * 8];
        rng.fill_normal(&mut px, 1.0);
        let x = Tensor::from_f32(&[2, 8, 8, 1], px);
        let out = NativeBackend::new().run_fwd(&g, &params, &[x]).unwrap();
        assert_eq!(out[0].shape, vec![2, 3]);
        assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn ced_conv_matches_dense_of_product() {
        // conv with w = reshape(a2d @ b2d) must agree with the CED path.
        let mut rng = Pcg64::seeded(12);
        let (kh, kw, cin, r, cout) = (3, 3, 2, 4, 6);
        let a2 = Matrix::randn(kh * kw * cin, r, 0.3, &mut rng);
        let b2 = Matrix::randn(r, cout, 0.3, &mut rng);
        let w2 = a2.matmul(&b2);
        let mut dense = ParamStore::new();
        dense.insert("conv1/w", Tensor::from_f32(&[kh, kw, cin, cout], w2.data.clone()));
        dense.insert("conv1/bias", Tensor::zeros(&[cout], Dtype::F32));
        let mut ced = ParamStore::new();
        ced.insert("conv1/a", Tensor::from_f32(&[kh, kw, cin, r], a2.data.clone()));
        ced.insert("conv1/b", Tensor::from_f32(&[1, 1, r, cout], b2.data.clone()));
        ced.insert("conv1/bias", Tensor::zeros(&[cout], Dtype::F32));
        let (b, h, w) = (1, 4, 4);
        let mut px = vec![0.0f32; b * h * w * cin];
        rng.fill_normal(&mut px, 1.0);
        let cols = im2col(&px, b, h, w, cin, kh, kw);
        let (_, yd) = apply_linear(&dense, "conv1", b * h * w, kh * kw * cin, &cols).unwrap();
        let (_, yc) = apply_linear(&ced, "conv1", b * h * w, kh * kw * cin, &cols).unwrap();
        for (d, c) in yd.iter().zip(&yc) {
            assert!((d - c).abs() <= 1e-4 * (1.0 + d.abs()), "{d} vs {c}");
        }
    }

    #[test]
    fn maxpool_and_im2col_basics() {
        let mut ws = Workspace::new();
        // 1x2x2x1 pool picks the max.
        let (oh, ow, p) = maxpool2(&[1.0, 3.0, 2.0, 0.5], 1, 2, 2, 1, &mut ws).unwrap();
        assert_eq!((oh, ow), (1, 1));
        assert_eq!(p, vec![3.0]);
        assert!(maxpool2(&[0.0; 3], 1, 3, 1, 1, &mut ws).is_err());
        // im2col of a 1x1 image with 3x3 kernel: center tap only.
        let cols = im2col(&[5.0], 1, 1, 1, 1, 3, 3);
        assert_eq!(cols.len(), 9);
        assert_eq!(cols[4], 5.0);
        assert_eq!(cols.iter().filter(|&&v| v != 0.0).count(), 1);
    }

    #[test]
    fn rejects_out_of_range_tokens_and_bad_shapes() {
        let cfg = small_cfg();
        let params = init_text_params(&cfg, 13);
        let g = synth_fwd_graph("text", "dense", 1, &params).unwrap();
        let be = NativeBackend::new();
        let bad = Tensor::from_i32(&[1, cfg.seq], vec![cfg.vocab as i32; cfg.seq]);
        assert!(be.run_fwd(&g, &params, &[bad]).is_err());
        let wrong_shape = Tensor::from_i32(&[2, cfg.seq], vec![0; 2 * cfg.seq]);
        assert!(be.run_fwd(&g, &params, &[wrong_shape]).is_err());
    }

    #[test]
    fn non_contiguous_blocks_error_instead_of_truncating() {
        let cfg = small_cfg();
        let mut params = init_text_params(&cfg, 15);
        // Rename block0 to block2: the model must refuse to run, not
        // silently skip the layer.
        for leaf in ["ln1/g", "ln1/bias"] {
            let t = params.remove(&format!("block0/{leaf}")).unwrap();
            params.insert(format!("block2/{leaf}"), t);
        }
        params.sort_canonical();
        assert!(num_blocks(&params).is_err());
        let g = synth_fwd_graph("text", "dense", 1, &params).unwrap();
        let x = tokens_for(&cfg, 1, 16);
        assert!(NativeBackend::new().run_fwd(&g, &params, &[x]).is_err());
    }

    #[test]
    fn demo_variants_builds_a_factorized_pair() {
        let (dense, led) = demo_variants(&small_cfg(), 21, 0.25).unwrap();
        assert!(led.n_params() < dense.n_params());
        assert!(led.get("block0/fc1/a").is_some());
        assert!(dense.get("block0/fc1/w").is_some());
    }

    #[test]
    fn synth_train_graph_batch_signatures() {
        let cfg = small_cfg();
        let params = init_text_params(&cfg, 17);
        let g = synth_train_graph("text", "dense", 4, &params).unwrap();
        assert_eq!(g.kind, "train");
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[1].shape, vec![4]);
        assert_eq!(g.inputs[1].dtype, "i32");
        assert_eq!(g.outputs[0].shape, Vec::<usize>::new());
        // The LM trains on tokens alone.
        let g = synth_train_graph("lm", "dense", 2, &params).unwrap();
        assert_eq!(g.inputs.len(), 1);
        // Image: pixels + labels.
        let img = init_image_params(
            &ImageModelCfg {
                hw: 8,
                ch: 1,
                classes: 3,
                c1: 4,
                c2: 8,
                fc: 16,
            },
            3,
        );
        let g = synth_train_graph("image", "dense", 2, &img).unwrap();
        assert_eq!(g.inputs.len(), 2);
        assert_eq!(g.inputs[0].shape, vec![2, 8, 8, 1]);
    }

    #[test]
    fn init_image_params_shapes_and_forward() {
        let cfg = ImageModelCfg {
            hw: 8,
            ch: 1,
            classes: 3,
            c1: 4,
            c2: 8,
            fc: 16,
        };
        let params = init_image_params(&cfg, 5);
        assert_eq!(params.get("conv2/w").unwrap().shape, vec![3, 3, 4, 8]);
        assert_eq!(params.get("fc1/w").unwrap().shape, vec![2 * 2 * 8, 16]);
        let g = synth_fwd_graph("image", "dense", 2, &params).unwrap();
        let mut rng = Pcg64::seeded(6);
        let mut px = vec![0.0f32; 2 * 8 * 8];
        rng.fill_normal(&mut px, 1.0);
        let out = NativeBackend::new()
            .run_fwd(&g, &params, &[Tensor::from_f32(&[2, 8, 8, 1], px)])
            .unwrap();
        assert_eq!(out[0].shape, vec![2, 3]);
        assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn synth_graph_records_ranks_and_config() {
        let cfg = small_cfg();
        let mut params = init_text_params(&cfg, 14);
        auto_fact(
            &mut params,
            &AutoFactConfig {
                rank: Rank::Ratio(0.5),
                solver: Solver::Svd,
                num_iter: 5,
                submodules: None,
                ..Default::default()
            },
        )
        .unwrap();
        let g = synth_fwd_graph("text", "led_r50", 4, &params).unwrap();
        assert!(!g.ranks.is_empty());
        assert_eq!(g.config["seq"], cfg.seq);
        assert_eq!(g.config["d"], cfg.d);
        assert_eq!(g.config["heads"], 4);
        assert_eq!(g.batch, 4);
        assert_eq!(g.n_params, params.n_params());
    }
}
