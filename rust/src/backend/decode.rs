//! KV-cached incremental decoding for the native LM path.
//!
//! The full-sequence forward pass ([`super::native`]) recomputes every
//! prefix position on every call — fine for scoring a fixed window, hopeless
//! for autoregressive generation, where production inference spends its
//! time. This module adds the serving-side counterpart: a [`DecodeSession`]
//! that owns per-layer key/value caches, so appending one token costs one
//! row of projections plus attention over the cache instead of a full
//! re-encode of the prefix.
//!
//! Numerics are **value-identical** to the full forward pass (exact to the
//! last bit, up to the sign of zero): every GEMM routes through the same
//! [`crate::linalg::matrix::matmul_into`] (whose k-dimension accumulation
//! order per output element does not depend on the row count), causally
//! masked score logits are pinned to the same `-1e9` before the same
//! softmax (where they underflow to exactly `0.0`), and those exactly-zero
//! attention weights contribute exactly-zero terms to the context GEMM —
//! `acc + ±0.0` leaves every accumulator's value unchanged, and no
//! downstream op distinguishes `-0.0` from `+0.0` (DESIGN.md §10–§11). The
//! KV-cache ≡ full-recompute equivalence is pinned for dense and LED models
//! by `tests/proptest_decode.rs` and for TT models by `tests/proptest_tt.rs`.
//!
//! Because LED factors and TT core chains keep each layer's I/O signature,
//! one decode path serves any mixture of dense and factorized layers — the
//! per-token GEMMs shrink with the rank, which is exactly where
//! Greenformer's speedup shows up on the decode hot path
//! (`benches/native_decode.rs` pins the number; `benches/native_tt.rs` the
//! TT variant). TT dispatch rides the same pre-resolved `LinearNames`, so
//! steady-state decode stays allocation-free for TT sessions too
//! (`tests/decode_alloc_steady.rs`).
//!
//! Sampling ([`SamplingCfg`] / [`sample_token`]) is driven by the seeded
//! [`Pcg64`] stream, so a fixed seed reproduces the same token stream
//! byte-for-byte — the determinism contract the coordinator's streaming
//! `generate` endpoint and the CLI both rely on.

use std::sync::Arc;

use anyhow::{anyhow, bail};

use crate::factorize::{quantize_led_params, QuantStore, WeightPrecision};
use crate::linalg::gemm::Activation;
use crate::linalg::matrix::matmul_into;
use crate::linalg::workspace::{with_thread_ws, Workspace};
use crate::runtime::GraphSpec;
use crate::tensor::{ParamStore, Tensor};
use crate::util::Pcg64;
use crate::Result;

use super::native::{
    apply_linear_quant, heads_for, layernorm_named, num_blocks, softmax_rows, LinearNames,
};
use super::Backend;

/// RNG stream id for sampling draws — distinct from the dataset/solver/init
/// streams so seeding a sampler never perturbs any other randomness.
const SAMPLE_STREAM: u64 = 0x5a17;

/// Per-layer key/value cache rows, appended as positions are decoded.
#[derive(Clone, Debug, Default)]
struct LayerKv {
    /// Keys, row-major `(len, d)` — one d-wide row per cached position.
    k: Vec<f32>,
    /// Values, row-major `(len, d)`.
    v: Vec<f32>,
}

impl LayerKv {
    /// Cache with capacity for `cap` f32s per side reserved up front, so
    /// appending tokens never reallocates mid-generation.
    fn with_capacity(cap: usize) -> Self {
        LayerKv { k: Vec::with_capacity(cap), v: Vec::with_capacity(cap) }
    }
}

/// Parameter names of one transformer block, resolved once per session so
/// the per-token step does zero string formatting (and therefore zero
/// string allocation).
#[derive(Clone, Debug)]
struct BlockNames {
    ln1_g: String,
    ln1_bias: String,
    ln2_g: String,
    ln2_bias: String,
    q: LinearNames,
    k: LinearNames,
    v: LinearNames,
    o: LinearNames,
    fc1: LinearNames,
    fc2: LinearNames,
}

impl BlockNames {
    fn new(i: usize) -> Self {
        let p = format!("block{i}");
        BlockNames {
            ln1_g: format!("{p}/ln1/g"),
            ln1_bias: format!("{p}/ln1/bias"),
            ln2_g: format!("{p}/ln2/g"),
            ln2_bias: format!("{p}/ln2/bias"),
            q: LinearNames::new(&format!("{p}/attn/q")),
            k: LinearNames::new(&format!("{p}/attn/k")),
            v: LinearNames::new(&format!("{p}/attn/v")),
            o: LinearNames::new(&format!("{p}/attn/o")),
            fc1: LinearNames::new(&format!("{p}/fc1")),
            fc2: LinearNames::new(&format!("{p}/fc2")),
        }
    }
}

/// All pre-resolved parameter names of one model: per-block names plus the
/// LM head. Depends only on the layer count, so sessions over the same
/// checkpoint share one set behind an `Arc` — the batched decode step can
/// then hold the names while mutably borrowing every session's KV caches.
#[derive(Debug)]
struct ModelNames {
    blocks: Vec<BlockNames>,
    head: LinearNames,
}

/// Mutable state of one in-flight autoregressive decode: the per-layer KV
/// caches plus the model dimensions they were sized for.
///
/// A session is created once per generation ([`DecodeSession::new`]), fed a
/// prompt via one prefill call to [`Backend::run_decode_step`], then
/// advanced one token at a time. The session owns only the caches — the
/// parameters stay in the caller's [`ParamStore`], so many sessions can
/// share one checkpoint.
#[derive(Clone, Debug)]
pub struct DecodeSession {
    /// Residual width.
    d: usize,
    /// Attention head count (from the graph config / model-zoo default).
    heads: usize,
    /// Logit width of the LM head.
    vocab: usize,
    /// Positional capacity: rows of `pos/table` the model was built with.
    max_seq: usize,
    /// Positions decoded so far (cache rows per layer).
    len: usize,
    layers: Vec<LayerKv>,
    /// Per-block + head parameter names, resolved once at session creation
    /// and shared (`Arc`) so batched steps can borrow them independently of
    /// the sessions' mutable cache state.
    names: Arc<ModelNames>,
    /// Weight precision the session's linears execute at (DESIGN.md §12).
    precision: WeightPrecision,
    /// Pre-packed quantized weights, built once at session creation and
    /// shared (`Arc`) across clones — the per-token step never re-quantizes
    /// a weight. `None` for [`WeightPrecision::F32`] (the bit-identical
    /// fallthrough path).
    quant: Option<Arc<QuantStore>>,
    /// Scratch arena for the step's activations; attention scratch is sized
    /// by `max_seq`, so every post-prefill step reuses identical buffers
    /// (cloning a session starts a fresh, unwarmed arena).
    ws: Workspace,
}

impl DecodeSession {
    /// Open a session for an LM graph + checkpoint pair.
    ///
    /// The graph must be a `fwd` graph with per-position logits `(B, S, V)`
    /// — the shape contract that marks the causal LM family. Classifier
    /// graphs are refused: their pooled head has no per-position
    /// distribution to sample from.
    pub fn new(graph: &GraphSpec, params: &ParamStore) -> Result<Self> {
        Self::new_with_precision(graph, params, WeightPrecision::F32)
    }

    /// [`DecodeSession::new`] with a weight-precision axis: for `Int8` /
    /// `Binary` the checkpoint's 2-D linear weights are quantized once, up
    /// front, into a session-held [`QuantStore`], and every per-token linear
    /// runs through the quantized kernels. `F32` is bit-identical to
    /// [`DecodeSession::new`].
    pub fn new_with_precision(
        graph: &GraphSpec,
        params: &ParamStore,
        precision: WeightPrecision,
    ) -> Result<Self> {
        let quant = if precision == WeightPrecision::F32 {
            None
        } else {
            let (store, _report) = quantize_led_params(params, precision)?;
            Some(Arc::new(store))
        };
        Self::build(graph, params, precision, quant)
    }

    /// Open a session over an already-built [`QuantStore`] (e.g. the one
    /// [`quantize_led_params`] returned alongside the report the caller
    /// printed), avoiding a second quantization pass. An empty `F32` store
    /// selects the plain f32 path.
    pub fn with_quant_store(
        graph: &GraphSpec,
        params: &ParamStore,
        store: Arc<QuantStore>,
    ) -> Result<Self> {
        let precision = store.precision();
        let quant = if precision == WeightPrecision::F32 { None } else { Some(store) };
        Self::build(graph, params, precision, quant)
    }

    fn build(
        graph: &GraphSpec,
        params: &ParamStore,
        precision: WeightPrecision,
        quant: Option<Arc<QuantStore>>,
    ) -> Result<Self> {
        if graph.kind != "fwd" {
            bail!("decode sessions need a fwd graph, got kind {:?}", graph.kind);
        }
        let out = graph
            .outputs
            .first()
            .ok_or_else(|| anyhow!("graph {} has no output spec", graph.name))?;
        if out.shape.len() != 3 {
            bail!(
                "decode sessions need an LM graph with per-position logits (B, S, vocab); \
                 {} emits {:?} (a classifier)",
                graph.name,
                out.shape
            );
        }
        let vocab = out.shape[2];
        let embed = params
            .get("embed/table")
            .ok_or_else(|| anyhow!("checkpoint missing embed/table"))?;
        let d = embed.shape[1];
        let heads = heads_for(graph);
        if heads == 0 || d % heads != 0 {
            bail!("d={d} not divisible by heads={heads}");
        }
        let pos = params
            .get("pos/table")
            .ok_or_else(|| anyhow!("checkpoint missing pos/table"))?;
        if pos.shape.len() != 2 || pos.shape[1] != d {
            bail!("pos/table {:?} incompatible with d {d}", pos.shape);
        }
        let max_seq = graph.config_usize("seq").unwrap_or(pos.shape[0]).min(pos.shape[0]);
        let n_layers = num_blocks(params)?;
        Ok(Self {
            d,
            heads,
            vocab,
            max_seq,
            len: 0,
            layers: (0..n_layers).map(|_| LayerKv::with_capacity(max_seq * d)).collect(),
            names: Arc::new(ModelNames {
                blocks: (0..n_layers).map(BlockNames::new).collect(),
                head: LinearNames::new("head"),
            }),
            precision,
            quant,
            ws: Workspace::new(),
        })
    }

    /// Weight precision this session's linears execute at.
    pub fn precision(&self) -> WeightPrecision {
        self.precision
    }

    /// Bytes held by the pre-packed quantized weights (0 for `F32`).
    pub fn quant_bytes(&self) -> usize {
        self.quant.as_ref().map_or(0, |q| q.bytes())
    }

    /// Positions decoded so far (prompt + generated, cached per layer).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True before the first prefill.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The model's positional capacity (rows of `pos/table`).
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    /// Positions that can still be appended before the context is full.
    pub fn remaining(&self) -> usize {
        self.max_seq - self.len
    }

    /// Logit width of the LM head (the sampling distribution's support).
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Bytes currently held by the KV caches across all layers.
    pub fn cache_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| std::mem::size_of_val(l.k.as_slice()) + std::mem::size_of_val(l.v.as_slice()))
            .sum()
    }

    /// Drop all cached positions, keeping the allocations for reuse.
    pub fn reset(&mut self) {
        self.len = 0;
        for l in &mut self.layers {
            l.k.clear();
            l.v.clear();
        }
    }

    /// Truncate the cache back to `len` positions, discarding every newer
    /// row (allocations are kept). This is the speculative-decode rollback
    /// primitive: rejected draft suffixes are erased so the cache holds
    /// exactly the accepted prefix — because each cached K/V row depends
    /// only on its own position's activations and the rows before it, the
    /// surviving prefix is bit-identical to a session that never saw the
    /// rejected tokens (pinned by `tests/proptest_spec_decode.rs`). A `len`
    /// at or beyond the current length is a no-op.
    pub fn truncate(&mut self, len: usize) {
        if len >= self.len {
            return;
        }
        self.len = len;
        for l in &mut self.layers {
            l.k.truncate(len * self.d);
            l.v.truncate(len * self.d);
        }
    }

    /// Raw K/V cache rows of one layer, each row-major `(len, d)` — exposed
    /// so equivalence tests can compare cache *state* (not just behavior)
    /// bit-for-bit, e.g. post-rollback vs a fresh replay of the accepted
    /// prefix. `None` if `layer` is out of range.
    pub fn layer_kv(&self, layer: usize) -> Option<(&[f32], &[f32])> {
        self.layers.get(layer).map(|l| (l.k.as_slice(), l.v.as_slice()))
    }

    /// Number of transformer blocks (and therefore KV cache layers).
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Scratch-arena takes that had to allocate because no retired buffer
    /// fit. Constant across steady-state decode steps (every post-prefill
    /// step requests identical buffer sizes) — the zero-allocation contract
    /// `tests/decode_alloc_steady.rs` pins.
    pub fn scratch_alloc_misses(&self) -> usize {
        self.ws.alloc_misses()
    }

    /// Reset the scratch arena's take/miss counters (buffers are kept).
    pub fn reset_scratch_stats(&mut self) {
        self.ws.reset_stats();
    }
}

/// The native implementation of [`Backend::run_decode_step`]: append
/// `new_tokens` (the whole prompt on prefill, a single token per step after
/// that) to the session's KV caches and return the logits of the **last**
/// appended position as a `(vocab,)` tensor.
///
/// All chunk rows run as one batch of GEMM rows — prefill gets the same
/// blocked-GEMM efficiency as the full forward — while attention for row
/// `i` of the chunk sees cache positions `0..=p0+i` (causal mask identical
/// to the full pass).
pub(crate) fn native_decode_step(
    params: &ParamStore,
    session: &mut DecodeSession,
    new_tokens: &[i32],
) -> Result<Tensor> {
    decode_chunk(params, session, new_tokens, false)
}

/// The native implementation of [`Backend::run_decode_step_multi`]: same
/// chunk append as [`native_decode_step`], but the LM head runs over **all**
/// `n` chunk rows, returning `(n, vocab)` logits — row `i` is the next-token
/// distribution after chunk position `i`. This is the speculative-verify
/// primitive: one stacked pass scores every drafted position, and each row
/// is value-identical to what a solo per-token step would have produced
/// (the chunk shares every op with the solo path; only the head's row count
/// differs, and `matmul_into`'s per-element accumulation order does not
/// depend on the row count).
pub(crate) fn native_decode_step_multi(
    params: &ParamStore,
    session: &mut DecodeSession,
    new_tokens: &[i32],
) -> Result<Tensor> {
    decode_chunk(params, session, new_tokens, true)
}

/// Shared chunk-append core of the two step flavors; `all_rows` picks
/// whether the LM head covers the whole chunk or just its last row.
fn decode_chunk(
    params: &ParamStore,
    session: &mut DecodeSession,
    new_tokens: &[i32],
    all_rows: bool,
) -> Result<Tensor> {
    let n = new_tokens.len();
    if n == 0 {
        bail!("decode step needs at least one new token");
    }
    let p0 = session.len;
    if p0 + n > session.max_seq {
        bail!(
            "decode overflows the positional capacity: {p0} cached + {n} new > seq {}",
            session.max_seq
        );
    }
    let (d, heads, max_seq) = (session.d, session.heads, session.max_seq);
    let dk = d / heads;

    // Token + position embedding of the chunk, at absolute positions
    // p0..p0+n (native::embed assumes position 0 — decode cannot reuse it).
    let table = params
        .get("embed/table")
        .ok_or_else(|| anyhow!("checkpoint missing embed/table"))?;
    let vocab_rows = table.shape[0];
    let td = table.as_f32()?;
    let pd = params
        .get("pos/table")
        .ok_or_else(|| anyhow!("checkpoint missing pos/table"))?
        .as_f32()?;
    // Disjoint field borrows: the KV caches and the scratch arena live in
    // different session fields, so the layer loop can hold both. The quant
    // side-table rides behind an Arc clone (no allocation) so the loop's
    // mutable borrows of the caches never conflict with it.
    let quant_arc = session.quant.clone();
    let quant = quant_arc.as_deref();
    let s = &mut *session;
    let ws = &mut s.ws;
    let mut x = ws.take_zeroed(n * d);
    for (si, &t) in new_tokens.iter().enumerate() {
        if t < 0 || t as usize >= vocab_rows {
            bail!("token id {t} out of range (vocab {vocab_rows})");
        }
        let row = &td[t as usize * d..(t as usize + 1) * d];
        let prow = &pd[(p0 + si) * d..(p0 + si + 1) * d];
        let dst = &mut x[si * d..(si + 1) * d];
        for ((dv, &rv), &pv) in dst.iter_mut().zip(row).zip(prow) {
            *dv = rv + pv;
        }
    }

    let len = p0 + n;
    let scale = 1.0 / (dk as f32).sqrt();
    // Step scratch. Attention buffers are sized by the positional capacity,
    // not the live cache length, so every post-prefill step requests the
    // same lengths and the arena serves them without touching the
    // allocator (the contract `scratch_alloc_misses` exposes).
    let mut xn = ws.take_zeroed(n * d);
    let mut ctx = ws.take_zeroed(n * d);
    let mut qh = ws.take_zeroed(n * dk);
    let mut kt = ws.take_zeroed(dk * max_seq); // cache keys pre-transposed: (dk, len)
    let mut vh = ws.take_zeroed(max_seq * dk);
    let mut scores = ws.take_zeroed(n * max_seq);
    let mut oh = ws.take_zeroed(n * dk);
    for (layer, names) in s.layers.iter_mut().zip(&s.names.blocks) {
        // Attention sublayer: project the chunk, append K/V to the cache,
        // then score each chunk row against every cached position.
        xn.copy_from_slice(&x);
        layernorm_named(params, &names.ln1_g, &names.ln1_bias, d, &mut xn)?;
        let (dq, q) =
            apply_linear_quant(params, quant, &names.q, n, d, &xn, Activation::None, ws)?;
        let (dkk, knew) =
            apply_linear_quant(params, quant, &names.k, n, d, &xn, Activation::None, ws)?;
        let (dv, vnew) =
            apply_linear_quant(params, quant, &names.v, n, d, &xn, Activation::None, ws)?;
        if dq != d || dkk != d || dv != d {
            bail!("{}: projection output dims {dq}/{dkk}/{dv} != d {d}", names.q.prefix);
        }
        layer.k.extend_from_slice(&knew);
        layer.v.extend_from_slice(&vnew);
        ws.give(knew);
        ws.give(vnew);
        debug_assert_eq!(layer.k.len(), len * d);

        for h in 0..heads {
            for si in 0..n {
                let src = si * d + h * dk;
                qh[si * dk..(si + 1) * dk].copy_from_slice(&q[src..src + dk]);
            }
            for pi in 0..len {
                let src = pi * d + h * dk;
                vh[pi * dk..(pi + 1) * dk].copy_from_slice(&layer.v[src..src + dk]);
                for ki in 0..dk {
                    kt[ki * len + pi] = layer.k[src + ki];
                }
            }
            // scores(n, len) = qh @ kt * scale; chunk row i may only see
            // cache positions 0..=p0+i (mask pinned to -1e9 pre-softmax,
            // exactly like the full pass — it underflows to 0.0 there too).
            scores[..n * len].fill(0.0);
            matmul_into(n, dk, len, &qh, &kt[..dk * len], &mut scores[..n * len]);
            for i in 0..n {
                let row = &mut scores[i * len..(i + 1) * len];
                for v in row.iter_mut() {
                    *v *= scale;
                }
                for v in row[p0 + i + 1..].iter_mut() {
                    *v = -1e9;
                }
            }
            softmax_rows(&mut scores[..n * len], len);
            oh.fill(0.0);
            matmul_into(n, len, dk, &scores[..n * len], &vh[..len * dk], &mut oh);
            for si in 0..n {
                let dst = si * d + h * dk;
                ctx[dst..dst + dk].copy_from_slice(&oh[si * dk..(si + 1) * dk]);
            }
        }
        let (do_, attn) =
            apply_linear_quant(params, quant, &names.o, n, d, &ctx, Activation::None, ws)?;
        ws.give(q);
        if do_ != d {
            bail!("{}: o-projection output dim {do_} != d {d}", names.o.prefix);
        }
        for (v, a) in x.iter_mut().zip(&attn) {
            *v += a;
        }
        ws.give(attn);

        // FFN sublayer (dense, LED, or TT — the linear dispatches on keys); the
        // GELU runs in fc1's GEMM epilogue.
        xn.copy_from_slice(&x);
        layernorm_named(params, &names.ln2_g, &names.ln2_bias, d, &mut xn)?;
        let (ff, hmid) =
            apply_linear_quant(params, quant, &names.fc1, n, d, &xn, Activation::Gelu, ws)?;
        let (d2, y) =
            apply_linear_quant(params, quant, &names.fc2, n, ff, &hmid, Activation::None, ws)?;
        if d2 != d {
            bail!("{}: fc2 output dim {d2} != d {d}", names.fc2.prefix);
        }
        for (v, a) in x.iter_mut().zip(&y) {
            *v += a;
        }
        ws.give(hmid);
        ws.give(y);
    }
    s.len = len;

    // Final layernorm, then the LM head: over every chunk row for the
    // multi-row (speculative verify) flavor, over the last row only for the
    // classic step — earlier rows' logits were (or could have been) emitted
    // by earlier steps.
    layernorm_named(params, "ln_f/g", "ln_f/bias", d, &mut x)?;
    let rows = if all_rows { n } else { 1 };
    let head_in = if all_rows { &x[..] } else { &x[(n - 1) * d..n * d] };
    let (vocab, logits) =
        apply_linear_quant(params, quant, &s.names.head, rows, d, head_in, Activation::None, ws)?;
    if vocab != s.vocab {
        bail!("head width {vocab} does not match the graph's logit width {}", s.vocab);
    }
    // The logits tensor is the step's output and the single unavoidable
    // per-token allocation; every interpreter-internal buffer goes back to
    // the arena.
    let out = if all_rows {
        Tensor::from_f32(&[n, vocab], logits.clone())
    } else {
        Tensor::from_f32(&[vocab], logits.clone())
    };
    ws.give_all([logits, x, xn, ctx, qh, kt, vh, scores, oh]);
    Ok(out)
}

/// The native implementation of [`Backend::run_decode_step_batched`]: advance
/// `m = sessions.len()` post-prefill sessions one token each, stacking every
/// per-session linear projection into one m-row GEMM.
///
/// Per transformer block, the six projections (q/k/v/o/fc1/fc2) and the LM
/// head run as single `(m, ·)` GEMMs over the stacked current-token rows —
/// continuous batching's whole point: at m concurrent streams the per-step
/// GEMV becomes a packed GEMM that the blocked kernel layer can tile.
/// Attention stays per-session (each session scores its own KV cache at its
/// own length) and LayerNorm/residuals are per-row, so every session's
/// logits are **value-identical** to what a solo [`native_decode_step`] call
/// would have produced: `matmul_into` accumulates each output element over k
/// in an order independent of the row count, and no other op mixes rows
/// (pinned by `tests/proptest_batched_decode.rs`).
///
/// All sessions must share one checkpoint (`params`) — same width, head
/// count, vocab, layer count and positional capacity — and must be past
/// prefill with at least one free position. Stacked scratch comes from the
/// calling thread's workspace (the dispatcher sweeps from one thread, so
/// steady-state sweeps at a stable batch size are allocation-free); the
/// per-session arenas keep serving the solo prefill/step paths.
pub(crate) fn native_decode_step_batched(
    params: &ParamStore,
    sessions: &mut [&mut DecodeSession],
    tokens: &[i32],
) -> Result<Vec<Tensor>> {
    let m = sessions.len();
    if m == 0 {
        bail!("batched decode needs at least one session");
    }
    if tokens.len() != m {
        bail!("batched decode got {m} sessions but {} tokens", tokens.len());
    }
    if m == 1 {
        // Solo step: keep the session-owned arena warm (the single-stream
        // zero-allocation contract of tests/decode_alloc_steady.rs).
        return Ok(vec![native_decode_step(params, sessions[0], tokens)?]);
    }
    let (d, heads, vocab, max_seq) = {
        let s0 = &sessions[0];
        (s0.d, s0.heads, s0.vocab, s0.max_seq)
    };
    let n_layers = sessions[0].layers.len();
    let table = params
        .get("embed/table")
        .ok_or_else(|| anyhow!("checkpoint missing embed/table"))?;
    let vocab_rows = table.shape[0];
    let td = table.as_f32()?;
    let pd = params
        .get("pos/table")
        .ok_or_else(|| anyhow!("checkpoint missing pos/table"))?
        .as_f32()?;
    // Validate everything before touching any cache: a rejected batch must
    // leave every session exactly as it was.
    let precision = sessions[0].precision;
    for (i, (s, &t)) in sessions.iter().zip(tokens).enumerate() {
        if s.d != d || s.heads != heads || s.vocab != vocab || s.max_seq != max_seq
            || s.layers.len() != n_layers
        {
            bail!(
                "session {i} is incompatible with session 0: \
                 d {}/{d}, heads {}/{heads}, vocab {}/{vocab}, seq {}/{max_seq}, layers {}/{n_layers}",
                s.d, s.heads, s.vocab, s.max_seq, s.layers.len()
            );
        }
        if s.precision != precision {
            bail!(
                "session {i} runs at precision {} but session 0 at {}: \
                 a batched step stacks one GEMM per projection, so every \
                 session must share one weight encoding",
                s.precision,
                precision
            );
        }
        if s.is_empty() {
            bail!("session {i} has no prefilled positions; batched steps are post-prefill only");
        }
        if s.remaining() == 0 {
            bail!("session {i} is at its positional capacity {max_seq}");
        }
        if t < 0 || t as usize >= vocab_rows {
            bail!("token id {t} out of range (vocab {vocab_rows})");
        }
    }
    let names = sessions[0].names.clone();
    // All sessions share one checkpoint, so session 0's pre-packed store
    // serves the whole stacked step.
    let quant_arc = sessions[0].quant.clone();
    let quant = quant_arc.as_deref();
    let dk = d / heads;
    let scale = 1.0 / (dk as f32).sqrt();

    with_thread_ws(|ws| {
        // Stacked current-token activations: row i = embed[token_i] +
        // pos[len_i] (each session sits at its own absolute position).
        let mut x = ws.take_zeroed(m * d);
        for ((dst, &t), s) in x.chunks_exact_mut(d).zip(tokens).zip(&*sessions) {
            let row = &td[t as usize * d..(t as usize + 1) * d];
            let prow = &pd[s.len * d..(s.len + 1) * d];
            for ((dv, &rv), &pv) in dst.iter_mut().zip(row).zip(prow) {
                *dv = rv + pv;
            }
        }

        // Stacked scratch (m rows); attention scratch is per-session, sized
        // by the positional capacity so every sweep at the same m reuses
        // identical buffers.
        let mut xn = ws.take_zeroed(m * d);
        let mut ctx = ws.take_zeroed(m * d);
        let mut kt = ws.take_zeroed(dk * max_seq); // cache keys pre-transposed: (dk, len)
        let mut vh = ws.take_zeroed(max_seq * dk);
        let mut scores = ws.take_zeroed(max_seq);
        let mut oh = ws.take_zeroed(dk);
        for (l, nb) in names.blocks.iter().enumerate() {
            // Attention sublayer: one stacked projection per q/k/v, then
            // per-session cache append + scoring (cache lengths differ).
            xn.copy_from_slice(&x);
            layernorm_named(params, &nb.ln1_g, &nb.ln1_bias, d, &mut xn)?;
            let (dq, q) =
                apply_linear_quant(params, quant, &nb.q, m, d, &xn, Activation::None, ws)?;
            let (dkk, knew) =
                apply_linear_quant(params, quant, &nb.k, m, d, &xn, Activation::None, ws)?;
            let (dv, vnew) =
                apply_linear_quant(params, quant, &nb.v, m, d, &xn, Activation::None, ws)?;
            if dq != d || dkk != d || dv != d {
                bail!("{}: projection output dims {dq}/{dkk}/{dv} != d {d}", nb.q.prefix);
            }
            for (i, s) in sessions.iter_mut().enumerate() {
                let layer = &mut s.layers[l];
                layer.k.extend_from_slice(&knew[i * d..(i + 1) * d]);
                layer.v.extend_from_slice(&vnew[i * d..(i + 1) * d]);
                let len = s.len + 1;
                debug_assert_eq!(layer.k.len(), len * d);
                for h in 0..heads {
                    let qrow = &q[i * d + h * dk..i * d + (h + 1) * dk];
                    for pi in 0..len {
                        let src = pi * d + h * dk;
                        vh[pi * dk..(pi + 1) * dk].copy_from_slice(&layer.v[src..src + dk]);
                        for ki in 0..dk {
                            kt[ki * len + pi] = layer.k[src + ki];
                        }
                    }
                    // The appended row is the last cache position, so it
                    // attends to everything: no causal mask to apply (same
                    // as the solo single-token step).
                    scores[..len].fill(0.0);
                    matmul_into(1, dk, len, qrow, &kt[..dk * len], &mut scores[..len]);
                    for v in scores[..len].iter_mut() {
                        *v *= scale;
                    }
                    softmax_rows(&mut scores[..len], len);
                    oh.fill(0.0);
                    matmul_into(1, len, dk, &scores[..len], &vh[..len * dk], &mut oh);
                    ctx[i * d + h * dk..i * d + (h + 1) * dk].copy_from_slice(&oh);
                }
            }
            ws.give(q);
            ws.give(knew);
            ws.give(vnew);
            let (do_, attn) =
                apply_linear_quant(params, quant, &nb.o, m, d, &ctx, Activation::None, ws)?;
            if do_ != d {
                bail!("{}: o-projection output dim {do_} != d {d}", nb.o.prefix);
            }
            for (v, a) in x.iter_mut().zip(&attn) {
                *v += a;
            }
            ws.give(attn);

            // FFN sublayer, stacked: (m, d) → (m, ff) → (m, d).
            xn.copy_from_slice(&x);
            layernorm_named(params, &nb.ln2_g, &nb.ln2_bias, d, &mut xn)?;
            let (ff, hmid) =
                apply_linear_quant(params, quant, &nb.fc1, m, d, &xn, Activation::Gelu, ws)?;
            let (d2, y) =
                apply_linear_quant(params, quant, &nb.fc2, m, ff, &hmid, Activation::None, ws)?;
            if d2 != d {
                bail!("{}: fc2 output dim {d2} != d {d}", nb.fc2.prefix);
            }
            for (v, a) in x.iter_mut().zip(&y) {
                *v += a;
            }
            ws.give(hmid);
            ws.give(y);
        }
        for s in sessions.iter_mut() {
            s.len += 1;
        }

        // Final layernorm + LM head, stacked: every row is some session's
        // newest position, so all m rows get logits in one GEMM.
        layernorm_named(params, "ln_f/g", "ln_f/bias", d, &mut x)?;
        let (hv, logits) =
            apply_linear_quant(params, quant, &names.head, m, d, &x, Activation::None, ws)?;
        if hv != vocab {
            bail!("head width {hv} does not match the graph's logit width {vocab}");
        }
        let out = logits
            .chunks_exact(vocab)
            .map(|row| Tensor::from_f32(&[vocab], row.to_vec()))
            .collect();
        ws.give_all([logits, x, xn, ctx, kt, vh, scores, oh]);
        Ok(out)
    })
}

// ---------------------------------------------------------------------------
// Sampling
// ---------------------------------------------------------------------------

/// How to turn next-token logits into a token: greedy (`temperature == 0`),
/// or temperature softmax optionally restricted to the `top_k` highest
/// logits. Draws come from a dedicated seeded [`Pcg64`] stream, so the same
/// seed reproduces the same token stream.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SamplingCfg {
    /// Softmax temperature; `<= 0.0` selects greedy argmax decoding.
    pub temperature: f32,
    /// Restrict sampling to the k highest logits; `0` disables the filter.
    pub top_k: usize,
    /// Seed of the sampling RNG stream.
    pub seed: u64,
}

impl SamplingCfg {
    /// Deterministic greedy decoding (the default).
    pub fn greedy() -> Self {
        Self::default()
    }

    /// The seeded sampler RNG for this configuration.
    pub fn rng(&self) -> Pcg64 {
        Pcg64::new(self.seed, SAMPLE_STREAM)
    }
}

/// First index of the maximum logit (ties break to the lowest index, like
/// the eval harness's argmax). Shared with the speculative engine so the
/// draft/verify accept rule uses the exact argmax `sample_token` greedy
/// decoding uses.
pub(crate) fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Sample one token id from next-token `logits` under `cfg`, advancing
/// `rng`. Greedy when `cfg.temperature <= 0.0` (the rng is untouched then,
/// so greedy streams are reproducible regardless of seed).
pub fn sample_token(logits: &[f32], cfg: &SamplingCfg, rng: &mut Pcg64) -> usize {
    debug_assert!(!logits.is_empty());
    if cfg.temperature <= 0.0 {
        return argmax(logits);
    }
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    if cfg.top_k > 0 && cfg.top_k < logits.len() {
        // Descending by logit, ties ascending by index — deterministic.
        idx.sort_by(|&a, &b| logits[b].total_cmp(&logits[a]).then(a.cmp(&b)));
        idx.truncate(cfg.top_k);
    }
    let inv_t = 1.0 / cfg.temperature;
    let max = idx.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| f64::from((logits[i] - max) * inv_t).exp())
        .collect();
    idx[rng.weighted(&weights)]
}

// ---------------------------------------------------------------------------
// Generation driver
// ---------------------------------------------------------------------------

/// What one [`generate`] run produced.
#[derive(Clone, Debug)]
pub struct GenerateOutcome {
    /// Generated token ids, in order (the prompt is not repeated).
    pub tokens: Vec<i32>,
    /// Prompt length consumed by the prefill.
    pub prefill_tokens: usize,
    /// Total positions held in the KV cache at the end (prompt + appended).
    pub positions_used: usize,
}

/// Autoregressive generation: one prefill over `prompt`, then single-token
/// decode steps, sampling each next token under `cfg`. Stops after
/// `max_new` tokens or when the positional capacity is exhausted (whichever
/// comes first — the final sampled token never needs to be appended).
/// `on_token(index, token)` fires as each token is sampled, enabling
/// streaming consumers.
///
/// An empty `prompt` or `max_new == 0` is a degenerate-but-valid request:
/// it returns a clean empty outcome (no tokens, no positions consumed, no
/// model work) rather than an error, so streaming callers get their normal
/// terminator without pre-filtering.
///
/// Works on any [`Backend`] that implements
/// [`Backend::run_decode_step`] — the PJRT backend refuses (AOT graphs are
/// fixed-shape full-sequence executables), the native backend implements it.
pub fn generate(
    backend: &dyn Backend,
    graph: &GraphSpec,
    params: &ParamStore,
    prompt: &[i32],
    max_new: usize,
    cfg: &SamplingCfg,
    on_token: impl FnMut(usize, i32),
) -> Result<GenerateOutcome> {
    if prompt.is_empty() || max_new == 0 {
        return Ok(GenerateOutcome { tokens: Vec::new(), prefill_tokens: 0, positions_used: 0 });
    }
    let mut session = DecodeSession::new(graph, params)?;
    generate_with_session(backend, graph, params, &mut session, prompt, max_new, cfg, on_token)
}

/// [`generate`] over a caller-supplied session — the entry point for
/// non-default sessions (e.g. [`DecodeSession::new_with_precision`] for
/// int8 / binary serving) and for reusing one warmed session across
/// generations (callers [`DecodeSession::reset`] between runs). The session
/// must be empty; prefill happens here.
#[allow(clippy::too_many_arguments)]
pub fn generate_with_session(
    backend: &dyn Backend,
    graph: &GraphSpec,
    params: &ParamStore,
    session: &mut DecodeSession,
    prompt: &[i32],
    max_new: usize,
    cfg: &SamplingCfg,
    mut on_token: impl FnMut(usize, i32),
) -> Result<GenerateOutcome> {
    if prompt.is_empty() || max_new == 0 {
        return Ok(GenerateOutcome { tokens: Vec::new(), prefill_tokens: 0, positions_used: 0 });
    }
    if !session.is_empty() {
        bail!(
            "generate_with_session needs an empty session, got {} cached positions",
            session.len()
        );
    }
    let mut logits_t = backend.run_decode_step(graph, params, session, prompt)?;
    let mut rng = cfg.rng();
    let mut tokens = Vec::with_capacity(max_new);
    loop {
        let tok = sample_token(logits_t.as_f32()?, cfg, &mut rng) as i32;
        on_token(tokens.len(), tok);
        tokens.push(tok);
        if tokens.len() >= max_new || session.remaining() == 0 {
            break;
        }
        logits_t = backend.run_decode_step(graph, params, session, &[tok])?;
    }
    Ok(GenerateOutcome {
        tokens,
        prefill_tokens: prompt.len(),
        positions_used: session.len(),
    })
}

/// Generate from several prompts concurrently, advancing all streams one
/// token per step through [`Backend::run_decode_step_batched`] — per layer,
/// the streams' projections run as one stacked GEMM instead of one GEMV
/// each (the library-level form of the coordinator's continuous batching).
///
/// Each stream prefills individually, then all live streams step together;
/// a stream leaves the batch when it has sampled `max_new` tokens or filled
/// its positional capacity, without stalling the others. `cfgs` supplies one
/// sampling policy per prompt (each stream draws from its own seeded RNG),
/// so stream `i` reproduces exactly what
/// [`generate`]`(backend, graph, params, &prompts[i], max_new, &cfgs[i], ..)`
/// would emit — the batched step is value-identical to the solo step.
///
/// # Examples
///
/// ```
/// use greenformer::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
/// use greenformer::backend::{generate_batched, NativeBackend, SamplingCfg};
///
/// let cfg = TextModelCfg { vocab: 48, seq: 12, d: 24, heads: 6, layers: 1, ff: 32, classes: 48 };
/// let params = init_text_params(&cfg, 7);
/// let graph = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
/// let prompts = vec![vec![1, 2, 3], vec![4, 5]];
/// let cfgs = vec![SamplingCfg::greedy(); 2];
/// let outs =
///     generate_batched(&NativeBackend::new(), &graph, &params, &prompts, 4, &cfgs).unwrap();
/// assert_eq!(outs.len(), 2);
/// assert!(outs.iter().all(|o| o.tokens.len() == 4));
/// ```
pub fn generate_batched(
    backend: &dyn Backend,
    graph: &GraphSpec,
    params: &ParamStore,
    prompts: &[Vec<i32>],
    max_new: usize,
    cfgs: &[SamplingCfg],
) -> Result<Vec<GenerateOutcome>> {
    if cfgs.len() != prompts.len() {
        bail!("generate_batched got {} prompts but {} sampling configs", prompts.len(), cfgs.len());
    }
    struct Stream {
        /// `None` for degenerate streams (empty prompt / `max_new == 0`)
        /// that never prefill and never join the batch.
        session: Option<DecodeSession>,
        rng: Pcg64,
        cfg: SamplingCfg,
        tokens: Vec<i32>,
        done: bool,
    }
    let mut streams = Vec::with_capacity(prompts.len());
    for (prompt, cfg) in prompts.iter().zip(cfgs) {
        // A degenerate stream yields a clean empty outcome (same contract
        // as solo `generate`) without stalling or poisoning the others.
        if prompt.is_empty() || max_new == 0 {
            streams.push(Stream {
                session: None,
                rng: cfg.rng(),
                cfg: *cfg,
                tokens: Vec::new(),
                done: true,
            });
            continue;
        }
        let mut session = DecodeSession::new(graph, params)?;
        let logits = backend.run_decode_step(graph, params, &mut session, prompt)?;
        let mut rng = cfg.rng();
        let tok = sample_token(logits.as_f32()?, cfg, &mut rng) as i32;
        let done = max_new == 1 || session.remaining() == 0;
        streams.push(Stream { session: Some(session), rng, cfg: *cfg, tokens: vec![tok], done });
    }
    loop {
        let mut idx = Vec::new();
        let mut toks = Vec::new();
        let mut live = Vec::new();
        for (i, st) in streams.iter_mut().enumerate() {
            if !st.done {
                idx.push(i);
                toks.push(*st.tokens.last().expect("stream sampled at least one token"));
                live.push(st.session.as_mut().expect("live streams have sessions"));
            }
        }
        if idx.is_empty() {
            break;
        }
        let all_logits = backend.run_decode_step_batched(graph, params, &mut live, &toks)?;
        for (i, logits) in idx.into_iter().zip(all_logits) {
            let st = &mut streams[i];
            let tok = sample_token(logits.as_f32()?, &st.cfg, &mut st.rng) as i32;
            st.tokens.push(tok);
            if st.tokens.len() >= max_new
                || st.session.as_ref().is_some_and(|s| s.remaining() == 0)
            {
                st.done = true;
            }
        }
    }
    Ok(streams
        .into_iter()
        .zip(prompts)
        .map(|(st, prompt)| GenerateOutcome {
            tokens: st.tokens,
            prefill_tokens: if st.session.is_some() { prompt.len() } else { 0 },
            positions_used: st.session.map_or(0, |s| s.len()),
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
    use crate::backend::NativeBackend;

    fn lm_cfg() -> TextModelCfg {
        TextModelCfg {
            vocab: 48,
            seq: 10,
            d: 24,
            heads: 6,
            layers: 1,
            ff: 32,
            classes: 48,
        }
    }

    #[test]
    fn session_rejects_classifier_graphs() {
        let cfg = TextModelCfg {
            classes: 4,
            ..lm_cfg()
        };
        let params = init_text_params(&cfg, 1);
        let g = synth_fwd_graph("text", "dense", 1, &params).unwrap();
        assert!(DecodeSession::new(&g, &params).is_err());
    }

    #[test]
    fn decode_matches_full_forward_smoke() {
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, 2);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        let be = NativeBackend::new();
        let toks: Vec<i32> = (0..cfg.seq as i32).map(|t| t % cfg.vocab as i32).collect();
        let full = be
            .run_fwd(&g, &params, &[Tensor::from_i32(&[1, cfg.seq], toks.clone())])
            .unwrap();
        let full_logits = full[0].as_f32().unwrap();

        let mut session = DecodeSession::new(&g, &params).unwrap();
        // Prefill 4 tokens, then append the rest one at a time; each step's
        // logits must equal the full forward's row at that position.
        let l = be.run_decode_step(&g, &params, &mut session, &toks[..4]).unwrap();
        let want = &full_logits[3 * cfg.vocab..4 * cfg.vocab];
        for (a, b) in l.as_f32().unwrap().iter().zip(want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        for p in 4..cfg.seq {
            let l = be.run_decode_step(&g, &params, &mut session, &toks[p..p + 1]).unwrap();
            let want = &full_logits[p * cfg.vocab..(p + 1) * cfg.vocab];
            for (a, b) in l.as_f32().unwrap().iter().zip(want) {
                assert!((a - b).abs() < 1e-5, "pos {p}: {a} vs {b}");
            }
        }
        assert_eq!(session.len(), cfg.seq);
        assert_eq!(session.remaining(), 0);
        assert!(session.cache_bytes() > 0);
    }

    #[test]
    fn decode_refuses_overflow_and_bad_tokens() {
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, 3);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        let be = NativeBackend::new();
        let mut session = DecodeSession::new(&g, &params).unwrap();
        let too_long = vec![0i32; cfg.seq + 1];
        assert!(be.run_decode_step(&g, &params, &mut session, &too_long).is_err());
        assert!(be
            .run_decode_step(&g, &params, &mut session, &[cfg.vocab as i32])
            .is_err());
        assert!(be.run_decode_step(&g, &params, &mut session, &[]).is_err());
        // A valid prefill still works after the failed attempts (the
        // overflow/range checks fire before any cache mutation).
        session.reset();
        assert!(be.run_decode_step(&g, &params, &mut session, &[0, 1, 2]).is_ok());
        assert_eq!(session.len(), 3);
    }

    #[test]
    fn greedy_sampling_is_argmax_and_ignores_rng() {
        let logits = [0.1f32, 2.0, -1.0, 2.0];
        let cfg = SamplingCfg::greedy();
        let mut rng = cfg.rng();
        let before = rng.clone().next_u64();
        assert_eq!(sample_token(&logits, &cfg, &mut rng), 1);
        assert_eq!(rng.next_u64(), before, "greedy must not consume rng draws");
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = [0.0f32, 5.0, 4.0, -3.0, 1.0];
        let cfg = SamplingCfg {
            temperature: 1.0,
            top_k: 2,
            seed: 9,
        };
        let mut rng = cfg.rng();
        for _ in 0..64 {
            let t = sample_token(&logits, &cfg, &mut rng);
            assert!(t == 1 || t == 2, "sampled outside top-2: {t}");
        }
    }

    #[test]
    fn fixed_seed_reproduces_the_stream() {
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, 4);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        let be = NativeBackend::new();
        let s = SamplingCfg {
            temperature: 0.9,
            top_k: 16,
            seed: 77,
        };
        let a = generate(&be, &g, &params, &[1, 2, 3], 6, &s, |_, _| {}).unwrap();
        let b = generate(&be, &g, &params, &[1, 2, 3], 6, &s, |_, _| {}).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.prefill_tokens, 3);
        assert_eq!(a.positions_used, 3 + 6 - 1); // final token is never appended
    }

    #[test]
    fn generate_batched_matches_solo_streams() {
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, 6);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        let be = NativeBackend::new();
        // Staggered prompt lengths: the third stream exhausts its positional
        // capacity mid-run and leaves the batch while the others keep going.
        let prompts = vec![vec![1, 2, 3], vec![4, 5], vec![6i32; 7]];
        let cfgs = vec![
            SamplingCfg::greedy(),
            SamplingCfg { temperature: 0.9, top_k: 8, seed: 3 },
            SamplingCfg { temperature: 0.7, top_k: 0, seed: 4 },
        ];
        let batched = generate_batched(&be, &g, &params, &prompts, 5, &cfgs).unwrap();
        for ((prompt, s), out) in prompts.iter().zip(&cfgs).zip(&batched) {
            let solo = generate(&be, &g, &params, prompt, 5, s, |_, _| {}).unwrap();
            assert_eq!(out.tokens, solo.tokens, "batched stream must equal its solo replay");
            assert_eq!(out.positions_used, solo.positions_used);
            assert_eq!(out.prefill_tokens, prompt.len());
        }
        assert_eq!(batched[0].tokens.len(), 5);
        assert_eq!(batched[2].tokens.len(), 4, "capacity-bound stream leaves early");
    }

    #[test]
    fn batched_step_validates_before_mutating() {
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, 8);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        let be = NativeBackend::new();
        let mut a = DecodeSession::new(&g, &params).unwrap();
        let mut b = DecodeSession::new(&g, &params).unwrap();
        be.run_decode_step(&g, &params, &mut a, &[1, 2]).unwrap();
        be.run_decode_step(&g, &params, &mut b, &[3]).unwrap();
        // One out-of-vocab token rejects the whole batch, leaving both
        // sessions untouched.
        {
            let mut sessions = vec![&mut a, &mut b];
            assert!(native_decode_step_batched(&params, &mut sessions, &[0, cfg.vocab as i32])
                .is_err());
        }
        assert_eq!(a.len(), 2);
        assert_eq!(b.len(), 1);
        // An un-prefilled session is refused too.
        let mut fresh = DecodeSession::new(&g, &params).unwrap();
        {
            let mut sessions = vec![&mut a, &mut fresh];
            assert!(native_decode_step_batched(&params, &mut sessions, &[0, 0]).is_err());
        }
        assert_eq!(a.len(), 2);
        // The same batch with valid tokens then advances both sessions.
        let mut sessions = vec![&mut a, &mut b];
        let out = native_decode_step_batched(&params, &mut sessions, &[0, 1]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(a.len(), 3);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn multi_row_step_matches_solo_rows_bitwise() {
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, 9);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        let be = NativeBackend::new();
        let prompt = [1i32, 2, 3];
        let chunk = [5i32, 7, 11];

        let mut multi = DecodeSession::new(&g, &params).unwrap();
        be.run_decode_step(&g, &params, &mut multi, &prompt).unwrap();
        let rows = native_decode_step_multi(&params, &mut multi, &chunk).unwrap();
        assert_eq!(rows.shape, vec![chunk.len(), cfg.vocab]);

        let mut solo = DecodeSession::new(&g, &params).unwrap();
        be.run_decode_step(&g, &params, &mut solo, &prompt).unwrap();
        for (i, t) in chunk.iter().enumerate() {
            let l = be.run_decode_step(&g, &params, &mut solo, &[*t]).unwrap();
            let want = l.as_f32().unwrap();
            let got = &rows.as_f32().unwrap()[i * cfg.vocab..(i + 1) * cfg.vocab];
            assert_eq!(got, want, "row {i}: multi-row verify logits must be bit-identical");
        }
        assert_eq!(multi.len(), solo.len());
    }

    #[test]
    fn truncate_restores_exact_prefix_cache() {
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, 10);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        let be = NativeBackend::new();

        let mut s = DecodeSession::new(&g, &params).unwrap();
        be.run_decode_step(&g, &params, &mut s, &[1, 2, 3, 4]).unwrap();
        be.run_decode_step(&g, &params, &mut s, &[5, 6, 7]).unwrap();
        s.truncate(4);
        assert_eq!(s.len(), 4);

        let mut fresh = DecodeSession::new(&g, &params).unwrap();
        be.run_decode_step(&g, &params, &mut fresh, &[1, 2, 3, 4]).unwrap();
        for l in 0..s.num_layers() {
            let (k, v) = s.layer_kv(l).unwrap();
            let (fk, fv) = fresh.layer_kv(l).unwrap();
            assert_eq!(k, fk, "layer {l}: rolled-back keys differ from fresh prefix");
            assert_eq!(v, fv, "layer {l}: rolled-back values differ from fresh prefix");
        }
        // Truncating to the current or a larger length is a no-op.
        s.truncate(4);
        s.truncate(100);
        assert_eq!(s.len(), 4);
        // Post-rollback decode continues identically to the fresh session.
        let a = be.run_decode_step(&g, &params, &mut s, &[9]).unwrap();
        let b = be.run_decode_step(&g, &params, &mut fresh, &[9]).unwrap();
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
    }

    #[test]
    fn generate_yields_clean_empty_outcomes_on_degenerate_input() {
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, 11);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        let be = NativeBackend::new();
        let mut fired = 0;
        let empty_prompt =
            generate(&be, &g, &params, &[], 4, &SamplingCfg::greedy(), |_, _| fired += 1).unwrap();
        assert!(empty_prompt.tokens.is_empty());
        assert_eq!(empty_prompt.prefill_tokens, 0);
        assert_eq!(empty_prompt.positions_used, 0);
        let zero_new =
            generate(&be, &g, &params, &[1, 2], 0, &SamplingCfg::greedy(), |_, _| fired += 1)
                .unwrap();
        assert!(zero_new.tokens.is_empty());
        assert_eq!(zero_new.positions_used, 0);
        assert_eq!(fired, 0, "degenerate generations must not emit tokens");
    }

    #[test]
    fn generate_batched_skips_degenerate_streams_cleanly() {
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, 12);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        let be = NativeBackend::new();
        // An empty-prompt stream rides along with two real ones.
        let prompts = vec![vec![1, 2, 3], vec![], vec![4, 5]];
        let cfgs = vec![SamplingCfg::greedy(); 3];
        let outs = generate_batched(&be, &g, &params, &prompts, 4, &cfgs).unwrap();
        assert_eq!(outs.len(), 3);
        assert!(outs[1].tokens.is_empty());
        assert_eq!(outs[1].positions_used, 0);
        for i in [0usize, 2] {
            let solo =
                generate(&be, &g, &params, &prompts[i], 4, &cfgs[i], |_, _| {}).unwrap();
            assert_eq!(outs[i].tokens, solo.tokens, "stream {i} diverged from solo");
            assert_eq!(outs[i].tokens.len(), 4);
        }
        // max_new == 0 empties every stream; an all-empty batch is fine too.
        let outs = generate_batched(&be, &g, &params, &prompts, 0, &cfgs).unwrap();
        assert!(outs.iter().all(|o| o.tokens.is_empty() && o.positions_used == 0));
        let outs = generate_batched(&be, &g, &params, &[], 4, &[]).unwrap();
        assert!(outs.is_empty());
    }

    #[test]
    fn generate_stops_at_positional_capacity() {
        let cfg = lm_cfg();
        let params = init_text_params(&cfg, 5);
        let g = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
        let be = NativeBackend::new();
        let prompt = vec![0i32; cfg.seq - 2];
        let mut seen = Vec::new();
        let out = generate(&be, &g, &params, &prompt, 50, &SamplingCfg::greedy(), |i, t| {
            seen.push((i, t));
        })
        .unwrap();
        // seq-2 prompt positions leave room to append 2 more: 3 sampled
        // tokens total (the last one is sampled without being appended).
        assert_eq!(out.tokens.len(), 3);
        assert_eq!(out.positions_used, cfg.seq);
        assert_eq!(seen.len(), out.tokens.len());
        assert_eq!(seen.last().unwrap().1, *out.tokens.last().unwrap());
    }
}
