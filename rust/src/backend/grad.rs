//! Native training: backward pass + Adam for the pure-Rust interpreter.
//!
//! The PJRT path trains through fused AOT `train_step` graphs (fwd + bwd +
//! Adam lowered by `python/compile/model.py::make_train_step`); this module
//! is the artifact-free equivalent. It re-runs the [`super::native`] forward
//! pass while recording a tape of intermediates, then walks the tape
//! backwards: softmax cross-entropy → head → final LayerNorm → pre-LN
//! transformer blocks (attention + GELU FFN, dense or LED) → embedding
//! scatter — or the im2col Conv2d/CED path for the image model — and applies
//! a pure-Rust Adam step with the same hyperparameters and bias-correction
//! formula as the AOT graphs.
//!
//! Every gradient GEMM routes through the blocked, multithreaded
//! [`matmul_into`] (transposes are materialized explicitly; `A^T·B` and
//! `A·B^T` never need a second kernel), so backward cost scales with the
//! same dense-vs-LED ratio Figure 2 prices: a factorized layer's backward is
//! four skinny GEMMs through the rank bottleneck instead of two wide ones.
//!
//! Numerics are deterministic: `matmul_into` accumulates per output element
//! in a fixed k-order regardless of thread count, and every reduction here
//! is a fixed-order sequential sum, so losses reproduce bit-for-bit across
//! runs and machines (`tests/golden_native_train.rs` pins them against an
//! independent numpy derivation).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail};

use crate::factorize::tt::{tt_core_grads, tt_materialize, TtCoreView, TT_MAX_MODES};
use crate::linalg::matrix::matmul_into;
use crate::linalg::workspace::{with_thread_ws, Workspace};
use crate::runtime::GraphSpec;
use crate::tensor::{Dtype, ParamStore, Tensor};
use crate::Result;

use super::native::{
    apply_linear, conv_kernel, embed, gelu, heads_for, im2col, layernorm, num_blocks, pname, relu,
    softmax_rows,
};

/// Adam hyperparameters — defaults mirror `AdamConfig` in
/// `python/compile/model.py` (the values baked into the AOT train graphs).
#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    /// Learning rate.
    pub lr: f32,
    /// First-moment decay β₁.
    pub b1: f32,
    /// Second-moment decay β₂.
    pub b2: f32,
    /// Denominator stabilizer ε.
    pub eps: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        Self {
            lr: 1e-3,
            b1: 0.9,
            b2: 0.999,
            eps: 1e-8,
        }
    }
}

/// Accumulated parameter gradients, keyed by the checkpoint names
/// (`block0/attn/q/w`, `embed/table`, ...). Flat `f32` buffers in the
/// tensor's row-major layout.
#[derive(Clone, Debug, Default)]
pub struct Grads {
    map: BTreeMap<String, Vec<f32>>,
}

impl Grads {
    /// Gradient buffer for a parameter name.
    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.map.get(name).map(Vec::as_slice)
    }

    /// Names of all parameters with accumulated gradients.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(String::as_str)
    }

    /// Add `g` into the gradient for `name` (insert if absent).
    fn acc(&mut self, name: String, g: Vec<f32>) {
        match self.map.get_mut(&name) {
            Some(cur) => {
                debug_assert_eq!(cur.len(), g.len(), "gradient size for {name}");
                for (c, v) in cur.iter_mut().zip(&g) {
                    *c += v;
                }
            }
            None => {
                self.map.insert(name, g);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Small dense helpers (all GEMMs through matmul_into)
// ---------------------------------------------------------------------------

/// GEMM into a fresh buffer — used when the product is *kept* (gradient
/// accumulators handed to [`Grads`], tape entries).
fn mm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_into(m, k, n, a, b, &mut out);
    out
}

/// GEMM into a workspace buffer — used for scratch products the caller
/// `give`s back, so steady-state training reuses the same allocations.
fn mm_ws(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], ws: &mut Workspace) -> Vec<f32> {
    let mut out = ws.take_zeroed(m * n);
    matmul_into(m, k, n, a, b, &mut out);
    out
}

fn transpose_into(rows: usize, cols: usize, x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * cols);
    debug_assert_eq!(out.len(), rows * cols);
    for i in 0..rows {
        for j in 0..cols {
            out[j * rows + i] = x[i * cols + j];
        }
    }
}

#[cfg(test)]
fn transpose(rows: usize, cols: usize, x: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; rows * cols];
    transpose_into(rows, cols, x, &mut out);
    out
}

/// Transpose into a workspace buffer (caller `give`s it back).
fn transpose_ws(rows: usize, cols: usize, x: &[f32], ws: &mut Workspace) -> Vec<f32> {
    let mut out = ws.take_zeroed(rows * cols);
    transpose_into(rows, cols, x, &mut out);
    out
}

fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

// ---------------------------------------------------------------------------
// Per-op backward passes
// ---------------------------------------------------------------------------

/// Backward through [`apply_linear`]: accumulates the weight/bias gradients
/// under `prefix` into `grads` and returns `dx(rows, k)`. `x` is the layer's
/// forward input, `dy(rows, n)` the gradient at its output. Dispatches dense
/// `w` vs LED/CED `a·b` vs TT `tt0..ttK` exactly like the forward (4-D conv
/// factors operate on their collapsed 2-D views, so the same code covers
/// CED; TT cores get per-core gradients via
/// [`crate::factorize::tt::tt_core_grads`]).
pub fn linear_bwd(
    params: &ParamStore,
    prefix: &str,
    rows: usize,
    k: usize,
    x: &[f32],
    dy: &[f32],
    grads: &mut Grads,
) -> Result<Vec<f32>> {
    let mut ws = Workspace::new();
    linear_bwd_ws(params, prefix, rows, k, x, dy, grads, &mut ws)
}

/// [`linear_bwd`] with the transpose/bottleneck scratch drawn from `ws`
/// (the form the training interpreter calls in its hot loop).
#[allow(clippy::too_many_arguments)]
fn linear_bwd_ws(
    params: &ParamStore,
    prefix: &str,
    rows: usize,
    k: usize,
    x: &[f32],
    dy: &[f32],
    grads: &mut Grads,
    ws: &mut Workspace,
) -> Result<Vec<f32>> {
    debug_assert_eq!(x.len(), rows * k);
    let n;
    let dx;
    if let Some(w) = params.get(&pname(prefix, "w")) {
        let (wk, wn, wd) = w.as_matrix_2d()?;
        if wk != k {
            bail!("{prefix}: input dim {k} does not match weight {wk}x{wn}");
        }
        n = wn;
        if dy.len() != rows * n {
            bail!("{prefix}: dy len {} != rows {rows} x n {n}", dy.len());
        }
        // dW(k, n) = x^T(k, rows) @ dy(rows, n)
        let xt = transpose_ws(rows, k, x, ws);
        grads.acc(pname(prefix, "w"), mm(k, rows, n, &xt, dy));
        ws.give(xt);
        // dx(rows, k) = dy(rows, n) @ W^T(n, k)
        let wt = transpose_ws(k, n, wd, ws);
        dx = mm(rows, n, k, dy, &wt);
        ws.give(wt);
    } else if let (Some(a), Some(b)) =
        (params.get(&pname(prefix, "a")), params.get(&pname(prefix, "b")))
    {
        let (ak, r, ad) = a.as_matrix_2d()?;
        let (br, bn, bd) = b.as_matrix_2d()?;
        if ak != k || br != r {
            bail!("{prefix}: LED factor shapes {ak}x{r} / {br}x{bn} do not chain from dim {k}");
        }
        n = bn;
        if dy.len() != rows * n {
            bail!("{prefix}: dy len {} != rows {rows} x n {n}", dy.len());
        }
        // Recompute the rank bottleneck h = x·a (cheaper than taping it).
        let h = mm_ws(rows, k, r, x, ad, ws);
        // dB(r, n) = h^T @ dy
        let ht = transpose_ws(rows, r, &h, ws);
        grads.acc(pname(prefix, "b"), mm(r, rows, n, &ht, dy));
        ws.give(ht);
        // dh(rows, r) = dy @ B^T
        let bt = transpose_ws(r, n, bd, ws);
        let dh = mm_ws(rows, n, r, dy, &bt, ws);
        ws.give(bt);
        // dA(k, r) = x^T @ dh
        let xt = transpose_ws(rows, k, x, ws);
        grads.acc(pname(prefix, "a"), mm(k, rows, r, &xt, &dh));
        ws.give(xt);
        // dx(rows, k) = dh @ A^T
        let at = transpose_ws(k, r, ad, ws);
        dx = mm(rows, r, k, &dh, &at);
        ws.give(at);
        ws.give(dh);
        ws.give(h);
    } else if params.get(&pname(prefix, "tt0")).is_some() {
        // TT core chain: gather the views, materialize W once, push the
        // dense weight gradient through the per-core environment GEMMs.
        let mut views = [TtCoreView::empty(); TT_MAX_MODES];
        let mut nc = 0;
        while nc < TT_MAX_MODES {
            let Some(t) = params.get(&pname(prefix, &format!("tt{nc}"))) else {
                break;
            };
            views[nc] = TtCoreView::of_tensor(t)?;
            nc += 1;
        }
        let views = &views[..nc];
        let (wm, wn, wd) =
            tt_materialize(views).map_err(|e| anyhow!("{prefix}: {e}"))?;
        if wm != k {
            bail!("{prefix}: input dim {k} does not match TT chain {wm}x{wn}");
        }
        n = wn;
        if dy.len() != rows * n {
            bail!("{prefix}: dy len {} != rows {rows} x n {n}", dy.len());
        }
        // dW(k, n) = x^T(k, rows) @ dy(rows, n), then split per core.
        let xt = transpose_ws(rows, k, x, ws);
        let dw = mm_ws(k, rows, n, &xt, dy, ws);
        ws.give(xt);
        for (idx, gk) in tt_core_grads(views, &dw)?.into_iter().enumerate() {
            grads.acc(pname(prefix, &format!("tt{idx}")), gk);
        }
        ws.give(dw);
        // dx(rows, k) = dy(rows, n) @ W^T(n, k)
        let wt = transpose_ws(k, n, &wd, ws);
        dx = mm(rows, n, k, dy, &wt);
        ws.give(wt);
    } else {
        bail!("no linear weights (w, a/b, or tt0..) under group {prefix:?}");
    }
    if let Some(bias) = params.get(&pname(prefix, "bias")) {
        if bias.as_f32()?.len() != n {
            bail!("{prefix}: bias len != output dim {n}");
        }
        let mut db = vec![0.0f32; n];
        for row in dy.chunks_exact(n) {
            add_into(&mut db, row);
        }
        grads.acc(pname(prefix, "bias"), db);
    }
    Ok(dx)
}

const LN_EPS: f32 = 1e-5;

/// Backward through the forward interpreter's LayerNorm: `x_pre` is the
/// *pre-normalization* input (stats are recomputed — cheaper than taping
/// mean/var per row). Accumulates gain/bias gradients, returns dx.
pub fn layernorm_bwd(
    params: &ParamStore,
    prefix: &str,
    d: usize,
    x_pre: &[f32],
    dy: &[f32],
    grads: &mut Grads,
) -> Result<Vec<f32>> {
    let g = params
        .get(&pname(prefix, "g"))
        .ok_or_else(|| anyhow!("missing layernorm gain {prefix:?}"))?
        .as_f32()?;
    if g.len() != d {
        bail!("{prefix}: layernorm dim {} != {d}", g.len());
    }
    debug_assert_eq!(x_pre.len(), dy.len());
    let mut dx = vec![0.0f32; x_pre.len()];
    let mut dgain = vec![0.0f32; d];
    let mut dbias = vec![0.0f32; d];
    let inv_d = 1.0 / d as f32;
    for (row_i, (xrow, dyrow)) in x_pre.chunks_exact(d).zip(dy.chunks_exact(d)).enumerate() {
        // Stats recomputed with the same div-by-d formula as the forward.
        let mean = xrow.iter().sum::<f32>() / d as f32;
        let var = xrow.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        // xhat_j = (x_j - mean) * inv;  y_j = xhat_j * g_j + bias_j
        let mut m1 = 0.0f32; // mean_j(dy_j * g_j)
        let mut m2 = 0.0f32; // mean_j(dy_j * g_j * xhat_j)
        for j in 0..d {
            let xhat = (xrow[j] - mean) * inv;
            let dxhat = dyrow[j] * g[j];
            dgain[j] += dyrow[j] * xhat;
            dbias[j] += dyrow[j];
            m1 += dxhat;
            m2 += dxhat * xhat;
        }
        m1 *= inv_d;
        m2 *= inv_d;
        let drow = &mut dx[row_i * d..(row_i + 1) * d];
        for j in 0..d {
            let xhat = (xrow[j] - mean) * inv;
            drow[j] = (dyrow[j] * g[j] - m1 - xhat * m2) * inv;
        }
    }
    grads.acc(pname(prefix, "g"), dgain);
    grads.acc(pname(prefix, "bias"), dbias);
    Ok(dx)
}

/// Derivative of the tanh-approximated GELU in [`gelu`], evaluated at the
/// pre-activation `h_pre`.
fn gelu_bwd(h_pre: &[f32], dy: &[f32]) -> Vec<f32> {
    const C: f32 = 0.797_884_6; // sqrt(2/pi), same constant as the forward
    const A: f32 = 0.044715;
    h_pre
        .iter()
        .zip(dy)
        .map(|(&x, &dv)| {
            let u = C * (x + A * x * x * x);
            let t = u.tanh();
            let du = C * (1.0 + 3.0 * A * x * x);
            dv * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du)
        })
        .collect()
}

fn relu_bwd(pre: &[f32], dy: &[f32]) -> Vec<f32> {
    pre.iter().zip(dy).map(|(&p, &d)| if p > 0.0 { d } else { 0.0 }).collect()
}

/// Mean softmax cross-entropy over `rows` rows of `width` logits; `labels`
/// are class ids. Returns `(loss, dlogits)` with the 1/rows factor already
/// folded into the gradient — the exact loss the AOT `softmax_xent` lowers.
pub fn softmax_xent(
    logits: &[f32],
    labels: &[i32],
    rows: usize,
    width: usize,
) -> Result<(f32, Vec<f32>)> {
    debug_assert_eq!(logits.len(), rows * width);
    if labels.len() != rows {
        bail!("softmax_xent: {} labels for {rows} rows", labels.len());
    }
    let inv_rows = 1.0 / rows as f32;
    let mut dlogits = vec![0.0f32; rows * width];
    let mut total = 0.0f32;
    for (i, row) in logits.chunks_exact(width).enumerate() {
        let gold = labels[i];
        if gold < 0 || gold as usize >= width {
            bail!("label {gold} out of range (width {width})");
        }
        let mut max = f32::NEG_INFINITY;
        for &v in row {
            if v > max {
                max = v;
            }
        }
        let mut sum = 0.0f32;
        let drow = &mut dlogits[i * width..(i + 1) * width];
        for (d, &v) in drow.iter_mut().zip(row) {
            *d = (v - max).exp(); // stash exp(v - max), normalized below
            sum += *d;
        }
        total += max + sum.ln() - row[gold as usize];
        let inv = 1.0 / sum;
        for (j, d) in drow.iter_mut().enumerate() {
            let p = *d * inv;
            *d = (p - if j == gold as usize { 1.0 } else { 0.0 }) * inv_rows;
        }
    }
    Ok((total * inv_rows, dlogits))
}

// ---------------------------------------------------------------------------
// Transformer forward-with-tape + backward
// ---------------------------------------------------------------------------

struct AttnTape {
    /// Post-projection q/k/v, (rows, d) each.
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Softmax attention weights, (b·heads, s, s).
    probs: Vec<f32>,
    /// Pre-o-projection context, (rows, d).
    ctx: Vec<f32>,
}

/// Multi-head attention forward, mirroring `native::attention` op-for-op but
/// recording the tape backward needs.
#[allow(clippy::too_many_arguments)]
fn attention_fwd(
    params: &ParamStore,
    prefix: &str,
    b: usize,
    s: usize,
    d: usize,
    heads: usize,
    causal: bool,
    x: &[f32],
    ws: &mut Workspace,
) -> Result<(AttnTape, Vec<f32>)> {
    if heads == 0 || d % heads != 0 {
        bail!("{prefix}: d={d} not divisible by heads={heads}");
    }
    let dk = d / heads;
    let rows = b * s;
    let (dq, q) = apply_linear(params, &pname(prefix, "q"), rows, d, x)?;
    let (dkk, kmat) = apply_linear(params, &pname(prefix, "k"), rows, d, x)?;
    let (dv, v) = apply_linear(params, &pname(prefix, "v"), rows, d, x)?;
    if dq != d || dkk != d || dv != d {
        bail!("{prefix}: projection output dims {dq}/{dkk}/{dv} != d {d}");
    }
    let scale = 1.0 / (dk as f32).sqrt();
    let mut ctx = vec![0.0f32; rows * d];
    let mut probs = vec![0.0f32; b * heads * s * s];
    let mut qh = ws.take_zeroed(s * dk);
    let mut kt = ws.take_zeroed(dk * s);
    let mut vh = ws.take_zeroed(s * dk);
    let mut scores = ws.take_zeroed(s * s);
    let mut oh = ws.take_zeroed(s * dk);
    for bi in 0..b {
        for h in 0..heads {
            for si in 0..s {
                let src = (bi * s + si) * d + h * dk;
                qh[si * dk..(si + 1) * dk].copy_from_slice(&q[src..src + dk]);
                vh[si * dk..(si + 1) * dk].copy_from_slice(&v[src..src + dk]);
                for ki in 0..dk {
                    kt[ki * s + si] = kmat[src + ki];
                }
            }
            scores.fill(0.0);
            matmul_into(s, dk, s, &qh, &kt, &mut scores);
            for i in 0..s {
                let row = &mut scores[i * s..(i + 1) * s];
                for v in row.iter_mut() {
                    *v *= scale;
                }
                if causal {
                    for v in row[i + 1..].iter_mut() {
                        *v = -1e9;
                    }
                }
            }
            softmax_rows(&mut scores, s);
            probs[(bi * heads + h) * s * s..(bi * heads + h + 1) * s * s]
                .copy_from_slice(&scores);
            oh.fill(0.0);
            matmul_into(s, s, dk, &scores, &vh, &mut oh);
            for si in 0..s {
                let dst = (bi * s + si) * d + h * dk;
                ctx[dst..dst + dk].copy_from_slice(&oh[si * dk..(si + 1) * dk]);
            }
        }
    }
    let (do_, out) = apply_linear(params, &pname(prefix, "o"), rows, d, &ctx)?;
    if do_ != d {
        bail!("{prefix}: o-projection output dim {do_} != d {d}");
    }
    ws.give(qh);
    ws.give(kt);
    ws.give(vh);
    ws.give(scores);
    ws.give(oh);
    Ok((
        AttnTape {
            q,
            k: kmat,
            v,
            probs,
            ctx,
        },
        out,
    ))
}

/// Attention backward: `x` is the attention input (the ln1 output), `dout`
/// the gradient at the attention output. Returns dx.
#[allow(clippy::too_many_arguments)]
fn attention_bwd(
    params: &ParamStore,
    prefix: &str,
    tape: &AttnTape,
    b: usize,
    s: usize,
    d: usize,
    heads: usize,
    x: &[f32],
    dout: &[f32],
    grads: &mut Grads,
    ws: &mut Workspace,
) -> Result<Vec<f32>> {
    let dk = d / heads;
    let rows = b * s;
    let scale = 1.0 / (dk as f32).sqrt();
    let dctx = linear_bwd_ws(params, &pname(prefix, "o"), rows, d, &tape.ctx, dout, grads, ws)?;
    let mut dq = ws.take_zeroed(rows * d);
    let mut dkm = ws.take_zeroed(rows * d);
    let mut dv = ws.take_zeroed(rows * d);
    let mut qh = ws.take_zeroed(s * dk);
    let mut kh = ws.take_zeroed(s * dk);
    let mut vh = ws.take_zeroed(s * dk);
    let mut dch = ws.take_zeroed(s * dk);
    let mut dscores = ws.take_zeroed(s * s);
    for bi in 0..b {
        for h in 0..heads {
            for si in 0..s {
                let src = (bi * s + si) * d + h * dk;
                qh[si * dk..(si + 1) * dk].copy_from_slice(&tape.q[src..src + dk]);
                kh[si * dk..(si + 1) * dk].copy_from_slice(&tape.k[src..src + dk]);
                vh[si * dk..(si + 1) * dk].copy_from_slice(&tape.v[src..src + dk]);
                dch[si * dk..(si + 1) * dk].copy_from_slice(&dctx[src..src + dk]);
            }
            let ph = &tape.probs[(bi * heads + h) * s * s..(bi * heads + h + 1) * s * s];
            // dprobs(s, s) = dctx_h @ v_h^T
            let vt = transpose_ws(s, dk, &vh, ws);
            let dprobs = mm_ws(s, dk, s, &dch, &vt, ws);
            // dv_h(s, dk) = probs^T @ dctx_h
            let pt = transpose_ws(s, s, ph, ws);
            let dvh = mm_ws(s, s, dk, &pt, &dch, ws);
            // Softmax backward per row; the causal mask needs no special
            // handling — masked probabilities are exactly 0 (exp of a
            // -1e9-shifted logit underflows), so their dscores vanish.
            for i in 0..s {
                let prow = &ph[i * s..(i + 1) * s];
                let dprow = &dprobs[i * s..(i + 1) * s];
                let mut dot = 0.0f32;
                for (p, dp) in prow.iter().zip(dprow) {
                    dot += p * dp;
                }
                let drow = &mut dscores[i * s..(i + 1) * s];
                for j in 0..s {
                    drow[j] = prow[j] * (dprow[j] - dot) * scale;
                }
            }
            // dq_h = dscores @ k_h;  dk_h = dscores^T @ q_h
            let dqh = mm_ws(s, s, dk, &dscores, &kh, ws);
            let dst_t = transpose_ws(s, s, &dscores, ws);
            let dkh = mm_ws(s, s, dk, &dst_t, &qh, ws);
            for si in 0..s {
                let dst = (bi * s + si) * d + h * dk;
                dq[dst..dst + dk].copy_from_slice(&dqh[si * dk..(si + 1) * dk]);
                dkm[dst..dst + dk].copy_from_slice(&dkh[si * dk..(si + 1) * dk]);
                dv[dst..dst + dk].copy_from_slice(&dvh[si * dk..(si + 1) * dk]);
            }
            ws.give(vt);
            ws.give(dprobs);
            ws.give(pt);
            ws.give(dvh);
            ws.give(dqh);
            ws.give(dst_t);
            ws.give(dkh);
        }
    }
    let mut dx = linear_bwd_ws(params, &pname(prefix, "q"), rows, d, x, &dq, grads, ws)?;
    let dxk = linear_bwd_ws(params, &pname(prefix, "k"), rows, d, x, &dkm, grads, ws)?;
    add_into(&mut dx, &dxk);
    let dxv = linear_bwd_ws(params, &pname(prefix, "v"), rows, d, x, &dv, grads, ws)?;
    add_into(&mut dx, &dxv);
    for buf in [dq, dkm, dv, qh, kh, vh, dch, dscores, dctx, dxk, dxv] {
        ws.give(buf);
    }
    Ok(dx)
}

struct BlockTape {
    /// Block input (pre-ln1) — the residual stream.
    x_in: Vec<f32>,
    /// ln1 output (attention input).
    xn1: Vec<f32>,
    attn: AttnTape,
    /// After the attention residual (pre-ln2).
    x_mid: Vec<f32>,
    /// ln2 output (fc1 input).
    xn2: Vec<f32>,
    /// fc1 output pre-GELU.
    h_pre: Vec<f32>,
    /// gelu(h_pre) — fc2 input.
    h_act: Vec<f32>,
    ff: usize,
}

#[allow(clippy::too_many_arguments)]
fn block_fwd(
    params: &ParamStore,
    prefix: &str,
    b: usize,
    s: usize,
    d: usize,
    heads: usize,
    causal: bool,
    x: &mut Vec<f32>,
    ws: &mut Workspace,
) -> Result<BlockTape> {
    let rows = b * s;
    let x_in = x.clone();
    let mut xn1 = x.clone();
    layernorm(params, &pname(prefix, "ln1"), d, &mut xn1)?;
    let (attn, attn_out) =
        attention_fwd(params, &pname(prefix, "attn"), b, s, d, heads, causal, &xn1, ws)?;
    add_into(x, &attn_out);
    let x_mid = x.clone();
    let mut xn2 = x.clone();
    layernorm(params, &pname(prefix, "ln2"), d, &mut xn2)?;
    let (ff, h_pre) = apply_linear(params, &pname(prefix, "fc1"), rows, d, &xn2)?;
    let mut h_act = h_pre.clone();
    gelu(&mut h_act);
    let (d2, y) = apply_linear(params, &pname(prefix, "fc2"), rows, ff, &h_act)?;
    if d2 != d {
        bail!("{prefix}: fc2 output dim {d2} != d {d}");
    }
    add_into(x, &y);
    Ok(BlockTape {
        x_in,
        xn1,
        attn,
        x_mid,
        xn2,
        h_pre,
        h_act,
        ff,
    })
}

#[allow(clippy::too_many_arguments)]
fn block_bwd(
    params: &ParamStore,
    prefix: &str,
    tape: &BlockTape,
    b: usize,
    s: usize,
    d: usize,
    heads: usize,
    dx_out: &[f32],
    grads: &mut Grads,
    ws: &mut Workspace,
) -> Result<Vec<f32>> {
    let rows = b * s;
    // FFN half: x_out = x_mid + fc2(gelu(fc1(ln2(x_mid))))
    let fc2 = pname(prefix, "fc2");
    let dh_act = linear_bwd_ws(params, &fc2, rows, tape.ff, &tape.h_act, dx_out, grads, ws)?;
    let dh_pre = gelu_bwd(&tape.h_pre, &dh_act);
    ws.give(dh_act);
    let fc1 = pname(prefix, "fc1");
    let dxn2 = linear_bwd_ws(params, &fc1, rows, d, &tape.xn2, &dh_pre, grads, ws)?;
    let dln2 = layernorm_bwd(params, &pname(prefix, "ln2"), d, &tape.x_mid, &dxn2, grads)?;
    ws.give(dxn2);
    let mut dmid = dx_out.to_vec(); // residual branch
    add_into(&mut dmid, &dln2);
    ws.give(dln2);
    // Attention half: x_mid = x_in + attn(ln1(x_in))
    let dxn1 = attention_bwd(
        params,
        &pname(prefix, "attn"),
        &tape.attn,
        b,
        s,
        d,
        heads,
        &tape.xn1,
        &dmid,
        grads,
        ws,
    )?;
    let dln1 = layernorm_bwd(params, &pname(prefix, "ln1"), d, &tape.x_in, &dxn1, grads)?;
    ws.give(dxn1);
    let mut dx_in = dmid;
    add_into(&mut dx_in, &dln1);
    ws.give(dln1);
    Ok(dx_in)
}

struct TrunkTape {
    d: usize,
    blocks: Vec<BlockTape>,
    /// Residual stream before the final LayerNorm.
    x_pre_lnf: Vec<f32>,
    /// Final trunk output (after ln_f).
    x_out: Vec<f32>,
}

fn trunk_fwd(
    params: &ParamStore,
    tokens: &[i32],
    b: usize,
    s: usize,
    heads: usize,
    causal: bool,
    ws: &mut Workspace,
) -> Result<TrunkTape> {
    let (d, mut x) = embed(params, tokens, b, s)?;
    let mut blocks = Vec::new();
    for i in 0..num_blocks(params)? {
        blocks.push(block_fwd(params, &format!("block{i}"), b, s, d, heads, causal, &mut x, ws)?);
    }
    let x_pre_lnf = x.clone();
    layernorm(params, "ln_f", d, &mut x)?;
    Ok(TrunkTape {
        d,
        blocks,
        x_pre_lnf,
        x_out: x,
    })
}

/// Backward through ln_f, the blocks (in reverse) and the embedding scatter.
#[allow(clippy::too_many_arguments)]
fn trunk_bwd(
    params: &ParamStore,
    tokens: &[i32],
    tape: &TrunkTape,
    b: usize,
    s: usize,
    heads: usize,
    dx_out: &[f32],
    grads: &mut Grads,
    ws: &mut Workspace,
) -> Result<()> {
    let d = tape.d;
    let mut dx = layernorm_bwd(params, "ln_f", d, &tape.x_pre_lnf, dx_out, grads)?;
    for (i, block) in tape.blocks.iter().enumerate().rev() {
        dx = block_bwd(params, &format!("block{i}"), block, b, s, d, heads, &dx, grads, ws)?;
    }
    // Embedding: x = table[token] + pos[position]; scatter-add both tables.
    let table = params.get("embed/table").ok_or_else(|| anyhow!("missing embed/table"))?;
    let pos = params.get("pos/table").ok_or_else(|| anyhow!("missing pos/table"))?;
    let vocab = table.shape[0];
    let mut dtable = vec![0.0f32; vocab * d];
    let mut dpos = vec![0.0f32; pos.shape[0] * d];
    for bi in 0..b {
        for si in 0..s {
            let t = tokens[bi * s + si] as usize;
            let row = &dx[(bi * s + si) * d..(bi * s + si + 1) * d];
            add_into(&mut dtable[t * d..(t + 1) * d], row);
            add_into(&mut dpos[si * d..(si + 1) * d], row);
        }
    }
    grads.acc("embed/table".to_string(), dtable);
    grads.acc("pos/table".to_string(), dpos);
    Ok(())
}

// ---------------------------------------------------------------------------
// Model-level loss + gradients
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_arguments)]
fn classifier_loss_grads(
    params: &ParamStore,
    tokens: &[i32],
    labels: &[i32],
    b: usize,
    s: usize,
    heads: usize,
    ws: &mut Workspace,
) -> Result<(f32, Grads)> {
    let tape = trunk_fwd(params, tokens, b, s, heads, false, ws)?;
    let d = tape.d;
    // Mean-pool over tokens (same op order as native::classifier_fwd).
    let mut pooled = vec![0.0f32; b * d];
    let inv_s = 1.0 / s as f32;
    for bi in 0..b {
        let dst = &mut pooled[bi * d..(bi + 1) * d];
        for si in 0..s {
            add_into(dst, &tape.x_out[(bi * s + si) * d..(bi * s + si + 1) * d]);
        }
        for v in dst.iter_mut() {
            *v *= inv_s;
        }
    }
    let (classes, logits) = apply_linear(params, "head", b, d, &pooled)?;
    let (loss, dlogits) = softmax_xent(&logits, labels, b, classes)?;
    let mut grads = Grads::default();
    let dpooled = linear_bwd_ws(params, "head", b, d, &pooled, &dlogits, &mut grads, ws)?;
    // Pool backward: every position receives dpooled / s.
    let mut dx = vec![0.0f32; b * s * d];
    for bi in 0..b {
        let src = &dpooled[bi * d..(bi + 1) * d];
        for si in 0..s {
            let dst = &mut dx[(bi * s + si) * d..(bi * s + si + 1) * d];
            for (dv, &sv) in dst.iter_mut().zip(src) {
                *dv = sv * inv_s;
            }
        }
    }
    trunk_bwd(params, tokens, &tape, b, s, heads, &dx, &mut grads, ws)?;
    Ok((loss, grads))
}

/// Next-token LM loss: forward on `tokens[:, :-1]`, cross-entropy against
/// `tokens[:, 1:]` — the exact `lm_loss` the AOT train graph lowers.
fn lm_loss_grads(
    params: &ParamStore,
    tokens: &[i32],
    b: usize,
    s_full: usize,
    heads: usize,
    ws: &mut Workspace,
) -> Result<(f32, Grads)> {
    if s_full < 2 {
        bail!("LM training needs seq >= 2, got {s_full}");
    }
    let s = s_full - 1;
    let mut tokens_in = Vec::with_capacity(b * s);
    let mut labels = Vec::with_capacity(b * s);
    for bi in 0..b {
        for si in 0..s {
            tokens_in.push(tokens[bi * s_full + si]);
            labels.push(tokens[bi * s_full + si + 1]);
        }
    }
    let tape = trunk_fwd(params, &tokens_in, b, s, heads, true, ws)?;
    let d = tape.d;
    let rows = b * s;
    let (vocab, logits) = apply_linear(params, "head", rows, d, &tape.x_out)?;
    let (loss, dlogits) = softmax_xent(&logits, &labels, rows, vocab)?;
    let mut grads = Grads::default();
    let dx = linear_bwd_ws(params, "head", rows, d, &tape.x_out, &dlogits, &mut grads, ws)?;
    trunk_bwd(params, &tokens_in, &tape, b, s, heads, &dx, &mut grads, ws)?;
    Ok((loss, grads))
}

// ---------------------------------------------------------------------------
// CNN forward-with-tape + backward
// ---------------------------------------------------------------------------

/// 2×2 max pool recording the flat argmax index per output element (first
/// strict max in (0,0),(0,1),(1,0),(1,1) scan order — the same tie-break as
/// `native::maxpool2`, whose outputs this reproduces exactly).
fn maxpool2_idx(
    x: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
) -> Result<(usize, usize, Vec<f32>, Vec<usize>)> {
    if h % 2 != 0 || w % 2 != 0 {
        bail!("maxpool2 needs even spatial dims, got {h}x{w}");
    }
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![0.0f32; b * oh * ow * c];
    let mut idx = vec![0usize; b * oh * ow * c];
    for bi in 0..b {
        for y in 0..oh {
            for xx in 0..ow {
                let dst = ((bi * oh + y) * ow + xx) * c;
                for (dy, dx) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                    let src = ((bi * h + 2 * y + dy) * w + 2 * xx + dx) * c;
                    for ci in 0..c {
                        let v = x[src + ci];
                        if (dy, dx) == (0, 0) || v > out[dst + ci] {
                            out[dst + ci] = v;
                            idx[dst + ci] = src + ci;
                        }
                    }
                }
            }
        }
    }
    Ok((oh, ow, out, idx))
}

/// Transpose of [`im2col`]: scatter-add patch-gradients back to pixel
/// positions (zero-padding taps are simply dropped).
fn col2im(
    dcols: &[f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
) -> Vec<f32> {
    let (ph, pw) = (kh / 2, kw / 2);
    let cols = kh * kw * c;
    let mut dx = vec![0.0f32; b * h * w * c];
    for bi in 0..b {
        for y in 0..h {
            for xx in 0..w {
                let row = ((bi * h + y) * w + xx) * cols;
                for ky in 0..kh {
                    let sy = y as isize + ky as isize - ph as isize;
                    if sy < 0 || sy >= h as isize {
                        continue;
                    }
                    for kx in 0..kw {
                        let sx = xx as isize + kx as isize - pw as isize;
                        if sx < 0 || sx >= w as isize {
                            continue;
                        }
                        let src = ((bi * h + sy as usize) * w + sx as usize) * c;
                        let dst = row + (ky * kw + kx) * c;
                        for ci in 0..c {
                            dx[src + ci] += dcols[dst + ci];
                        }
                    }
                }
            }
        }
    }
    dx
}

struct ConvTape {
    cols: Vec<f32>,
    y_pre: Vec<f32>,
    pool_idx: Vec<usize>,
    /// (h, w, cin, cout, kh, kw) at this conv's input resolution.
    dims: (usize, usize, usize, usize, usize, usize),
}

fn image_loss_grads(
    params: &ParamStore,
    x: &Tensor,
    labels: &[i32],
    ws: &mut Workspace,
) -> Result<(f32, Grads)> {
    let (b, mut h, mut w, mut c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut cur = x.as_f32()?.to_vec();
    let mut tapes: Vec<ConvTape> = Vec::new();
    for conv in ["conv1", "conv2"] {
        let (kh, kw, cin) = conv_kernel(params, conv)?;
        if cin != c {
            bail!("{conv}: input channels {c} != weight cin {cin}");
        }
        let cols = im2col(&cur, b, h, w, c, kh, kw);
        let (cout, mut y) = apply_linear(params, conv, b * h * w, kh * kw * c, &cols)?;
        let y_pre = y.clone();
        relu(&mut y);
        let (oh, ow, pooled, pool_idx) = maxpool2_idx(&y, b, h, w, cout)?;
        tapes.push(ConvTape {
            cols,
            y_pre,
            pool_idx,
            dims: (h, w, c, cout, kh, kw),
        });
        cur = pooled;
        h = oh;
        w = ow;
        c = cout;
    }
    let flat = h * w * c;
    let flat_in = cur;
    let (fc, f1_pre) = apply_linear(params, "fc1", b, flat, &flat_in)?;
    let mut f1_act = f1_pre.clone();
    relu(&mut f1_act);
    let (classes, logits) = apply_linear(params, "fc2", b, fc, &f1_act)?;
    let (loss, dlogits) = softmax_xent(&logits, labels, b, classes)?;

    let mut grads = Grads::default();
    let df1_act = linear_bwd_ws(params, "fc2", b, fc, &f1_act, &dlogits, &mut grads, ws)?;
    let df1_pre = relu_bwd(&f1_pre, &df1_act);
    let mut dcur = linear_bwd_ws(params, "fc1", b, flat, &flat_in, &df1_pre, &mut grads, ws)?;
    for (conv, tape) in ["conv1", "conv2"].into_iter().zip(&tapes).rev() {
        let (th, tw, tc, cout, kh, kw) = tape.dims;
        // Pool backward: route each pooled gradient to its argmax source.
        let mut dy_act = vec![0.0f32; b * th * tw * cout];
        for (&i, &g) in tape.pool_idx.iter().zip(&dcur) {
            dy_act[i] += g;
        }
        let dy_pre = relu_bwd(&tape.y_pre, &dy_act);
        let dcols = linear_bwd_ws(
            params,
            conv,
            b * th * tw,
            kh * kw * tc,
            &tape.cols,
            &dy_pre,
            &mut grads,
            ws,
        )?;
        dcur = col2im(&dcols, b, th, tw, tc, kh, kw);
    }
    Ok((loss, grads))
}

// ---------------------------------------------------------------------------
// Entry points: loss+grads dispatch, Adam, the fused native train step
// ---------------------------------------------------------------------------

/// Forward + backward for one batch of a `train` graph: returns the loss and
/// the parameter gradients (no optimizer update). Dispatches on the graph's
/// batch signature exactly like [`native_train_step`].
pub fn loss_and_grads(
    graph: &GraphSpec,
    params: &ParamStore,
    batch: &[Tensor],
) -> Result<(f32, Grads)> {
    with_thread_ws(|ws| loss_and_grads_ws(graph, params, batch, ws))
}

/// [`loss_and_grads`] with scratch drawn from `ws`; the training loop
/// reuses one per-thread workspace across steps so steady-state training
/// stops hitting the allocator for transposes and per-head scratch.
fn loss_and_grads_ws(
    graph: &GraphSpec,
    params: &ParamStore,
    batch: &[Tensor],
    ws: &mut Workspace,
) -> Result<(f32, Grads)> {
    if batch.len() != graph.inputs.len() {
        bail!(
            "graph {} wants {} batch tensors, got {}",
            graph.name,
            graph.inputs.len(),
            batch.len()
        );
    }
    for (t, spec) in batch.iter().zip(&graph.inputs) {
        if t.shape != spec.shape {
            bail!(
                "batch input {:?}: shape {:?} does not match graph {} spec {:?}",
                spec.name,
                t.shape,
                graph.name,
                spec.shape
            );
        }
    }
    let x = &batch[0];
    let heads = heads_for(graph);
    if x.ndim() == 4 {
        let labels = batch
            .get(1)
            .ok_or_else(|| anyhow!("image train graph {} needs labels", graph.name))?
            .as_i32()?;
        return image_loss_grads(params, x, labels, ws);
    }
    if x.ndim() != 2 {
        bail!("expected (batch, seq) tokens or (b, h, w, c) pixels, got {:?}", x.shape);
    }
    let (b, s) = (x.shape[0], x.shape[1]);
    let tokens = x.as_i32()?;
    if batch.len() == 2 {
        let labels = batch[1].as_i32()?;
        classifier_loss_grads(params, tokens, labels, b, s, heads, ws)
    } else {
        lm_loss_grads(params, tokens, b, s, heads, ws)
    }
}

/// One Adam update over the graph's declared parameter list, in place.
/// `step_no` is the 1-based step as f32 (the bias-correction input, matching
/// the AOT graphs). Parameters with no recorded gradient (e.g. unused
/// positional-table rows) update with g = 0, exactly like the fused graph.
pub fn adam_step(
    graph: &GraphSpec,
    params: &mut ParamStore,
    m: &mut ParamStore,
    v: &mut ParamStore,
    grads: &Grads,
    step_no: f32,
    cfg: &AdamConfig,
) -> Result<()> {
    let bc1 = 1.0 - cfg.b1.powf(step_no);
    let bc2 = 1.0 - cfg.b2.powf(step_no);
    for spec in &graph.params {
        let name = spec.name.as_str();
        if spec.dtype()? != Dtype::F32 {
            if grads.get(name).is_some() {
                bail!("gradient recorded for non-f32 param {name:?}");
            }
            continue;
        }
        let g = grads.get(name);
        let p = params
            .get_mut(name)
            .ok_or_else(|| anyhow!("param {name:?} missing from store"))?
            .as_f32_mut()?;
        if let Some(g) = g {
            if g.len() != p.len() {
                bail!("gradient for {name:?} has {} elements, param has {}", g.len(), p.len());
            }
        }
        let n = p.len();
        // m/v live in sibling stores ordered like the graph; look up by name.
        let mt = m
            .get_mut(name)
            .ok_or_else(|| anyhow!("optimizer state m missing {name:?}"))?
            .as_f32_mut()?;
        if mt.len() != n {
            bail!("optimizer state m for {name:?} has wrong size");
        }
        // Split borrows: v looked up after m is done mutating its store.
        let vt = v
            .get_mut(name)
            .ok_or_else(|| anyhow!("optimizer state v missing {name:?}"))?
            .as_f32_mut()?;
        if vt.len() != n {
            bail!("optimizer state v for {name:?} has wrong size");
        }
        for i in 0..n {
            let gi = g.map_or(0.0, |g| g[i]);
            mt[i] = cfg.b1 * mt[i] + (1.0 - cfg.b1) * gi;
            vt[i] = cfg.b2 * vt[i] + (1.0 - cfg.b2) * gi * gi;
            let mhat = mt[i] / bc1;
            let vhat = vt[i] / bc2;
            p[i] -= cfg.lr * mhat / (vhat.sqrt() + cfg.eps);
        }
    }
    Ok(())
}

/// The native fused train step: forward + backward + Adam, updating
/// `params`/`m`/`v` in place and returning the loss — the same contract as
/// [`crate::runtime::Engine::run_train_step`] over an AOT graph.
pub fn native_train_step(
    graph: &GraphSpec,
    params: &mut ParamStore,
    m: &mut ParamStore,
    v: &mut ParamStore,
    step_no: f32,
    batch: &[Tensor],
    cfg: &AdamConfig,
) -> Result<f32> {
    if graph.kind != "train" {
        bail!("native train step on non-train graph {}", graph.name);
    }
    let (loss, grads) = loss_and_grads(graph, params, batch)?;
    adam_step(graph, params, m, v, &grads, step_no, cfg)?;
    Ok(loss)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{init_text_params, synth_train_graph, TextModelCfg};
    use crate::util::Pcg64;

    #[test]
    fn adam_step1_bias_correction_is_signlike() {
        // At step 1: mhat = g, vhat = g^2, so the update is
        // lr * g / (|g| + eps) ≈ lr * sign(g) — pin that exactly.
        let mut params = ParamStore::new();
        params.insert("w", Tensor::from_f32(&[3], vec![1.0, -2.0, 0.5]));
        let mut m = ParamStore::new();
        m.insert("w", Tensor::zeros(&[3], Dtype::F32));
        let mut v = ParamStore::new();
        v.insert("w", Tensor::zeros(&[3], Dtype::F32));
        let mut grads = Grads::default();
        grads.acc("w".to_string(), vec![0.3, -0.7, 0.0]);
        let graph = crate::runtime::GraphSpec {
            name: "t".into(),
            file: String::new(),
            model: "text".into(),
            variant: "dense".into(),
            kind: "train".into(),
            batch: 1,
            params: vec![crate::runtime::TensorSpec {
                name: "w".into(),
                shape: vec![3],
                dtype: "f32".into(),
            }],
            inputs: vec![],
            outputs: vec![],
            ranks: Default::default(),
            n_params: 3,
            config: Default::default(),
            sha256_16: String::new(),
        };
        let cfg = AdamConfig::default();
        adam_step(&graph, &mut params, &mut m, &mut v, &grads, 1.0, &cfg).unwrap();
        let p = params.get("w").unwrap().as_f32().unwrap();
        // g > 0 => p decreases by ~lr; g < 0 => increases by ~lr; g = 0 => fixed.
        assert!((p[0] - (1.0 - cfg.lr)).abs() < 1e-6, "{}", p[0]);
        assert!((p[1] - (-2.0 + cfg.lr)).abs() < 1e-6, "{}", p[1]);
        assert_eq!(p[2], 0.5);
        // m and v hold the decayed first/second moments.
        let mv = m.get("w").unwrap().as_f32().unwrap();
        assert!((mv[0] - 0.03).abs() < 1e-7);
    }

    #[test]
    fn softmax_xent_uniform_logits() {
        let (loss, d) = softmax_xent(&[0.0, 0.0, 0.0, 0.0], &[2], 1, 4).unwrap();
        assert!((loss - (4.0f32).ln()).abs() < 1e-6);
        // dlogits = softmax - onehot = 0.25 everywhere except gold (-0.75).
        assert!((d[0] - 0.25).abs() < 1e-6);
        assert!((d[2] + 0.75).abs() < 1e-6);
        assert!(softmax_xent(&[0.0, 0.0], &[5], 1, 2).is_err());
    }

    #[test]
    fn train_step_reduces_loss_on_repeated_batch() {
        // Same batch every step: the loss must fall monotonically-ish.
        let cfg = TextModelCfg {
            vocab: 64,
            seq: 8,
            d: 16,
            heads: 2,
            layers: 1,
            ff: 32,
            classes: 3,
        };
        let mut params = init_text_params(&cfg, 9);
        let graph = synth_train_graph("text", "dense", 4, &params).unwrap();
        let mut m = ParamStore::new();
        let mut v = ParamStore::new();
        for (name, t) in params.iter() {
            m.insert(name, Tensor::zeros(&t.shape, Dtype::F32));
            v.insert(name, Tensor::zeros(&t.shape, Dtype::F32));
        }
        let mut rng = Pcg64::seeded(31);
        let toks: Vec<i32> = (0..4 * 8).map(|_| rng.below(64) as i32).collect();
        let x = Tensor::from_i32(&[4, 8], toks);
        let y = Tensor::from_i32(&[4], vec![0, 1, 2, 1]);
        let acfg = AdamConfig {
            lr: 1e-2,
            ..Default::default()
        };
        let mut losses = Vec::new();
        for step in 1..=20 {
            let batch = [x.clone(), y.clone()];
            losses.push(
                native_train_step(&graph, &mut params, &mut m, &mut v, step as f32, &batch, &acfg)
                    .unwrap(),
            );
        }
        assert!(
            losses[19] < losses[0] - 0.1,
            "no learning: first {} last {}",
            losses[0],
            losses[19]
        );
        assert!(losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn grads_accumulate() {
        let mut g = Grads::default();
        g.acc("x".into(), vec![1.0, 2.0]);
        g.acc("x".into(), vec![0.5, -1.0]);
        assert_eq!(g.get("x").unwrap(), &[1.5, 1.0]);
        assert!(g.get("y").is_none());
    }

    #[test]
    fn transpose_roundtrip() {
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let t = transpose(2, 3, &x);
        assert_eq!(t, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
        assert_eq!(transpose(3, 2, &t), x);
    }

    #[test]
    fn maxpool_idx_matches_forward_and_routes_grad() {
        let x = vec![1.0, 3.0, 2.0, 0.5];
        let (oh, ow, out, idx) = maxpool2_idx(&x, 1, 2, 2, 1).unwrap();
        assert_eq!((oh, ow), (1, 1));
        assert_eq!(out, vec![3.0]);
        assert_eq!(idx, vec![1]);
    }

    #[test]
    fn col2im_is_transpose_of_im2col() {
        // <dcols, im2col(x)> == <col2im(dcols), x> — the adjoint identity.
        let mut rng = Pcg64::seeded(77);
        let (b, h, w, c, kh, kw) = (1, 4, 3, 2, 3, 3);
        let mut x = vec![0.0f32; b * h * w * c];
        rng.fill_normal(&mut x, 1.0);
        let mut dcols = vec![0.0f32; b * h * w * kh * kw * c];
        rng.fill_normal(&mut dcols, 1.0);
        let cols = im2col(&x, b, h, w, c, kh, kw);
        let dx = col2im(&dcols, b, h, w, c, kh, kw);
        let lhs: f64 = dcols.iter().zip(&cols).map(|(a, b)| (a * b) as f64).sum();
        let rhs: f64 = dx.iter().zip(&x).map(|(a, b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3 * (1.0 + lhs.abs()), "{lhs} vs {rhs}");
    }
}
