//! Execution backends: one trait, two engines.
//!
//! The serving stack (coordinator), the training driver, the evaluation
//! harnesses and the CLI all execute graphs through the [`Backend`] trait
//! instead of talking to the PJRT [`Engine`] directly:
//!
//! * [`PjrtBackend`] — wraps [`Engine`] unchanged: AOT HLO artifacts,
//!   compiled once, executed forever. Preferred whenever artifacts exist and
//!   the PJRT runtime is available; `train` graphs run as one fused
//!   fwd+bwd+Adam executable.
//! * [`native::NativeBackend`] — a pure-Rust interpreter that walks the
//!   checkpoint's layer structure (via [`crate::model::classify`]) and
//!   executes the classifier/LM/CNN forward pass on the blocked,
//!   multithreaded GEMM in [`crate::linalg::matrix`] — and, since PR 3, the
//!   matching backward pass + Adam in [`grad`], so the full
//!   factorize→train→eval loop runs with no artifacts and no FFI — and,
//!   since PR 4, KV-cached incremental decoding in [`decode`], so the LM
//!   path generates autoregressively instead of re-scoring full windows.
//!
//! Selection is automatic in [`crate::coordinator::serve_classifier`]
//! (PJRT when artifacts resolve, native otherwise) and explicit via the CLI
//! `--backend {native,pjrt}` flag. See DESIGN.md §8–§10 for the contract.
//!
//! # Examples
//!
//! Run a forward pass hermetically: random-init a checkpoint, synthesize
//! its graph, execute on the native interpreter:
//!
//! ```
//! use greenformer::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
//! use greenformer::backend::{Backend, NativeBackend};
//! use greenformer::tensor::Tensor;
//!
//! let cfg = TextModelCfg { vocab: 64, seq: 8, d: 32, heads: 4, layers: 1, ff: 64, classes: 3 };
//! let params = init_text_params(&cfg, 7);
//! let graph = synth_fwd_graph("text", "dense", 2, &params).unwrap();
//! let x = Tensor::from_i32(&[2, 8], vec![1; 16]);
//! let out = NativeBackend::new().run_fwd(&graph, &params, &[x]).unwrap();
//! assert_eq!(out[0].shape, vec![2, 3]);
//! ```
//!
//! Generate from a causal LM with the KV cache (greedy sampling):
//!
//! ```
//! use greenformer::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
//! use greenformer::backend::{generate, NativeBackend, SamplingCfg};
//!
//! // An LM is a text model whose head width equals its vocab.
//! let cfg = TextModelCfg { vocab: 48, seq: 12, d: 24, heads: 6, layers: 1, ff: 32, classes: 48 };
//! let params = init_text_params(&cfg, 7);
//! let graph = synth_fwd_graph("lm", "dense", 1, &params).unwrap();
//! let out = generate(
//!     &NativeBackend::new(), &graph, &params,
//!     &[1, 2, 3], 4, &SamplingCfg::greedy(), |_, _| {},
//! )
//! .unwrap();
//! assert_eq!(out.tokens.len(), 4);
//! ```

pub mod decode;
pub mod grad;
pub mod native;
pub mod spec;

use crate::runtime::{Engine, GraphSpec};
use crate::tensor::{ParamStore, Tensor};
use crate::Result;

pub use decode::{
    generate, generate_batched, generate_with_session, sample_token, DecodeSession,
    GenerateOutcome, SamplingCfg,
};
pub use native::NativeBackend;
pub use spec::{
    build_draft_params, generate_speculative, SpecConfig, SpecGenerateOutcome, SpecSession,
    SpecStep,
};

/// Which engine a [`Backend`] is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust interpreter (always available).
    Native,
    /// PJRT over AOT HLO artifacts (needs `artifacts/` + the XLA runtime).
    Pjrt,
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendKind::Native => write!(f, "native"),
            BackendKind::Pjrt => write!(f, "pjrt"),
        }
    }
}

/// A graph executor. Implementations must be usable from a single thread
/// that owns them (the coordinator's dispatcher); they are not required to
/// be `Send` (the PJRT client wrapper is `Rc`-based).
pub trait Backend {
    /// Human-readable platform tag (e.g. `"cpu"` / `"native-cpu"`).
    fn platform(&self) -> String;

    /// Which engine this is.
    fn kind(&self) -> BackendKind;

    /// Whether this backend can execute `graph`. Capability query used by
    /// callers that hold a mixed graph set.
    fn supports(&self, graph: &GraphSpec) -> bool;

    /// Run a forward graph: `outputs = f(params, inputs)`.
    fn run_fwd(
        &self,
        graph: &GraphSpec,
        params: &ParamStore,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>>;

    /// Run one fused train step on a `train` graph:
    /// `(params', m', v', loss) = step(params, m, v, step_no, batch...)`,
    /// updating `params`/`m`/`v` in place and returning the loss. PJRT
    /// executes the AOT-lowered step; the native backend runs the
    /// [`grad`] interpreter. The default refuses, so purely-forward
    /// backends stay trivially implementable.
    fn run_train_step(
        &self,
        graph: &GraphSpec,
        params: &mut ParamStore,
        m: &mut ParamStore,
        v: &mut ParamStore,
        step_no: f32,
        batch: &[Tensor],
    ) -> Result<f32> {
        let _ = (graph, params, m, v, step_no, batch);
        anyhow::bail!("backend {:?} cannot execute train graphs", self.platform())
    }

    /// Advance one KV-cached decode session: append `new_tokens` (the whole
    /// prompt on the first call, one sampled token per call after that) and
    /// return the next-token logits of the last appended position as a
    /// `(vocab,)` tensor.
    ///
    /// The native backend implements this with numerics identical to
    /// [`Backend::run_fwd`] on the full prefix (see [`decode`] for the
    /// argument). The default — and therefore PJRT — refuses: the AOT fwd
    /// graphs are fixed-shape full-sequence executables with no cache
    /// inputs, so incremental decoding is a native-only capability for now.
    fn run_decode_step(
        &self,
        graph: &GraphSpec,
        params: &ParamStore,
        session: &mut DecodeSession,
        new_tokens: &[i32],
    ) -> Result<Tensor> {
        let _ = (graph, params, session, new_tokens);
        anyhow::bail!(
            "backend {:?} cannot run incremental decode (KV-cached generation is native-only; \
             AOT fwd graphs are fixed-shape full-sequence executables)",
            self.platform()
        )
    }

    /// Advance `m = sessions.len()` post-prefill decode sessions one token
    /// each: append `tokens[i]` to `sessions[i]` and return one `(vocab,)`
    /// next-token logits tensor per session, in order.
    ///
    /// This is the continuous-batching step: the native backend stacks the
    /// sessions' per-layer projections into single m-row GEMMs
    /// ([`decode::native_decode_step_batched`]), with per-session results
    /// value-identical to m solo [`Backend::run_decode_step`] calls. The
    /// default advances the sessions sequentially — semantically equivalent,
    /// so any backend that decodes at all participates in batched serving.
    /// All sessions must share `params`/`graph` (one model variant).
    fn run_decode_step_batched(
        &self,
        graph: &GraphSpec,
        params: &ParamStore,
        sessions: &mut [&mut DecodeSession],
        tokens: &[i32],
    ) -> Result<Vec<Tensor>> {
        if sessions.len() != tokens.len() {
            anyhow::bail!(
                "batched decode got {} sessions but {} tokens",
                sessions.len(),
                tokens.len()
            );
        }
        sessions
            .iter_mut()
            .zip(tokens)
            .map(|(s, t)| self.run_decode_step(graph, params, s, std::slice::from_ref(t)))
            .collect()
    }

    /// Append a chunk of `new_tokens` to one session and return the
    /// next-token logits of **every** appended position as an
    /// `(n, vocab)` tensor — row `i` is the distribution after chunk
    /// position `i`.
    ///
    /// This is the speculative-decode verify primitive: the target model
    /// scores all k drafted tokens in one stacked pass instead of k solo
    /// steps. The native backend runs it as a single chunk
    /// ([`decode::native_decode_step_multi`]); the default advances the
    /// session one token at a time and stacks the rows, which is
    /// value-identical (each solo step sees exactly the prefix the chunk
    /// row would have seen), so any backend that decodes at all can verify
    /// drafts.
    fn run_decode_step_multi(
        &self,
        graph: &GraphSpec,
        params: &ParamStore,
        session: &mut DecodeSession,
        new_tokens: &[i32],
    ) -> Result<Tensor> {
        if new_tokens.is_empty() {
            anyhow::bail!("multi-row decode step needs at least one new token");
        }
        let mut rows: Vec<f32> = Vec::new();
        let mut vocab = 0;
        for t in new_tokens {
            let logits = self.run_decode_step(graph, params, session, std::slice::from_ref(t))?;
            let row = logits.as_f32()?;
            vocab = row.len();
            rows.extend_from_slice(row);
        }
        Ok(Tensor::from_f32(&[new_tokens.len(), vocab], rows))
    }
}

/// [`Backend`] over the PJRT [`Engine`] — a thin newtype so backend
/// selection sites name the engine explicitly.
pub struct PjrtBackend {
    engine: Engine,
}

impl PjrtBackend {
    /// Load the engine over an artifacts directory.
    pub fn load(dir: impl Into<std::path::PathBuf>) -> Result<Self> {
        Ok(Self {
            engine: Engine::load(dir)?,
        })
    }

    /// Wrap an already-loaded engine.
    pub fn from_engine(engine: Engine) -> Self {
        Self { engine }
    }

    /// The wrapped PJRT engine.
    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.engine.platform()
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn supports(&self, graph: &GraphSpec) -> bool {
        self.engine.manifest().graph(&graph.name).is_ok()
    }

    fn run_fwd(
        &self,
        graph: &GraphSpec,
        params: &ParamStore,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        self.engine.run_fwd(graph, params, inputs)
    }

    fn run_train_step(
        &self,
        graph: &GraphSpec,
        params: &mut ParamStore,
        m: &mut ParamStore,
        v: &mut ParamStore,
        step_no: f32,
        batch: &[Tensor],
    ) -> Result<f32> {
        self.engine.run_train_step(graph, params, m, v, step_no, batch)
    }
}

/// The engine itself is a backend, so existing `&Engine` call sites coerce
/// straight into `&dyn Backend` APIs (eval, experiments, examples).
impl Backend for Engine {
    fn platform(&self) -> String {
        Engine::platform(self)
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Pjrt
    }

    fn supports(&self, graph: &GraphSpec) -> bool {
        self.manifest().graph(&graph.name).is_ok()
    }

    fn run_fwd(
        &self,
        graph: &GraphSpec,
        params: &ParamStore,
        inputs: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        Engine::run_fwd(self, graph, params, inputs)
    }

    fn run_train_step(
        &self,
        graph: &GraphSpec,
        params: &mut ParamStore,
        m: &mut ParamStore,
        v: &mut ParamStore,
        step_no: f32,
        batch: &[Tensor],
    ) -> Result<f32> {
        Engine::run_train_step(self, graph, params, m, v, step_no, batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_renders() {
        assert_eq!(BackendKind::Native.to_string(), "native");
        assert_eq!(BackendKind::Pjrt.to_string(), "pjrt");
    }
}
