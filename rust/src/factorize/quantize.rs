//! Post-SVD quantization of LED factors (and any remaining dense linears).
//!
//! Rank truncation compresses FLOPs; the decode path is memory-bound
//! (DESIGN.md §10), so shrinking the *bytes per weight* multiplies with the
//! rank cut — the argument of Binary Matrix Factorization
//! (arxiv 2210.13468) and StrassenNets (arxiv 1712.03942). This module is
//! the checkpoint-level pass: walk a [`ParamStore`], re-encode every 2-D
//! linear weight (`*/w` dense, `*/a` + `*/b` LED factors) at the requested
//! [`WeightPrecision`], and hand back a [`QuantStore`] side-table the
//! native interpreters consult at apply time. The f32 checkpoint itself is
//! untouched — quantization is a serving-time transform, and the training
//! path stays in f32.
//!
//! The scheme and its exactness argument live in DESIGN.md §12; the
//! bit-for-bit kernel contract is pinned by `tests/proptest_quant.rs`.

use std::collections::BTreeMap;
use std::fmt;
use std::str::FromStr;

use anyhow::bail;

use crate::linalg::gemm::Activation;
use crate::linalg::{BinaryMatrix, QuantizedMatrix};
use crate::tensor::{Dtype, ParamStore};
use crate::Result;

/// Weight storage precision for the native fwd/decode interpreters.
///
/// `F32` is the identity (no side-table). `Int8` stores per-output-channel
/// symmetric int8 with one f32 scale per channel. `Binary` keeps only the
/// sign bit per entry (bit-packed, 64 per word) plus one mean-magnitude
/// scale per channel — the BMF / XNOR-Net regime.
///
/// ```
/// use greenformer::factorize::WeightPrecision;
///
/// let p: WeightPrecision = "int8".parse().unwrap();
/// assert_eq!(p, WeightPrecision::Int8);
/// assert_eq!(WeightPrecision::default(), WeightPrecision::F32);
/// assert_eq!(format!("{}", WeightPrecision::Binary), "binary");
/// ```
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum WeightPrecision {
    /// Full f32 weights (the default; bit-identical to the seed paths).
    #[default]
    F32,
    /// Per-output-channel symmetric int8, i32 accumulation.
    Int8,
    /// ±1 sign bits + per-channel magnitude, XOR/popcount matvec.
    Binary,
}

impl fmt::Display for WeightPrecision {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            WeightPrecision::F32 => "f32",
            WeightPrecision::Int8 => "int8",
            WeightPrecision::Binary => "binary",
        })
    }
}

impl FromStr for WeightPrecision {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(WeightPrecision::F32),
            "int8" => Ok(WeightPrecision::Int8),
            "binary" => Ok(WeightPrecision::Binary),
            _ => bail!("unknown precision {s:?} (expected f32|int8|binary)"),
        }
    }
}

/// One quantized weight: int8 per-channel or bit-packed ±1.
#[derive(Clone, Debug)]
pub enum QuantTensor {
    /// Per-output-channel symmetric int8.
    Int8(QuantizedMatrix),
    /// Bit-packed ±1 signs + per-channel magnitude.
    Binary(BinaryMatrix),
}

impl QuantTensor {
    /// Input dimension of the underlying `k×n` weight.
    pub fn k(&self) -> usize {
        match self {
            QuantTensor::Int8(m) => m.k(),
            QuantTensor::Binary(m) => m.k(),
        }
    }

    /// Output dimension.
    pub fn n(&self) -> usize {
        match self {
            QuantTensor::Int8(m) => m.n(),
            QuantTensor::Binary(m) => m.n(),
        }
    }

    /// Storage footprint in bytes.
    pub fn bytes(&self) -> usize {
        match self {
            QuantTensor::Int8(m) => m.bytes(),
            QuantTensor::Binary(m) => m.bytes(),
        }
    }

    /// `out(rows,n) = act(out + x @ Ŵ + bias)` through the quantized
    /// kernel for this format (activations quantized/binarized per row
    /// into thread-local scratch — zero steady-state allocation).
    pub fn apply(
        &self,
        rows: usize,
        x: &[f32],
        bias: Option<&[f32]>,
        act: Activation,
        out: &mut [f32],
    ) {
        match self {
            QuantTensor::Int8(m) => m.apply(rows, x, bias, act, out),
            QuantTensor::Binary(m) => m.apply(rows, x, bias, act, out),
        }
    }
}

/// Side-table of quantized weights, keyed by the full parameter name
/// (`block0/attn/q/a`, `head/w`, …). Built once by
/// [`quantize_led_params`]; the interpreters fall through to the f32
/// tensor for any name not present (embeddings, layernorms, convs).
#[derive(Clone, Debug)]
pub struct QuantStore {
    precision: WeightPrecision,
    tensors: BTreeMap<String, QuantTensor>,
}

impl QuantStore {
    /// The precision every entry is stored at.
    pub fn precision(&self) -> WeightPrecision {
        self.precision
    }

    /// Quantized weight by full parameter name.
    pub fn get(&self, name: &str) -> Option<&QuantTensor> {
        self.tensors.get(name)
    }

    /// Number of quantized weights.
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    /// True when nothing was quantized (the `F32` identity store).
    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Total quantized storage in bytes.
    pub fn bytes(&self) -> usize {
        self.tensors.values().map(QuantTensor::bytes).sum()
    }
}

/// Per-weight record in a [`QuantReport`].
#[derive(Clone, Debug)]
pub struct QuantLayer {
    /// Full parameter name (`block0/fc1/a`, …).
    pub name: String,
    /// Weight rows (input dim).
    pub k: usize,
    /// Weight cols (output dim).
    pub n: usize,
    /// Largest per-channel scale.
    pub scale_max: f32,
    /// Worst-case per-entry weight error: `scale/2` for int8 (round to
    /// nearest), `2·maxabs` for binary (sign + mean magnitude).
    pub weight_err_bound: f32,
}

/// Summary returned by [`quantize_led_params`].
#[derive(Clone, Debug)]
pub struct QuantReport {
    /// Storage precision of the pass.
    pub precision: WeightPrecision,
    /// One record per quantized weight, in name order.
    pub layers: Vec<QuantLayer>,
    /// f32 bytes of the weights that were quantized.
    pub bytes_f32: usize,
    /// Bytes of their quantized encodings.
    pub bytes_quant: usize,
    /// Worst-case |Δlogit| bound from first-order interval propagation
    /// through the LM structure (None when the store is not LM-shaped or
    /// precision is `F32`). A *loose engineering envelope* — it certifies
    /// the e2e test's outer bound, it is not a tight theorem.
    pub logit_bound: Option<f64>,
}

impl QuantReport {
    /// Quantized/f32 byte ratio over the quantized weights (1.0 = no
    /// compression; ~0.25 for int8, ~0.03 for binary).
    pub fn compression(&self) -> f64 {
        self.bytes_quant as f64 / self.bytes_f32.max(1) as f64
    }
}

impl fmt::Display for QuantReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "quantize[{}]: {} weights, {} -> {} bytes ({:.1}%){}",
            self.precision,
            self.layers.len(),
            self.bytes_f32,
            self.bytes_quant,
            100.0 * self.compression(),
            self.logit_bound
                .map(|b| format!(", |Δlogit| ≤ {b:.3e}"))
                .unwrap_or_default()
        )?;
        for l in &self.layers {
            writeln!(
                f,
                "  {:<28} {:>5}x{:<5} scale_max={:.3e} w_err<={:.3e}",
                l.name, l.k, l.n, l.scale_max, l.weight_err_bound
            )?;
        }
        Ok(())
    }
}

/// Quantize every 2-D linear weight in `params` — LED `*/a` / `*/b` factors
/// and any dense `*/w` left by the Eq.-1 gate — at `precision`, leaving the
/// f32 store untouched. Embeddings, layernorm gains/biases and 4-D conv
/// factors stay f32 (they are not GEMM operands on the decode path).
///
/// Returns the [`QuantStore`] side-table plus a [`QuantReport`] with
/// per-weight scales, worst-case per-entry error bounds, byte counts, and
/// (for LM-shaped stores) a propagated worst-case logit error bound.
/// `WeightPrecision::F32` yields an empty store (the identity).
///
/// ```
/// use greenformer::factorize::{quantize_led_params, WeightPrecision};
/// use greenformer::tensor::{ParamStore, Tensor};
///
/// let mut params = ParamStore::new();
/// params.insert(
///     "fc/a",
///     Tensor::from_f32(&[4, 2], vec![0.5, -1.0, 0.25, 1.0, -0.75, 0.125, 1.0, -0.5]),
/// );
/// params.insert("fc/b", Tensor::from_f32(&[2, 3], vec![1.0, -2.0, 0.5, 0.25, 1.5, -1.0]));
///
/// let (store, report) = quantize_led_params(&params, WeightPrecision::Int8).unwrap();
/// assert_eq!(store.len(), 2);
/// assert!(store.get("fc/a").is_some() && store.get("fc/b").is_some());
/// // int8 per-entry error is at most half the largest channel scale
/// for layer in &report.layers {
///     assert_eq!(layer.weight_err_bound, layer.scale_max * 0.5);
/// }
/// assert!(report.compression() < 0.5);
/// ```
pub fn quantize_led_params(
    params: &ParamStore,
    precision: WeightPrecision,
) -> Result<(QuantStore, QuantReport)> {
    let mut tensors = BTreeMap::new();
    let mut layers = Vec::new();
    let mut bytes_f32 = 0usize;
    let mut bytes_quant = 0usize;
    if precision != WeightPrecision::F32 {
        for (name, t) in params.iter() {
            let quantizable = t.dtype() == Dtype::F32
                && t.ndim() == 2
                && (name.ends_with("/w") || name.ends_with("/a") || name.ends_with("/b"));
            if !quantizable {
                continue;
            }
            let (k, n, w) = t.as_matrix_2d()?;
            let maxabs = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let (qt, scale_max, err_bound) = match precision {
                WeightPrecision::Int8 => {
                    let qm = QuantizedMatrix::from_f32(k, n, w);
                    let smax = qm.scales().iter().fold(0.0f32, |m, &s| m.max(s));
                    (QuantTensor::Int8(qm), smax, smax * 0.5)
                }
                WeightPrecision::Binary => {
                    let bm = BinaryMatrix::from_f32(k, n, w);
                    let smax = bm.scales().iter().fold(0.0f32, |m, &s| m.max(s));
                    (QuantTensor::Binary(bm), smax, 2.0 * maxabs)
                }
                WeightPrecision::F32 => unreachable!(),
            };
            bytes_f32 += w.len() * 4;
            bytes_quant += qt.bytes();
            layers.push(QuantLayer {
                name: name.to_string(),
                k,
                n,
                scale_max,
                weight_err_bound: err_bound,
            });
            tensors.insert(name.to_string(), qt);
        }
    }
    let store = QuantStore { precision, tensors };
    let logit_bound = if precision == WeightPrecision::F32 {
        None
    } else {
        derive_logit_bound(params, precision)
    };
    let report = QuantReport {
        precision,
        layers,
        bytes_f32,
        bytes_quant,
        logit_bound,
    };
    Ok((store, report))
}

/// Magnitude/error interval: `|exact| ≤ x`, `|quantized − exact| ≤ e`
/// element-wise, both in f64.
#[derive(Clone, Copy)]
struct Iv {
    x: f64,
    e: f64,
}

fn maxabs_of(params: &ParamStore, name: &str) -> Option<f64> {
    let t = params.get(name)?;
    let v = t.as_f32().ok()?;
    Some(v.iter().fold(0.0f32, |m, &x| m.max(x.abs())) as f64)
}

/// One quantized linear `k-dim → bias`: propagate the magnitude bound and
/// add the three first-order error terms (carried input error × weight,
/// input magnitude × weight-quant step, activation-quant step × weight).
fn lin_step(iv: Iv, k: usize, wmax: f64, bias_max: f64, precision: WeightPrecision) -> Iv {
    let (ax, aw) = match precision {
        // Symmetric int8 round-to-nearest: step/2 = range/254.
        WeightPrecision::Int8 => ((iv.x + iv.e) / 254.0, wmax / 254.0),
        // Sign + mean magnitude: |v − α·sign v| ≤ |v| + α ≤ 2·range.
        WeightPrecision::Binary => (2.0 * (iv.x + iv.e), 2.0 * wmax),
        WeightPrecision::F32 => (0.0, 0.0),
    };
    let kf = k as f64;
    Iv {
        x: kf * iv.x * wmax + bias_max,
        e: kf * (iv.e * wmax + (iv.x + iv.e) * aw + ax * (wmax + aw)),
    }
}

/// A full linear group (`prefix/w` dense, or `prefix/a` + `prefix/b` LED),
/// bias exact in f32.
fn lin_group(params: &ParamStore, prefix: &str, iv: Iv, precision: WeightPrecision) -> Option<Iv> {
    let bias_max = maxabs_of(params, &format!("{prefix}/bias")).unwrap_or(0.0);
    if let Some(w) = params.get(&format!("{prefix}/w")) {
        let (k, _, _) = w.as_matrix_2d().ok()?;
        let wmax = maxabs_of(params, &format!("{prefix}/w"))?;
        Some(lin_step(iv, k, wmax, bias_max, precision))
    } else {
        let a = params.get(&format!("{prefix}/a"))?;
        let (k, _, _) = a.as_matrix_2d().ok()?;
        let b = params.get(&format!("{prefix}/b"))?;
        let (r, _, _) = b.as_matrix_2d().ok()?;
        let amax = maxabs_of(params, &format!("{prefix}/a"))?;
        let bmax = maxabs_of(params, &format!("{prefix}/b"))?;
        let mid = lin_step(iv, k, amax, 0.0, precision);
        Some(lin_step(mid, r, bmax, bias_max, precision))
    }
}

/// LayerNorm envelope: outputs lie in `±(√d·max|g| + max|bias|)` whatever
/// the input, so the carried error collapses to the output-range diameter.
fn ln_step(params: &ParamStore, prefix: &str, d: usize, had_err: bool) -> Option<Iv> {
    let gmax = maxabs_of(params, &format!("{prefix}/g"))?;
    let bmax = maxabs_of(params, &format!("{prefix}/bias")).unwrap_or(0.0);
    let sd = (d as f64).sqrt();
    Some(Iv {
        x: sd * gmax + bmax,
        e: if had_err { 2.0 * sd * gmax } else { 0.0 },
    })
}

/// Worst-case |Δlogit| for the text-LM structure under `precision`, by
/// first-order interval propagation (f64): embeddings exact, each block's
/// LayerNorm resets the branch range, attention treated as a convex
/// mixture envelope, GELU as 1.2-Lipschitz, residual adds summing both
/// magnitude and error. Deliberately loose — every inequality is an outer
/// envelope — but finite and sound, which is what the e2e bound test pins.
fn derive_logit_bound(params: &ParamStore, precision: WeightPrecision) -> Option<f64> {
    let embed = params.get("embed/table")?;
    let d = *embed.shape.last()?;
    let x0 = maxabs_of(params, "embed/table")? + maxabs_of(params, "pos/table")?;
    let mut res = Iv { x: x0, e: 0.0 };
    let mut i = 0usize;
    while params.get(&format!("block{i}/ln1/g")).is_some() {
        let pre = format!("block{i}");
        // Attention branch.
        let xn = ln_step(params, &format!("{pre}/ln1"), d, res.e > 0.0)?;
        // q/k only shape the softmax weights, which the mixture envelope
        // below absorbs; only v's range reaches the output.
        let v = lin_group(params, &format!("{pre}/attn/v"), xn, precision)?;
        // Softmax mixture: |ctx| ≤ max|v| exactly; perturbed weights can at
        // worst swap the mixture endpoints, so Δctx ≤ 2·(|v| + Δv).
        let ctx = Iv {
            x: v.x,
            e: 2.0 * v.x + 3.0 * v.e,
        };
        let o = lin_group(params, &format!("{pre}/attn/o"), ctx, precision)?;
        res = Iv {
            x: res.x + o.x,
            e: res.e + o.e,
        };
        // MLP branch.
        let xn = ln_step(params, &format!("{pre}/ln2"), d, res.e > 0.0)?;
        let h = lin_group(params, &format!("{pre}/fc1"), xn, precision)?;
        // |gelu(x)| ≤ |x|; sup |gelu'| < 1.2 for the tanh approximation.
        let h = Iv { x: h.x, e: 1.2 * h.e };
        let f = lin_group(params, &format!("{pre}/fc2"), h, precision)?;
        res = Iv {
            x: res.x + f.x,
            e: res.e + f.e,
        };
        i += 1;
    }
    if i == 0 {
        return None;
    }
    let xn = ln_step(params, "ln_f", d, res.e > 0.0)?;
    let logits = lin_group(params, "head", xn, precision)?;
    Some(logits.e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn led_store() -> ParamStore {
        let mut s = ParamStore::new();
        s.insert("fc/a", Tensor::from_f32(&[3, 2], vec![0.5, -1.0, 0.25, 1.0, -0.75, 0.125]));
        s.insert("fc/b", Tensor::from_f32(&[2, 2], vec![1.0, -2.0, 0.5, 0.25]));
        s.insert("fc/bias", Tensor::from_f32(&[2], vec![0.0, 0.1]));
        s.insert("emb/table", Tensor::from_f32(&[2, 3], vec![0.0; 6]));
        s
    }

    #[test]
    fn f32_is_identity() {
        let (store, report) = quantize_led_params(&led_store(), WeightPrecision::F32).unwrap();
        assert!(store.is_empty());
        assert!(report.layers.is_empty());
        assert_eq!(report.logit_bound, None);
    }

    #[test]
    fn int8_quantizes_factors_not_tables() {
        let (store, report) = quantize_led_params(&led_store(), WeightPrecision::Int8).unwrap();
        assert_eq!(store.len(), 2);
        assert!(store.get("fc/a").is_some());
        assert!(store.get("fc/b").is_some());
        assert!(store.get("emb/table").is_none());
        assert!(store.get("fc/bias").is_none());
        assert!(report.compression() < 0.5);
        // Not LM-shaped: no propagated bound.
        assert_eq!(report.logit_bound, None);
    }

    #[test]
    fn binary_compresses_below_int8() {
        let (s8, r8) = quantize_led_params(&led_store(), WeightPrecision::Int8).unwrap();
        let (sb, rb) = quantize_led_params(&led_store(), WeightPrecision::Binary).unwrap();
        assert_eq!(s8.len(), sb.len());
        assert!(rb.bytes_quant < r8.bytes_quant);
    }

    #[test]
    fn precision_roundtrips_through_strings() {
        for p in [WeightPrecision::F32, WeightPrecision::Int8, WeightPrecision::Binary] {
            let s = p.to_string();
            assert_eq!(s.parse::<WeightPrecision>().unwrap(), p);
        }
        assert!("fp16".parse::<WeightPrecision>().is_err());
    }
}
