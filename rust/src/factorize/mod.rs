//! The Greenformer toolkit: automatic low-rank factorization of any model.
//!
//! This is the paper's contribution, reproduced with the same API surface as
//! the PyTorch original's one-liner (`auto_fact(module, rank, solver,
//! num_iter, submodules)`), but operating on [`ParamStore`] checkpoints +
//! the module tree reconstructed from parameter names:
//!
//! * [`rank`] — Eq. 1 (`r_max = mn/(m+n)`), ratio/fixed rank policies, the
//!   factorize-only-if-it-wins gate. Bit-for-bit mirror of
//!   `python/compile/rank.py`.
//! * [`energy`] — extension (paper future work): per-layer spectral-energy
//!   rank selection and effective-rank diagnostics.
//! * [`solver`] — Random / SVD / SNMF / TT / auto dispatch over
//!   [`crate::linalg`].
//! * [`tt`] — tensor-train (TT-matrix) factorization: the TT-SVD sweep,
//!   typed core groups, and the interpreter's core-chain contraction
//!   (DESIGN.md §13).
//! * [`auto_fact`] — the module walk: classify layers, apply the filter,
//!   gate by Eq. 1, replace Linear→LED/TT and Conv→CED, and report; with
//!   `solver = auto`, pick the family minimizing serialized bytes per
//!   layer within the energy budget.
//! * [`quantize`] — post-SVD bit-width pass: re-encode LED factors (and
//!   surviving dense linears) as int8 or bit-packed ±1 for the native
//!   serving interpreters (DESIGN.md §12).
//!
//! [`ParamStore`]: crate::tensor::ParamStore

pub mod auto_fact;
pub mod energy;
pub mod quantize;
pub mod rank;
pub mod solver;
pub mod tt;

pub use auto_fact::{auto_fact, AutoFactConfig, FactReport, LayerDecision};
pub use energy::{energy_rank, Spectrum};
pub use quantize::{
    quantize_led_params, QuantLayer, QuantReport, QuantStore, QuantTensor, WeightPrecision,
};
pub use rank::{r_max, rank_for, Rank, MIN_RANK, RANK_MULTIPLE};
pub use solver::Solver;
pub use tt::{tt_svd, TtConfig, TtCore, TtCoreView, TtParams, TT_MAX_MODES};
