//! `auto_fact` — the paper's one-line API, over checkpoints.
//!
//! Walks the module tree recovered from a [`ParamStore`], and for every
//! linear / convolution layer that (a) matches the submodule filter and
//! (b) passes the Eq.-1 gate, replaces the dense weight with LED/CED
//! factors computed by the chosen solver. The store keeps the canonical
//! name order afterwards, so the result loads directly into the matching
//! AOT graph variant.

use std::fmt;

use anyhow::bail;

use crate::linalg::Matrix;
use crate::model::{classify, LayerKind};
use crate::tensor::{ParamStore, Tensor};
use crate::Result;

use super::quantize::{quantize_led_params, QuantReport};
use super::{Rank, Solver, WeightPrecision};

/// The arguments of the paper's `greenformer.auto_fact(...)` call.
#[derive(Clone, Debug)]
pub struct AutoFactConfig {
    /// Target rank: fixed or a ratio of each layer's r_max.
    pub rank: Rank,
    /// Factor solver: Random init, truncated SVD, or Semi-NMF.
    pub solver: Solver,
    /// Iterations for SNMF (the paper's `num_iter`).
    pub num_iter: usize,
    /// Submodule filter: only layers whose name contains one of these
    /// substrings are factorized (`None` = all layers — the paper's
    /// `submodules=None` default).
    pub submodules: Option<Vec<String>>,
    /// Serving-time weight precision. The checkpoint stays f32; a non-F32
    /// value runs the post-SVD [`quantize_led_params`] pass and attaches
    /// its report (the side-table itself is built by the interpreters /
    /// decode sessions on demand).
    pub precision: WeightPrecision,
}

impl Default for AutoFactConfig {
    fn default() -> Self {
        Self {
            rank: Rank::Ratio(0.25),
            solver: Solver::Svd,
            num_iter: 50,
            submodules: None,
            precision: WeightPrecision::F32,
        }
    }
}

/// Why a layer was or wasn't factorized.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    /// Replaced with rank-r factors.
    Factorized { rank: usize },
    /// Eq.-1 gate rejected (no theoretical cost reduction).
    GateRejected,
    /// Name didn't match the submodule filter.
    Filtered,
    /// Not a factorizable layer kind (embedding, layernorm, already LED...).
    NotApplicable,
}

/// Per-layer record of what [`auto_fact`] did and why.
#[derive(Clone, Debug)]
pub struct LayerDecision {
    /// Layer group name (e.g. `block0/fc1`).
    pub name: String,
    /// Classified layer kind (Linear, Conv2d, …).
    pub kind: LayerKind,
    /// Collapsed weight rows (input dim, kh·kw·cin for convs).
    pub m: usize,
    /// Collapsed weight cols (output dim).
    pub n: usize,
    /// The outcome for this layer.
    pub decision: Decision,
    /// Relative reconstruction error ‖W − AB‖_F / ‖W‖_F (None for Random,
    /// which does not approximate).
    pub recon_error: Option<f64>,
}

/// Summary returned by [`auto_fact`].
#[derive(Clone, Debug, Default)]
pub struct FactReport {
    /// One decision per walked layer, in canonical order.
    pub layers: Vec<LayerDecision>,
    /// Total parameter count before factorization.
    pub params_before: usize,
    /// Total parameter count after factorization.
    pub params_after: usize,
    /// Post-SVD quantization summary when `cfg.precision != F32`.
    pub quant: Option<QuantReport>,
}

impl FactReport {
    /// How many layers were actually replaced with factors.
    pub fn n_factorized(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| matches!(l.decision, Decision::Factorized { .. }))
            .count()
    }

    /// Parameter ratio after/before (1.0 = nothing factorized).
    pub fn compression(&self) -> f64 {
        self.params_after as f64 / self.params_before.max(1) as f64
    }
}

impl fmt::Display for FactReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "auto_fact: {}/{} layers factorized, params {} -> {} ({:.1}%)",
            self.n_factorized(),
            self.layers.len(),
            self.params_before,
            self.params_after,
            100.0 * self.compression()
        )?;
        for l in &self.layers {
            match &l.decision {
                Decision::Factorized { rank } => writeln!(
                    f,
                    "  {:<28} {:>5}x{:<5} -> r={:<4}{}",
                    l.name,
                    l.m,
                    l.n,
                    rank,
                    l.recon_error
                        .map(|e| format!("  err={e:.4}"))
                        .unwrap_or_default()
                )?,
                d => writeln!(f, "  {:<28} {:>5}x{:<5}    [{d:?}]", l.name, l.m, l.n)?,
            }
        }
        if let Some(q) = &self.quant {
            write!(f, "{q}")?;
        }
        Ok(())
    }
}

/// Factorize a checkpoint in place. Returns the per-layer report.
///
/// Equivalent to the paper's
/// `fact_model = greenformer.auto_fact(module, rank, solver, num_iter,
/// submodules)` applied to the model's state dict.
///
/// # Examples
///
/// Factorize a random-init text classifier at half of each layer's
/// break-even rank (hermetic — no artifacts needed):
///
/// ```
/// use greenformer::backend::native::{init_text_params, TextModelCfg};
/// use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
///
/// let mut params = init_text_params(&TextModelCfg::default(), 42);
/// let before = params.n_params();
/// let report = auto_fact(
///     &mut params,
///     &AutoFactConfig {
///         rank: Rank::Ratio(0.5),
///         solver: Solver::Random, // instant; use Svd post-training
///         ..AutoFactConfig::default()
///     },
/// )
/// .unwrap();
/// assert!(report.n_factorized() > 0);
/// assert!(params.n_params() < before);
/// ```
pub fn auto_fact(params: &mut ParamStore, cfg: &AutoFactConfig) -> Result<FactReport> {
    let mut report = FactReport {
        params_before: params.n_params(),
        ..Default::default()
    };

    let layers = classify(params);
    for layer in layers {
        let applicable = matches!(layer.kind, LayerKind::Linear | LayerKind::Conv2d);
        if !applicable {
            report.layers.push(LayerDecision {
                name: layer.name,
                kind: layer.kind,
                m: layer.in_dim,
                n: layer.out_dim,
                decision: Decision::NotApplicable,
                recon_error: None,
            });
            continue;
        }
        let matches_filter = match &cfg.submodules {
            Some(subs) => subs.iter().any(|s| layer.name.contains(s.as_str())),
            None => true,
        };
        if !matches_filter {
            report.layers.push(LayerDecision {
                name: layer.name,
                kind: layer.kind,
                m: layer.in_dim,
                n: layer.out_dim,
                decision: Decision::Filtered,
                recon_error: None,
            });
            continue;
        }
        // (m, n) is the paper's rearranged 2-D view: linear (in, out),
        // conv (kh·kw·cin, cout).
        let (m, n) = (layer.in_dim, layer.out_dim);
        let Some(r) = cfg.rank.resolve(m, n) else {
            report.layers.push(LayerDecision {
                name: layer.name,
                kind: layer.kind,
                m,
                n,
                decision: Decision::GateRejected,
                recon_error: None,
            });
            continue;
        };

        let wname = if layer.name.is_empty() {
            "w".to_string()
        } else {
            format!("{}/w", layer.name)
        };
        let Some(w) = params.get(&wname) else {
            bail!("classified layer {:?} lost its weight {wname:?}", layer.name);
        };
        let w_shape = w.shape.clone();
        let (rows, cols, data) = w.as_matrix_2d()?;
        debug_assert_eq!((rows, cols), (m, n));
        let wm = Matrix::from_vec(rows, cols, data.to_vec());

        // Deterministic per-layer seed so repeated runs agree.
        let seed = layer
            .name
            .bytes()
            .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
        let (a, b) = cfg.solver.factorize(&wm, r, cfg.num_iter, seed);

        let recon_error = cfg.solver.approximates().then(|| {
            let diff = wm.sub(&a.matmul(&b));
            diff.fro_norm() / wm.fro_norm().max(1e-30)
        });

        // Shape the factors for the layer kind and swap them in.
        params.remove(&wname);
        let prefix = if layer.name.is_empty() {
            String::new()
        } else {
            format!("{}/", layer.name)
        };
        match layer.kind {
            LayerKind::Linear => {
                params.insert(format!("{prefix}a"), Tensor::from_f32(&[m, r], a.data));
                params.insert(format!("{prefix}b"), Tensor::from_f32(&[r, n], b.data));
            }
            LayerKind::Conv2d => {
                // A': (kh·kw·cin, r) -> (kh, kw, cin, r); B: (r, cout) ->
                // (1, 1, r, cout). Figure 3's CED layer.
                let (kh, kw) = layer.kernel.expect("conv has kernel");
                let cin = w_shape[2];
                params.insert(
                    format!("{prefix}a"),
                    Tensor::from_f32(&[kh, kw, cin, r], a.data),
                );
                params.insert(format!("{prefix}b"), Tensor::from_f32(&[1, 1, r, n], b.data));
            }
            _ => unreachable!(),
        }
        report.layers.push(LayerDecision {
            name: layer.name,
            kind: layer.kind,
            m,
            n,
            decision: Decision::Factorized { rank: r },
            recon_error,
        });
    }

    params.sort_canonical();
    report.params_after = params.n_params();
    if cfg.precision != WeightPrecision::F32 {
        let (_store, quant) = quantize_led_params(params, cfg.precision)?;
        report.quant = Some(quant);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Dtype;
    use crate::util::Pcg64;

    fn linear_store(d: usize) -> ParamStore {
        let mut rng = Pcg64::seeded(70);
        let mut s = ParamStore::new();
        let mut w = vec![0.0f32; d * d];
        rng.fill_normal(&mut w, 0.1);
        s.insert("fc/w", Tensor::from_f32(&[d, d], w));
        s.insert("fc/bias", Tensor::zeros(&[d], Dtype::F32));
        s.insert("ln/g", Tensor::zeros(&[d], Dtype::F32));
        s.insert("ln/bias", Tensor::zeros(&[d], Dtype::F32));
        s
    }

    #[test]
    fn factorizes_linear_and_reports() {
        let mut s = linear_store(64);
        let before = s.n_params();
        let report = auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        assert_eq!(report.n_factorized(), 1);
        assert!(s.get("fc/w").is_none());
        // ratio 0.25 on 64x64: r_max = 32, trunc(8) -> rank 8.
        assert_eq!(s.get("fc/a").unwrap().shape, vec![64, 8]);
        assert_eq!(s.get("fc/b").unwrap().shape, vec![8, 64]);
        assert!(s.get("fc/bias").is_some());
        assert!(s.n_params() < before);
        assert_eq!(report.params_before, before);
        assert_eq!(report.params_after, s.n_params());
        // layernorm untouched
        assert!(s.get("ln/g").is_some());
    }

    #[test]
    fn store_stays_canonically_sorted() {
        let mut s = linear_store(64);
        auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        let names: Vec<_> = s.names().to_vec();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn gate_rejects_small_layers() {
        let mut s = ParamStore::new();
        s.insert("tiny/w", Tensor::zeros(&[8, 8], Dtype::F32));
        let report = auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        assert_eq!(report.layers[0].decision, Decision::GateRejected);
        assert!(s.get("tiny/w").is_some()); // untouched
    }

    #[test]
    fn filter_limits_scope() {
        let mut s = linear_store(64);
        let mut rng = Pcg64::seeded(71);
        let mut w = vec![0.0f32; 64 * 64];
        rng.fill_normal(&mut w, 0.1);
        s.insert("attn/q/w", Tensor::from_f32(&[64, 64], w));
        let cfg = AutoFactConfig {
            submodules: Some(vec!["attn".into()]),
            ..Default::default()
        };
        let report = auto_fact(&mut s, &cfg).unwrap();
        assert!(s.get("attn/q/a").is_some());
        assert!(s.get("fc/w").is_some());
        assert!(report
            .layers
            .iter()
            .any(|l| l.name == "fc" && l.decision == Decision::Filtered));
    }

    #[test]
    fn conv_becomes_ced_with_paper_shapes() {
        let mut rng = Pcg64::seeded(72);
        let mut s = ParamStore::new();
        let mut w = vec![0.0f32; 3 * 3 * 16 * 32];
        rng.fill_normal(&mut w, 0.1);
        s.insert("conv/w", Tensor::from_f32(&[3, 3, 16, 32], w));
        s.insert("conv/bias", Tensor::zeros(&[32], Dtype::F32));
        let cfg = AutoFactConfig {
            rank: Rank::Ratio(0.5),
            ..Default::default()
        };
        let report = auto_fact(&mut s, &cfg).unwrap();
        // m = 144, n = 32, r_max = 26.18 -> r = int(13.09)//8*8 = 8
        assert_eq!(report.layers[0].decision, Decision::Factorized { rank: 8 });
        assert_eq!(s.get("conv/a").unwrap().shape, vec![3, 3, 16, 8]);
        assert_eq!(s.get("conv/b").unwrap().shape, vec![1, 1, 8, 32]);
    }

    #[test]
    fn svd_reconstruction_error_reported_and_small_for_low_rank_w() {
        // Exactly rank-8 weight: SVD at r=16 must reconstruct ~perfectly.
        let mut rng = Pcg64::seeded(73);
        let u = Matrix::randn(64, 8, 1.0, &mut rng);
        let v = Matrix::randn(8, 64, 1.0, &mut rng);
        let w = u.matmul(&v);
        let mut s = ParamStore::new();
        s.insert("fc/w", Tensor::from_f32(&[64, 64], w.data.clone()));
        let cfg = AutoFactConfig {
            rank: Rank::Fixed(16),
            ..Default::default()
        };
        let report = auto_fact(&mut s, &cfg).unwrap();
        let err = report.layers[0].recon_error.unwrap();
        assert!(err < 1e-4, "err={err}");
    }

    #[test]
    fn random_solver_reports_no_error() {
        let mut s = linear_store(64);
        let cfg = AutoFactConfig {
            solver: Solver::Random,
            ..Default::default()
        };
        let report = auto_fact(&mut s, &cfg).unwrap();
        assert!(report.layers[0].recon_error.is_none());
    }

    #[test]
    fn idempotent_on_already_factorized() {
        let mut s = linear_store(64);
        auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        let names_before: Vec<_> = s.names().to_vec();
        let report = auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        assert_eq!(report.n_factorized(), 0);
        assert_eq!(s.names(), &names_before[..]);
    }

    #[test]
    fn display_renders() {
        let mut s = linear_store(64);
        let report = auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        let text = report.to_string();
        assert!(text.contains("auto_fact"));
        assert!(text.contains("fc"));
    }
}
