//! `auto_fact` — the paper's one-line API, over checkpoints.
//!
//! Walks the module tree recovered from a [`ParamStore`], and for every
//! linear / convolution layer that (a) matches the submodule filter and
//! (b) passes the Eq.-1 gate, replaces the dense weight with LED/CED
//! factors computed by the chosen solver. The store keeps the canonical
//! name order afterwards, so the result loads directly into the matching
//! AOT graph variant.

use std::fmt;

use anyhow::bail;

use crate::linalg::Matrix;
use crate::model::{classify, LayerKind};
use crate::tensor::{ParamStore, Tensor};
use crate::Result;

use super::energy::energy_rank;
use super::quantize::{quantize_led_params, QuantReport};
use super::tt::tt_svd;
use super::{Rank, Solver, TtConfig, WeightPrecision};

/// The arguments of the paper's `greenformer.auto_fact(...)` call.
#[derive(Clone, Debug)]
pub struct AutoFactConfig {
    /// Target rank: fixed or a ratio of each layer's r_max.
    pub rank: Rank,
    /// Factor solver: Random init, truncated SVD, or Semi-NMF.
    pub solver: Solver,
    /// Iterations for SNMF (the paper's `num_iter`).
    pub num_iter: usize,
    /// Submodule filter: only layers whose name contains one of these
    /// substrings are factorized (`None` = all layers — the paper's
    /// `submodules=None` default).
    pub submodules: Option<Vec<String>>,
    /// TT sweep settings for `solver = tt|auto`: mode count, retained
    /// energy τ, per-core rank cap. The same τ drives the `auto` chooser's
    /// LED candidate (via [`energy_rank`]) so the families compete at an
    /// equal approximation budget.
    pub tt: TtConfig,
    /// Serving-time weight precision. The checkpoint stays f32; a non-F32
    /// value runs the post-SVD [`quantize_led_params`] pass and attaches
    /// its report (the side-table itself is built by the interpreters /
    /// decode sessions on demand).
    pub precision: WeightPrecision,
}

impl Default for AutoFactConfig {
    fn default() -> Self {
        Self {
            rank: Rank::Ratio(0.25),
            solver: Solver::Svd,
            num_iter: 50,
            submodules: None,
            tt: TtConfig::default(),
            precision: WeightPrecision::F32,
        }
    }
}

/// Why a layer was or wasn't factorized.
#[derive(Clone, Debug, PartialEq)]
pub enum Decision {
    /// Replaced with rank-r factors.
    Factorized { rank: usize },
    /// Replaced with a TT core chain (internal ranks `r_1..r_{d-1}`).
    FactorizedTt {
        /// The chain's internal TT ranks.
        ranks: Vec<usize>,
    },
    /// Eq.-1 gate rejected (no theoretical cost reduction).
    GateRejected,
    /// Name didn't match the submodule filter.
    Filtered,
    /// Not a factorizable layer kind (embedding, layernorm, already LED...).
    NotApplicable,
}

/// Per-layer record of what [`auto_fact`] did and why.
#[derive(Clone, Debug)]
pub struct LayerDecision {
    /// Layer group name (e.g. `block0/fc1`).
    pub name: String,
    /// Classified layer kind (Linear, Conv2d, …).
    pub kind: LayerKind,
    /// Collapsed weight rows (input dim, kh·kw·cin for convs).
    pub m: usize,
    /// Collapsed weight cols (output dim).
    pub n: usize,
    /// The outcome for this layer.
    pub decision: Decision,
    /// Relative reconstruction error ‖W − AB‖_F / ‖W‖_F (None for Random,
    /// which does not approximate).
    pub recon_error: Option<f64>,
}

/// Summary returned by [`auto_fact`].
#[derive(Clone, Debug, Default)]
pub struct FactReport {
    /// One decision per walked layer, in canonical order.
    pub layers: Vec<LayerDecision>,
    /// Total parameter count before factorization.
    pub params_before: usize,
    /// Total parameter count after factorization.
    pub params_after: usize,
    /// True serialized checkpoint bytes before factorization. The `auto`
    /// chooser minimizes bytes, not element counts — on mixed-dtype stores
    /// the two disagree, so both gates and reports use bytes.
    pub bytes_before: usize,
    /// True serialized checkpoint bytes after factorization.
    pub bytes_after: usize,
    /// Post-SVD quantization summary when `cfg.precision != F32`.
    pub quant: Option<QuantReport>,
}

impl FactReport {
    /// How many layers were actually replaced with factors.
    pub fn n_factorized(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| {
                matches!(
                    l.decision,
                    Decision::Factorized { .. } | Decision::FactorizedTt { .. }
                )
            })
            .count()
    }

    /// Parameter ratio after/before (1.0 = nothing factorized).
    pub fn compression(&self) -> f64 {
        self.params_after as f64 / self.params_before.max(1) as f64
    }
}

impl fmt::Display for FactReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "auto_fact: {}/{} layers factorized, params {} -> {} ({:.1}%), bytes {} -> {}",
            self.n_factorized(),
            self.layers.len(),
            self.params_before,
            self.params_after,
            100.0 * self.compression(),
            self.bytes_before,
            self.bytes_after
        )?;
        for l in &self.layers {
            match &l.decision {
                Decision::Factorized { rank } => writeln!(
                    f,
                    "  {:<28} {:>5}x{:<5} -> r={:<4}{}",
                    l.name,
                    l.m,
                    l.n,
                    rank,
                    l.recon_error
                        .map(|e| format!("  err={e:.4}"))
                        .unwrap_or_default()
                )?,
                Decision::FactorizedTt { ranks } => writeln!(
                    f,
                    "  {:<28} {:>5}x{:<5} -> tt r={ranks:?}{}",
                    l.name,
                    l.m,
                    l.n,
                    l.recon_error
                        .map(|e| format!("  err={e:.4}"))
                        .unwrap_or_default()
                )?,
                d => writeln!(f, "  {:<28} {:>5}x{:<5}    [{d:?}]", l.name, l.m, l.n)?,
            }
        }
        if let Some(q) = &self.quant {
            write!(f, "{q}")?;
        }
        Ok(())
    }
}

/// Factorize a checkpoint in place. Returns the per-layer report.
///
/// Equivalent to the paper's
/// `fact_model = greenformer.auto_fact(module, rank, solver, num_iter,
/// submodules)` applied to the model's state dict.
///
/// # Examples
///
/// Factorize a random-init text classifier at half of each layer's
/// break-even rank (hermetic — no artifacts needed):
///
/// ```
/// use greenformer::backend::native::{init_text_params, TextModelCfg};
/// use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
///
/// let mut params = init_text_params(&TextModelCfg::default(), 42);
/// let before = params.n_params();
/// let report = auto_fact(
///     &mut params,
///     &AutoFactConfig {
///         rank: Rank::Ratio(0.5),
///         solver: Solver::Random, // instant; use Svd post-training
///         ..AutoFactConfig::default()
///     },
/// )
/// .unwrap();
/// assert!(report.n_factorized() > 0);
/// assert!(params.n_params() < before);
/// ```
/// True serialized size of every tensor in the store (dtype-aware) — the
/// quantity the `auto` chooser minimizes and [`FactReport`] records.
fn store_bytes(params: &ParamStore) -> usize {
    params.iter().map(|(_, t)| t.raw_bytes().len()).sum()
}

pub fn auto_fact(params: &mut ParamStore, cfg: &AutoFactConfig) -> Result<FactReport> {
    let mut report = FactReport {
        params_before: params.n_params(),
        bytes_before: store_bytes(params),
        ..Default::default()
    };

    let layers = classify(params);
    for layer in layers {
        let applicable = matches!(layer.kind, LayerKind::Linear | LayerKind::Conv2d);
        if !applicable {
            report.layers.push(LayerDecision {
                name: layer.name,
                kind: layer.kind,
                m: layer.in_dim,
                n: layer.out_dim,
                decision: Decision::NotApplicable,
                recon_error: None,
            });
            continue;
        }
        let matches_filter = match &cfg.submodules {
            Some(subs) => subs.iter().any(|s| layer.name.contains(s.as_str())),
            None => true,
        };
        if !matches_filter {
            report.layers.push(LayerDecision {
                name: layer.name,
                kind: layer.kind,
                m: layer.in_dim,
                n: layer.out_dim,
                decision: Decision::Filtered,
                recon_error: None,
            });
            continue;
        }
        // (m, n) is the paper's rearranged 2-D view: linear (in, out),
        // conv (kh·kw·cin, cout).
        let (m, n) = (layer.in_dim, layer.out_dim);

        let wname = if layer.name.is_empty() {
            "w".to_string()
        } else {
            format!("{}/w", layer.name)
        };
        let Some(w) = params.get(&wname) else {
            bail!("classified layer {:?} lost its weight {wname:?}", layer.name);
        };
        let w_shape = w.shape.clone();
        let (rows, cols, data) = w.as_matrix_2d()?;
        debug_assert_eq!((rows, cols), (m, n));
        let wm = Matrix::from_vec(rows, cols, data.to_vec());
        let prefix = if layer.name.is_empty() {
            String::new()
        } else {
            format!("{}/", layer.name)
        };

        // Resolve the rank policy. The TT family (tt|auto) is energy-driven
        // — LED candidates come from [`energy_rank`] at the shared τ, not
        // from `cfg.rank` — so both families compete at equal budget.
        let tt_family = matches!(cfg.solver, Solver::Tt | Solver::Auto);
        let led_rank = if tt_family {
            energy_rank(&wm, cfg.tt.energy)
        } else {
            cfg.rank.resolve(m, n)
        };

        if tt_family && layer.kind == LayerKind::Linear {
            // Family chooser on true serialized bytes (f32): dense 4·m·n vs
            // LED 4·r·(m+n) vs the TT chain's 4·Σ r_{k-1}·m_k·n_k·r_k —
            // element counts and bytes agree here, but the report carries
            // bytes so mixed-precision stores stay honest.
            let f32b = std::mem::size_of::<f32>();
            let dense_bytes = m * n * f32b;
            let tt = tt_svd(&wm, &cfg.tt)?;
            let led_bytes = match cfg.solver {
                // Plain `tt` never falls back to LED — only dense survives.
                Solver::Auto => led_rank.map(|r| r * (m + n) * f32b),
                _ => None,
            };
            let beats_led = match led_bytes {
                Some(lb) => tt.bytes() < lb,
                None => true,
            };
            if tt.bytes() < dense_bytes && beats_led {
                let rec = tt.reconstruct();
                let recon = wm.sub(&rec).fro_norm() / wm.fro_norm().max(1e-30);
                let ranks = tt.ranks();
                params.remove(&wname);
                tt.insert_into(params, &prefix);
                report.layers.push(LayerDecision {
                    name: layer.name,
                    kind: layer.kind,
                    m,
                    n,
                    decision: Decision::FactorizedTt { ranks },
                    recon_error: Some(recon),
                });
                continue;
            }
            if cfg.solver == Solver::Tt || led_rank.is_none() {
                report.layers.push(LayerDecision {
                    name: layer.name,
                    kind: layer.kind,
                    m,
                    n,
                    decision: Decision::GateRejected,
                    recon_error: None,
                });
                continue;
            }
            // `auto` falls through: LED at the energy rank wins on bytes.
        }

        let Some(r) = led_rank else {
            report.layers.push(LayerDecision {
                name: layer.name,
                kind: layer.kind,
                m,
                n,
                decision: Decision::GateRejected,
                recon_error: None,
            });
            continue;
        };

        // Deterministic per-layer seed so repeated runs agree.
        let seed = layer
            .name
            .bytes()
            .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
        let (a, b) = cfg.solver.factorize(&wm, r, cfg.num_iter, seed);

        let recon_error = cfg.solver.approximates().then(|| {
            let diff = wm.sub(&a.matmul(&b));
            diff.fro_norm() / wm.fro_norm().max(1e-30)
        });

        // Shape the factors for the layer kind and swap them in.
        params.remove(&wname);
        match layer.kind {
            LayerKind::Linear => {
                params.insert(format!("{prefix}a"), Tensor::from_f32(&[m, r], a.data));
                params.insert(format!("{prefix}b"), Tensor::from_f32(&[r, n], b.data));
            }
            LayerKind::Conv2d => {
                // A': (kh·kw·cin, r) -> (kh, kw, cin, r); B: (r, cout) ->
                // (1, 1, r, cout). Figure 3's CED layer.
                let (kh, kw) = layer.kernel.expect("conv has kernel");
                let cin = w_shape[2];
                params.insert(
                    format!("{prefix}a"),
                    Tensor::from_f32(&[kh, kw, cin, r], a.data),
                );
                params.insert(format!("{prefix}b"), Tensor::from_f32(&[1, 1, r, n], b.data));
            }
            _ => unreachable!(),
        }
        report.layers.push(LayerDecision {
            name: layer.name,
            kind: layer.kind,
            m,
            n,
            decision: Decision::Factorized { rank: r },
            recon_error,
        });
    }

    params.sort_canonical();
    report.params_after = params.n_params();
    report.bytes_after = store_bytes(params);
    if cfg.precision != WeightPrecision::F32 {
        let (_store, quant) = quantize_led_params(params, cfg.precision)?;
        report.quant = Some(quant);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Dtype;
    use crate::util::Pcg64;

    fn linear_store(d: usize) -> ParamStore {
        let mut rng = Pcg64::seeded(70);
        let mut s = ParamStore::new();
        let mut w = vec![0.0f32; d * d];
        rng.fill_normal(&mut w, 0.1);
        s.insert("fc/w", Tensor::from_f32(&[d, d], w));
        s.insert("fc/bias", Tensor::zeros(&[d], Dtype::F32));
        s.insert("ln/g", Tensor::zeros(&[d], Dtype::F32));
        s.insert("ln/bias", Tensor::zeros(&[d], Dtype::F32));
        s
    }

    #[test]
    fn factorizes_linear_and_reports() {
        let mut s = linear_store(64);
        let before = s.n_params();
        let report = auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        assert_eq!(report.n_factorized(), 1);
        assert!(s.get("fc/w").is_none());
        // ratio 0.25 on 64x64: r_max = 32, trunc(8) -> rank 8.
        assert_eq!(s.get("fc/a").unwrap().shape, vec![64, 8]);
        assert_eq!(s.get("fc/b").unwrap().shape, vec![8, 64]);
        assert!(s.get("fc/bias").is_some());
        assert!(s.n_params() < before);
        assert_eq!(report.params_before, before);
        assert_eq!(report.params_after, s.n_params());
        // layernorm untouched
        assert!(s.get("ln/g").is_some());
    }

    #[test]
    fn store_stays_canonically_sorted() {
        let mut s = linear_store(64);
        auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        let names: Vec<_> = s.names().to_vec();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn gate_rejects_small_layers() {
        let mut s = ParamStore::new();
        s.insert("tiny/w", Tensor::zeros(&[8, 8], Dtype::F32));
        let report = auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        assert_eq!(report.layers[0].decision, Decision::GateRejected);
        assert!(s.get("tiny/w").is_some()); // untouched
    }

    #[test]
    fn filter_limits_scope() {
        let mut s = linear_store(64);
        let mut rng = Pcg64::seeded(71);
        let mut w = vec![0.0f32; 64 * 64];
        rng.fill_normal(&mut w, 0.1);
        s.insert("attn/q/w", Tensor::from_f32(&[64, 64], w));
        let cfg = AutoFactConfig {
            submodules: Some(vec!["attn".into()]),
            ..Default::default()
        };
        let report = auto_fact(&mut s, &cfg).unwrap();
        assert!(s.get("attn/q/a").is_some());
        assert!(s.get("fc/w").is_some());
        assert!(report
            .layers
            .iter()
            .any(|l| l.name == "fc" && l.decision == Decision::Filtered));
    }

    #[test]
    fn conv_becomes_ced_with_paper_shapes() {
        let mut rng = Pcg64::seeded(72);
        let mut s = ParamStore::new();
        let mut w = vec![0.0f32; 3 * 3 * 16 * 32];
        rng.fill_normal(&mut w, 0.1);
        s.insert("conv/w", Tensor::from_f32(&[3, 3, 16, 32], w));
        s.insert("conv/bias", Tensor::zeros(&[32], Dtype::F32));
        let cfg = AutoFactConfig {
            rank: Rank::Ratio(0.5),
            ..Default::default()
        };
        let report = auto_fact(&mut s, &cfg).unwrap();
        // m = 144, n = 32, r_max = 26.18 -> r = int(13.09)//8*8 = 8
        assert_eq!(report.layers[0].decision, Decision::Factorized { rank: 8 });
        assert_eq!(s.get("conv/a").unwrap().shape, vec![3, 3, 16, 8]);
        assert_eq!(s.get("conv/b").unwrap().shape, vec![1, 1, 8, 32]);
    }

    #[test]
    fn svd_reconstruction_error_reported_and_small_for_low_rank_w() {
        // Exactly rank-8 weight: SVD at r=16 must reconstruct ~perfectly.
        let mut rng = Pcg64::seeded(73);
        let u = Matrix::randn(64, 8, 1.0, &mut rng);
        let v = Matrix::randn(8, 64, 1.0, &mut rng);
        let w = u.matmul(&v);
        let mut s = ParamStore::new();
        s.insert("fc/w", Tensor::from_f32(&[64, 64], w.data.clone()));
        let cfg = AutoFactConfig {
            rank: Rank::Fixed(16),
            ..Default::default()
        };
        let report = auto_fact(&mut s, &cfg).unwrap();
        let err = report.layers[0].recon_error.unwrap();
        assert!(err < 1e-4, "err={err}");
    }

    #[test]
    fn random_solver_reports_no_error() {
        let mut s = linear_store(64);
        let cfg = AutoFactConfig {
            solver: Solver::Random,
            ..Default::default()
        };
        let report = auto_fact(&mut s, &cfg).unwrap();
        assert!(report.layers[0].recon_error.is_none());
    }

    #[test]
    fn idempotent_on_already_factorized() {
        let mut s = linear_store(64);
        auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        let names_before: Vec<_> = s.names().to_vec();
        let report = auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        assert_eq!(report.n_factorized(), 0);
        assert_eq!(s.names(), &names_before[..]);
    }

    /// kron(A, B) with A, B 8×8: exactly TT-rank-1 at modes=2, while the
    /// flat 64×64 spectrum is full-rank (LED can never pass the Eq.-1
    /// gate) — the canonical shape where TT wins and LED cannot.
    fn kron_store() -> ParamStore {
        let mut rng = Pcg64::seeded(74);
        let a = Matrix::randn(8, 8, 1.0, &mut rng);
        let b = Matrix::randn(8, 8, 1.0, &mut rng);
        let mut w = vec![0.0f32; 64 * 64];
        for i1 in 0..8 {
            for j1 in 0..8 {
                for i2 in 0..8 {
                    for j2 in 0..8 {
                        w[(i1 * 8 + i2) * 64 + (j1 * 8 + j2)] =
                            a.data[i1 * 8 + j1] * b.data[i2 * 8 + j2];
                    }
                }
            }
        }
        let mut s = ParamStore::new();
        s.insert("fc/w", Tensor::from_f32(&[64, 64], w));
        s.insert("fc/bias", Tensor::zeros(&[64], Dtype::F32));
        s
    }

    fn tt2_cfg(solver: Solver) -> AutoFactConfig {
        AutoFactConfig {
            solver,
            tt: crate::factorize::TtConfig {
                modes: 2,
                energy: 0.99,
                max_rank: None,
            },
            ..Default::default()
        }
    }

    #[test]
    fn auto_picks_tt_on_kron_layer_where_led_cannot_win() {
        let mut s = kron_store();
        let report = auto_fact(&mut s, &tt2_cfg(Solver::Auto)).unwrap();
        let l = &report.layers[0];
        assert_eq!(l.decision, Decision::FactorizedTt { ranks: vec![1] });
        assert!(l.recon_error.unwrap() < 1e-4, "err={:?}", l.recon_error);
        assert!(s.get("fc/w").is_none());
        assert_eq!(s.get("fc/tt0").unwrap().shape, vec![1, 8, 8, 1]);
        assert_eq!(s.get("fc/tt1").unwrap().shape, vec![1, 8, 8, 1]);
        // Byte accounting is over true serialized sizes, and TT shrinks it.
        assert_eq!(report.bytes_after, store_bytes(&s));
        assert!(report.bytes_after < report.bytes_before);
        assert_eq!(report.n_factorized(), 1);
    }

    #[test]
    fn auto_falls_back_to_led_when_cheaper() {
        // Exactly rank-4 unstructured weight: LED keeps τ=0.9999 energy at
        // MIN_RANK bytes, while the permuted TT unfoldings are high-rank.
        let mut rng = Pcg64::seeded(75);
        let u = Matrix::randn(64, 4, 1.0, &mut rng);
        let v = Matrix::randn(4, 64, 1.0, &mut rng);
        let mut s = ParamStore::new();
        s.insert("fc/w", Tensor::from_f32(&[64, 64], u.matmul(&v).data));
        let mut cfg = tt2_cfg(Solver::Auto);
        cfg.tt.energy = 0.9999;
        let report = auto_fact(&mut s, &cfg).unwrap();
        assert_eq!(report.layers[0].decision, Decision::Factorized { rank: 8 });
        assert_eq!(s.get("fc/a").unwrap().shape, vec![64, 8]);
    }

    #[test]
    fn tt_solver_gate_rejects_unstructured_noise() {
        // Full-rank 16×16 noise at modes=2 needs 512 TT elements vs 256
        // dense — the byte gate must keep the layer dense (and plain `tt`
        // never falls back to LED).
        let mut rng = Pcg64::seeded(76);
        let mut s = ParamStore::new();
        let mut w = vec![0.0f32; 16 * 16];
        rng.fill_normal(&mut w, 1.0);
        s.insert("fc/w", Tensor::from_f32(&[16, 16], w));
        let mut cfg = tt2_cfg(Solver::Tt);
        cfg.tt.energy = 1.0;
        let report = auto_fact(&mut s, &cfg).unwrap();
        assert_eq!(report.layers[0].decision, Decision::GateRejected);
        assert!(s.get("fc/w").is_some());
        assert_eq!(report.bytes_after, report.bytes_before);
    }

    #[test]
    fn tt_solver_replaces_structured_linear_with_cores() {
        let mut s = kron_store();
        let report = auto_fact(&mut s, &tt2_cfg(Solver::Tt)).unwrap();
        assert_eq!(
            report.layers[0].decision,
            Decision::FactorizedTt { ranks: vec![1] }
        );
        assert!(s.get("fc/tt0").is_some() && s.get("fc/tt1").is_some());
        let text = report.to_string();
        assert!(text.contains("tt r=[1]"), "{text}");
    }

    #[test]
    fn auto_on_conv_takes_energy_gated_ced_path() {
        // Low-rank conv weight: energy rank 4 -> MIN_RANK 8 < r_max(144,32),
        // so `auto` lands on the same CED shapes as the SVD solver.
        let mut rng = Pcg64::seeded(77);
        let u = Matrix::randn(144, 4, 1.0, &mut rng);
        let v = Matrix::randn(4, 32, 1.0, &mut rng);
        let mut s = ParamStore::new();
        s.insert("conv/w", Tensor::from_f32(&[3, 3, 16, 32], u.matmul(&v).data));
        let cfg = AutoFactConfig {
            solver: Solver::Auto,
            ..Default::default()
        };
        let report = auto_fact(&mut s, &cfg).unwrap();
        assert_eq!(report.layers[0].decision, Decision::Factorized { rank: 8 });
        assert_eq!(s.get("conv/a").unwrap().shape, vec![3, 3, 16, 8]);
        assert_eq!(s.get("conv/b").unwrap().shape, vec![1, 1, 8, 32]);
    }

    #[test]
    fn display_renders() {
        let mut s = linear_store(64);
        let report = auto_fact(&mut s, &AutoFactConfig::default()).unwrap();
        let text = report.to_string();
        assert!(text.contains("auto_fact"));
        assert!(text.contains("fc"));
    }
}
