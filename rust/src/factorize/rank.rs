//! Rank policy — paper Eq. 1 and its resolution rules.
//!
//! Single Rust source of truth, mirrored bit-for-bit by
//! `python/compile/rank.py` (see `PINNED_VECTORS` there; the same vectors are
//! asserted in `tests::pinned_vectors` below). The AOT graph shapes and the
//! Rust-factorized checkpoint shapes must agree exactly, so any change here
//! must be made in both places.

/// Factor ranks are rounded down to a multiple of this (TPU lane
/// granularity; DESIGN.md §4).
pub const RANK_MULTIPLE: usize = 8;

/// Smallest rank ever emitted.
pub const MIN_RANK: usize = 8;

/// Paper Eq. 1: the break-even rank of an (m, n) weight matrix. A rank-r
/// factorization costs r·(m+n) against m·n, so it only wins when r < r_max.
pub fn r_max(m: usize, n: usize) -> f64 {
    (m as f64 * n as f64) / (m as f64 + n as f64)
}

/// The `rank` argument of `auto_fact`: a fixed integer rank or a ratio of
/// each layer's own r_max (the paper's "dynamic rank across all layers").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Rank {
    /// One concrete rank for every layer (still subject to the Eq.-1 gate).
    Fixed(usize),
    /// A fraction of each layer's own r_max (the paper's dynamic rank).
    Ratio(f64),
}

impl Rank {
    /// Resolve to a concrete rank for an (m, n) weight, or None when the
    /// Eq.-1 gate rejects (factorization would not reduce theoretical cost).
    pub fn resolve(self, m: usize, n: usize) -> Option<usize> {
        match self {
            Rank::Ratio(ratio) => rank_for(m, n, ratio),
            Rank::Fixed(r) => {
                if r == 0 || m == 0 || n == 0 {
                    return None;
                }
                // Fixed ranks skip ratio rounding but still face the gate.
                if (r as f64) >= r_max(m, n) {
                    None
                } else {
                    Some(r)
                }
            }
        }
    }
}

/// Ratio resolution: truncate ratio·r_max to a multiple of [`RANK_MULTIPLE`],
/// clamp up to [`MIN_RANK`], then apply the Eq.-1 gate.
/// Mirrors `python/compile/rank.py::rank_for`.
pub fn rank_for(m: usize, n: usize, ratio: f64) -> Option<usize> {
    if m == 0 || n == 0 || ratio <= 0.0 {
        return None;
    }
    let rmax = r_max(m, n);
    let mut r = (ratio * rmax) as usize; // trunc, like Python int()
    r = (r / RANK_MULTIPLE) * RANK_MULTIPLE;
    if r < MIN_RANK {
        r = MIN_RANK;
    }
    if (r as f64) >= rmax {
        None
    } else {
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared with python/compile/rank.py::PINNED_VECTORS — update together.
    #[test]
    fn pinned_vectors() {
        let cases: &[((usize, usize, f64), Option<usize>)] = &[
            ((128, 128, 0.50), Some(32)),
            ((128, 128, 0.25), Some(16)),
            ((128, 128, 0.10), Some(8)),
            ((128, 128, 0.90), Some(56)),
            ((768, 768, 0.50), Some(192)),
            ((768, 3072, 0.25), Some(152)),
            ((768, 3072, 0.50), Some(304)),
            ((512, 128, 0.75), Some(72)),
            ((16, 16, 0.50), None),
            ((8, 8, 0.99), None),
            ((4096, 4096, 0.75), Some(1536)),
        ];
        for &((m, n, ratio), want) in cases {
            assert_eq!(rank_for(m, n, ratio), want, "({m}, {n}, {ratio})");
        }
    }

    #[test]
    fn gate_always_reduces_cost() {
        // Exhaustive-ish sweep; the Eq.-1 invariant r(m+n) < mn must hold
        // for every accepted rank.
        for m in [1usize, 3, 8, 17, 64, 129, 768, 4096] {
            for n in [1usize, 4, 8, 33, 128, 3072] {
                for ratio in [0.01, 0.1, 0.25, 0.5, 0.75, 0.99] {
                    if let Some(r) = rank_for(m, n, ratio) {
                        assert!(r * (m + n) < m * n, "({m},{n},{ratio}) -> {r}");
                        assert!(r >= MIN_RANK);
                    }
                }
            }
        }
    }

    #[test]
    fn fixed_rank_gate() {
        assert_eq!(Rank::Fixed(32).resolve(128, 128), Some(32));
        assert_eq!(Rank::Fixed(64).resolve(128, 128), None); // == r_max
        assert_eq!(Rank::Fixed(100).resolve(128, 128), None);
        assert_eq!(Rank::Fixed(0).resolve(128, 128), None);
        // Fixed ranks are not rounded.
        assert_eq!(Rank::Fixed(13).resolve(128, 128), Some(13));
    }

    #[test]
    fn ratio_monotone_in_ratio() {
        let mut last = 0usize;
        for ratio in [0.1, 0.2, 0.3, 0.5, 0.7, 0.9] {
            if let Some(r) = rank_for(768, 768, ratio) {
                assert!(r >= last);
                last = r;
            }
        }
    }

    #[test]
    fn r_max_values() {
        assert!((r_max(128, 128) - 64.0).abs() < 1e-12);
        assert!((r_max(768, 3072) - 614.4).abs() < 1e-9);
    }
}
