//! Solver dispatch: the `solver=` argument of `auto_fact`.

use std::fmt;
use std::str::FromStr;

use crate::linalg::{snmf_factorize, svd_factorize, Matrix};
use crate::util::Pcg64;

/// Greenformer's factorization solvers (paper §Design), plus the TT family
/// and the per-layer byte-minimizing chooser (`auto`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// Fresh random factors — factorization-by-design only ("not suitable
    /// for post-training factorization, since it may break what the model
    /// learnt" — the paper; `table_solvers` bench demonstrates exactly that).
    Random,
    /// Truncated SVD (optimal rank-r approximation, Eckart–Young).
    Svd,
    /// Semi-NMF: B ≥ 0, A unconstrained.
    Snmf,
    /// Tensor-train (TT-matrix) sweep — `auto_fact` replaces each linear
    /// with `tt0..ttK` cores via [`crate::factorize::tt::tt_svd`]; convs
    /// fall back to the energy-gated SVD/CED path.
    Tt,
    /// Per-layer chooser: dense vs LED (energy rank) vs TT, whichever
    /// serializes to the fewest bytes within the energy budget.
    Auto,
}

impl Solver {
    /// Factorize `w` (m×n) into (A: m×r, B: r×n).
    /// `num_iter` only affects SNMF; `seed` only Random/SNMF. The Tt/Auto
    /// families are driven by `auto_fact` directly (cores, not factor
    /// pairs); as a two-factor fallback they behave like [`Solver::Svd`]
    /// (used for conv layers, which have no TT path).
    pub fn factorize(self, w: &Matrix, r: usize, num_iter: usize, seed: u64) -> (Matrix, Matrix) {
        match self {
            Solver::Svd | Solver::Tt | Solver::Auto => svd_factorize(w, r),
            Solver::Snmf => snmf_factorize(w, r, num_iter, seed),
            Solver::Random => random_factorize(w.rows, w.cols, r, seed),
        }
    }

    /// Whether the solver approximates W (Random does not — it re-inits).
    pub fn approximates(self) -> bool {
        !matches!(self, Solver::Random)
    }
}

impl fmt::Display for Solver {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Solver::Random => "random",
            Solver::Svd => "svd",
            Solver::Snmf => "snmf",
            Solver::Tt => "tt",
            Solver::Auto => "auto",
        };
        f.write_str(s)
    }
}

impl FromStr for Solver {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "random" => Ok(Solver::Random),
            "svd" => Ok(Solver::Svd),
            "snmf" => Ok(Solver::Snmf),
            "tt" => Ok(Solver::Tt),
            "auto" => Ok(Solver::Auto),
            other => Err(format!("unknown solver {other:?} (random|svd|snmf|tt|auto)")),
        }
    }
}

/// Random solver: glorot-variance-matched factors (mirror of
/// `python/compile/solvers.py::random_factorize`).
pub fn random_factorize(m: usize, n: usize, r: usize, seed: u64) -> (Matrix, Matrix) {
    let mut rng = Pcg64::new(seed, 3);
    // var(sum_r a·b) = r·va·vb; target glorot vw = 2/(m+n), va = vb.
    let vw = 2.0 / (m + n) as f64;
    let sigma = (vw / r as f64).sqrt().sqrt() as f32; // sqrt(va), va = sqrt(vw/r)
    let a = Matrix::randn(m, r, sigma, &mut rng);
    let b = Matrix::randn(r, n, sigma, &mut rng);
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in [Solver::Random, Solver::Svd, Solver::Snmf, Solver::Tt, Solver::Auto] {
            assert_eq!(s.to_string().parse::<Solver>().unwrap(), s);
        }
        assert!("qr".parse::<Solver>().is_err());
    }

    #[test]
    fn svd_approximates_random_does_not() {
        let mut rng = Pcg64::seeded(60);
        let w = Matrix::randn(24, 16, 1.0, &mut rng);
        let (a, b) = Solver::Svd.factorize(&w, 8, 0, 0);
        let esvd = w.sub(&a.matmul(&b)).fro_norm() / w.fro_norm();
        let (a, b) = Solver::Random.factorize(&w, 8, 0, 0);
        let ernd = w.sub(&a.matmul(&b)).fro_norm() / w.fro_norm();
        assert!(esvd < 0.9, "svd should approximate: {esvd}");
        assert!(ernd > 0.9, "random must not approximate: {ernd}");
        assert!(Solver::Svd.approximates() && !Solver::Random.approximates());
    }

    #[test]
    fn random_factor_scale_near_glorot() {
        let (a, b) = random_factorize(64, 48, 16, 0);
        let prod = a.matmul(&b);
        let n = prod.data.len() as f64;
        let var = {
            let mean: f64 = prod.data.iter().map(|&x| x as f64).sum::<f64>() / n;
            prod.data.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n
        };
        let glorot = 2.0 / (64.0 + 48.0);
        assert!(var > glorot * 0.2 && var < glorot * 5.0, "var={var} glorot={glorot}");
    }

    #[test]
    fn shapes_correct_all_solvers() {
        let mut rng = Pcg64::seeded(61);
        let w = Matrix::randn(12, 20, 1.0, &mut rng);
        for s in [Solver::Random, Solver::Svd, Solver::Snmf] {
            let (a, b) = s.factorize(&w, 5, 10, 0);
            assert_eq!((a.rows, a.cols), (12, 5), "{s}");
            assert_eq!((b.rows, b.cols), (5, 20), "{s}");
        }
    }
}
