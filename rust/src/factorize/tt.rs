//! Tensor-train (TT-matrix / MPO) factorization — the third solver family.
//!
//! LED/CED cut one global rank through a layer; the TT-matrix format
//! (Oseledets 2011; Novikov et al., *Tensorizing Neural Networks*, arXiv
//! 1509.06569) instead reshapes a `(m, n)` weight into a `d`-way tensor
//! over factorized mode dims `m = m_1⋯m_d`, `n = n_1⋯n_d` and writes
//!
//! ```text
//! W[(i_1..i_d), (j_1..j_d)] = G_1[i_1,j_1] · G_2[i_2,j_2] ⋯ G_d[i_d,j_d]
//! ```
//!
//! where core `G_k` is a `(r_{k-1}, m_k, n_k, r_k)` tensor and the products
//! contract over the internal TT ranks (`r_0 = r_d = 1`). Structured
//! weights (Kronecker-like mixing, separable patterns) admit tiny TT ranks
//! even when their flat singular spectrum blocks an LED cut, which is
//! exactly the per-layer frontier the `auto` chooser in
//! [`super::auto_fact`] navigates.
//!
//! Everything here is deterministic: the sweep is plain [`jacobi_svd`] per
//! unfolding, and the forward contraction routes every product through
//! [`matmul_into`], whose fixed k-order accumulation makes TT layers
//! reproduce bit-for-bit across thread counts like the dense/LED paths
//! (DESIGN.md §13).

use anyhow::bail;

use crate::linalg::matrix::matmul_into;
use crate::linalg::workspace::Workspace;
use crate::linalg::{jacobi_svd, Matrix};
use crate::tensor::{ParamStore, Tensor};
use crate::Result;

use super::energy::Spectrum;

/// Hard cap on TT cores per layer: hot paths pre-resolve the `tt0..ttK`
/// parameter names and stack-allocate core views at this bound, keeping the
/// decode loop free of per-step allocation.
pub const TT_MAX_MODES: usize = 6;

/// Configuration of the TT-SVD sweep.
#[derive(Clone, Copy, Debug)]
pub struct TtConfig {
    /// Number of tensor modes `d` (cores). 2–[`TT_MAX_MODES`].
    pub modes: usize,
    /// Total retained spectral energy τ ∈ (0, 1]: the sweep budgets the
    /// discarded energy so that ‖W − TT‖²_F ≤ (1 − τ)·‖W‖²_F (the TT-SVD
    /// bound: per-unfolding truncation errors add in squared Frobenius
    /// norm). τ = 1.0 keeps every rank — an exact round-trip.
    pub energy: f64,
    /// Optional hard cap on every internal rank r_k.
    pub max_rank: Option<usize>,
}

impl Default for TtConfig {
    fn default() -> Self {
        Self { modes: 3, energy: 0.9, max_rank: None }
    }
}

/// One TT core `G_k`, row-major `(r_in, m, n, r_out)`.
#[derive(Clone, Debug)]
pub struct TtCore {
    /// Incoming TT rank r_{k-1} (1 for the first core).
    pub r_in: usize,
    /// This mode's share of the input (row) dimension.
    pub m: usize,
    /// This mode's share of the output (column) dimension.
    pub n: usize,
    /// Outgoing TT rank r_k (1 for the last core).
    pub r_out: usize,
    /// The elements, row-major over `(r_in, m, n, r_out)`.
    pub data: Vec<f32>,
}

impl TtCore {
    /// Element count of this core.
    pub fn n_params(&self) -> usize {
        self.r_in * self.m * self.n * self.r_out
    }

    /// Borrow as a [`TtCoreView`].
    pub fn view(&self) -> TtCoreView<'_> {
        TtCoreView {
            r_in: self.r_in,
            m: self.m,
            n: self.n,
            r_out: self.r_out,
            data: &self.data,
        }
    }
}

/// Borrowed core used by the interpreter hot paths (built on the stack from
/// [`ParamStore`] tensors — no allocation).
#[derive(Clone, Copy, Debug)]
pub struct TtCoreView<'a> {
    /// Incoming TT rank r_{k-1}.
    pub r_in: usize,
    /// Mode input dim.
    pub m: usize,
    /// Mode output dim.
    pub n: usize,
    /// Outgoing TT rank r_k.
    pub r_out: usize,
    /// Elements, row-major `(r_in, m, n, r_out)`.
    pub data: &'a [f32],
}

impl TtCoreView<'static> {
    /// Placeholder view for stack arrays (coerces to any lifetime).
    pub fn empty() -> Self {
        TtCoreView { r_in: 0, m: 0, n: 0, r_out: 0, data: &[] }
    }
}

impl<'a> TtCoreView<'a> {
    /// View a `(r_in, m, n, r_out)` checkpoint tensor as a TT core.
    pub fn of_tensor(t: &'a Tensor) -> Result<Self> {
        if t.ndim() != 4 {
            bail!("TT core must be 4-D (r_in, m, n, r_out), got shape {:?}", t.shape);
        }
        Ok(TtCoreView {
            r_in: t.shape[0],
            m: t.shape[1],
            n: t.shape[2],
            r_out: t.shape[3],
            data: t.as_f32()?,
        })
    }
}

/// A full TT factorization of one `(m, n)` weight.
#[derive(Clone, Debug)]
pub struct TtParams {
    /// Input mode dims, `∏ m_k` = rows of W.
    pub m_dims: Vec<usize>,
    /// Output mode dims, `∏ n_k` = cols of W.
    pub n_dims: Vec<usize>,
    /// The cores, first to last.
    pub cores: Vec<TtCore>,
}

impl TtParams {
    /// Rows of the represented weight (`∏ m_k`).
    pub fn in_dim(&self) -> usize {
        self.m_dims.iter().product()
    }

    /// Cols of the represented weight (`∏ n_k`).
    pub fn out_dim(&self) -> usize {
        self.n_dims.iter().product()
    }

    /// The internal TT ranks `r_1..r_{d-1}` (boundary ranks are always 1).
    pub fn ranks(&self) -> Vec<usize> {
        self.cores[..self.cores.len().saturating_sub(1)]
            .iter()
            .map(|c| c.r_out)
            .collect()
    }

    /// Largest internal rank (1 for a single-core TT).
    pub fn max_rank(&self) -> usize {
        self.ranks().into_iter().max().unwrap_or(1)
    }

    /// Total stored elements across all cores.
    pub fn n_params(&self) -> usize {
        self.cores.iter().map(TtCore::n_params).sum()
    }

    /// Serialized size in bytes (f32 cores) — what the `auto` chooser
    /// compares against dense / LED byte counts.
    pub fn bytes(&self) -> usize {
        self.n_params() * std::mem::size_of::<f32>()
    }

    /// Materialize the represented `(m, n)` weight.
    pub fn reconstruct(&self) -> Matrix {
        let views: Vec<TtCoreView<'_>> = self.cores.iter().map(TtCore::view).collect();
        let (m, n, data) = tt_materialize(&views).expect("self-consistent cores");
        Matrix::from_vec(m, n, data)
    }

    /// `y(rows, n) = x(rows, m) @ W` without materializing W.
    pub fn apply(&self, rows: usize, x: &[f32]) -> Result<Vec<f32>> {
        let views: Vec<TtCoreView<'_>> = self.cores.iter().map(TtCore::view).collect();
        let mut ws = Workspace::new();
        let (_, y) = tt_apply_ws(rows, self.in_dim(), x, &views, &mut ws)?;
        Ok(y)
    }

    /// Insert the cores into `params` as `{prefix}tt0..tt{d-1}` (the
    /// interpreter's dispatch keys; `prefix` includes any trailing `/`).
    pub fn insert_into(self, params: &mut ParamStore, prefix: &str) {
        for (k, core) in self.cores.into_iter().enumerate() {
            let shape = [core.r_in, core.m, core.n, core.r_out];
            params.insert(format!("{prefix}tt{k}"), Tensor::from_f32(&shape, core.data));
        }
    }
}

/// Factor `dim` into `modes` near-balanced integer factors (descending
/// greedy: each slot takes the divisor of the remainder closest to the
/// geometric target). Primes degrade gracefully to `1 × … × dim`.
pub fn mode_dims(dim: usize, modes: usize) -> Vec<usize> {
    assert!(dim >= 1 && modes >= 1, "mode_dims({dim}, {modes})");
    let mut dims = Vec::with_capacity(modes);
    let mut rem = dim;
    for slots in (2..=modes).rev() {
        let target = (rem as f64).powf(1.0 / slots as f64);
        let mut best = 1usize;
        let mut best_gap = f64::INFINITY;
        for d in 1..=rem {
            if rem % d == 0 {
                let gap = (d as f64 - target).abs();
                if gap < best_gap {
                    best = d;
                    best_gap = gap;
                }
            }
        }
        dims.push(best);
        rem /= best;
    }
    dims.push(rem);
    dims
}

/// Row-major big-endian digit decomposition of `flat` over `dims`.
#[inline]
fn digits(mut flat: usize, dims: &[usize], out: &mut [usize]) {
    for k in (0..dims.len()).rev() {
        out[k] = flat % dims[k];
        flat /= dims[k];
    }
}

/// Permute the flat `(m, n)` weight into the grouped-pair TT tensor layout:
/// a `d`-way tensor with mode dims `g_k = m_k·n_k`, pair index
/// `g_k = i_k·n_k + j_k`, all indices row-major big-endian.
pub fn permute_w_to_t(w: &[f32], m_dims: &[usize], n_dims: &[usize]) -> Vec<f32> {
    let d = m_dims.len();
    debug_assert_eq!(d, n_dims.len());
    let n: usize = n_dims.iter().product();
    let g: Vec<usize> = (0..d).map(|k| m_dims[k] * n_dims[k]).collect();
    let total: usize = g.iter().product();
    debug_assert_eq!(w.len(), total);
    let mut t = vec![0.0f32; total];
    let mut gs = vec![0usize; d];
    for (tflat, slot) in t.iter_mut().enumerate() {
        digits(tflat, &g, &mut gs);
        let mut i = 0usize;
        let mut j = 0usize;
        for k in 0..d {
            i = i * m_dims[k] + gs[k] / n_dims[k];
            j = j * n_dims[k] + gs[k] % n_dims[k];
        }
        *slot = w[i * n + j];
    }
    t
}

/// Inverse of [`permute_w_to_t`]: grouped tensor back to the flat weight.
pub fn permute_t_to_w(t: &[f32], m_dims: &[usize], n_dims: &[usize]) -> Vec<f32> {
    let d = m_dims.len();
    debug_assert_eq!(d, n_dims.len());
    let n: usize = n_dims.iter().product();
    let g: Vec<usize> = (0..d).map(|k| m_dims[k] * n_dims[k]).collect();
    let total: usize = g.iter().product();
    debug_assert_eq!(t.len(), total);
    let mut w = vec![0.0f32; total];
    let mut gs = vec![0usize; d];
    for (tflat, &v) in t.iter().enumerate() {
        digits(tflat, &g, &mut gs);
        let mut i = 0usize;
        let mut j = 0usize;
        for k in 0..d {
            i = i * m_dims[k] + gs[k] / n_dims[k];
            j = j * n_dims[k] + gs[k] % n_dims[k];
        }
        w[i * n + j] = v;
    }
    w
}

/// TT-SVD sweep (Oseledets) over the grouped-pair tensor of `w`.
///
/// Each of the `d − 1` sequential unfoldings is truncated by the existing
/// spectral-energy selector ([`Spectrum::rank_for_energy`]): the total
/// discard budget `(1 − τ)·‖W‖²_F` is split evenly across unfoldings, so
/// the summed per-step truncation errors keep
/// `‖W − TT‖²_F ≤ (1 − τ)·‖W‖²_F`.
///
/// # Examples
///
/// A Kronecker-structured weight is exactly TT-rank-1 at `modes = 2`, even
/// though its flat spectrum is full-rank (where an LED cut cannot win):
///
/// ```
/// use greenformer::factorize::tt::{tt_svd, TtConfig};
/// use greenformer::linalg::Matrix;
/// use greenformer::util::Pcg64;
///
/// let mut rng = Pcg64::seeded(7);
/// let (a, b) = (Matrix::randn(8, 8, 1.0, &mut rng), Matrix::randn(8, 8, 1.0, &mut rng));
/// let mut w = Matrix::zeros(64, 64);
/// for i in 0..64 {
///     for j in 0..64 {
///         *w.at_mut(i, j) = a.at(i / 8, j / 8) * b.at(i % 8, j % 8);
///     }
/// }
/// let tt = tt_svd(&w, &TtConfig { modes: 2, energy: 0.999, max_rank: None }).unwrap();
/// assert_eq!(tt.ranks(), vec![1]); // 128 stored params vs 4096 dense
/// let err = w.sub(&tt.reconstruct()).fro_norm() / w.fro_norm();
/// assert!(err < 1e-3, "err={err}");
/// ```
pub fn tt_svd(w: &Matrix, cfg: &TtConfig) -> Result<TtParams> {
    if cfg.modes < 2 || cfg.modes > TT_MAX_MODES {
        bail!("TT modes must be in 2..={TT_MAX_MODES}, got {}", cfg.modes);
    }
    if !(0.0..=1.0).contains(&cfg.energy) || cfg.energy <= 0.0 {
        bail!("TT energy must be in (0, 1], got {}", cfg.energy);
    }
    let d = cfg.modes;
    let m_dims = mode_dims(w.rows, d);
    let n_dims = mode_dims(w.cols, d);
    let g: Vec<usize> = (0..d).map(|k| m_dims[k] * n_dims[k]).collect();
    let total_energy: f64 = w.data.iter().map(|&x| (x as f64) * (x as f64)).sum();
    // Evenly split discard budget; the per-step truncations are on
    // mutually orthogonal complements, so the squared errors add.
    let budget = (1.0 - cfg.energy) * total_energy / (d - 1) as f64;

    let mut c = permute_w_to_t(&w.data, &m_dims, &n_dims);
    let mut r_prev = 1usize;
    let mut cores = Vec::with_capacity(d);
    for k in 0..d - 1 {
        let rows = r_prev * g[k];
        let cols = c.len() / rows;
        let svd = jacobi_svd(&Matrix::from_vec(rows, cols, c));
        let spec = Spectrum::from_singular_values(&svd.s);
        let tau_step = if spec.total > 0.0 {
            ((spec.total - budget) / spec.total).max(0.0)
        } else {
            0.0
        };
        let mut r = spec.rank_for_energy(tau_step).max(1);
        if let Some(cap) = cfg.max_rank {
            r = r.min(cap.max(1));
        }
        r = r.min(svd.s.len());
        // Core k = leading left singular vectors, (r_prev, m_k, n_k, r).
        let mut core = vec![0.0f32; rows * r];
        for (dst, src) in core.chunks_exact_mut(r).zip(svd.u.data.chunks_exact(svd.u.cols)) {
            dst.copy_from_slice(&src[..r]);
        }
        cores.push(TtCore {
            r_in: r_prev,
            m: m_dims[k],
            n: n_dims[k],
            r_out: r,
            data: core,
        });
        // Carry C = diag(s_r) · Vt_r into the next unfolding.
        let mut next = vec![0.0f32; r * cols];
        for ((dst, src), &s) in next
            .chunks_exact_mut(cols)
            .zip(svd.vt.data.chunks_exact(cols))
            .zip(&svd.s[..r])
        {
            for (d, &v) in dst.iter_mut().zip(src) {
                *d = s * v;
            }
        }
        c = next;
        r_prev = r;
    }
    cores.push(TtCore {
        r_in: r_prev,
        m: m_dims[d - 1],
        n: n_dims[d - 1],
        r_out: 1,
        data: c,
    });
    Ok(TtParams { m_dims, n_dims, cores })
}

/// Validate that `cores` chain (`r_out == next r_in`, boundary ranks 1)
/// and map input dim `k`. Returns `(d, out_dim)`.
fn validate_chain(cores: &[TtCoreView<'_>], k: usize) -> Result<(usize, usize)> {
    let d = cores.len();
    if d == 0 || d > TT_MAX_MODES {
        bail!("TT group must have 1..={TT_MAX_MODES} cores, got {d}");
    }
    if cores[0].r_in != 1 || cores[d - 1].r_out != 1 {
        bail!("TT boundary ranks must be 1, got r_0={} r_d={}", cores[0].r_in, cores[d - 1].r_out);
    }
    let mut in_dim = 1usize;
    let mut out_dim = 1usize;
    for (idx, c) in cores.iter().enumerate() {
        if c.data.len() != c.r_in * c.m * c.n * c.r_out {
            bail!("TT core {idx}: data len {} != shape product", c.data.len());
        }
        if idx > 0 && cores[idx - 1].r_out != c.r_in {
            let prev = cores[idx - 1].r_out;
            bail!("TT cores {}/{idx} do not chain: r_out {prev} != r_in {}", idx - 1, c.r_in);
        }
        in_dim *= c.m;
        out_dim *= c.n;
    }
    if in_dim != k {
        bail!("TT input dim {in_dim} does not match activation dim {k}");
    }
    Ok((d, out_dim))
}

/// Workspace-backed TT-matvec: `y(rows, N) = x(rows, M) @ W` contracting
/// the cores left-to-right without ever materializing W. Returns `(N, y)`
/// with `y` drawn from `ws` (callers `give` it back).
///
/// Per core the running state `(P, r_{k-1}·m_k, S)` is transposed per-`P`
/// slab, multiplied by the core's natural `(r_{k-1}·m_k, n_k·r_k)` matrix
/// through one [`matmul_into`] call, and transposed back — so each output
/// element keeps the kernel's fixed ascending-k accumulation order and,
/// like the dense path, each activation row's outputs are independent of
/// how many other rows share the batch (the decode ≡ full-prefix and
/// batched ≡ solo contracts, DESIGN.md §10/§13). Steady-state buffer sizes
/// depend only on `rows` and the core shapes, so decode sessions reuse the
/// same workspace blocks step after step: zero allocations.
pub fn tt_apply_ws(
    rows: usize,
    k: usize,
    x: &[f32],
    cores: &[TtCoreView<'_>],
    ws: &mut Workspace,
) -> Result<(usize, Vec<f32>)> {
    debug_assert_eq!(x.len(), rows * k);
    let (d, out_dim) = validate_chain(cores, k)?;
    // Suffix products of the input mode dims: s[k] = ∏_{l>k} m_l.
    let mut m_suffix = [1usize; TT_MAX_MODES + 1];
    for idx in (0..d).rev() {
        m_suffix[idx] = m_suffix[idx + 1] * cores[idx].m;
    }
    let mut cur = ws.take_copied(x);
    let mut p = rows;
    for (idx, c) in cores.iter().enumerate() {
        let s = m_suffix[idx + 1];
        let ri = c.r_in * c.m;
        let nr = c.n * c.r_out;
        // (P, RI, S) -> (P, S, RI): per-P slab transpose.
        let mut t1 = ws.take_zeroed(p * s * ri);
        for pi in 0..p {
            let src = &cur[pi * ri * s..(pi + 1) * ri * s];
            let dst = &mut t1[pi * s * ri..(pi + 1) * s * ri];
            for a in 0..ri {
                for b in 0..s {
                    dst[b * ri + a] = src[a * s + b];
                }
            }
        }
        ws.give(cur);
        // One GEMM against the core's natural row-major matrix.
        let mut prod = ws.take_zeroed(p * s * nr);
        matmul_into(p * s, ri, nr, &t1, c.data, &mut prod);
        ws.give(t1);
        // (P, S, NR) -> (P, NR, S); the flat result reinterprets as
        // (P·n_k, r_k·m_{k+1}, S/m_{k+1}) for the next core.
        let mut t2 = ws.take_zeroed(p * nr * s);
        for pi in 0..p {
            let src = &prod[pi * s * nr..(pi + 1) * s * nr];
            let dst = &mut t2[pi * nr * s..(pi + 1) * nr * s];
            for a in 0..s {
                for b in 0..nr {
                    dst[b * s + a] = src[a * nr + b];
                }
            }
        }
        ws.give(prod);
        cur = t2;
        p *= c.n;
    }
    debug_assert_eq!(cur.len(), rows * out_dim);
    Ok((out_dim, cur))
}

/// Materialize the `(m, n)` weight a TT core chain represents. Returns
/// `(m, n, w)` row-major. Used by the backward pass and reports; the
/// forward/decode paths never call this.
pub fn tt_materialize(cores: &[TtCoreView<'_>]) -> Result<(usize, usize, Vec<f32>)> {
    let in_dim: usize = cores.iter().map(|c| c.m).product();
    let (_, out_dim) = validate_chain(cores, in_dim)?;
    // Left-to-right: acc (P, r_{k-1}) @ core (r_{k-1}, g_k·r_k) -> (P·g_k, r_k).
    let mut acc = vec![1.0f32];
    let mut pdim = 1usize;
    for c in cores {
        let gk = c.m * c.n;
        let mut next = vec![0.0f32; pdim * gk * c.r_out];
        matmul_into(pdim, c.r_in, gk * c.r_out, &acc, c.data, &mut next);
        acc = next;
        pdim *= gk;
    }
    let m_dims: Vec<usize> = cores.iter().map(|c| c.m).collect();
    let n_dims: Vec<usize> = cores.iter().map(|c| c.n).collect();
    let w = permute_t_to_w(&acc, &m_dims, &n_dims);
    Ok((in_dim, out_dim, w))
}

/// Per-core gradients `∂L/∂G_k` given the dense weight gradient
/// `dw (m, n)` of the materialized layer (`dw = xᵀ·dy` upstream).
///
/// Splitting the TT contraction at core `k` as
/// `T[p, g, q] = Σ_{α,β} A_k[p,α] · G_k[α,g,β] · B_k[β,q]` (left/right
/// environments accumulated by one GEMM per core each), the gradient is
/// two GEMMs: `dG_k = A_kᵀ · dT_k · B_kᵀ`, returned in each core's natural
/// row-major `(r_in, m, n, r_out)` layout, ready for `Grads::acc`.
pub fn tt_core_grads(cores: &[TtCoreView<'_>], dw: &[f32]) -> Result<Vec<Vec<f32>>> {
    let in_dim: usize = cores.iter().map(|c| c.m).product();
    let (d, out_dim) = validate_chain(cores, in_dim)?;
    debug_assert_eq!(dw.len(), in_dim * out_dim);
    let m_dims: Vec<usize> = cores.iter().map(|c| c.m).collect();
    let n_dims: Vec<usize> = cores.iter().map(|c| c.n).collect();
    let g: Vec<usize> = (0..d).map(|k| m_dims[k] * n_dims[k]).collect();
    let dt = permute_w_to_t(dw, &m_dims, &n_dims);

    // Left environments A_k (P_k, r_{k-1}), P_k = ∏_{l<k} g_l.
    let mut a_env: Vec<Vec<f32>> = vec![vec![1.0f32]];
    let mut pk = 1usize;
    for k in 0..d - 1 {
        let c = &cores[k];
        let mut next = vec![0.0f32; pk * g[k] * c.r_out];
        matmul_into(pk, c.r_in, g[k] * c.r_out, &a_env[k], c.data, &mut next);
        a_env.push(next);
        pk *= g[k];
    }
    // Right environments B_k (r_k, Q_k), Q_k = ∏_{l>k} g_l.
    let mut b_env: Vec<Vec<f32>> = vec![Vec::new(); d];
    b_env[d - 1] = vec![1.0f32];
    let mut q = 1usize;
    for k in (0..d - 1).rev() {
        let c = &cores[k + 1];
        // (r_in·g_{k+1}, r_out) @ (r_out, Q_{k+1}) -> (r_in, g_{k+1}·Q_{k+1}).
        let mut b = vec![0.0f32; c.r_in * g[k + 1] * q];
        matmul_into(c.r_in * g[k + 1], c.r_out, q, c.data, &b_env[k + 1], &mut b);
        b_env[k] = b;
        q *= g[k + 1];
    }

    let mut grads = Vec::with_capacity(d);
    let mut p_prod = 1usize;
    let mut q_prod: usize = g.iter().product::<usize>();
    for k in 0..d {
        let c = &cores[k];
        q_prod /= g[k];
        let (pk, qk) = (p_prod, q_prod);
        // M1 (r_in, g_k·Q_k) = A_kᵀ (r_in, P_k) @ dT (P_k, g_k·Q_k).
        let mut at = vec![0.0f32; pk * c.r_in];
        for i in 0..pk {
            for j in 0..c.r_in {
                at[j * pk + i] = a_env[k][i * c.r_in + j];
            }
        }
        let mut m1 = vec![0.0f32; c.r_in * g[k] * qk];
        matmul_into(c.r_in, pk, g[k] * qk, &at, &dt, &mut m1);
        // dG_k (r_in·g_k, r_out) = M1 (r_in·g_k, Q_k) @ B_kᵀ (Q_k, r_out).
        let mut bt = vec![0.0f32; qk * c.r_out];
        for i in 0..c.r_out {
            for j in 0..qk {
                bt[j * c.r_out + i] = b_env[k][i * qk + j];
            }
        }
        let mut dg = vec![0.0f32; c.r_in * g[k] * c.r_out];
        matmul_into(c.r_in * g[k], qk, c.r_out, &m1, &bt, &mut dg);
        grads.push(dg);
        p_prod *= g[k];
    }
    Ok(grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn randn(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Pcg64::seeded(seed);
        Matrix::randn(rows, cols, 1.0, &mut rng)
    }

    #[test]
    fn mode_dims_balanced_and_exact() {
        assert_eq!(mode_dims(64, 3), vec![4, 4, 4]);
        assert_eq!(mode_dims(512, 3), vec![8, 8, 8]);
        assert_eq!(mode_dims(768, 3), vec![8, 8, 12]);
        assert_eq!(mode_dims(7, 3), vec![1, 1, 7]); // prime: degrade to 1s
        assert_eq!(mode_dims(13, 2), vec![1, 13]);
        for (dim, modes) in [(128, 3), (192, 4), (30, 2), (97, 3)] {
            assert_eq!(mode_dims(dim, modes).iter().product::<usize>(), dim);
            assert_eq!(mode_dims(dim, modes).len(), modes);
        }
    }

    #[test]
    fn permutation_round_trips() {
        let w = randn(12, 18, 1);
        let (md, nd) = (mode_dims(12, 3), mode_dims(18, 3));
        let t = permute_w_to_t(&w.data, &md, &nd);
        assert_eq!(permute_t_to_w(&t, &md, &nd), w.data);
    }

    #[test]
    fn full_energy_round_trips_exactly() {
        for (m, n, modes, seed) in [(12, 18, 3, 2), (7, 13, 2, 3), (16, 16, 4, 4)] {
            let w = randn(m, n, seed);
            let cfg = TtConfig { modes, energy: 1.0, max_rank: None };
            let tt = tt_svd(&w, &cfg).unwrap();
            let err = w.sub(&tt.reconstruct()).fro_norm() / w.fro_norm();
            assert!(err < 1e-4, "({m},{n},{modes}): err={err}");
        }
    }

    #[test]
    fn energy_budget_bounds_reconstruction_error() {
        // Decaying spectrum, like trained weights.
        let w = crate::experiments::tables::trained_like_matrix(48, 40, 1.0, 9);
        for tau in [0.8, 0.9, 0.99] {
            let tt = tt_svd(&w, &TtConfig { modes: 3, energy: tau, max_rank: None }).unwrap();
            let err = w.sub(&tt.reconstruct()).fro_norm();
            let rel2 = err * err / (w.fro_norm() * w.fro_norm());
            assert!(rel2 <= (1.0 - tau) + 1e-5, "tau={tau}: rel2={rel2}");
        }
    }

    #[test]
    fn apply_matches_materialized_matvec() {
        let w = randn(24, 30, 5);
        let tt = tt_svd(&w, &TtConfig { modes: 3, energy: 0.95, max_rank: None }).unwrap();
        let wr = tt.reconstruct();
        let x = randn(4, 24, 6);
        let y = tt.apply(4, &x.data).unwrap();
        let want = x.matmul(&wr);
        for (a, b) in y.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn max_rank_cap_respected() {
        let w = randn(32, 32, 7);
        let tt = tt_svd(&w, &TtConfig { modes: 3, energy: 1.0, max_rank: Some(3) }).unwrap();
        assert!(tt.max_rank() <= 3, "ranks={:?}", tt.ranks());
    }

    #[test]
    fn store_round_trip_and_views() {
        let w = randn(12, 12, 8);
        let tt = tt_svd(&w, &TtConfig { modes: 2, energy: 1.0, max_rank: None }).unwrap();
        let want = tt.reconstruct();
        let mut store = ParamStore::new();
        tt.insert_into(&mut store, "fc/");
        let t0 = store.get("fc/tt0").unwrap();
        let t1 = store.get("fc/tt1").unwrap();
        let views = [TtCoreView::of_tensor(t0).unwrap(), TtCoreView::of_tensor(t1).unwrap()];
        let (m, n, data) = tt_materialize(&views).unwrap();
        assert_eq!((m, n), (12, 12));
        assert_eq!(data, want.data);
    }

    #[test]
    fn bad_chains_rejected() {
        let c0 = TtCore { r_in: 1, m: 2, n: 2, r_out: 3, data: vec![0.0; 12] };
        let c1 = TtCore { r_in: 2, m: 2, n: 2, r_out: 1, data: vec![0.0; 8] };
        let views = [c0.view(), c1.view()];
        assert!(tt_materialize(&views).is_err());
        let mut ws = Workspace::new();
        assert!(tt_apply_ws(1, 4, &[0.0; 4], &views, &mut ws).is_err());
    }
}
