//! Energy-based automatic rank selection — the paper's future-work
//! "dynamic rank" taken one step further.
//!
//! Instead of a fixed ratio of r_max, pick each layer's rank from its own
//! spectrum: the smallest r whose leading singular values retain a target
//! fraction τ of the spectral energy (Σ_{i≤r} σ_i² ≥ τ · Σ σ_i²), then
//! round to the TPU lane multiple and apply the Eq.-1 gate as usual. Layers
//! with concentrated spectra (trained layers, typically) compress far
//! harder than the fixed-ratio policy would dare; flat-spectrum layers are
//! left dense instead of being damaged.

use crate::linalg::{jacobi_svd, Matrix};

use super::rank::{r_max, MIN_RANK, RANK_MULTIPLE};

/// Spectral profile of one weight matrix.
#[derive(Clone, Debug)]
pub struct Spectrum {
    /// Squared singular values, descending.
    pub energies: Vec<f64>,
    /// Total spectral energy (Σ σ_i² = ‖W‖_F²).
    pub total: f64,
}

impl Spectrum {
    /// Compute the spectrum of `w` via the one-sided Jacobi SVD.
    pub fn of(w: &Matrix) -> Self {
        Self::from_singular_values(&jacobi_svd(w).s)
    }

    /// Build a spectrum from already-computed singular values (descending).
    /// The TT-SVD sweep reuses this to truncate each unfolding with the
    /// same selector as the LED energy policy.
    pub fn from_singular_values(s: &[f32]) -> Self {
        let energies: Vec<f64> = s.iter().map(|&s| (s as f64) * (s as f64)).collect();
        let total = energies.iter().sum();
        Spectrum { energies, total }
    }

    /// Smallest r with cumulative energy ≥ tau * total (tau in (0, 1]).
    pub fn rank_for_energy(&self, tau: f64) -> usize {
        assert!((0.0..=1.0).contains(&tau), "tau must be in (0, 1]");
        let target = tau * self.total;
        let mut acc = 0.0;
        for (i, e) in self.energies.iter().enumerate() {
            acc += e;
            if acc >= target - 1e-12 {
                return i + 1;
            }
        }
        self.energies.len()
    }

    /// Effective rank (exp of spectral entropy) — a scale-free measure of
    /// how concentrated the spectrum is.
    pub fn effective_rank(&self) -> f64 {
        if self.total <= 0.0 {
            return 0.0;
        }
        let mut h = 0.0;
        for &e in &self.energies {
            let p = e / self.total;
            if p > 1e-300 {
                h -= p * p.ln();
            }
        }
        h.exp()
    }
}

/// Resolve an energy threshold to a concrete, gated rank for `w`:
/// spectrum → energy rank → round down to [`RANK_MULTIPLE`] (clamped up to
/// [`MIN_RANK`]) → Eq.-1 gate. Returns None when the layer should stay
/// dense (needs more than break-even rank to keep τ energy).
pub fn energy_rank(w: &Matrix, tau: f64) -> Option<usize> {
    let spec = Spectrum::of(w);
    let raw = spec.rank_for_energy(tau);
    let mut r = (raw.div_ceil(RANK_MULTIPLE)) * RANK_MULTIPLE; // round UP: keep ≥ τ
    if r < MIN_RANK {
        r = MIN_RANK;
    }
    if (r as f64) >= r_max(w.rows, w.cols) {
        None
    } else {
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn low_rank(m: usize, n: usize, k: usize, rng: &mut Pcg64) -> Matrix {
        let u = Matrix::randn(m, k, 1.0, rng);
        let v = Matrix::randn(k, n, 1.0, rng);
        u.matmul(&v)
    }

    #[test]
    fn exact_low_rank_found() {
        let mut rng = Pcg64::seeded(80);
        let w = low_rank(64, 48, 5, &mut rng);
        let spec = Spectrum::of(&w);
        assert_eq!(spec.rank_for_energy(0.9999), 5);
        assert!(spec.effective_rank() <= 5.5);
    }

    #[test]
    fn full_energy_needs_full_rank_on_noise() {
        let mut rng = Pcg64::seeded(81);
        let w = Matrix::randn(30, 20, 1.0, &mut rng);
        let spec = Spectrum::of(&w);
        assert_eq!(spec.rank_for_energy(1.0), 20);
        // Flat spectrum: effective rank near min dim.
        assert!(spec.effective_rank() > 14.0);
    }

    #[test]
    fn rank_monotone_in_tau() {
        let mut rng = Pcg64::seeded(82);
        let w = Matrix::randn(40, 40, 1.0, &mut rng);
        let spec = Spectrum::of(&w);
        let mut last = 0;
        for tau in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99] {
            let r = spec.rank_for_energy(tau);
            assert!(r >= last, "tau={tau}");
            last = r;
        }
    }

    #[test]
    fn energy_rank_gates_flat_spectra() {
        let mut rng = Pcg64::seeded(83);
        // Flat spectrum at high tau: energy rank ~ min dim > r_max -> dense.
        let w = Matrix::randn(64, 64, 1.0, &mut rng);
        assert_eq!(energy_rank(&w, 0.99), None);
        // Concentrated spectrum: tiny rank accepted.
        let lr = low_rank(64, 64, 4, &mut rng);
        let r = energy_rank(&lr, 0.999).expect("low-rank layer must factorize");
        assert!(r <= 16, "r={r}");
        assert_eq!(r % RANK_MULTIPLE, 0);
    }

    #[test]
    fn retained_energy_actually_reached() {
        // Reconstruction at the energy rank must keep >= tau of the energy.
        // (decaying spectrum, like trained weights)
        let w = crate::experiments::tables::trained_like_matrix(48, 40, 1.0, 5);
        let tau = 0.9;
        let spec = Spectrum::of(&w);
        let r = spec.rank_for_energy(tau);
        let (a, b) = crate::linalg::svd_factorize(&w, r);
        let err2 = {
            let d = w.sub(&a.matmul(&b)).fro_norm();
            d * d
        };
        let retained = 1.0 - err2 / spec.total;
        assert!(retained >= tau - 1e-3, "retained={retained}");
    }
}
