//! The serving loop: queue → router → batcher/decoder → backend → responses.
//!
//! Thread-based (the offline build has no async runtime — and none is
//! needed: graph execution is the only blocking operation and it is CPU
//! bound). One dispatcher thread owns all batchers and all in-flight decode
//! sessions; execution happens on the dispatcher so batches are strictly
//! ordered per variant. Clients block on a oneshot-style channel (classify)
//! or consume a streaming token channel (generate); concurrency comes from
//! client threads.
//!
//! Two request kinds share one queue and one router:
//!
//! * [`ClassifyRequest`] — one token window in, one [`ClassifyResponse`]
//!   out, dynamically batched per variant.
//! * [`GenerateRequest`] — KV-cached autoregressive decoding
//!   ([`crate::backend::DecodeSession`]) under **continuous batching**:
//!   each dispatcher iteration runs one *decode sweep* that advances every
//!   active session one token as a single stacked
//!   [`Backend::run_decode_step_batched`] call per variant, with each
//!   sampled token streamed to its client as a [`TokenEvent`] the moment it
//!   exists. New sessions prefill on arrival and merge into the next sweep;
//!   finished sessions drop out without stalling the batch. Admission is
//!   controlled by [`ServeConfig::max_sessions`] — beyond it, requests are
//!   shed with a typed [`TokenEvent::Rejected`] — and the decode/classify
//!   interleave is governed by [`FairnessConfig`]. See SERVING.md for the
//!   full serving model.
//!
//! With [`ServeConfig::spec`] set, the server also accepts **speculative**
//! generations ([`ServerHandle::generate_speculative`]): at startup it
//! builds one LED draft checkpoint per variant
//! ([`crate::backend::build_draft_params`]), and each speculative session
//! ([`crate::backend::SpecSession`]) advances one draft→verify→rollback
//! round per decode sweep — emitting up to `k + 1` tokens per sweep —
//! alongside the plain stacked sessions. Spec rounds are excluded from the
//! merged-step counters (they are not stacked steps) and feed the
//! speculation ledger on [`Metrics`] instead.
//!
//! Execution goes through the [`Backend`] abstraction: the PJRT engine when
//! AOT artifacts resolve, the pure-Rust [`NativeBackend`] otherwise — so the
//! full serving path runs (and is tested, see
//! `tests/integration_serving_native.rs`) on a fresh checkout with no
//! `artifacts/` and no XLA runtime. Generation is native-only: PJRT's
//! fixed-shape fwd graphs refuse `run_decode_step` and the client receives
//! a clean [`TokenEvent::Failed`].
//!
//! Invariants (pinned by rust/tests/proptest_coordinator.rs and the serving
//! integration tests):
//! * every submitted request receives exactly one terminal outcome — a
//!   classify response/error, or a `Done`/`Failed`/`Rejected` event ending
//!   its stream;
//! * a batched decode sweep is value-identical to advancing each session
//!   solo (`tests/proptest_batched_decode.rs`), so continuous batching
//!   never changes any stream's tokens;
//! * executed batches never exceed the artifact batch size;
//! * padding rows never produce responses;
//! * responses carry the variant that actually served them;
//! * a malformed request (wrong token length, out-of-range ids, classify on
//!   an LM variant, generate on a classifier variant) gets an error
//!   response and never panics the dispatcher;
//! * a fixed sampling seed reproduces the same token stream.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::batcher::{plan, Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::router::{Router, Tier};
use crate::backend::{
    build_draft_params, native, sample_token, Backend, DecodeSession, NativeBackend, PjrtBackend,
    SamplingCfg, SpecConfig, SpecSession,
};
use crate::runtime::{Engine, GraphSpec};
use crate::tensor::{ParamStore, Tensor};
use crate::util::{BackoffCfg, Pcg64};
use crate::Result;

/// Per-request outcome sent back over the classify response channel: the
/// response, or a rejection/failure message (`String`, so the channel stays
/// `Send`).
pub type ServeResult = std::result::Result<ClassifyResponse, String>;

/// Anything a client can submit to the dispatcher queue.
pub enum Request {
    /// Classifier inference over one token window (dynamically batched).
    Classify(ClassifyRequest),
    /// KV-cached autoregressive generation (streamed tokens).
    Generate(GenerateRequest),
}

/// A text-classification request: tokens (seq,) + quality tier.
pub struct ClassifyRequest {
    /// Token window; must match the variant graph's `seq` dimension.
    pub tokens: Vec<i32>,
    /// Requested quality tier (the router maps it to a variant).
    pub tier: Tier,
    resp: SyncSender<ServeResult>,
}

/// One classify outcome: logits, argmax label, serving variant, latency.
#[derive(Clone, Debug)]
pub struct ClassifyResponse {
    /// Class logits of this request's row.
    pub logits: Vec<f32>,
    /// Argmax over `logits`.
    pub label: usize,
    /// The variant that actually served the request.
    pub variant: String,
    /// Queue + batch + execution time as seen by this request.
    pub latency: Duration,
}

/// An autoregressive generation request: prompt in, token stream out.
pub struct GenerateRequest {
    /// Prompt token ids (prefilled in one step; must fit the model's
    /// positional capacity).
    pub prompt: Vec<i32>,
    /// Maximum number of tokens to generate (≥ 1).
    pub max_new: usize,
    /// Sampling policy (greedy / top-k / temperature, seeded).
    pub sampling: SamplingCfg,
    /// Requested quality tier (the router maps it to a variant).
    pub tier: Tier,
    /// Serve this request speculatively (draft + verify) instead of one
    /// token per sweep. Requires [`ServeConfig::spec`]; otherwise the
    /// stream fails cleanly with [`TokenEvent::Failed`].
    pub speculative: bool,
    /// When the client submitted the request (latency is measured from
    /// here, so queue wait is included).
    submitted: Instant,
    resp: SyncSender<TokenEvent>,
}

/// One event on a generation stream. Clients receive zero or more `Token`
/// events followed by exactly one terminal `Done`, `Failed` or `Rejected`.
#[derive(Clone, Debug)]
pub enum TokenEvent {
    /// One sampled token, streamed as soon as the decode step produced it.
    Token {
        /// 0-based position of this token in the generated stream.
        index: usize,
        /// The sampled token id.
        token: i32,
    },
    /// Generation finished; carries the full result.
    Done(GenerateResponse),
    /// Generation was rejected or died mid-stream; no further events follow.
    Failed(String),
    /// Admission control shed the request before any decode work ran; no
    /// further events follow. Unlike [`TokenEvent::Failed`] the request was
    /// well-formed — the server chose load over latency collapse, and the
    /// client may retry later.
    Rejected(ShedReason),
}

/// Why admission control shed a generate request (the typed counterpart of
/// the free-text [`TokenEvent::Failed`] message, so clients can branch on
/// it and retry policies stay mechanical).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The decode scheduler already holds [`ServeConfig::max_sessions`]
    /// concurrent sessions.
    SessionsFull {
        /// Live decode sessions when the request was dequeued.
        active: usize,
        /// The configured admission ceiling.
        max: usize,
    },
    /// The bounded submit queue was full at enqueue time — a client-side
    /// fail-fast from the `_or_shed` submit paths; the dispatcher never saw
    /// the request.
    QueueFull {
        /// The configured queue bound ([`ServeConfig::queue_capacity`]).
        capacity: usize,
    },
}

impl ShedReason {
    /// Suggested minimum client backoff before retrying — the `Retry-After`
    /// hint the HTTP front end serializes. A full submit queue clears in
    /// roughly one batch flush; a saturated decode scheduler holds sessions
    /// for whole generations and takes longer to drain.
    pub fn retry_after(&self) -> Duration {
        match self {
            ShedReason::SessionsFull { .. } => Duration::from_millis(50),
            ShedReason::QueueFull { .. } => Duration::from_millis(10),
        }
    }
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShedReason::SessionsFull { active, max } => {
                write!(f, "decode scheduler at capacity ({active}/{max} sessions)")
            }
            ShedReason::QueueFull { capacity } => {
                write!(f, "submit queue at capacity ({capacity} requests)")
            }
        }
    }
}

/// Typed outcome of the `_or_shed` client paths ([`ServerHandle::classify_or_shed`],
/// [`ServerHandle::generate_or_shed`], [`drain_stream_or_shed`]), so callers
/// can branch mechanically: retry `Overloaded` (it carries the hint), report
/// `Failed`, give up on `Shutdown`.
///
/// Implements `std::error::Error` — the vendored `anyhow` has no downcast,
/// so retry-able overloads must stay a real type end to end; `?` still
/// converts into the crate-wide error via the blanket `From`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control shed the request. Retryable: wait at least
    /// `retry_after`, then resubmit (see [`crate::util::try_with_backoff`]).
    Overloaded {
        /// Why the request was shed.
        reason: ShedReason,
        /// Suggested minimum delay before retrying.
        retry_after: Duration,
    },
    /// The request was rejected as malformed or died mid-flight; not
    /// retryable.
    Failed(String),
    /// The dispatcher is gone; not retryable.
    Shutdown,
}

impl ServeError {
    /// `Some(hint)` when the error is retryable — exactly the shape
    /// [`crate::util::try_with_backoff`] consumes as its retry predicate.
    pub fn retry_after(&self) -> Option<Duration> {
        match self {
            ServeError::Overloaded { retry_after, .. } => Some(*retry_after),
            ServeError::Failed(_) | ServeError::Shutdown => None,
        }
    }

    fn overloaded(reason: ShedReason) -> Self {
        ServeError::Overloaded { retry_after: reason.retry_after(), reason }
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { reason, retry_after } => {
                write!(f, "server overloaded: {reason} (retry after {}ms)", retry_after.as_millis())
            }
            ServeError::Failed(msg) => write!(f, "request failed: {msg}"),
            ServeError::Shutdown => write!(f, "server shut down"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Terminal summary of one generation.
#[derive(Clone, Debug)]
pub struct GenerateResponse {
    /// All generated token ids, in stream order (prompt not repeated).
    pub tokens: Vec<i32>,
    /// The variant that actually served the generation.
    pub variant: String,
    /// Prompt length consumed by the prefill step.
    pub prefill_tokens: usize,
    /// Submission-to-`Done` wall time as seen by this request.
    pub latency: Duration,
}

/// Handle returned by [`serve_classifier`]: submit requests, inspect
/// metrics. Dropping all clones shuts the dispatcher down (after a flush —
/// in-flight generations run to completion since their token streams may
/// outlive the handle).
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<Request>,
    /// Shared serving counters (requests, per-token decode counters,
    /// latency histogram).
    pub metrics: Arc<Metrics>,
    depth: Arc<AtomicUsize>,
    /// Configured queue bound, echoed into [`ShedReason::QueueFull`].
    queue_capacity: usize,
}

impl ServerHandle {
    /// Submit a classify request and block until the batch containing it
    /// executes.
    pub fn classify(&self, tokens: Vec<i32>, tier: Tier) -> Result<ClassifyResponse> {
        let (tx, rx) = sync_channel(1);
        self.metrics.record_request();
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Request::Classify(ClassifyRequest {
                tokens,
                tier,
                resp: tx,
            }))
            .map_err(|_| anyhow!("server shut down"))?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => Err(anyhow!("request rejected: {msg}")),
            Err(_) => Err(anyhow!("request dropped (server shut down mid-batch)")),
        }
    }

    /// Non-blocking classify submit; Err(tokens) when the queue is full.
    pub fn try_classify(
        &self,
        tokens: Vec<i32>,
        tier: Tier,
    ) -> std::result::Result<Receiver<ServeResult>, Vec<i32>> {
        let (tx, rx) = sync_channel(1);
        let req = ClassifyRequest {
            tokens,
            tier,
            resp: tx,
        };
        match self.tx.try_send(Request::Classify(req)) {
            Ok(()) => {
                self.metrics.record_request();
                self.depth.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(TrySendError::Full(Request::Classify(req)))
            | Err(TrySendError::Disconnected(Request::Classify(req))) => Err(req.tokens),
            Err(_) => unreachable!("classify submit returned a non-classify request"),
        }
    }

    /// Submit a generation request; returns the token stream immediately.
    ///
    /// The stream yields one [`TokenEvent::Token`] per sampled token as the
    /// dispatcher's continuous-batching sweeps advance the session
    /// (stacked with every other live session of the same variant), then a
    /// terminal [`TokenEvent::Done`] or [`TokenEvent::Failed`] — or a
    /// single [`TokenEvent::Rejected`] when admission control sheds the
    /// request at the [`ServeConfig::max_sessions`] ceiling. The channel is
    /// buffered for the whole stream, so a slow consumer never blocks the
    /// dispatcher.
    pub fn generate(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingCfg,
        tier: Tier,
    ) -> Result<Receiver<TokenEvent>> {
        self.submit_generate(prompt, max_new, sampling, tier, false)
    }

    /// Submit a **speculative** generation request; returns the token
    /// stream immediately.
    ///
    /// Same contract as [`ServerHandle::generate`], but the session is
    /// served by a [`SpecSession`]: the variant's LED draft model proposes
    /// up to [`SpecConfig::k`] tokens per sweep and the target verifies
    /// them in one stacked pass, so a stream can receive several `Token`
    /// events per sweep. Under greedy sampling the token stream is
    /// identical to the plain [`ServerHandle::generate`] stream. If the
    /// server was built without [`ServeConfig::spec`], the stream fails
    /// cleanly with a single [`TokenEvent::Failed`].
    pub fn generate_speculative(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingCfg,
        tier: Tier,
    ) -> Result<Receiver<TokenEvent>> {
        self.submit_generate(prompt, max_new, sampling, tier, true)
    }

    fn submit_generate(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingCfg,
        tier: Tier,
        speculative: bool,
    ) -> Result<Receiver<TokenEvent>> {
        let (tx, rx) = sync_channel(max_new + 2);
        self.metrics.record_request();
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Request::Generate(GenerateRequest {
                prompt,
                max_new,
                sampling,
                tier,
                speculative,
                submitted: Instant::now(),
                resp: tx,
            }))
            .map_err(|_| anyhow!("server shut down"))?;
        Ok(rx)
    }

    /// Blocking convenience over [`ServerHandle::generate`]: drain the
    /// stream and return the terminal [`GenerateResponse`].
    pub fn generate_collect(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingCfg,
        tier: Tier,
    ) -> Result<GenerateResponse> {
        drain_stream(self.generate(prompt, max_new, sampling, tier)?)
    }

    /// Blocking convenience over [`ServerHandle::generate_speculative`]:
    /// drain the stream and return the terminal [`GenerateResponse`].
    pub fn generate_speculative_collect(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingCfg,
        tier: Tier,
    ) -> Result<GenerateResponse> {
        drain_stream(self.generate_speculative(prompt, max_new, sampling, tier)?)
    }

    /// Requests submitted but not yet answered (the adaptive router's
    /// input). In-flight generations count until their terminal event.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Typed, fail-fast classify: like [`ServerHandle::classify`], but a
    /// full submit queue returns [`ServeError::Overloaded`] immediately
    /// (with its retry hint) instead of blocking, and rejections keep their
    /// typed shape. Queue-full sheds happen client-side — they are *not*
    /// recorded in [`Metrics`] (the dispatcher never saw the request); the
    /// HTTP front end tallies them in its own counters.
    pub fn classify_or_shed(
        &self,
        tokens: Vec<i32>,
        tier: Tier,
    ) -> std::result::Result<ClassifyResponse, ServeError> {
        let (tx, rx) = sync_channel(1);
        let req = ClassifyRequest { tokens, tier, resp: tx };
        match self.tx.try_send(Request::Classify(req)) {
            Ok(()) => {
                self.metrics.record_request();
                self.depth.fetch_add(1, Ordering::Relaxed);
            }
            Err(TrySendError::Full(_)) => {
                return Err(ServeError::overloaded(ShedReason::QueueFull {
                    capacity: self.queue_capacity,
                }))
            }
            Err(TrySendError::Disconnected(_)) => return Err(ServeError::Shutdown),
        }
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => Err(ServeError::Failed(msg)),
            Err(_) => Err(ServeError::Shutdown),
        }
    }

    /// Typed, fail-fast generate submit: like [`ServerHandle::generate`],
    /// but a full submit queue returns [`ServeError::Overloaded`]
    /// immediately instead of blocking. The returned stream can still end
    /// in [`TokenEvent::Rejected`] (the dispatcher's own admission
    /// control); [`drain_stream_or_shed`] maps that back to the same typed
    /// error.
    pub fn generate_or_shed(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingCfg,
        tier: Tier,
    ) -> std::result::Result<Receiver<TokenEvent>, ServeError> {
        let (tx, rx) = sync_channel(max_new + 2);
        let req = GenerateRequest {
            prompt,
            max_new,
            sampling,
            tier,
            speculative: false,
            submitted: Instant::now(),
            resp: tx,
        };
        match self.tx.try_send(Request::Generate(req)) {
            Ok(()) => {
                self.metrics.record_request();
                self.depth.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(TrySendError::Full(_)) => Err(ServeError::overloaded(ShedReason::QueueFull {
                capacity: self.queue_capacity,
            })),
            Err(TrySendError::Disconnected(_)) => Err(ServeError::Shutdown),
        }
    }

    /// Typed blocking convenience over [`ServerHandle::generate_or_shed`]:
    /// drain the stream to its terminal event.
    pub fn generate_collect_or_shed(
        &self,
        prompt: Vec<i32>,
        max_new: usize,
        sampling: SamplingCfg,
        tier: Tier,
    ) -> std::result::Result<GenerateResponse, ServeError> {
        drain_stream_or_shed(self.generate_or_shed(prompt, max_new, sampling, tier)?)
    }

    /// [`ServerHandle::classify_or_shed`] under bounded exponential backoff:
    /// `Overloaded` errors retry per `cfg` (honoring each shed's
    /// `retry_after` hint, sleeping for real); `Failed`/`Shutdown` return
    /// immediately. See [`crate::util::try_with_backoff`] for the schedule.
    pub fn classify_with_backoff(
        &self,
        tokens: &[i32],
        tier: Tier,
        cfg: &BackoffCfg,
    ) -> std::result::Result<ClassifyResponse, ServeError> {
        crate::util::try_with_backoff(
            cfg,
            |_| self.classify_or_shed(tokens.to_vec(), tier),
            ServeError::retry_after,
            std::thread::sleep,
        )
    }

    /// [`ServerHandle::generate_collect_or_shed`] under bounded exponential
    /// backoff, mirroring [`ServerHandle::classify_with_backoff`]: sheds
    /// (queue-full *and* the dispatcher's session-ceiling rejections) retry
    /// per `cfg`; failures return immediately.
    pub fn generate_collect_with_backoff(
        &self,
        prompt: &[i32],
        max_new: usize,
        sampling: SamplingCfg,
        tier: Tier,
        cfg: &BackoffCfg,
    ) -> std::result::Result<GenerateResponse, ServeError> {
        crate::util::try_with_backoff(
            cfg,
            |_| self.generate_collect_or_shed(prompt.to_vec(), max_new, sampling, tier),
            ServeError::retry_after,
            std::thread::sleep,
        )
    }
}

/// Drain one token stream to its terminal event with a **typed** error:
/// [`TokenEvent::Rejected`] becomes [`ServeError::Overloaded`] (retryable,
/// hint attached), [`TokenEvent::Failed`] becomes [`ServeError::Failed`],
/// and a dropped channel becomes [`ServeError::Shutdown`].
pub fn drain_stream_or_shed(
    rx: Receiver<TokenEvent>,
) -> std::result::Result<GenerateResponse, ServeError> {
    loop {
        match rx.recv() {
            Ok(TokenEvent::Token { .. }) => continue,
            Ok(TokenEvent::Done(resp)) => return Ok(resp),
            Ok(TokenEvent::Failed(msg)) => return Err(ServeError::Failed(msg)),
            Ok(TokenEvent::Rejected(reason)) => return Err(ServeError::overloaded(reason)),
            Err(_) => return Err(ServeError::Shutdown),
        }
    }
}

/// Drain one token stream to its terminal event, mapping failures/sheds to
/// errors.
fn drain_stream(rx: Receiver<TokenEvent>) -> Result<GenerateResponse> {
    loop {
        match rx.recv() {
            Ok(TokenEvent::Token { .. }) => continue,
            Ok(TokenEvent::Done(resp)) => return Ok(resp),
            Ok(TokenEvent::Failed(msg)) => return Err(anyhow!("generate rejected: {msg}")),
            Ok(TokenEvent::Rejected(reason)) => return Err(anyhow!("generate shed: {reason}")),
            Err(_) => return Err(anyhow!("generate dropped (server shut down mid-stream)")),
        }
    }
}

struct Pending {
    tokens: Vec<i32>,
    arrived: Instant,
    resp: SyncSender<ServeResult>,
}

/// How one in-flight generation advances per decode sweep.
enum DecodeEngine {
    /// One KV-cached session, one token per sweep, stacked into the
    /// variant's batched step with every other plain session.
    Plain(DecodeSession),
    /// Draft + target session pair; one speculative round (up to `k + 1`
    /// tokens) per sweep. Sampling state lives inside the [`SpecSession`].
    Spec(SpecSession),
}

/// One in-flight generation owned by the dispatcher: the decode engine
/// plus everything needed to sample, stream and finish it.
struct ActiveDecode {
    variant: String,
    engine: DecodeEngine,
    /// Sampling policy; for [`DecodeEngine::Spec`] the session carries its
    /// own copy and `sampling`/`rng` here are unused.
    sampling: SamplingCfg,
    rng: Pcg64,
    max_new: usize,
    /// Sampled tokens so far; the last one is what the next decode step
    /// appends to the cache.
    tokens: Vec<i32>,
    prefill_tokens: usize,
    /// Client submission time (latency includes queue wait).
    arrived: Instant,
    resp: SyncSender<TokenEvent>,
}

impl ActiveDecode {
    /// Positional capacity left on the cache that gates this stream (the
    /// target cache for speculative sessions).
    fn remaining(&self) -> usize {
        match &self.engine {
            DecodeEngine::Plain(s) => s.remaining(),
            DecodeEngine::Spec(s) => s.target().remaining(),
        }
    }
}

/// What a backend factory hands the dispatcher: the executor plus one fwd
/// graph (real or synthesized) per variant.
pub type BackendBundle = (Box<dyn Backend>, HashMap<String, GraphSpec>);

/// Resolve the PJRT bundle over a loaded engine: one fwd graph per variant
/// (largest batch ≤ `max_batch`, falling back to the largest available),
/// with the executable cache warmed so first requests don't pay compile
/// time. Startup errors (missing graph, compile failure) are returned.
fn pjrt_bundle(
    engine: Engine,
    model: &str,
    variants: &HashMap<String, ParamStore>,
    max_batch: usize,
) -> Result<BackendBundle> {
    let mut graphs = HashMap::new();
    for name in variants.keys() {
        let g = engine
            .manifest()
            .find(model, name, "fwd", Some(max_batch.max(1)))
            .or_else(|_| engine.manifest().find(model, name, "fwd", None))
            .cloned()?;
        engine.executable(&g.name)?;
        graphs.insert(name.clone(), g);
    }
    Ok((Box::new(PjrtBackend::from_engine(engine)), graphs))
}

/// Build the native bundle: synthesize a fwd spec per variant directly from
/// its checkpoint — no artifacts required.
fn native_bundle(
    model: &str,
    variants: &HashMap<String, ParamStore>,
    max_batch: usize,
) -> Result<BackendBundle> {
    let mut graphs = HashMap::new();
    for (name, store) in variants {
        let g = native::synth_fwd_graph(model, name, max_batch.max(1), store)?;
        graphs.insert(name.clone(), g);
    }
    Ok((Box::new(NativeBackend::new()), graphs))
}

/// Decode/classify interleave policy for the dispatcher loop — the explicit
/// form of what used to be hard-coded ("one decode token per idle
/// iteration").
///
/// Each dispatcher iteration ingests at most `drain_per_sweep` queued
/// requests (classify admission + generate prefills), then runs
/// `sweeps_per_iteration` decode sweeps, each advancing *every* active
/// session one token. The defaults (8 / 1) mean a sustained classify
/// backlog can delay a decode sweep by at most eight ingests, and decode
/// work can never starve classify ingestion — see SERVING.md for the
/// fairness analysis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FairnessConfig {
    /// Queued requests ingested per dispatcher iteration before decoding
    /// resumes (must be ≥ 1).
    pub drain_per_sweep: usize,
    /// Decode sweeps per dispatcher iteration (must be ≥ 1; each sweep is
    /// one stacked token step over all active sessions).
    pub sweeps_per_iteration: usize,
}

impl Default for FairnessConfig {
    fn default() -> Self {
        FairnessConfig { drain_per_sweep: 8, sweeps_per_iteration: 1 }
    }
}

/// Serving policy for one dispatcher: dynamic-batching shape, queue bound,
/// decode admission ceiling, and the decode/classify fairness policy.
///
/// Backpressure is layered: the submit queue holds at most
/// `queue_capacity` requests (blocking [`ServerHandle::classify`] /
/// [`ServerHandle::generate`] block there; [`ServerHandle::try_classify`]
/// fails fast), and at most `max_sessions` generate requests hold live
/// decode sessions — beyond that the dispatcher sheds with a typed
/// [`TokenEvent::Rejected`] instead of letting per-token latency collapse
/// for every stream.
///
/// # Examples
///
/// ```
/// use greenformer::coordinator::{BatcherConfig, FairnessConfig, ServeConfig};
///
/// let cfg = ServeConfig {
///     max_sessions: 4,     // admission ceiling: shed the 5th concurrent stream
///     queue_capacity: 32,  // bounded submit queue
///     ..ServeConfig::default()
/// };
/// assert_eq!(cfg.fairness, FairnessConfig::default());
/// assert_eq!(cfg.batcher.max_batch, BatcherConfig::default().max_batch);
/// ```
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Classify dynamic-batching shape (size-or-deadline per variant).
    pub batcher: BatcherConfig,
    /// Bound of the shared submit queue (requests, classify + generate).
    pub queue_capacity: usize,
    /// Maximum concurrent decode sessions before generate requests are
    /// shed with [`ShedReason::SessionsFull`] (must be ≥ 1).
    pub max_sessions: usize,
    /// Decode/classify interleave policy.
    pub fairness: FairnessConfig,
    /// Speculative-decoding policy. `Some` makes the server build one LED
    /// draft checkpoint per variant at startup and accept
    /// [`ServerHandle::generate_speculative`] requests; `None` (the
    /// default) rejects them per-request with a clean
    /// [`TokenEvent::Failed`].
    pub spec: Option<SpecConfig>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batcher: BatcherConfig::default(),
            queue_capacity: 256,
            max_sessions: 64,
            fairness: FairnessConfig::default(),
            spec: None,
        }
    }
}

impl ServeConfig {
    /// Convenience for the common "tune the batcher, default the rest"
    /// call sites.
    pub fn with_batcher(batcher: BatcherConfig, queue_capacity: usize) -> Self {
        ServeConfig { batcher, queue_capacity, ..ServeConfig::default() }
    }

    /// Reject zero-valued knobs that would wedge the dispatcher (a queue
    /// that admits nothing, a scheduler that never decodes).
    fn validate(&self) -> Result<()> {
        if self.queue_capacity == 0 {
            anyhow::bail!("ServeConfig.queue_capacity must be >= 1");
        }
        if self.max_sessions == 0 {
            anyhow::bail!("ServeConfig.max_sessions must be >= 1");
        }
        if self.fairness.drain_per_sweep == 0 {
            anyhow::bail!("FairnessConfig.drain_per_sweep must be >= 1");
        }
        if self.fairness.sweeps_per_iteration == 0 {
            anyhow::bail!("FairnessConfig.sweeps_per_iteration must be >= 1");
        }
        if let Some(spec) = &self.spec {
            spec.validate()?;
        }
        Ok(())
    }
}

/// Spawn the serving loop for one model family, selecting the backend
/// automatically: PJRT when `artifacts_dir` holds a manifest and the runtime
/// loads, the native interpreter otherwise. With artifacts present, a
/// variant without a fwd graph is still a synchronous startup error (it
/// signals a store/manifest mismatch, not a missing runtime).
///
/// `variants` maps variant name → its trained/factorized checkpoint.
/// Requests route per `router`. The dispatcher thread builds its *own*
/// backend: the PJRT client wrapper is `Rc`-based and cannot cross threads,
/// so the thread that executes graphs owns the client.
pub fn serve_classifier(
    artifacts_dir: std::path::PathBuf,
    model: &str,
    variants: HashMap<String, ParamStore>,
    router: Router,
    cfg: ServeConfig,
) -> Result<ServerHandle> {
    let model = model.to_string();
    let max_batch = cfg.batcher.max_batch;
    serve_classifier_with(
        move |variants| {
            if artifacts_dir.join("manifest.json").exists() {
                match Engine::load(artifacts_dir.clone()) {
                    Ok(engine) => return pjrt_bundle(engine, &model, variants, max_batch),
                    Err(e) => {
                        eprintln!("PJRT runtime unavailable ({e:#}); serving on native backend");
                    }
                }
            }
            native_bundle(&model, variants, max_batch)
        },
        variants,
        router,
        cfg,
    )
}

/// [`serve_classifier`] pinned to the native backend — fully hermetic, used
/// by the artifact-free serving tests and benches. Despite the name the
/// model family is the caller's choice: pass `model = "lm"` with LM
/// checkpoints (head width = vocab) to stand up a generation server —
/// classify requests are then rejected per-request, generate requests
/// stream tokens.
pub fn serve_classifier_native(
    model: &str,
    variants: HashMap<String, ParamStore>,
    router: Router,
    cfg: ServeConfig,
) -> Result<ServerHandle> {
    let model = model.to_string();
    let max_batch = cfg.batcher.max_batch;
    serve_classifier_with(
        move |variants| native_bundle(&model, variants, max_batch),
        variants,
        router,
        cfg,
    )
}

/// Core serving entry point, generic over how the backend is built. The
/// factory runs *on the dispatcher thread* (backends need not be `Send`) and
/// must return a graph for every variant key; its error is reported
/// synchronously from this call.
pub fn serve_classifier_with(
    factory: impl FnOnce(&HashMap<String, ParamStore>) -> Result<BackendBundle> + Send + 'static,
    variants: HashMap<String, ParamStore>,
    router: Router,
    cfg: ServeConfig,
) -> Result<ServerHandle> {
    cfg.validate()?;
    let metrics = Arc::new(Metrics::new());
    let depth = Arc::new(AtomicUsize::new(0));
    let queue_capacity = cfg.queue_capacity;
    let (tx, rx) = sync_channel::<Request>(queue_capacity);
    // Rendezvous for startup success/failure.
    let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);

    let metrics_bg = metrics.clone();
    let depth_bg = depth.clone();
    std::thread::Builder::new()
        .name("gf-dispatch".into())
        .spawn(move || {
            // The backend lives on this thread for its whole life.
            let (backend, graphs) = match factory(&variants) {
                Ok(bundle) => bundle,
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            for name in variants.keys() {
                if !graphs.contains_key(name) {
                    let _ = ready_tx.send(Err(anyhow!("backend returned no graph for {name:?}")));
                    return;
                }
            }
            // Speculation enabled: factorize one LED draft per variant up
            // front (drafts share the variant's graph — LED preserves every
            // I/O shape). A failed factorization is a synchronous startup
            // error, like a missing graph.
            let mut drafts: HashMap<String, ParamStore> = HashMap::new();
            if let Some(spec) = &cfg.spec {
                for (name, store) in &variants {
                    match build_draft_params(store, spec.draft_ratio) {
                        Ok(d) => {
                            drafts.insert(name.clone(), d);
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(anyhow!(
                                "building LED draft for variant {name:?}: {e:#}"
                            )));
                            return;
                        }
                    }
                }
            }
            let _ = ready_tx.send(Ok(()));
            dispatch_loop(
                backend.as_ref(),
                graphs,
                variants,
                drafts,
                router,
                cfg,
                rx,
                metrics_bg,
                depth_bg,
            );
        })
        .expect("spawning dispatcher");

    ready_rx
        .recv()
        .map_err(|_| anyhow!("dispatcher died during startup"))??;
    Ok(ServerHandle { tx, metrics, depth, queue_capacity })
}

#[allow(clippy::too_many_arguments)]
fn dispatch_loop(
    backend: &dyn Backend,
    graphs: HashMap<String, GraphSpec>,
    variants: HashMap<String, ParamStore>,
    drafts: HashMap<String, ParamStore>,
    router: Router,
    cfg: ServeConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
    depth: Arc<AtomicUsize>,
) {
    // One batcher per variant: executed batches are variant-homogeneous.
    let mut batchers: HashMap<String, (Batcher, Vec<Pending>)> = graphs
        .keys()
        .map(|k| {
            // Effective per-variant max batch: bounded by the artifact.
            let eff = BatcherConfig {
                max_batch: cfg.batcher.max_batch.min(graphs[k].batch),
                max_wait: cfg.batcher.max_wait,
            };
            (k.clone(), (Batcher::new(eff), Vec::new()))
        })
        .collect();
    // In-flight generations under continuous batching: every decode sweep
    // advances all of them one token, stacked into one batched step per
    // variant. Sessions join after their prefill and leave on completion
    // without stalling the others.
    let mut active: Vec<ActiveDecode> = Vec::new();

    loop {
        let now = Instant::now();
        let next_deadline = batchers
            .values()
            .filter_map(|(b, _)| b.time_to_deadline(now))
            .min();

        // Ingest phase: block only when there is no decode work; otherwise
        // take what the queue already holds, bounded by the fairness policy
        // so a deep classify backlog delays the next decode sweep by at
        // most `drain_per_sweep` ingests.
        let first = if active.is_empty() {
            match next_deadline {
                Some(d) => rx.recv_timeout(d),
                None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
            }
        } else {
            match rx.try_recv() {
                Ok(m) => Ok(m),
                Err(TryRecvError::Empty) => Err(RecvTimeoutError::Timeout),
                Err(TryRecvError::Disconnected) => Err(RecvTimeoutError::Disconnected),
            }
        };
        let mut disconnected = false;
        match first {
            Ok(msg) => {
                handle_request(
                    msg, backend, &graphs, &variants, &drafts, &router, &mut batchers,
                    &mut active, &cfg, &metrics, &depth,
                );
                for _ in 1..cfg.fairness.drain_per_sweep {
                    match rx.try_recv() {
                        Ok(msg) => handle_request(
                            msg, backend, &graphs, &variants, &drafts, &router, &mut batchers,
                            &mut active, &cfg, &metrics, &depth,
                        ),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            disconnected = true;
                            break;
                        }
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => disconnected = true,
        }

        // Deadline pass every iteration (not just on an idle timeout): with
        // live decode sessions the loop never blocks, and a partial classify
        // batch must still flush once its max_wait expires.
        flush_due_batches(backend, &graphs, &variants, &mut batchers, &metrics, &depth);

        if disconnected {
            // All handles dropped: flush whatever is queued and exit.
            for (variant, (batcher, pendings)) in batchers.iter_mut() {
                if let Some(ids) = batcher.flush() {
                    let taken = std::mem::take(pendings);
                    depth.fetch_sub(taken.len(), Ordering::Relaxed);
                    run_batch(
                        backend,
                        &graphs[variant],
                        &variants[variant],
                        variant,
                        ids,
                        taken,
                        &metrics,
                    );
                }
            }
            // Token streams may outlive the submitting handle — sweep every
            // in-flight generation to completion before exiting.
            while !active.is_empty() {
                decode_sweep(backend, &graphs, &variants, &drafts, &mut active, &metrics, &depth);
            }
            break;
        }

        // Decode phase: each sweep advances every active session one token
        // — one stacked batched step per variant — so sustained classify
        // traffic (a never-empty queue) cannot starve generations, and no
        // session can starve another.
        for _ in 0..cfg.fairness.sweeps_per_iteration {
            if active.is_empty() {
                break;
            }
            decode_sweep(backend, &graphs, &variants, &drafts, &mut active, &metrics, &depth);
        }
    }
}

/// Execute every classify batch whose `max_wait` deadline has passed.
fn flush_due_batches(
    backend: &dyn Backend,
    graphs: &HashMap<String, GraphSpec>,
    variants: &HashMap<String, ParamStore>,
    batchers: &mut HashMap<String, (Batcher, Vec<Pending>)>,
    metrics: &Metrics,
    depth: &AtomicUsize,
) {
    let now = Instant::now();
    for (variant, (batcher, pendings)) in batchers.iter_mut() {
        if let Some(ids) = batcher.poll_deadline(now) {
            let taken = std::mem::take(pendings);
            depth.fetch_sub(taken.len(), Ordering::Relaxed);
            run_batch(
                backend,
                &graphs[variant],
                &variants[variant],
                variant,
                ids,
                taken,
                metrics,
            );
        }
    }
}

/// Ingest one queued request: admit a classify row into its variant's
/// batcher (executing the batch if it filled), or admit/shed + prefill a
/// generation. Runs on the dispatcher thread.
#[allow(clippy::too_many_arguments)]
fn handle_request(
    msg: Request,
    backend: &dyn Backend,
    graphs: &HashMap<String, GraphSpec>,
    variants: &HashMap<String, ParamStore>,
    drafts: &HashMap<String, ParamStore>,
    router: &Router,
    batchers: &mut HashMap<String, (Batcher, Vec<Pending>)>,
    active: &mut Vec<ActiveDecode>,
    cfg: &ServeConfig,
    metrics: &Metrics,
    depth: &AtomicUsize,
) {
    match msg {
        Request::Classify(req) => {
            let variant = router
                .route(req.tier, depth.load(Ordering::Relaxed))
                .to_string();
            let (batcher, pendings) = batchers
                .get_mut(&variant)
                .expect("router validated variants at build");
            pendings.push(Pending {
                tokens: req.tokens,
                arrived: Instant::now(),
                resp: req.resp,
            });
            if let Some(ids) = batcher.push(pendings.len() - 1, Instant::now()) {
                let taken = std::mem::take(pendings);
                depth.fetch_sub(taken.len(), Ordering::Relaxed);
                run_batch(
                    backend,
                    &graphs[&variant],
                    &variants[&variant],
                    &variant,
                    ids,
                    taken,
                    metrics,
                );
            }
        }
        Request::Generate(req) => {
            // Admission control: beyond the session ceiling, shed with a
            // typed rejection instead of letting every stream's per-token
            // latency collapse. Sheds are terminal and counted separately
            // from errors (the request was well-formed).
            if active.len() >= cfg.max_sessions {
                metrics.record_shed();
                depth.fetch_sub(1, Ordering::Relaxed);
                let _ = req.resp.send(TokenEvent::Rejected(ShedReason::SessionsFull {
                    active: active.len(),
                    max: cfg.max_sessions,
                }));
                return;
            }
            if let Some(state) =
                start_decode(backend, graphs, variants, drafts, router, req, cfg, metrics, depth)
            {
                active.push(state);
            }
        }
    }
}

/// One continuous-batching decode sweep: advance every active plain session
/// one token — stacked into a single [`Backend::run_decode_step_batched`]
/// call per variant (sessions only stack over a shared checkpoint) — and
/// every speculative session one draft→verify→rollback round (up to
/// `k + 1` tokens). Finished sessions leave `active`; survivors are
/// regrouped, preserving arrival order within each variant.
fn decode_sweep(
    backend: &dyn Backend,
    graphs: &HashMap<String, GraphSpec>,
    variants: &HashMap<String, ParamStore>,
    drafts: &HashMap<String, ParamStore>,
    active: &mut Vec<ActiveDecode>,
    metrics: &Metrics,
    depth: &AtomicUsize,
) {
    let mut groups: Vec<(String, Vec<ActiveDecode>)> = Vec::new();
    let mut specs: Vec<ActiveDecode> = Vec::new();
    for state in active.drain(..) {
        match state.engine {
            DecodeEngine::Spec(_) => specs.push(state),
            DecodeEngine::Plain(_) => match groups.iter_mut().find(|(v, _)| *v == state.variant) {
                Some((_, members)) => members.push(state),
                None => groups.push((state.variant.clone(), vec![state])),
            },
        }
    }
    for (variant, mut group) in groups {
        let graph = &graphs[&variant];
        let store = &variants[&variant];
        let tokens: Vec<i32> = group
            .iter()
            .map(|s| *s.tokens.last().expect("active decode has at least one sampled token"))
            .collect();
        let step = {
            let mut sessions: Vec<&mut DecodeSession> = group
                .iter_mut()
                .map(|s| match &mut s.engine {
                    DecodeEngine::Plain(sess) => sess,
                    DecodeEngine::Spec(_) => unreachable!("spec sessions are swept separately"),
                })
                .collect();
            backend.run_decode_step_batched(graph, store, &mut sessions, &tokens)
        };
        match step {
            Ok(all_logits) => {
                metrics.record_decode_step(group.len());
                for (mut state, logits) in group.into_iter().zip(all_logits) {
                    if !emit_token(&mut state, &logits, metrics, depth) {
                        active.push(state);
                    }
                }
            }
            Err(e) => {
                // The stacked step validates every session before touching
                // any cache, so a failure is systemic (malformed model) and
                // fails the whole group — each member gets its terminal
                // event.
                for state in group {
                    decode_failed(&state.resp, format!("decode step failed: {e:#}"), metrics, depth);
                }
            }
        }
    }
    // Speculative sessions advance independently (their verify pass is
    // already a stacked multi-row step on the target). A failed round
    // fails only its own stream — speculation errors are per-session, not
    // systemic. Spec rounds are deliberately absent from the merged-step
    // counters: `record_decode_step` measures plain-sweep stacking.
    for mut state in specs {
        let graph = &graphs[&state.variant];
        let store = &variants[&state.variant];
        let draft_store = &drafts[&state.variant];
        let max_emit = state.max_new - state.tokens.len();
        let round = match &mut state.engine {
            DecodeEngine::Spec(session) => {
                session.step(backend, graph, store, graph, draft_store, max_emit)
            }
            DecodeEngine::Plain(_) => unreachable!("plain sessions are swept above"),
        };
        match round {
            Ok(step) => {
                metrics.record_spec_step(step.drafted, step.accepted, step.rolled_back > 0);
                if !emit_spec_tokens(&mut state, &step.tokens, metrics, depth) {
                    active.push(state);
                }
            }
            Err(e) => decode_failed(
                &state.resp,
                format!("speculative step failed: {e:#}"),
                metrics,
                depth,
            ),
        }
    }
}

/// Reject/fail one generation: error metrics, depth bookkeeping, terminal
/// event. (Send failures are fine — the client may have gone away.)
fn decode_failed(
    resp: &SyncSender<TokenEvent>,
    msg: String,
    metrics: &Metrics,
    depth: &AtomicUsize,
) {
    metrics.record_error();
    depth.fetch_sub(1, Ordering::Relaxed);
    let _ = resp.send(TokenEvent::Failed(msg));
}

/// Route + validate + prefill one generation request. Returns the active
/// session when it must keep running, `None` when it already finished
/// (single-token and degenerate generations) or failed.
#[allow(clippy::too_many_arguments)]
fn start_decode(
    backend: &dyn Backend,
    graphs: &HashMap<String, GraphSpec>,
    variants: &HashMap<String, ParamStore>,
    drafts: &HashMap<String, ParamStore>,
    router: &Router,
    req: GenerateRequest,
    cfg: &ServeConfig,
    metrics: &Metrics,
    depth: &AtomicUsize,
) -> Option<ActiveDecode> {
    let variant = router
        .route(req.tier, depth.load(Ordering::Relaxed))
        .to_string();
    let graph = &graphs[&variant];
    let store = &variants[&variant];
    if req.max_new == 0 || req.prompt.is_empty() {
        // Degenerate but well-formed — mirror `backend::generate`: an
        // empty stream that finishes cleanly, not an error.
        let latency = Instant::now().duration_since(req.submitted);
        metrics.record_latency(latency);
        metrics.record_decode_done(&variant);
        depth.fetch_sub(1, Ordering::Relaxed);
        let _ = req.resp.send(TokenEvent::Done(GenerateResponse {
            tokens: Vec::new(),
            variant,
            prefill_tokens: 0,
            latency,
        }));
        return None;
    }
    if req.speculative {
        let Some(spec) = cfg.spec else {
            decode_failed(
                &req.resp,
                "speculative decoding is not enabled on this server (set ServeConfig.spec)"
                    .to_string(),
                metrics,
                depth,
            );
            return None;
        };
        let draft_store = &drafts[&variant];
        // The draft shares the target's graph: LED factorization preserves
        // every I/O shape, and decoding reads only the graph's config.
        let (session, first) = match SpecSession::new(
            backend,
            graph,
            store,
            graph,
            draft_store,
            &req.prompt,
            req.sampling,
            &spec,
        ) {
            Ok(pair) => pair,
            Err(e) => {
                decode_failed(
                    &req.resp,
                    format!("speculative prefill failed: {e:#}"),
                    metrics,
                    depth,
                );
                return None;
            }
        };
        metrics.record_prefill_tokens(req.prompt.len());
        metrics.record_spec_prefill_sample();
        let mut state = ActiveDecode {
            variant,
            engine: DecodeEngine::Spec(session),
            sampling: req.sampling,
            rng: req.sampling.rng(),
            max_new: req.max_new,
            tokens: Vec::with_capacity(req.max_new),
            prefill_tokens: req.prompt.len(),
            arrived: req.submitted,
            resp: req.resp,
        };
        return if emit_spec_tokens(&mut state, &[first], metrics, depth) {
            None
        } else {
            Some(state)
        };
    }
    let mut session = match DecodeSession::new(graph, store) {
        Ok(s) => s,
        Err(e) => {
            decode_failed(
                &req.resp,
                format!("variant {variant:?} cannot decode: {e:#}"),
                metrics,
                depth,
            );
            return None;
        }
    };
    let logits = match backend.run_decode_step(graph, store, &mut session, &req.prompt) {
        Ok(t) => t,
        Err(e) => {
            decode_failed(&req.resp, format!("prefill failed: {e:#}"), metrics, depth);
            return None;
        }
    };
    metrics.record_prefill_tokens(req.prompt.len());
    let rng = req.sampling.rng();
    let mut state = ActiveDecode {
        variant,
        engine: DecodeEngine::Plain(session),
        sampling: req.sampling,
        rng,
        max_new: req.max_new,
        tokens: Vec::with_capacity(req.max_new),
        prefill_tokens: req.prompt.len(),
        arrived: req.submitted,
        resp: req.resp,
    };
    if emit_token(&mut state, &logits, metrics, depth) {
        None
    } else {
        Some(state)
    }
}

/// Sample + stream one token from `logits` (plain sessions only). Returns
/// true when the session reached a terminal state (Done sent) — the caller
/// then drops it.
fn emit_token(
    state: &mut ActiveDecode,
    logits: &Tensor,
    metrics: &Metrics,
    depth: &AtomicUsize,
) -> bool {
    let data = match logits.as_f32() {
        Ok(d) => d,
        Err(e) => {
            decode_failed(
                &state.resp,
                format!("decode produced non-f32 logits: {e:#}"),
                metrics,
                depth,
            );
            return true;
        }
    };
    let tok = sample_token(data, &state.sampling, &mut state.rng) as i32;
    let _ = state.resp.send(TokenEvent::Token {
        index: state.tokens.len(),
        token: tok,
    });
    state.tokens.push(tok);
    metrics.record_generated_tokens(1);
    if state.tokens.len() >= state.max_new || state.remaining() == 0 {
        finish_stream(state, metrics, depth);
        return true;
    }
    false
}

/// Stream every token one speculative round emitted (already sampled by
/// the [`SpecSession`]). Returns true when the session reached a terminal
/// state (Done sent) — the caller then drops it.
fn emit_spec_tokens(
    state: &mut ActiveDecode,
    toks: &[i32],
    metrics: &Metrics,
    depth: &AtomicUsize,
) -> bool {
    for &tok in toks {
        let _ = state.resp.send(TokenEvent::Token {
            index: state.tokens.len(),
            token: tok,
        });
        state.tokens.push(tok);
    }
    metrics.record_generated_tokens(toks.len());
    debug_assert!(state.tokens.len() <= state.max_new, "spec round overshot max_new");
    if state.tokens.len() >= state.max_new || state.remaining() == 0 {
        finish_stream(state, metrics, depth);
        return true;
    }
    false
}

/// Send the terminal [`TokenEvent::Done`] for a finished stream and settle
/// its latency/depth bookkeeping.
fn finish_stream(state: &mut ActiveDecode, metrics: &Metrics, depth: &AtomicUsize) {
    let latency = Instant::now().duration_since(state.arrived);
    metrics.record_latency(latency);
    metrics.record_decode_done(&state.variant);
    depth.fetch_sub(1, Ordering::Relaxed);
    let _ = state.resp.send(TokenEvent::Done(GenerateResponse {
        tokens: state.tokens.clone(),
        variant: state.variant.clone(),
        prefill_tokens: state.prefill_tokens,
        latency,
    }));
}

fn run_batch(
    backend: &dyn Backend,
    graph: &GraphSpec,
    params: &ParamStore,
    variant: &str,
    ids: Vec<usize>,
    pendings: Vec<Pending>,
    metrics: &Metrics,
) {
    // Classify needs pooled (batch, classes) logits; an LM variant emits
    // per-position logits and must reject cleanly rather than misread its
    // seq dim as the class count.
    if graph.outputs[0].shape.len() != 2 {
        for i in ids {
            metrics.record_error();
            let _ = pendings[i].resp.send(Err(format!(
                "variant {variant:?} serves per-position LM logits; classify is unsupported — \
                 submit a generate request instead"
            )));
        }
        return;
    }
    let artifact_batch = graph.batch;
    let seq = graph.inputs[0].shape[1];
    let classes = graph.outputs[0].shape[1];

    // Bounds-check requests against the graph: token length vs the seq dim,
    // and token ids vs the vocab when the graph records it. A malformed
    // request gets an error response; it must never panic the dispatcher or
    // fail the well-formed requests co-batched with it.
    let vocab = graph.config_usize("vocab").ok();
    let mut valid = Vec::with_capacity(ids.len());
    for i in ids {
        let toks = &pendings[i].tokens;
        let reject = if toks.len() != seq {
            Some(format!("token length {} does not match model seq {seq}", toks.len()))
        } else if let Some(v) = vocab {
            toks.iter()
                .find(|&&t| t < 0 || t as usize >= v)
                .map(|&t| format!("token id {t} out of range (vocab {v})"))
        } else {
            None
        };
        match reject {
            None => valid.push(i),
            Some(msg) => {
                metrics.record_error();
                let _ = pendings[i].resp.send(Err(msg));
            }
        }
    }
    if valid.is_empty() {
        return;
    }
    let p = plan(valid, artifact_batch);

    let mut toks = Vec::with_capacity(artifact_batch * seq);
    for &i in &p.members {
        toks.extend_from_slice(&pendings[i].tokens);
    }
    toks.resize(artifact_batch * seq, 0); // PAD rows
    let x = Tensor::from_i32(&[artifact_batch, seq], toks);

    match backend.run_fwd(graph, params, &[x]) {
        Ok(out) => {
            let logits = out[0].as_f32().expect("f32 logits");
            metrics.record_batch(p.members.len(), p.pad_rows, variant);
            let finished = Instant::now();
            for (row, &i) in p.members.iter().enumerate() {
                let pend = &pendings[i];
                let row_logits = logits[row * classes..(row + 1) * classes].to_vec();
                let label = row_logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                let latency = finished.duration_since(pend.arrived);
                metrics.record_latency(latency);
                let _ = pend.resp.send(Ok(ClassifyResponse {
                    logits: row_logits,
                    label,
                    variant: variant.to_string(),
                    latency,
                }));
            }
        }
        Err(e) => {
            eprintln!("batch execution failed on {variant}: {e:#}");
            for &i in &p.members {
                metrics.record_error();
                let _ = pendings[i]
                    .resp
                    .send(Err(format!("batch execution failed: {e:#}")));
            }
        }
    }
}
