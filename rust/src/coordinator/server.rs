//! The serving loop: queue → router → batcher → engine → responses.
//!
//! Thread-based (the offline build has no async runtime — and none is
//! needed: PJRT execution is the only blocking operation and it is CPU
//! bound). One dispatcher thread owns all batchers; execution happens on the
//! dispatcher so batches are strictly ordered per variant. Clients block on
//! a oneshot-style channel; concurrency comes from client threads.
//!
//! Invariants (pinned by rust/tests/proptest_coordinator.rs):
//! * every submitted request receives exactly one response or an error;
//! * executed batches never exceed the artifact batch size;
//! * padding rows never produce responses;
//! * responses carry the variant that actually served them.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::batcher::{plan, Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::router::{Router, Tier};
use crate::runtime::Engine;
use crate::tensor::{ParamStore, Tensor};
use crate::Result;

/// A text-classification request: tokens (seq,) + quality tier.
pub struct ClassifyRequest {
    pub tokens: Vec<i32>,
    pub tier: Tier,
    resp: SyncSender<ClassifyResponse>,
}

#[derive(Clone, Debug)]
pub struct ClassifyResponse {
    pub logits: Vec<f32>,
    pub label: usize,
    pub variant: String,
    pub latency: Duration,
}

/// Handle returned by [`serve_classifier`]: submit requests, inspect
/// metrics. Dropping all clones shuts the dispatcher down (after a flush).
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<ClassifyRequest>,
    pub metrics: Arc<Metrics>,
    depth: Arc<AtomicUsize>,
}

impl ServerHandle {
    /// Submit a request and block until the batch containing it executes.
    pub fn classify(&self, tokens: Vec<i32>, tier: Tier) -> Result<ClassifyResponse> {
        let (tx, rx) = sync_channel(1);
        self.metrics.record_request();
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(ClassifyRequest {
                tokens,
                tier,
                resp: tx,
            })
            .map_err(|_| anyhow!("server shut down"))?;
        rx.recv().map_err(|_| anyhow!("request dropped (batch failed)"))
    }

    /// Non-blocking submit; Err(tokens) when the queue is full.
    pub fn try_classify(
        &self,
        tokens: Vec<i32>,
        tier: Tier,
    ) -> std::result::Result<Receiver<ClassifyResponse>, Vec<i32>> {
        let (tx, rx) = sync_channel(1);
        let req = ClassifyRequest {
            tokens,
            tier,
            resp: tx,
        };
        match self.tx.try_send(req) {
            Ok(()) => {
                self.metrics.record_request();
                self.depth.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(TrySendError::Full(req)) | Err(TrySendError::Disconnected(req)) => {
                Err(req.tokens)
            }
        }
    }

    /// Requests submitted but not yet answered (the adaptive router's input).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }
}

struct Pending {
    tokens: Vec<i32>,
    arrived: Instant,
    resp: SyncSender<ClassifyResponse>,
}

/// Spawn the serving loop for one model family.
///
/// `variants` maps variant name → its trained/factorized checkpoint. Each
/// variant must have a fwd graph in the manifest; the largest batch ≤
/// `cfg.max_batch` is used. Requests route per `router`.
///
/// The dispatcher thread builds its *own* [`Engine`] over `artifacts_dir`:
/// the PJRT client wrapper is `Rc`-based and cannot cross threads, so each
/// thread that executes graphs owns a client. Startup errors (bad variant,
/// missing graph, compile failure) are reported synchronously.
pub fn serve_classifier(
    artifacts_dir: std::path::PathBuf,
    model: &str,
    variants: HashMap<String, ParamStore>,
    router: Router,
    cfg: BatcherConfig,
    queue_capacity: usize,
) -> Result<ServerHandle> {
    let metrics = Arc::new(Metrics::new());
    let depth = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = sync_channel::<ClassifyRequest>(queue_capacity);
    // Rendezvous for startup success/failure.
    let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);

    let metrics_bg = metrics.clone();
    let depth_bg = depth.clone();
    let model = model.to_string();
    std::thread::Builder::new()
        .name("gf-dispatch".into())
        .spawn(move || {
            // Engine lives on this thread for its whole life.
            let engine = match Engine::load(artifacts_dir) {
                Ok(e) => e,
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            // Resolve one fwd graph per variant and warm the executable
            // cache so first requests don't pay compile time.
            let mut graphs = HashMap::new();
            for name in variants.keys() {
                let g = engine
                    .manifest()
                    .find(&model, name, "fwd", Some(cfg.max_batch.max(1)))
                    .or_else(|_| engine.manifest().find(&model, name, "fwd", None))
                    .cloned();
                match g.and_then(|g| engine.executable(&g.name).map(|_| g)) {
                    Ok(g) => {
                        graphs.insert(name.clone(), g);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                }
            }
            let _ = ready_tx.send(Ok(()));
            dispatch_loop(engine, graphs, variants, router, cfg, rx, metrics_bg, depth_bg);
        })
        .expect("spawning dispatcher");

    ready_rx
        .recv()
        .map_err(|_| anyhow!("dispatcher died during startup"))??;
    Ok(ServerHandle { tx, metrics, depth })
}

#[allow(clippy::too_many_arguments)]
fn dispatch_loop(
    engine: Engine,
    graphs: HashMap<String, crate::runtime::GraphSpec>,
    variants: HashMap<String, ParamStore>,
    router: Router,
    cfg: BatcherConfig,
    rx: Receiver<ClassifyRequest>,
    metrics: Arc<Metrics>,
    depth: Arc<AtomicUsize>,
) {
    // One batcher per variant: executed batches are variant-homogeneous.
    let mut batchers: HashMap<String, (Batcher, Vec<Pending>)> = graphs
        .keys()
        .map(|k| {
            // Effective per-variant max batch: bounded by the artifact.
            let eff = BatcherConfig {
                max_batch: cfg.max_batch.min(graphs[k].batch),
                max_wait: cfg.max_wait,
            };
            (k.clone(), (Batcher::new(eff), Vec::new()))
        })
        .collect();

    loop {
        let now = Instant::now();
        let next_deadline = batchers
            .values()
            .filter_map(|(b, _)| b.time_to_deadline(now))
            .min();

        let msg = match next_deadline {
            Some(d) => rx.recv_timeout(d),
            None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
        };

        match msg {
            Ok(req) => {
                let variant = router
                    .route(req.tier, depth.load(Ordering::Relaxed))
                    .to_string();
                let (batcher, pendings) = batchers
                    .get_mut(&variant)
                    .expect("router validated variants at build");
                pendings.push(Pending {
                    tokens: req.tokens,
                    arrived: Instant::now(),
                    resp: req.resp,
                });
                if let Some(ids) = batcher.push(pendings.len() - 1, Instant::now()) {
                    let taken = std::mem::take(pendings);
                    depth.fetch_sub(taken.len(), Ordering::Relaxed);
                    run_batch(
                        &engine,
                        &graphs[&variant],
                        &variants[&variant],
                        &variant,
                        ids,
                        taken,
                        &metrics,
                    );
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                let now = Instant::now();
                for (variant, (batcher, pendings)) in batchers.iter_mut() {
                    if let Some(ids) = batcher.poll_deadline(now) {
                        let taken = std::mem::take(pendings);
                        depth.fetch_sub(taken.len(), Ordering::Relaxed);
                        run_batch(
                            &engine,
                            &graphs[variant],
                            &variants[variant],
                            variant,
                            ids,
                            taken,
                            &metrics,
                        );
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // All handles dropped: flush whatever is queued and exit.
                for (variant, (batcher, pendings)) in batchers.iter_mut() {
                    if let Some(ids) = batcher.flush() {
                        let taken = std::mem::take(pendings);
                        depth.fetch_sub(taken.len(), Ordering::Relaxed);
                        run_batch(
                            &engine,
                            &graphs[variant],
                            &variants[variant],
                            variant,
                            ids,
                            taken,
                            &metrics,
                        );
                    }
                }
                break;
            }
        }
    }
}

fn run_batch(
    engine: &Engine,
    graph: &crate::runtime::GraphSpec,
    params: &ParamStore,
    variant: &str,
    ids: Vec<usize>,
    pendings: Vec<Pending>,
    metrics: &Metrics,
) {
    let artifact_batch = graph.batch;
    let seq = graph.inputs[0].shape[1];
    let classes = graph.outputs[0].shape[1];
    let p = plan(ids, artifact_batch);

    let mut toks = Vec::with_capacity(artifact_batch * seq);
    for &i in &p.members {
        let t = &pendings[i].tokens;
        assert_eq!(t.len(), seq, "request seq mismatch");
        toks.extend_from_slice(t);
    }
    toks.resize(artifact_batch * seq, 0); // PAD rows
    let x = Tensor::from_i32(&[artifact_batch, seq], toks);

    match engine.run_fwd(graph, params, &[x]) {
        Ok(out) => {
            let logits = out[0].as_f32().expect("f32 logits");
            metrics.record_batch(p.members.len(), p.pad_rows, variant);
            let finished = Instant::now();
            for (row, &i) in p.members.iter().enumerate() {
                let pend = &pendings[i];
                let row_logits = logits[row * classes..(row + 1) * classes].to_vec();
                let label = row_logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                let latency = finished.duration_since(pend.arrived);
                metrics.record_latency(latency);
                let _ = pend.resp.send(ClassifyResponse {
                    logits: row_logits,
                    label,
                    variant: variant.to_string(),
                    latency,
                });
            }
        }
        Err(e) => {
            metrics.record_error();
            eprintln!("batch execution failed on {variant}: {e:#}");
            // Dropping pendings closes their channels; clients see an error.
        }
    }
}
