//! The serving loop: queue → router → batcher → backend → responses.
//!
//! Thread-based (the offline build has no async runtime — and none is
//! needed: graph execution is the only blocking operation and it is CPU
//! bound). One dispatcher thread owns all batchers; execution happens on the
//! dispatcher so batches are strictly ordered per variant. Clients block on
//! a oneshot-style channel; concurrency comes from client threads.
//!
//! Execution goes through the [`Backend`] abstraction: the PJRT engine when
//! AOT artifacts resolve, the pure-Rust [`NativeBackend`] otherwise — so the
//! full serving path runs (and is tested, see
//! `tests/integration_serving_native.rs`) on a fresh checkout with no
//! `artifacts/` and no XLA runtime.
//!
//! Invariants (pinned by rust/tests/proptest_coordinator.rs and the serving
//! integration tests):
//! * every submitted request receives exactly one response or an error;
//! * executed batches never exceed the artifact batch size;
//! * padding rows never produce responses;
//! * responses carry the variant that actually served them;
//! * a malformed request (wrong token length) gets an error response and
//!   never panics the dispatcher.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::anyhow;

use super::batcher::{plan, Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::router::{Router, Tier};
use crate::backend::{native, Backend, NativeBackend, PjrtBackend};
use crate::runtime::{Engine, GraphSpec};
use crate::tensor::{ParamStore, Tensor};
use crate::Result;

/// Per-request outcome sent back over the response channel: the response, or
/// a rejection/failure message (`String`, so the channel stays `Send`).
pub type ServeResult = std::result::Result<ClassifyResponse, String>;

/// A text-classification request: tokens (seq,) + quality tier.
pub struct ClassifyRequest {
    pub tokens: Vec<i32>,
    pub tier: Tier,
    resp: SyncSender<ServeResult>,
}

#[derive(Clone, Debug)]
pub struct ClassifyResponse {
    pub logits: Vec<f32>,
    pub label: usize,
    pub variant: String,
    pub latency: Duration,
}

/// Handle returned by [`serve_classifier`]: submit requests, inspect
/// metrics. Dropping all clones shuts the dispatcher down (after a flush).
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<ClassifyRequest>,
    pub metrics: Arc<Metrics>,
    depth: Arc<AtomicUsize>,
}

impl ServerHandle {
    /// Submit a request and block until the batch containing it executes.
    pub fn classify(&self, tokens: Vec<i32>, tier: Tier) -> Result<ClassifyResponse> {
        let (tx, rx) = sync_channel(1);
        self.metrics.record_request();
        self.depth.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(ClassifyRequest {
                tokens,
                tier,
                resp: tx,
            })
            .map_err(|_| anyhow!("server shut down"))?;
        match rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => Err(anyhow!("request rejected: {msg}")),
            Err(_) => Err(anyhow!("request dropped (server shut down mid-batch)")),
        }
    }

    /// Non-blocking submit; Err(tokens) when the queue is full.
    pub fn try_classify(
        &self,
        tokens: Vec<i32>,
        tier: Tier,
    ) -> std::result::Result<Receiver<ServeResult>, Vec<i32>> {
        let (tx, rx) = sync_channel(1);
        let req = ClassifyRequest {
            tokens,
            tier,
            resp: tx,
        };
        match self.tx.try_send(req) {
            Ok(()) => {
                self.metrics.record_request();
                self.depth.fetch_add(1, Ordering::Relaxed);
                Ok(rx)
            }
            Err(TrySendError::Full(req)) | Err(TrySendError::Disconnected(req)) => {
                Err(req.tokens)
            }
        }
    }

    /// Requests submitted but not yet answered (the adaptive router's input).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }
}

struct Pending {
    tokens: Vec<i32>,
    arrived: Instant,
    resp: SyncSender<ServeResult>,
}

/// What a backend factory hands the dispatcher: the executor plus one fwd
/// graph (real or synthesized) per variant.
pub type BackendBundle = (Box<dyn Backend>, HashMap<String, GraphSpec>);

/// Resolve the PJRT bundle over a loaded engine: one fwd graph per variant
/// (largest batch ≤ `max_batch`, falling back to the largest available),
/// with the executable cache warmed so first requests don't pay compile
/// time. Startup errors (missing graph, compile failure) are returned.
fn pjrt_bundle(
    engine: Engine,
    model: &str,
    variants: &HashMap<String, ParamStore>,
    max_batch: usize,
) -> Result<BackendBundle> {
    let mut graphs = HashMap::new();
    for name in variants.keys() {
        let g = engine
            .manifest()
            .find(model, name, "fwd", Some(max_batch.max(1)))
            .or_else(|_| engine.manifest().find(model, name, "fwd", None))
            .cloned()?;
        engine.executable(&g.name)?;
        graphs.insert(name.clone(), g);
    }
    Ok((Box::new(PjrtBackend::from_engine(engine)), graphs))
}

/// Build the native bundle: synthesize a fwd spec per variant directly from
/// its checkpoint — no artifacts required.
fn native_bundle(
    model: &str,
    variants: &HashMap<String, ParamStore>,
    max_batch: usize,
) -> Result<BackendBundle> {
    let mut graphs = HashMap::new();
    for (name, store) in variants {
        let g = native::synth_fwd_graph(model, name, max_batch.max(1), store)?;
        graphs.insert(name.clone(), g);
    }
    Ok((Box::new(NativeBackend::new()), graphs))
}

/// Spawn the serving loop for one model family, selecting the backend
/// automatically: PJRT when `artifacts_dir` holds a manifest and the runtime
/// loads, the native interpreter otherwise. With artifacts present, a
/// variant without a fwd graph is still a synchronous startup error (it
/// signals a store/manifest mismatch, not a missing runtime).
///
/// `variants` maps variant name → its trained/factorized checkpoint.
/// Requests route per `router`. The dispatcher thread builds its *own*
/// backend: the PJRT client wrapper is `Rc`-based and cannot cross threads,
/// so the thread that executes graphs owns the client.
pub fn serve_classifier(
    artifacts_dir: std::path::PathBuf,
    model: &str,
    variants: HashMap<String, ParamStore>,
    router: Router,
    cfg: BatcherConfig,
    queue_capacity: usize,
) -> Result<ServerHandle> {
    let model = model.to_string();
    let max_batch = cfg.max_batch;
    serve_classifier_with(
        move |variants| {
            if artifacts_dir.join("manifest.json").exists() {
                match Engine::load(artifacts_dir.clone()) {
                    Ok(engine) => return pjrt_bundle(engine, &model, variants, max_batch),
                    Err(e) => {
                        eprintln!("PJRT runtime unavailable ({e:#}); serving on native backend");
                    }
                }
            }
            native_bundle(&model, variants, max_batch)
        },
        variants,
        router,
        cfg,
        queue_capacity,
    )
}

/// [`serve_classifier`] pinned to the native backend — fully hermetic, used
/// by the artifact-free serving tests and benches.
pub fn serve_classifier_native(
    model: &str,
    variants: HashMap<String, ParamStore>,
    router: Router,
    cfg: BatcherConfig,
    queue_capacity: usize,
) -> Result<ServerHandle> {
    let model = model.to_string();
    let max_batch = cfg.max_batch;
    serve_classifier_with(
        move |variants| native_bundle(&model, variants, max_batch),
        variants,
        router,
        cfg,
        queue_capacity,
    )
}

/// Core serving entry point, generic over how the backend is built. The
/// factory runs *on the dispatcher thread* (backends need not be `Send`) and
/// must return a graph for every variant key; its error is reported
/// synchronously from this call.
pub fn serve_classifier_with(
    factory: impl FnOnce(&HashMap<String, ParamStore>) -> Result<BackendBundle> + Send + 'static,
    variants: HashMap<String, ParamStore>,
    router: Router,
    cfg: BatcherConfig,
    queue_capacity: usize,
) -> Result<ServerHandle> {
    let metrics = Arc::new(Metrics::new());
    let depth = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = sync_channel::<ClassifyRequest>(queue_capacity);
    // Rendezvous for startup success/failure.
    let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);

    let metrics_bg = metrics.clone();
    let depth_bg = depth.clone();
    std::thread::Builder::new()
        .name("gf-dispatch".into())
        .spawn(move || {
            // The backend lives on this thread for its whole life.
            let (backend, graphs) = match factory(&variants) {
                Ok(bundle) => bundle,
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            for name in variants.keys() {
                if !graphs.contains_key(name) {
                    let _ = ready_tx.send(Err(anyhow!("backend returned no graph for {name:?}")));
                    return;
                }
            }
            let _ = ready_tx.send(Ok(()));
            dispatch_loop(
                backend.as_ref(),
                graphs,
                variants,
                router,
                cfg,
                rx,
                metrics_bg,
                depth_bg,
            );
        })
        .expect("spawning dispatcher");

    ready_rx
        .recv()
        .map_err(|_| anyhow!("dispatcher died during startup"))??;
    Ok(ServerHandle { tx, metrics, depth })
}

#[allow(clippy::too_many_arguments)]
fn dispatch_loop(
    backend: &dyn Backend,
    graphs: HashMap<String, GraphSpec>,
    variants: HashMap<String, ParamStore>,
    router: Router,
    cfg: BatcherConfig,
    rx: Receiver<ClassifyRequest>,
    metrics: Arc<Metrics>,
    depth: Arc<AtomicUsize>,
) {
    // One batcher per variant: executed batches are variant-homogeneous.
    let mut batchers: HashMap<String, (Batcher, Vec<Pending>)> = graphs
        .keys()
        .map(|k| {
            // Effective per-variant max batch: bounded by the artifact.
            let eff = BatcherConfig {
                max_batch: cfg.max_batch.min(graphs[k].batch),
                max_wait: cfg.max_wait,
            };
            (k.clone(), (Batcher::new(eff), Vec::new()))
        })
        .collect();

    loop {
        let now = Instant::now();
        let next_deadline = batchers
            .values()
            .filter_map(|(b, _)| b.time_to_deadline(now))
            .min();

        let msg = match next_deadline {
            Some(d) => rx.recv_timeout(d),
            None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
        };

        match msg {
            Ok(req) => {
                let variant = router
                    .route(req.tier, depth.load(Ordering::Relaxed))
                    .to_string();
                let (batcher, pendings) = batchers
                    .get_mut(&variant)
                    .expect("router validated variants at build");
                pendings.push(Pending {
                    tokens: req.tokens,
                    arrived: Instant::now(),
                    resp: req.resp,
                });
                if let Some(ids) = batcher.push(pendings.len() - 1, Instant::now()) {
                    let taken = std::mem::take(pendings);
                    depth.fetch_sub(taken.len(), Ordering::Relaxed);
                    run_batch(
                        backend,
                        &graphs[&variant],
                        &variants[&variant],
                        &variant,
                        ids,
                        taken,
                        &metrics,
                    );
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                let now = Instant::now();
                for (variant, (batcher, pendings)) in batchers.iter_mut() {
                    if let Some(ids) = batcher.poll_deadline(now) {
                        let taken = std::mem::take(pendings);
                        depth.fetch_sub(taken.len(), Ordering::Relaxed);
                        run_batch(
                            backend,
                            &graphs[variant],
                            &variants[variant],
                            variant,
                            ids,
                            taken,
                            &metrics,
                        );
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // All handles dropped: flush whatever is queued and exit.
                for (variant, (batcher, pendings)) in batchers.iter_mut() {
                    if let Some(ids) = batcher.flush() {
                        let taken = std::mem::take(pendings);
                        depth.fetch_sub(taken.len(), Ordering::Relaxed);
                        run_batch(
                            backend,
                            &graphs[variant],
                            &variants[variant],
                            variant,
                            ids,
                            taken,
                            &metrics,
                        );
                    }
                }
                break;
            }
        }
    }
}

fn run_batch(
    backend: &dyn Backend,
    graph: &GraphSpec,
    params: &ParamStore,
    variant: &str,
    ids: Vec<usize>,
    pendings: Vec<Pending>,
    metrics: &Metrics,
) {
    let artifact_batch = graph.batch;
    let seq = graph.inputs[0].shape[1];
    let classes = graph.outputs[0].shape[1];

    // Bounds-check requests against the graph: token length vs the seq dim,
    // and token ids vs the vocab when the graph records it. A malformed
    // request gets an error response; it must never panic the dispatcher or
    // fail the well-formed requests co-batched with it.
    let vocab = graph.config_usize("vocab").ok();
    let mut valid = Vec::with_capacity(ids.len());
    for i in ids {
        let toks = &pendings[i].tokens;
        let reject = if toks.len() != seq {
            Some(format!("token length {} does not match model seq {seq}", toks.len()))
        } else if let Some(v) = vocab {
            toks.iter()
                .find(|&&t| t < 0 || t as usize >= v)
                .map(|&t| format!("token id {t} out of range (vocab {v})"))
        } else {
            None
        };
        match reject {
            None => valid.push(i),
            Some(msg) => {
                metrics.record_error();
                let _ = pendings[i].resp.send(Err(msg));
            }
        }
    }
    if valid.is_empty() {
        return;
    }
    let p = plan(valid, artifact_batch);

    let mut toks = Vec::with_capacity(artifact_batch * seq);
    for &i in &p.members {
        toks.extend_from_slice(&pendings[i].tokens);
    }
    toks.resize(artifact_batch * seq, 0); // PAD rows
    let x = Tensor::from_i32(&[artifact_batch, seq], toks);

    match backend.run_fwd(graph, params, &[x]) {
        Ok(out) => {
            let logits = out[0].as_f32().expect("f32 logits");
            metrics.record_batch(p.members.len(), p.pad_rows, variant);
            let finished = Instant::now();
            for (row, &i) in p.members.iter().enumerate() {
                let pend = &pendings[i];
                let row_logits = logits[row * classes..(row + 1) * classes].to_vec();
                let label = row_logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                let latency = finished.duration_since(pend.arrived);
                metrics.record_latency(latency);
                let _ = pend.resp.send(Ok(ClassifyResponse {
                    logits: row_logits,
                    label,
                    variant: variant.to_string(),
                    latency,
                }));
            }
        }
        Err(e) => {
            eprintln!("batch execution failed on {variant}: {e:#}");
            for &i in &p.members {
                metrics.record_error();
                let _ = pendings[i]
                    .resp
                    .send(Err(format!("batch execution failed: {e:#}")));
            }
        }
    }
}
