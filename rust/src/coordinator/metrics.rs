//! Serving metrics: request counters, per-variant tallies, and a fixed-
//! bucket latency histogram. Lock-free on the hot path (atomics only).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Histogram bucket upper bounds in microseconds (last bucket = +inf).
pub const BUCKETS_US: [u64; 12] = [
    100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000, 1_000_000,
];

/// Shared serving counters. Classify work counts requests/responses/batches;
/// decode work additionally counts *tokens* — one generation is one request
/// and one response, but its cost is `prefill_tokens + generated_tokens`
/// decode steps, and throughput only reconciles against
/// `benches/native_decode.rs` when tallied per token.
#[derive(Default)]
pub struct Metrics {
    /// Requests submitted (classify + generate).
    pub requests: AtomicU64,
    /// Terminal successes: classify rows answered + generations completed.
    pub responses: AtomicU64,
    /// Classify batches executed.
    pub batches: AtomicU64,
    /// Padding rows executed across all classify batches.
    pub padded_rows: AtomicU64,
    /// Requests rejected or failed (classify + generate).
    pub errors: AtomicU64,
    /// Prompt tokens consumed by decode prefill steps.
    pub prefill_tokens: AtomicU64,
    /// Tokens sampled and streamed by decode sessions.
    pub generated_tokens: AtomicU64,
    /// Decode sessions run to completion (`Done` sent).
    pub decode_sessions: AtomicU64,
    /// Continuous-batching decode sweeps executed (one stacked step over
    /// every active session of one variant).
    pub merged_steps: AtomicU64,
    /// Session-tokens advanced by merged steps: each merged step of batch
    /// size m contributes m. `merged_step_tokens / merged_steps` is the
    /// mean decode batch occupancy.
    pub merged_step_tokens: AtomicU64,
    /// Generate requests shed by admission control before any decode work
    /// (terminal `Rejected` sent; disjoint from `errors`).
    pub shed_requests: AtomicU64,
    /// Draft tokens proposed by speculative sessions' draft models.
    pub drafted_tokens: AtomicU64,
    /// Drafted tokens the target model accepted (and which were therefore
    /// streamed). `accepted_tokens / drafted_tokens` is the acceptance rate.
    pub accepted_tokens: AtomicU64,
    /// Speculative steps that rolled back at least one rejected draft
    /// (KV-cache truncation events).
    pub spec_rollbacks: AtomicU64,
    /// Target-sampled tokens streamed by speculative sessions: the prefill
    /// sample plus one per step (the correction or bonus token). For a
    /// purely speculative workload,
    /// `generated_tokens == accepted_tokens + spec_corrections` exactly.
    pub spec_corrections: AtomicU64,
    latency_buckets: [AtomicU64; BUCKETS_US.len() + 1],
    latency_sum_us: AtomicU64,
    per_variant: Mutex<HashMap<String, u64>>,
}

impl Metrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one submitted request (classify or generate).
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one executed classify batch: `real` answered rows plus
    /// `padded` PAD rows, served by `variant`.
    pub fn record_batch(&self, real: usize, padded: usize, variant: &str) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.responses.fetch_add(real as u64, Ordering::Relaxed);
        self.padded_rows.fetch_add(padded as u64, Ordering::Relaxed);
        *self
            .per_variant
            .lock()
            .unwrap()
            .entry(variant.to_string())
            .or_insert(0) += real as u64;
    }

    /// Record one request's end-to-end latency.
    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        self.latency_sum_us.fetch_add(us, Ordering::Relaxed);
        let idx = BUCKETS_US.partition_point(|&b| us > b);
        self.latency_buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Count one rejected/failed request.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Tally one prefill step's prompt tokens.
    pub fn record_prefill_tokens(&self, n: usize) {
        self.prefill_tokens.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Tally sampled-and-streamed tokens.
    pub fn record_generated_tokens(&self, n: usize) {
        self.generated_tokens.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// One generation ran to completion on `variant`: counts as one
    /// response (its per-token work is already in the token counters).
    pub fn record_decode_done(&self, variant: &str) {
        self.decode_sessions.fetch_add(1, Ordering::Relaxed);
        self.responses.fetch_add(1, Ordering::Relaxed);
        *self
            .per_variant
            .lock()
            .unwrap()
            .entry(variant.to_string())
            .or_insert(0) += 1;
    }

    /// Count one continuous-batching decode sweep that advanced `sessions`
    /// concurrent sessions by one token each.
    pub fn record_decode_step(&self, sessions: usize) {
        self.merged_steps.fetch_add(1, Ordering::Relaxed);
        self.merged_step_tokens.fetch_add(sessions as u64, Ordering::Relaxed);
    }

    /// Count one generate request shed by admission control.
    pub fn record_shed(&self) {
        self.shed_requests.fetch_add(1, Ordering::Relaxed);
    }

    /// Tally one speculative step: `drafted` proposals, `accepted` of them
    /// kept, plus one target-sampled token (correction/bonus), with
    /// `rolled_back` marking whether the step truncated the KV caches.
    pub fn record_spec_step(&self, drafted: usize, accepted: usize, rolled_back: bool) {
        self.drafted_tokens.fetch_add(drafted as u64, Ordering::Relaxed);
        self.accepted_tokens.fetch_add(accepted as u64, Ordering::Relaxed);
        self.spec_corrections.fetch_add(1, Ordering::Relaxed);
        if rolled_back {
            self.spec_rollbacks.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Tally a speculative session's prefill sample (a target-emitted token
    /// outside any step).
    pub fn record_spec_prefill_sample(&self) {
        self.spec_corrections.fetch_add(1, Ordering::Relaxed);
    }

    /// Fraction of drafted tokens accepted by the verify passes; 0.0 before
    /// any speculation ran.
    pub fn acceptance_rate(&self) -> f64 {
        let drafted = self.drafted_tokens.load(Ordering::Relaxed);
        if drafted == 0 {
            return 0.0;
        }
        self.accepted_tokens.load(Ordering::Relaxed) as f64 / drafted as f64
    }

    /// Mean decode batch occupancy: sessions advanced per merged step
    /// (1.0 = the scheduler only ever had one live stream; higher means the
    /// stacked GEMMs actually carried concurrent streams).
    pub fn decode_batch_occupancy(&self) -> f64 {
        let steps = self.merged_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.merged_step_tokens.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// Approximate latency percentile from the histogram (upper bound of the
    /// bucket containing the p-quantile), in microseconds.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let counts: Vec<u64> = self
            .latency_buckets
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((p / 100.0) * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return BUCKETS_US.get(i).copied().unwrap_or(u64::MAX);
            }
        }
        u64::MAX
    }

    /// Mean recorded latency, microseconds.
    pub fn mean_latency_us(&self) -> f64 {
        let n = self
            .latency_buckets
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum::<u64>();
        if n == 0 {
            0.0
        } else {
            self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Successful responses per serving variant.
    pub fn variant_counts(&self) -> HashMap<String, u64> {
        self.per_variant.lock().unwrap().clone()
    }

    /// Mean occupancy of executed batches (real rows / artifact rows).
    pub fn batch_occupancy(&self, artifact_batch: usize) -> f64 {
        let batches = self.batches.load(Ordering::Relaxed);
        if batches == 0 {
            return 0.0;
        }
        let real = self.responses.load(Ordering::Relaxed) as f64;
        real / (batches as f64 * artifact_batch as f64)
    }

    /// One-line human-readable rollup of every counter.
    pub fn summary(&self) -> String {
        format!(
            "requests={} responses={} batches={} pad={} err={} shed={} sessions={} \
             merged_steps={} occupancy={:.2} prefill_tok={} gen_tok={} drafted_tok={} \
             accepted_tok={} acc_rate={:.2} spec_rollbacks={} p50={}us p95={}us \
             mean={:.0}us",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.padded_rows.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.shed_requests.load(Ordering::Relaxed),
            self.decode_sessions.load(Ordering::Relaxed),
            self.merged_steps.load(Ordering::Relaxed),
            self.decode_batch_occupancy(),
            self.prefill_tokens.load(Ordering::Relaxed),
            self.generated_tokens.load(Ordering::Relaxed),
            self.drafted_tokens.load(Ordering::Relaxed),
            self.accepted_tokens.load(Ordering::Relaxed),
            self.acceptance_rate(),
            self.spec_rollbacks.load(Ordering::Relaxed),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.mean_latency_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_batch(2, 6, "dense");
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.responses.load(Ordering::Relaxed), 2);
        assert_eq!(m.padded_rows.load(Ordering::Relaxed), 6);
        assert_eq!(m.variant_counts()["dense"], 2);
        assert!((m.batch_occupancy(8) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn decode_token_counters_reconcile() {
        let m = Metrics::new();
        m.record_request();
        m.record_prefill_tokens(16);
        for _ in 0..4 {
            m.record_generated_tokens(1);
        }
        m.record_decode_done("led_r25");
        assert_eq!(m.prefill_tokens.load(Ordering::Relaxed), 16);
        assert_eq!(m.generated_tokens.load(Ordering::Relaxed), 4);
        assert_eq!(m.decode_sessions.load(Ordering::Relaxed), 1);
        assert_eq!(m.responses.load(Ordering::Relaxed), 1);
        assert_eq!(m.variant_counts()["led_r25"], 1);
        let s = m.summary();
        assert!(s.contains("prefill_tok=16") && s.contains("gen_tok=4"), "{s}");
    }

    #[test]
    fn merged_step_and_shed_counters_reconcile() {
        let m = Metrics::new();
        assert_eq!(m.decode_batch_occupancy(), 0.0);
        m.record_decode_step(3);
        m.record_decode_step(1);
        m.record_shed();
        assert_eq!(m.merged_steps.load(Ordering::Relaxed), 2);
        assert_eq!(m.merged_step_tokens.load(Ordering::Relaxed), 4);
        assert_eq!(m.shed_requests.load(Ordering::Relaxed), 1);
        assert!((m.decode_batch_occupancy() - 2.0).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("merged_steps=2") && s.contains("shed=1"), "{s}");
    }

    #[test]
    fn spec_counters_reconcile() {
        let m = Metrics::new();
        assert_eq!(m.acceptance_rate(), 0.0, "no drafts yet");
        // One session: prefill sample, then three steps — full accept (3/3),
        // partial (1/3, rollback), degenerate plain tail (0 drafts).
        m.record_spec_prefill_sample();
        m.record_generated_tokens(1);
        m.record_spec_step(3, 3, false);
        m.record_generated_tokens(4);
        m.record_spec_step(3, 1, true);
        m.record_generated_tokens(2);
        m.record_spec_step(0, 0, false);
        m.record_generated_tokens(1);
        assert_eq!(m.drafted_tokens.load(Ordering::Relaxed), 6);
        assert_eq!(m.accepted_tokens.load(Ordering::Relaxed), 4);
        assert_eq!(m.spec_rollbacks.load(Ordering::Relaxed), 1);
        assert_eq!(m.spec_corrections.load(Ordering::Relaxed), 4);
        // The reconciliation invariant the serving integration test pins:
        assert_eq!(
            m.generated_tokens.load(Ordering::Relaxed),
            m.accepted_tokens.load(Ordering::Relaxed)
                + m.spec_corrections.load(Ordering::Relaxed)
        );
        assert!((m.acceptance_rate() - 4.0 / 6.0).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("drafted_tok=6") && s.contains("acc_rate=0.67"), "{s}");
        assert!(s.contains("spec_rollbacks=1"), "{s}");
    }

    #[test]
    fn latency_percentiles_monotone() {
        let m = Metrics::new();
        for us in [50u64, 200, 800, 3_000, 30_000, 200_000] {
            m.record_latency(Duration::from_micros(us));
        }
        let p50 = m.latency_percentile_us(50.0);
        let p95 = m.latency_percentile_us(95.0);
        assert!(p50 <= p95);
        assert!(p50 >= 500, "p50 bucket: {p50}");
        assert!(m.mean_latency_us() > 0.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(99.0), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.batch_occupancy(8), 0.0);
    }

    #[test]
    fn summary_renders() {
        let m = Metrics::new();
        m.record_request();
        assert!(m.summary().contains("requests=1"));
    }
}
