//! Serving coordinator: dynamic batching + variant routing over a
//! [`crate::backend::Backend`]. Greenformer's serving story is "same model,
//! a family of factorized variants at different speed/quality points"; the
//! coordinator turns that into a runtime policy:
//!
//! * [`batcher`] — size-or-deadline dynamic batching with padding to the
//!   artifact batch size (pure assembly logic, proptest-able).
//! * [`router`] — picks the variant per request: static pinning, per-request
//!   tier, or adaptive load-shedding (deep queue → lower-rank variant, the
//!   latency/quality trade Figure 2 quantifies).
//! * [`server`] — the dispatcher thread tying queue → batcher → backend →
//!   responses. Backend selection is automatic (PJRT when artifacts resolve,
//!   the native interpreter otherwise) or pinned via
//!   [`server::serve_classifier_native`].
//! * [`metrics`] — counters + latency histogram.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPlan, Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use router::{RoutePolicy, Router, Tier};
pub use server::{
    serve_classifier, serve_classifier_native, serve_classifier_with, ClassifyRequest,
    ClassifyResponse, ServeResult, ServerHandle,
};
