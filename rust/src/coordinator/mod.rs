//! Serving coordinator: dynamic batching + variant routing over the PJRT
//! engine. Greenformer's serving story is "same model, a family of
//! factorized variants at different speed/quality points"; the coordinator
//! turns that into a runtime policy:
//!
//! * [`batcher`] — size-or-deadline dynamic batching with padding to the
//!   artifact batch size (pure assembly logic, proptest-able).
//! * [`router`] — picks the variant per request: static pinning, per-request
//!   tier, or adaptive load-shedding (deep queue → lower-rank variant, the
//!   latency/quality trade Figure 2 quantifies).
//! * [`server`] — the tokio loop tying queue → batcher → engine → responses.
//! * [`metrics`] — counters + latency histogram.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPlan, Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use router::{RoutePolicy, Router, Tier};
pub use server::{serve_classifier, ClassifyRequest, ClassifyResponse, ServerHandle};
