//! Serving coordinator: dynamic batching + variant routing over a
//! [`crate::backend::Backend`]. Greenformer's serving story is "same model,
//! a family of factorized variants at different speed/quality points"; the
//! coordinator turns that into a runtime policy:
//!
//! * [`batcher`] — size-or-deadline dynamic batching with padding to the
//!   artifact batch size (pure assembly logic, proptest-able).
//! * [`router`] — picks the variant per request: static pinning, per-request
//!   tier, or adaptive load-shedding (deep queue → lower-rank variant, the
//!   latency/quality trade Figure 2 quantifies).
//! * [`server`] — the dispatcher thread tying queue → batcher/scheduler →
//!   backend → responses. Backend selection is automatic (PJRT when
//!   artifacts resolve, the native interpreter otherwise) or pinned via
//!   [`server::serve_classifier_native`]. Two request kinds share the
//!   queue: batched classify, and KV-cached streaming `generate` under
//!   continuous batching — every dispatcher sweep advances all live
//!   sessions one token as a single stacked GEMM step per variant, with
//!   admission control ([`server::ServeConfig::max_sessions`]) shedding
//!   excess streams via a typed [`server::TokenEvent::Rejected`]. With
//!   [`server::ServeConfig::spec`] set, speculative sessions (LED draft
//!   proposes, target verifies — [`crate::backend::SpecSession`]) ride the
//!   same sweep, emitting up to `k + 1` tokens per round. The
//!   decode/classify interleave is configurable
//!   ([`server::FairnessConfig`]); SERVING.md documents the full model.
//! * [`metrics`] — counters (incl. per-token prefill/generated tallies,
//!   merged-step/occupancy/shed gauges, the drafted/accepted speculation
//!   ledger) + latency histogram.
//!
//! # Examples
//!
//! Stand up a hermetic single-variant classifier server and classify one
//! window (no artifacts, no PJRT):
//!
//! ```
//! use std::collections::HashMap;
//! use greenformer::backend::native::{init_text_params, TextModelCfg};
//! use greenformer::coordinator::{
//!     serve_classifier_native, RoutePolicy, Router, ServeConfig, Tier,
//! };
//!
//! let cfg = TextModelCfg { vocab: 64, seq: 8, d: 32, heads: 4, layers: 1, ff: 64, classes: 3 };
//! let mut variants = HashMap::new();
//! variants.insert("dense".to_string(), init_text_params(&cfg, 1));
//! let router = Router::new(RoutePolicy::Static("dense".into()), vec!["dense".into()]).unwrap();
//! let handle =
//!     serve_classifier_native("text", variants, router, ServeConfig::default()).unwrap();
//! let resp = handle.classify(vec![1; 8], Tier::Quality).unwrap();
//! assert_eq!(resp.variant, "dense");
//! assert!(resp.label < 3);
//! ```

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPlan, Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use router::{RoutePolicy, Router, Tier};
// Speculation policy is part of the serving config surface; re-export it so
// `coordinator::{ServeConfig, SpecConfig}` imports stay one-stop.
pub use crate::backend::SpecConfig;
pub use server::{
    drain_stream_or_shed, serve_classifier, serve_classifier_native, serve_classifier_with,
    ClassifyRequest, ClassifyResponse, FairnessConfig, GenerateRequest, GenerateResponse, Request,
    ServeConfig, ServeError, ServeResult, ServerHandle, ShedReason, TokenEvent,
};
