//! Variant routing: which factorized variant serves a request.
//!
//! The factorized family (`dense`, `led_r75`, …, `led_r10`) is a
//! quality→speed ladder. The router maps requests onto it by policy:
//!
//! * `Static` — everything on one pinned variant.
//! * `Tiered` — the request asks for a quality tier.
//! * `Adaptive` — load shedding: queue depth picks the rung, so latency is
//!   bounded by degrading quality exactly as Figure 2 prices it.

/// Client-requested quality tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// Full quality (dense / highest-rank variant).
    Quality,
    /// Balanced.
    Balanced,
    /// Fastest available variant.
    Fast,
}

impl std::str::FromStr for Tier {
    type Err = String;

    /// Parse the wire form used by the HTTP API and CLI flags:
    /// `"quality" | "balanced" | "fast"` (exact, lowercase — the serving
    /// surface is fail-closed, so near-misses are errors, not guesses).
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "quality" => Ok(Tier::Quality),
            "balanced" => Ok(Tier::Balanced),
            "fast" => Ok(Tier::Fast),
            other => Err(format!("unknown tier {other:?} (expected quality|balanced|fast)")),
        }
    }
}

impl Tier {
    /// The wire form accepted by [`Tier::from_str`] and emitted by the API.
    pub fn as_str(&self) -> &'static str {
        match self {
            Tier::Quality => "quality",
            Tier::Balanced => "balanced",
            Tier::Fast => "fast",
        }
    }
}

/// How the router maps (tier, queue depth) onto a variant.
#[derive(Clone, Debug)]
pub enum RoutePolicy {
    /// Everything on one pinned variant.
    Static(String),
    /// Tier → variant name.
    Tiered {
        quality: String,
        balanced: String,
        fast: String,
    },
    /// Queue-depth thresholds: depth < low → quality, < high → balanced,
    /// else fast.
    Adaptive {
        quality: String,
        balanced: String,
        fast: String,
        low: usize,
        high: usize,
    },
}

/// Validated routing policy over the variants that actually exist.
#[derive(Clone, Debug)]
pub struct Router {
    policy: RoutePolicy,
    /// Variants that actually exist in the manifest (validated at build).
    available: Vec<String>,
}

impl Router {
    /// Build a router, rejecting policies that name unknown variants.
    pub fn new(policy: RoutePolicy, available: Vec<String>) -> crate::Result<Self> {
        let check = |v: &String| -> crate::Result<()> {
            if available.iter().any(|a| a == v) {
                Ok(())
            } else {
                Err(anyhow::anyhow!("variant {v:?} not in manifest: {available:?}"))
            }
        };
        match &policy {
            RoutePolicy::Static(v) => check(v)?,
            RoutePolicy::Tiered {
                quality,
                balanced,
                fast,
            }
            | RoutePolicy::Adaptive {
                quality,
                balanced,
                fast,
                ..
            } => {
                check(quality)?;
                check(balanced)?;
                check(fast)?;
            }
        }
        Ok(Self { policy, available })
    }

    /// The validated variant names.
    pub fn available(&self) -> &[String] {
        &self.available
    }

    /// Choose the variant for a request given its tier and the current
    /// queue depth.
    pub fn route(&self, tier: Tier, queue_depth: usize) -> &str {
        match &self.policy {
            RoutePolicy::Static(v) => v,
            RoutePolicy::Tiered {
                quality,
                balanced,
                fast,
            } => match tier {
                Tier::Quality => quality,
                Tier::Balanced => balanced,
                Tier::Fast => fast,
            },
            RoutePolicy::Adaptive {
                quality,
                balanced,
                fast,
                low,
                high,
            } => {
                if queue_depth < *low {
                    quality
                } else if queue_depth < *high {
                    balanced
                } else {
                    fast
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn avail() -> Vec<String> {
        vec!["dense".into(), "led_r50".into(), "led_r10".into()]
    }

    #[test]
    fn static_policy_ignores_everything() {
        let r = Router::new(RoutePolicy::Static("led_r50".into()), avail()).unwrap();
        assert_eq!(r.route(Tier::Quality, 0), "led_r50");
        assert_eq!(r.route(Tier::Fast, 999), "led_r50");
    }

    #[test]
    fn tiered_policy_honors_tier() {
        let r = Router::new(
            RoutePolicy::Tiered {
                quality: "dense".into(),
                balanced: "led_r50".into(),
                fast: "led_r10".into(),
            },
            avail(),
        )
        .unwrap();
        assert_eq!(r.route(Tier::Quality, 100), "dense");
        assert_eq!(r.route(Tier::Balanced, 0), "led_r50");
        assert_eq!(r.route(Tier::Fast, 0), "led_r10");
    }

    #[test]
    fn adaptive_sheds_load() {
        let r = Router::new(
            RoutePolicy::Adaptive {
                quality: "dense".into(),
                balanced: "led_r50".into(),
                fast: "led_r10".into(),
                low: 4,
                high: 16,
            },
            avail(),
        )
        .unwrap();
        assert_eq!(r.route(Tier::Quality, 0), "dense");
        assert_eq!(r.route(Tier::Quality, 4), "led_r50");
        assert_eq!(r.route(Tier::Quality, 15), "led_r50");
        assert_eq!(r.route(Tier::Quality, 16), "led_r10");
    }

    #[test]
    fn unknown_variant_rejected_at_build() {
        assert!(Router::new(RoutePolicy::Static("led_r99".into()), avail()).is_err());
    }

    #[test]
    fn tier_wire_form_roundtrips_and_fails_closed() {
        for tier in [Tier::Quality, Tier::Balanced, Tier::Fast] {
            assert_eq!(tier.as_str().parse::<Tier>().unwrap(), tier);
        }
        assert!("Fast".parse::<Tier>().is_err(), "case-sensitive by design");
        assert!("turbo".parse::<Tier>().is_err());
    }
}
