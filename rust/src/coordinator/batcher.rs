//! Dynamic batch assembly — pure logic, exhaustively testable.
//!
//! Requests arrive one at a time; the batcher groups them into execution
//! batches under two limits: `max_batch` requests, or `max_wait` since the
//! oldest queued request. Execution pads the group to the artifact's fixed
//! batch size (AOT graphs have static shapes), and padding rows are sliced
//! off the output before responses are sent — invariants pinned by the
//! proptests in `rust/tests/proptest_coordinator.rs`.

use std::time::{Duration, Instant};

/// Size/deadline limits of the dynamic batcher.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Max real requests per executed batch (≤ artifact batch size).
    pub max_batch: usize,
    /// Deadline from the oldest queued request to forced flush.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// A planned execution batch over request ids 0..n.
#[derive(Clone, Debug, PartialEq)]
pub struct BatchPlan {
    /// Indices (into the queue) of the requests in this batch, in order.
    pub members: Vec<usize>,
    /// Rows of padding appended to reach the artifact batch size.
    pub pad_rows: usize,
}

/// Incremental batcher state machine.
#[derive(Debug)]
pub struct Batcher {
    cfg: BatcherConfig,
    queued: Vec<usize>,
    oldest: Option<Instant>,
}

impl Batcher {
    /// Empty batcher under `cfg`.
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch > 0, "max_batch must be positive");
        Self {
            cfg,
            queued: Vec::new(),
            oldest: None,
        }
    }

    /// The configured limits.
    pub fn config(&self) -> BatcherConfig {
        self.cfg
    }

    /// Requests currently queued.
    pub fn pending(&self) -> usize {
        self.queued.len()
    }

    /// Enqueue a request id; returns a full batch if the size limit is hit.
    pub fn push(&mut self, id: usize, now: Instant) -> Option<Vec<usize>> {
        if self.queued.is_empty() {
            self.oldest = Some(now);
        }
        self.queued.push(id);
        if self.queued.len() >= self.cfg.max_batch {
            self.oldest = None;
            return Some(std::mem::take(&mut self.queued));
        }
        None
    }

    /// Flush if the oldest queued request has waited past the deadline.
    pub fn poll_deadline(&mut self, now: Instant) -> Option<Vec<usize>> {
        match self.oldest {
            Some(t0) if now.duration_since(t0) >= self.cfg.max_wait && !self.queued.is_empty() => {
                self.oldest = None;
                Some(std::mem::take(&mut self.queued))
            }
            _ => None,
        }
    }

    /// Force-flush whatever is queued (shutdown path).
    pub fn flush(&mut self) -> Option<Vec<usize>> {
        self.oldest = None;
        if self.queued.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut self.queued))
        }
    }

    /// Time remaining until the deadline flush (None if queue empty).
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest
            .map(|t0| self.cfg.max_wait.saturating_sub(now.duration_since(t0)))
    }
}

/// Plan the padded execution batch for a member set against an artifact
/// batch size. `members.len()` must be ≤ `artifact_batch`.
pub fn plan(members: Vec<usize>, artifact_batch: usize) -> BatchPlan {
    assert!(
        members.len() <= artifact_batch,
        "batch of {} exceeds artifact batch {artifact_batch}",
        members.len()
    );
    let pad_rows = artifact_batch - members.len();
    BatchPlan { members, pad_rows }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t0() -> Instant {
        Instant::now()
    }

    #[test]
    fn size_trigger_flushes_exactly_at_max() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 3,
            max_wait: Duration::from_secs(10),
        });
        let now = t0();
        assert!(b.push(0, now).is_none());
        assert!(b.push(1, now).is_none());
        let batch = b.push(2, now).unwrap();
        assert_eq!(batch, vec![0, 1, 2]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_trigger_flushes_partial() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
        });
        let now = t0();
        b.push(7, now);
        assert!(b.poll_deadline(now + Duration::from_millis(1)).is_none());
        let batch = b.poll_deadline(now + Duration::from_millis(6)).unwrap();
        assert_eq!(batch, vec![7]);
        // Deadline cleared after flush.
        assert!(b.poll_deadline(now + Duration::from_secs(1)).is_none());
    }

    #[test]
    fn deadline_measured_from_oldest() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
        });
        let now = t0();
        b.push(0, now);
        b.push(1, now + Duration::from_millis(9));
        // 10ms after the FIRST push, flush fires even though the second
        // request just arrived.
        let batch = b.poll_deadline(now + Duration::from_millis(10)).unwrap();
        assert_eq!(batch, vec![0, 1]);
    }

    #[test]
    fn flush_drains() {
        let mut b = Batcher::new(BatcherConfig::default());
        assert!(b.flush().is_none());
        b.push(1, t0());
        assert_eq!(b.flush().unwrap(), vec![1]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn plan_pads_to_artifact() {
        let p = plan(vec![4, 5], 8);
        assert_eq!(p.pad_rows, 6);
        let p = plan(vec![1, 2, 3], 3);
        assert_eq!(p.pad_rows, 0);
    }

    #[test]
    #[should_panic]
    fn plan_rejects_oversize() {
        plan(vec![0, 1, 2, 3], 2);
    }
}
