//! Registry manifest v1 — the typed, fail-closed deployment contract.
//!
//! A registry manifest names the models a serving process may load: for
//! each model, a family, a version tag, one or more named checkpoints
//! (each pinned to the sha256 of its GTZ file), the default checkpoint,
//! and an optional tier→checkpoint route. Parsing follows the
//! `manifest_v1` template: strict schema validation (unknown fields are
//! errors), then invariant validation (id syntax, uniqueness, reference
//! integrity, hash format) — a manifest either parses into a fully-checked
//! [`RegistryManifest`] or yields a typed [`RegistryError`], never a
//! half-trusted value. The write side ([`RegistryManifest::compose`]) is
//! the same contract in reverse, so composed manifests always re-parse.

use std::path::{Path, PathBuf};

use crate::util::json::{Kind, ObjBuilder, Schema, Value};

use super::RegistryError;

/// The manifest format this build understands.
pub const REGISTRY_FORMAT: usize = 1;

/// One named checkpoint: a GTZ file pinned to its content hash.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CheckpointEntry {
    /// Checkpoint (= serving variant) name, e.g. `dense`, `led_r25`.
    pub name: String,
    /// GTZ file path, relative to the manifest's directory.
    pub file: String,
    /// Full sha256 of the file's bytes, 64 lowercase hex chars.
    pub sha256: String,
}

/// Optional tier→checkpoint routing for one model (absent = everything on
/// the default checkpoint).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteSpec {
    /// Checkpoint serving [`crate::coordinator::Tier::Quality`].
    pub quality: String,
    /// Checkpoint serving [`crate::coordinator::Tier::Balanced`].
    pub balanced: String,
    /// Checkpoint serving [`crate::coordinator::Tier::Fast`].
    pub fast: String,
}

/// One model entry: family, version, checkpoints, routing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelManifest {
    /// Registry-unique model name (id syntax: `[a-z0-9._-]`, ≤ 64 chars).
    pub name: String,
    /// Model family: `"text"` (classifier) or `"lm"` (generator).
    pub family: String,
    /// Opaque version tag; a hot-swap installs a new version over an old
    /// one.
    pub version: String,
    /// Name of the checkpoint that serves when no route/tier applies.
    pub default: String,
    /// The named, hash-pinned checkpoints (serving variants).
    pub checkpoints: Vec<CheckpointEntry>,
    /// Optional tier routing over the checkpoints.
    pub route: Option<RouteSpec>,
}

/// A parsed, invariant-checked registry manifest plus the directory its
/// checkpoint paths resolve against.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RegistryManifest {
    /// The validated model entries, in manifest order.
    pub models: Vec<ModelManifest>,
    /// Directory checkpoint `file` fields resolve against (the manifest
    /// file's parent for [`RegistryManifest::load`]).
    pub dir: PathBuf,
}

fn schema() -> Schema {
    let ckpt = Schema::new("checkpoint")
        .required("name", Kind::Str)
        .required("file", Kind::Str)
        .required("sha256", Kind::Str);
    let route = Schema::new("route")
        .required("quality", Kind::Str)
        .required("balanced", Kind::Str)
        .required("fast", Kind::Str);
    let model = Schema::new("model")
        .required("name", Kind::Str)
        .required("family", Kind::Str)
        .required("version", Kind::Str)
        .required("default", Kind::Str)
        .required("checkpoints", Kind::Arr(Box::new(Kind::Obj(Box::new(ckpt)))))
        .optional("route", Kind::Obj(Box::new(route)));
    Schema::new("manifest")
        .required("format", Kind::UInt)
        .required("models", Kind::Arr(Box::new(Kind::Obj(Box::new(model)))))
}

/// Id syntax shared by model and checkpoint names: 1–64 chars of
/// `[a-z0-9._-]`, starting alphanumeric.
fn valid_id(s: &str) -> bool {
    !s.is_empty()
        && s.len() <= 64
        && s.chars().next().is_some_and(|c| c.is_ascii_lowercase() || c.is_ascii_digit())
        && s.chars()
            .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || matches!(c, '.' | '_' | '-'))
}

fn is_sha256_hex(s: &str) -> bool {
    s.len() == 64 && s.chars().all(|c| c.is_ascii_hexdigit())
}

fn field_str(v: &Value, key: &str) -> String {
    v.get(key).and_then(|x| x.as_str().ok()).unwrap_or_default().to_string()
}

impl RegistryManifest {
    /// Parse and fully validate manifest bytes. `dir` is the directory
    /// checkpoint paths resolve against. Fail-closed: schema violations
    /// (including unknown fields), a wrong `format`, bad ids, duplicate
    /// names, dangling references and malformed hashes are all typed
    /// errors.
    pub fn parse_bytes(
        bytes: &[u8],
        dir: impl Into<PathBuf>,
    ) -> std::result::Result<Self, RegistryError> {
        let v = Value::parse_bytes(bytes)
            .map_err(|e| RegistryError::Parse { detail: format!("{e:#}") })?;
        schema()
            .validate(&v)
            .map_err(|e| RegistryError::Parse { detail: e.to_string() })?;
        let format = v.usize_or("format", 0);
        if format != REGISTRY_FORMAT {
            return Err(RegistryError::Invariant {
                model: None,
                detail: format!("unsupported manifest format {format} (expected {REGISTRY_FORMAT})"),
            });
        }
        let mut models = Vec::new();
        for mv in v.get("models").and_then(|m| m.as_arr().ok()).unwrap_or_default() {
            let mut checkpoints = Vec::new();
            for cv in mv.get("checkpoints").and_then(|c| c.as_arr().ok()).unwrap_or_default() {
                checkpoints.push(CheckpointEntry {
                    name: field_str(cv, "name"),
                    file: field_str(cv, "file"),
                    // Hashes compare case-insensitively; normalize here so
                    // verification is a plain string equality.
                    sha256: field_str(cv, "sha256").to_ascii_lowercase(),
                });
            }
            let route = mv.get("route").map(|rv| RouteSpec {
                quality: field_str(rv, "quality"),
                balanced: field_str(rv, "balanced"),
                fast: field_str(rv, "fast"),
            });
            models.push(ModelManifest {
                name: field_str(mv, "name"),
                family: field_str(mv, "family"),
                version: field_str(mv, "version"),
                default: field_str(mv, "default"),
                checkpoints,
                route,
            });
        }
        let manifest = RegistryManifest { models, dir: dir.into() };
        manifest.validate_invariants()?;
        Ok(manifest)
    }

    /// Read + parse + validate a manifest file; checkpoint paths resolve
    /// against the file's parent directory.
    pub fn load(path: &Path) -> std::result::Result<Self, RegistryError> {
        let bytes = std::fs::read(path).map_err(|e| RegistryError::Io {
            path: path.display().to_string(),
            detail: e.to_string(),
        })?;
        let dir = path.parent().map(Path::to_path_buf).unwrap_or_else(|| PathBuf::from("."));
        Self::parse_bytes(&bytes, dir)
    }

    fn validate_invariants(&self) -> std::result::Result<(), RegistryError> {
        let fail = |model: &str, detail: String| {
            Err(RegistryError::Invariant { model: Some(model.to_string()), detail })
        };
        let mut seen_models = std::collections::BTreeSet::new();
        for m in &self.models {
            if !valid_id(&m.name) {
                return fail(&m.name, format!("invalid model name {:?}", m.name));
            }
            if !seen_models.insert(m.name.clone()) {
                return fail(&m.name, format!("duplicate model name {:?}", m.name));
            }
            if m.family != "text" && m.family != "lm" {
                return fail(
                    &m.name,
                    format!("family {:?} is not servable (expected \"text\" or \"lm\")", m.family),
                );
            }
            if m.version.is_empty() || m.version.len() > 64 {
                return fail(&m.name, format!("invalid version {:?}", m.version));
            }
            if m.checkpoints.is_empty() {
                return fail(&m.name, "no checkpoints".to_string());
            }
            let mut seen_ckpts = std::collections::BTreeSet::new();
            for c in &m.checkpoints {
                if !valid_id(&c.name) {
                    return fail(&m.name, format!("invalid checkpoint name {:?}", c.name));
                }
                if !seen_ckpts.insert(c.name.clone()) {
                    return fail(&m.name, format!("duplicate checkpoint name {:?}", c.name));
                }
                // Paths must stay inside the manifest directory: relative,
                // no parent traversal.
                let p = Path::new(&c.file);
                if c.file.is_empty()
                    || p.is_absolute()
                    || p.components().any(|x| x == std::path::Component::ParentDir)
                {
                    return fail(
                        &m.name,
                        format!("checkpoint {:?}: file {:?} must be a relative path without '..'",
                                c.name, c.file),
                    );
                }
                if !is_sha256_hex(&c.sha256) {
                    return fail(
                        &m.name,
                        format!("checkpoint {:?}: sha256 must be 64 hex chars, got {:?}",
                                c.name, c.sha256),
                    );
                }
            }
            if !seen_ckpts.contains(&m.default) {
                return fail(
                    &m.name,
                    format!("default checkpoint {:?} is not among the checkpoints", m.default),
                );
            }
            if let Some(r) = &m.route {
                for (tier, name) in
                    [("quality", &r.quality), ("balanced", &r.balanced), ("fast", &r.fast)]
                {
                    if !seen_ckpts.contains(name) {
                        return fail(
                            &m.name,
                            format!("route.{tier} names unknown checkpoint {name:?}"),
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Compose the manifest back into its canonical JSON [`Value`] (the
    /// write half of the contract; always re-parses under
    /// [`RegistryManifest::parse_bytes`]).
    pub fn compose(&self) -> Value {
        let models = self
            .models
            .iter()
            .map(|m| {
                let ckpts = m
                    .checkpoints
                    .iter()
                    .map(|c| {
                        ObjBuilder::new()
                            .str("name", &c.name)
                            .str("file", &c.file)
                            .str("sha256", &c.sha256)
                            .build()
                    })
                    .collect();
                let mut b = ObjBuilder::new()
                    .str("name", &m.name)
                    .str("family", &m.family)
                    .str("version", &m.version)
                    .str("default", &m.default)
                    .arr("checkpoints", ckpts);
                if let Some(r) = &m.route {
                    b = b.set(
                        "route",
                        ObjBuilder::new()
                            .str("quality", &r.quality)
                            .str("balanced", &r.balanced)
                            .str("fast", &r.fast)
                            .build(),
                    );
                }
                b.build()
            })
            .collect();
        ObjBuilder::new().uint("format", REGISTRY_FORMAT as u64).arr("models", models).build()
    }

    /// Compose to compact JSON text.
    pub fn render(&self) -> String {
        self.compose().render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sha(fill: char) -> String {
        std::iter::repeat(fill).take(64).collect()
    }

    fn minimal(route: bool) -> RegistryManifest {
        RegistryManifest {
            models: vec![ModelManifest {
                name: "lm-demo".into(),
                family: "lm".into(),
                version: "2026-08-08.1".into(),
                default: "dense".into(),
                checkpoints: vec![
                    CheckpointEntry {
                        name: "dense".into(),
                        file: "lm_dense.gtz".into(),
                        sha256: sha('a'),
                    },
                    CheckpointEntry {
                        name: "led_r25".into(),
                        file: "lm_led25.gtz".into(),
                        sha256: sha('b'),
                    },
                ],
                route: route.then(|| RouteSpec {
                    quality: "dense".into(),
                    balanced: "dense".into(),
                    fast: "led_r25".into(),
                }),
            }],
            dir: PathBuf::from("."),
        }
    }

    #[test]
    fn compose_parse_roundtrip() {
        for route in [false, true] {
            let m = minimal(route);
            let text = m.render();
            let back = RegistryManifest::parse_bytes(text.as_bytes(), ".").unwrap();
            assert_eq!(back, m);
        }
    }

    #[test]
    fn unknown_fields_fail_closed() {
        let mut v = minimal(false).compose();
        if let Value::Obj(m) = &mut v {
            m.insert("extra".into(), Value::Null);
        }
        let e = RegistryManifest::parse_bytes(v.render().as_bytes(), ".").unwrap_err();
        assert!(matches!(e, RegistryError::Parse { .. }), "{e}");
        assert!(e.to_string().contains("manifest.extra"), "{e}");
    }

    #[test]
    fn wrong_format_rejected() {
        let text = minimal(false).render().replace("\"format\":1", "\"format\":2");
        let e = RegistryManifest::parse_bytes(text.as_bytes(), ".").unwrap_err();
        assert!(matches!(e, RegistryError::Invariant { .. }), "{e}");
    }

    #[test]
    fn invariant_violations_are_typed() {
        // Bad model id.
        let mut m = minimal(false);
        m.models[0].name = "Bad Name!".into();
        assert!(RegistryManifest::parse_bytes(m.render().as_bytes(), ".").is_err());

        // Unsupported family.
        let mut m = minimal(false);
        m.models[0].family = "image".into();
        let e = RegistryManifest::parse_bytes(m.render().as_bytes(), ".").unwrap_err();
        assert!(e.to_string().contains("not servable"), "{e}");

        // Duplicate checkpoint names.
        let mut m = minimal(false);
        m.models[0].checkpoints[1].name = "dense".into();
        let e = RegistryManifest::parse_bytes(m.render().as_bytes(), ".").unwrap_err();
        assert!(e.to_string().contains("duplicate checkpoint"), "{e}");

        // Dangling default.
        let mut m = minimal(false);
        m.models[0].default = "missing".into();
        let e = RegistryManifest::parse_bytes(m.render().as_bytes(), ".").unwrap_err();
        assert!(e.to_string().contains("default checkpoint"), "{e}");

        // Route referencing an unknown checkpoint.
        let mut m = minimal(true);
        m.models[0].route.as_mut().unwrap().fast = "nope".into();
        let e = RegistryManifest::parse_bytes(m.render().as_bytes(), ".").unwrap_err();
        assert!(e.to_string().contains("route.fast"), "{e}");

        // Malformed sha256.
        let mut m = minimal(false);
        m.models[0].checkpoints[0].sha256 = "abc123".into();
        let e = RegistryManifest::parse_bytes(m.render().as_bytes(), ".").unwrap_err();
        assert!(e.to_string().contains("64 hex"), "{e}");

        // Path traversal.
        let mut m = minimal(false);
        m.models[0].checkpoints[0].file = "../outside.gtz".into();
        let e = RegistryManifest::parse_bytes(m.render().as_bytes(), ".").unwrap_err();
        assert!(e.to_string().contains("relative path"), "{e}");
    }

    #[test]
    fn uppercase_hashes_normalize() {
        let mut m = minimal(false);
        m.models[0].checkpoints[0].sha256 = sha('A');
        let back = RegistryManifest::parse_bytes(m.render().as_bytes(), ".").unwrap();
        assert_eq!(back.models[0].checkpoints[0].sha256, sha('a'));
    }

    #[test]
    fn duplicate_model_names_rejected() {
        let mut m = minimal(false);
        let dup = m.models[0].clone();
        m.models.push(dup);
        let e = RegistryManifest::parse_bytes(m.render().as_bytes(), ".").unwrap_err();
        assert!(e.to_string().contains("duplicate model"), "{e}");
    }
}
