//! Fail-closed model registry: versioned manifests, hash-verified
//! checkpoints, atomic hot-swap.
//!
//! The registry is the trust boundary between deployment artifacts on disk
//! and the serving fleet. Its contract:
//!
//! * **Fail-closed loads.** A model enters the registry only after every
//!   gate passes: manifest schema + invariant validation
//!   ([`RegistryManifest`]), per-checkpoint sha256 verification against the
//!   manifest pin, GTZ parse, graph synthesis of the default checkpoint,
//!   and dispatcher startup (which itself builds every variant). A corrupt,
//!   truncated, or hash-mismatched entry rejects *that model* with a typed
//!   [`RegistryError`] — other models in the same manifest still install,
//!   and a previously serving version of the rejected model keeps serving.
//! * **Atomic hot-swap.** Each installed model is an epoch-stamped
//!   [`Arc<ServingModel>`] in a [`std::sync::RwLock`]'d map. Applying a new
//!   manifest swaps the `Arc` under a short write lock: requests that
//!   already resolved the old `Arc` (in-flight classify batches, streaming
//!   decode sessions) finish on the old version's dispatcher — its
//!   [`ServerHandle`] stays alive until the last clone drops, and the
//!   dispatcher drains live sessions before exiting — while every new
//!   resolve sees the new version. No request ever observes a half-swapped
//!   model.
//! * **Accounting.** [`RegistryMetrics`] counts installs, swaps, rejected
//!   manifests/models, and per-model request tallies, feeding the HTTP
//!   `/v1/metrics` surface.

pub mod manifest;

pub use manifest::{
    CheckpointEntry, ModelManifest, RegistryManifest, RouteSpec, REGISTRY_FORMAT,
};

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::backend::native;
use crate::coordinator::{
    serve_classifier_native, RoutePolicy, Router, ServeConfig, ServerHandle,
};
use crate::tensor::{gtz, ParamStore};
use crate::util::sha256_hex;

/// Typed, fail-closed registry error. Every rejection path names what was
/// rejected and why; nothing panics and nothing half-installs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RegistryError {
    /// Reading the manifest or a checkpoint file failed.
    Io {
        /// Path that failed to read.
        path: String,
        /// OS error detail.
        detail: String,
    },
    /// The manifest bytes are not a valid v1 document (bad JSON or a
    /// schema violation such as an unknown field).
    Parse {
        /// What the parser/validator rejected.
        detail: String,
    },
    /// A structural invariant failed: bad id, duplicate name, dangling
    /// reference, unsupported format or family.
    Invariant {
        /// Offending model, when the invariant is model-scoped.
        model: Option<String>,
        /// What was violated.
        detail: String,
    },
    /// A checkpoint's bytes do not hash to the manifest's sha256 pin.
    HashMismatch {
        /// Model being installed.
        model: String,
        /// Checkpoint whose file failed verification.
        checkpoint: String,
        /// The file that was read.
        file: String,
        /// Hash the manifest pinned.
        expected: String,
        /// Hash the bytes actually produced.
        actual: String,
    },
    /// A checkpoint verified but was rejected downstream (corrupt GTZ
    /// payload, graph synthesis failure on its parameters).
    Checkpoint {
        /// Model being installed.
        model: String,
        /// What was rejected.
        detail: String,
    },
    /// Standing up the model's dispatcher failed.
    Serve {
        /// Model being installed.
        model: String,
        /// Dispatcher startup error.
        detail: String,
    },
    /// Lookup of a model that is not registered.
    UnknownModel {
        /// The requested name.
        model: String,
    },
    /// A lookup without an explicit model name when the registry does not
    /// hold exactly one model.
    NoDefaultModel {
        /// How many models are registered.
        registered: usize,
    },
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::Io { path, detail } => write!(f, "io error on {path:?}: {detail}"),
            RegistryError::Parse { detail } => write!(f, "manifest parse error: {detail}"),
            RegistryError::Invariant { model: Some(m), detail } => {
                write!(f, "manifest invariant violated for model {m:?}: {detail}")
            }
            RegistryError::Invariant { model: None, detail } => {
                write!(f, "manifest invariant violated: {detail}")
            }
            RegistryError::HashMismatch { model, checkpoint, file, expected, actual } => write!(
                f,
                "hash mismatch for model {model:?} checkpoint {checkpoint:?} ({file}): \
                 manifest pins {expected}, file hashes to {actual}"
            ),
            RegistryError::Checkpoint { model, detail } => {
                write!(f, "checkpoint rejected for model {model:?}: {detail}")
            }
            RegistryError::Serve { model, detail } => {
                write!(f, "failed to serve model {model:?}: {detail}")
            }
            RegistryError::UnknownModel { model } => write!(f, "unknown model {model:?}"),
            RegistryError::NoDefaultModel { registered } => write!(
                f,
                "no model specified and registry holds {registered} models (expected exactly 1)"
            ),
        }
    }
}

impl std::error::Error for RegistryError {}

/// Registry-level counters, surfaced through `/v1/metrics`.
#[derive(Debug, Default)]
pub struct RegistryMetrics {
    /// Successful installs (first installs + hot-swaps).
    pub installs: AtomicU64,
    /// Installs that replaced an already-serving model (subset of
    /// `installs`).
    pub swaps: AtomicU64,
    /// Whole manifests rejected before any model was considered.
    pub rejected_manifests: AtomicU64,
    /// Individual model entries rejected fail-closed.
    pub rejected_models: AtomicU64,
    requests: Mutex<BTreeMap<String, u64>>,
}

impl RegistryMetrics {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Tally one routed request against `model`.
    pub fn record_request(&self, model: &str) {
        let mut m = self.requests.lock().expect("registry metrics lock");
        *m.entry(model.to_string()).or_insert(0) += 1;
    }

    /// Snapshot of per-model request tallies.
    pub fn request_counts(&self) -> BTreeMap<String, u64> {
        self.requests.lock().expect("registry metrics lock").clone()
    }
}

/// One installed, serving model version: immutable metadata plus the live
/// [`ServerHandle`]. Hot-swap replaces the whole `Arc`; holders of an old
/// `Arc` keep a fully functional old-version server until they drop it.
pub struct ServingModel {
    /// Registry name.
    pub name: String,
    /// `"text"` or `"lm"`.
    pub family: String,
    /// Manifest version tag.
    pub version: String,
    /// Monotone install epoch (registry-wide; a swap gets a higher epoch
    /// than what it replaced).
    pub epoch: u64,
    /// Default checkpoint/variant name.
    pub default: String,
    /// Sorted serving variant names.
    pub variants: Vec<String>,
    /// Model input window (tokens per classify request / max prompt).
    pub seq: usize,
    /// Vocabulary size, when the family has one in its graph config.
    pub vocab: Option<usize>,
    handle: Mutex<ServerHandle>,
}

impl ServingModel {
    /// Clone the live handle for this version. Clones share the version's
    /// dispatcher; the dispatcher shuts down (draining in-flight sessions)
    /// only after every clone and the registry slot are gone.
    pub fn handle(&self) -> ServerHandle {
        self.handle.lock().expect("serving model lock").clone()
    }
}

impl std::fmt::Debug for ServingModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServingModel")
            .field("name", &self.name)
            .field("family", &self.family)
            .field("version", &self.version)
            .field("epoch", &self.epoch)
            .field("default", &self.default)
            .field("variants", &self.variants)
            .finish_non_exhaustive()
    }
}

/// Outcome of applying a manifest: what installed, what was rejected (and
/// why). Rejections are per-model; they never poison sibling entries or
/// already-serving versions.
#[derive(Debug, Default)]
pub struct ApplyReport {
    /// Models installed or hot-swapped, in manifest order.
    pub installed: Vec<String>,
    /// Models rejected fail-closed, with the typed reason.
    pub rejected: Vec<(String, RegistryError)>,
}

/// The registry: named slots of epoch-pinned [`Arc<ServingModel>`]s.
pub struct ModelRegistry {
    slots: RwLock<BTreeMap<String, Arc<ServingModel>>>,
    epoch: AtomicU64,
    serve_cfg: ServeConfig,
    /// Install/swap/rejection counters and per-model request tallies.
    pub metrics: Arc<RegistryMetrics>,
}

impl Default for ModelRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelRegistry {
    /// Empty registry; installed models serve under
    /// [`ServeConfig::default`].
    pub fn new() -> Self {
        Self::with_serve_config(ServeConfig::default())
    }

    /// Empty registry with an explicit serving configuration applied to
    /// every install.
    pub fn with_serve_config(serve_cfg: ServeConfig) -> Self {
        ModelRegistry {
            slots: RwLock::new(BTreeMap::new()),
            epoch: AtomicU64::new(0),
            serve_cfg,
            metrics: Arc::new(RegistryMetrics::new()),
        }
    }

    /// Resolve a model by name. The returned `Arc` pins that version: it
    /// keeps serving even if a hot-swap replaces the slot.
    pub fn get(&self, name: &str) -> Option<Arc<ServingModel>> {
        self.slots.read().expect("registry lock").get(name).cloned()
    }

    /// The sole model, when exactly one is registered.
    pub fn single(&self) -> Option<Arc<ServingModel>> {
        let slots = self.slots.read().expect("registry lock");
        if slots.len() == 1 {
            slots.values().next().cloned()
        } else {
            None
        }
    }

    /// Resolve an optional wire-form model name: `Some` must match a
    /// registered model, `None` is allowed only when exactly one model is
    /// registered.
    pub fn resolve(
        &self,
        name: Option<&str>,
    ) -> std::result::Result<Arc<ServingModel>, RegistryError> {
        match name {
            Some(n) => {
                self.get(n).ok_or_else(|| RegistryError::UnknownModel { model: n.to_string() })
            }
            None => self
                .single()
                .ok_or_else(|| RegistryError::NoDefaultModel { registered: self.len() }),
        }
    }

    /// Registered model names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.slots.read().expect("registry lock").keys().cloned().collect()
    }

    /// Snapshot of all registered models, sorted by name.
    pub fn models(&self) -> Vec<Arc<ServingModel>> {
        self.slots.read().expect("registry lock").values().cloned().collect()
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.slots.read().expect("registry lock").len()
    }

    /// Whether the registry is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Apply a validated manifest: verify + install every model entry,
    /// fail-closed per model. Never returns an error itself — per-model
    /// outcomes are in the report.
    pub fn apply_manifest(&self, manifest: &RegistryManifest) -> ApplyReport {
        let mut report = ApplyReport::default();
        for m in &manifest.models {
            match self.install_from_manifest(manifest, m) {
                Ok(_) => report.installed.push(m.name.clone()),
                Err(e) => {
                    self.metrics.rejected_models.fetch_add(1, Ordering::Relaxed);
                    report.rejected.push((m.name.clone(), e));
                }
            }
        }
        report
    }

    /// Load a manifest file and apply it. A manifest that fails to parse
    /// or validate rejects as a whole (counted in
    /// [`RegistryMetrics::rejected_manifests`]) and changes nothing.
    pub fn load_and_apply(&self, path: &Path) -> std::result::Result<ApplyReport, RegistryError> {
        let manifest = RegistryManifest::load(path).map_err(|e| {
            self.metrics.rejected_manifests.fetch_add(1, Ordering::Relaxed);
            e
        })?;
        Ok(self.apply_manifest(&manifest))
    }

    /// Install a model from in-memory parameter stores (tests, benches,
    /// the demo server) through the same gates as a manifest install —
    /// minus file reads and hash checks, which have no file to act on.
    pub fn install_local(
        &self,
        name: &str,
        family: &str,
        version: &str,
        default: &str,
        variants: HashMap<String, ParamStore>,
        route: Option<RoutePolicy>,
    ) -> std::result::Result<Arc<ServingModel>, RegistryError> {
        self.install_entry(name, family, version, default, variants, route)
    }

    fn install_from_manifest(
        &self,
        manifest: &RegistryManifest,
        m: &ModelManifest,
    ) -> std::result::Result<Arc<ServingModel>, RegistryError> {
        let mut stores = HashMap::new();
        for ckpt in &m.checkpoints {
            let path = manifest.dir.join(&ckpt.file);
            let bytes = std::fs::read(&path).map_err(|e| RegistryError::Io {
                path: path.display().to_string(),
                detail: e.to_string(),
            })?;
            // Verify bytes against the manifest pin BEFORE parsing: a
            // tampered or truncated file is rejected without ever reaching
            // the GTZ decoder.
            let actual = sha256_hex(&bytes);
            if actual != ckpt.sha256 {
                return Err(RegistryError::HashMismatch {
                    model: m.name.clone(),
                    checkpoint: ckpt.name.clone(),
                    file: ckpt.file.clone(),
                    expected: ckpt.sha256.clone(),
                    actual,
                });
            }
            let store = gtz::parse(&bytes).map_err(|e| RegistryError::Checkpoint {
                model: m.name.clone(),
                detail: format!("checkpoint {:?} ({}): {e:#}", ckpt.name, ckpt.file),
            })?;
            stores.insert(ckpt.name.clone(), store);
        }
        let route = m.route.as_ref().map(|r| RoutePolicy::Tiered {
            quality: r.quality.clone(),
            balanced: r.balanced.clone(),
            fast: r.fast.clone(),
        });
        self.install_entry(&m.name, &m.family, &m.version, &m.default, stores, route)
    }

    /// The shared install gate: validate family/default/route, probe the
    /// default checkpoint's graph for metadata, stand up the dispatcher
    /// (which builds every variant's graph, fail-closed), then swap the
    /// slot atomically.
    fn install_entry(
        &self,
        name: &str,
        family: &str,
        version: &str,
        default: &str,
        stores: HashMap<String, ParamStore>,
        route: Option<RoutePolicy>,
    ) -> std::result::Result<Arc<ServingModel>, RegistryError> {
        let invariant = |detail: String| RegistryError::Invariant {
            model: Some(name.to_string()),
            detail,
        };
        if family != "text" && family != "lm" {
            return Err(invariant(format!(
                "family {family:?} is not servable (expected \"text\" or \"lm\")"
            )));
        }
        if stores.is_empty() {
            return Err(invariant("no checkpoints".to_string()));
        }
        let default_store = stores.get(default).ok_or_else(|| {
            invariant(format!("default checkpoint {default:?} is not among the checkpoints"))
        })?;
        // Metadata probe doubles as the first per-parameter gate: a store
        // whose shapes don't assemble into the family's graph is rejected
        // here, before any serving state exists.
        let probe = native::synth_fwd_graph(family, default, 1, default_store).map_err(|e| {
            RegistryError::Checkpoint {
                model: name.to_string(),
                detail: format!("default checkpoint {default:?} rejected: {e:#}"),
            }
        })?;
        let seq = probe.inputs.first().and_then(|i| i.shape.get(1)).copied().unwrap_or(0);
        let vocab = probe.config.get("vocab").copied();
        let mut variant_names: Vec<String> = stores.keys().cloned().collect();
        variant_names.sort();
        let policy = route.unwrap_or_else(|| RoutePolicy::Static(default.to_string()));
        let router = Router::new(policy, variant_names.clone())
            .map_err(|e| invariant(format!("route: {e:#}")))?;
        let handle = serve_classifier_native(family, stores, router, self.serve_cfg.clone())
            .map_err(|e| RegistryError::Serve {
                model: name.to_string(),
                detail: format!("{e:#}"),
            })?;
        let epoch = self.epoch.fetch_add(1, Ordering::Relaxed) + 1;
        let model = Arc::new(ServingModel {
            name: name.to_string(),
            family: family.to_string(),
            version: version.to_string(),
            epoch,
            default: default.to_string(),
            variants: variant_names,
            seq,
            vocab,
            handle: Mutex::new(handle),
        });
        // The swap itself: a plain BTreeMap insert under the write lock.
        // The displaced Arc (if any) lives on in whoever resolved it; its
        // dispatcher drains and exits when the last clone drops.
        let prev =
            self.slots.write().expect("registry lock").insert(name.to_string(), model.clone());
        self.metrics.installs.fetch_add(1, Ordering::Relaxed);
        if prev.is_some() {
            self.metrics.swaps.fetch_add(1, Ordering::Relaxed);
        }
        Ok(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{init_text_params, TextModelCfg};

    fn tiny_cfg() -> TextModelCfg {
        TextModelCfg { vocab: 64, seq: 8, d: 32, heads: 4, layers: 1, ff: 64, classes: 3 }
    }

    fn tiny_store() -> ParamStore {
        init_text_params(&tiny_cfg(), 7)
    }

    #[test]
    fn install_local_serves_and_reports_metadata() {
        let reg = ModelRegistry::new();
        let mut variants = HashMap::new();
        variants.insert("dense".to_string(), tiny_store());
        let model =
            reg.install_local("text-demo", "text", "v1", "dense", variants, None).unwrap();
        assert_eq!(model.seq, 8);
        assert_eq!(model.epoch, 1);
        assert_eq!(model.variants, vec!["dense".to_string()]);
        let resp = model.handle().classify(vec![1; 8], crate::coordinator::Tier::Quality).unwrap();
        assert!(resp.label < 3);
        assert_eq!(reg.metrics.installs.load(Ordering::Relaxed), 1);
        assert_eq!(reg.metrics.swaps.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn hot_swap_bumps_epoch_and_pins_old_version() {
        let reg = ModelRegistry::new();
        let mut v1 = HashMap::new();
        v1.insert("dense".to_string(), tiny_store());
        let old = reg.install_local("m", "text", "v1", "dense", v1, None).unwrap();

        let mut v2 = HashMap::new();
        v2.insert("dense".to_string(), init_text_params(&tiny_cfg(), 8));
        let new = reg.install_local("m", "text", "v2", "dense", v2, None).unwrap();

        assert!(new.epoch > old.epoch);
        assert_eq!(reg.get("m").unwrap().version, "v2");
        assert_eq!(reg.metrics.swaps.load(Ordering::Relaxed), 1);
        // The pinned old Arc still serves its own dispatcher.
        let resp = old.handle().classify(vec![1; 8], crate::coordinator::Tier::Quality).unwrap();
        assert!(resp.label < 3);
    }

    #[test]
    fn bad_family_and_bad_default_fail_closed() {
        let reg = ModelRegistry::new();
        let mut variants = HashMap::new();
        variants.insert("dense".to_string(), tiny_store());
        let e = reg
            .install_local("m", "image", "v1", "dense", variants.clone(), None)
            .unwrap_err();
        assert!(matches!(e, RegistryError::Invariant { .. }), "{e}");
        let e = reg.install_local("m", "text", "v1", "missing", variants, None).unwrap_err();
        assert!(e.to_string().contains("default checkpoint"), "{e}");
        assert!(reg.is_empty());
    }

    #[test]
    fn resolve_handles_default_and_unknown() {
        let reg = ModelRegistry::new();
        assert!(matches!(
            reg.resolve(None).unwrap_err(),
            RegistryError::NoDefaultModel { registered: 0 }
        ));
        let mut variants = HashMap::new();
        variants.insert("dense".to_string(), tiny_store());
        reg.install_local("only", "text", "v1", "dense", variants, None).unwrap();
        assert_eq!(reg.resolve(None).unwrap().name, "only");
        assert!(matches!(
            reg.resolve(Some("nope")).unwrap_err(),
            RegistryError::UnknownModel { .. }
        ));
    }
}
