//! Randomized property tests for KV-cached incremental decoding (in-tree
//! generator over `Pcg64` — proptest is unavailable offline; the
//! methodology is the same: many random cases per invariant, failing seed
//! printed on panic). Runs hermetically: no artifacts, no PJRT.
//!
//! Invariants:
//! * a full KV-cached decode of N tokens produces logits identical (within
//!   1e-5 — in practice bit-identical, see `backend::decode`) to N
//!   independent full-prefix forward passes, for dense **and** LED models;
//! * prefilling in several chunks is equivalent to one prefill;
//! * a fixed sampling seed reproduces the same token stream, and greedy
//!   decoding is seed-independent.

use greenformer::backend::native::{init_text_params, synth_fwd_graph, TextModelCfg};
use greenformer::backend::{generate, Backend, DecodeSession, NativeBackend, SamplingCfg};
use greenformer::factorize::{auto_fact, AutoFactConfig, Rank, Solver};
use greenformer::runtime::GraphSpec;
use greenformer::tensor::{ParamStore, Tensor};
use greenformer::util::Pcg64;

const TOL: f32 = 1e-5;

/// Random small LM dims. `d >= 18` so the Eq.-1 gate (MIN_RANK = 8) accepts
/// the attention/FFN layers of the LED cases.
fn rand_lm_cfg(rng: &mut Pcg64) -> TextModelCfg {
    let heads = if rng.below(2) == 0 { 3 } else { 4 };
    let dk = 6 + rng.below(4); // 6..=9 → d in 18..=36
    let vocab = 32 + rng.below(33);
    TextModelCfg {
        vocab,
        seq: 8 + rng.below(7),
        d: heads * dk,
        heads,
        layers: 1 + rng.below(2),
        ff: 24 + rng.below(33),
        classes: vocab, // head width = vocab: causal LM
    }
}

/// Synthesized LM graph with the cfg's actual head count stamped in (the
/// zoo default of 6 is not recoverable from the parameters).
fn lm_graph(cfg: &TextModelCfg, variant: &str, params: &ParamStore) -> GraphSpec {
    let mut g = synth_fwd_graph("lm", variant, 1, params).unwrap();
    g.config.insert("heads".to_string(), cfg.heads);
    g
}

#[test]
fn kv_cached_decode_matches_full_recompute_dense_and_led() {
    for seed in 0..12u64 {
        let mut rng = Pcg64::new(seed, 300);
        let cfg = rand_lm_cfg(&mut rng);
        let mut params = init_text_params(&cfg, seed ^ 0xD0);
        let mut variant = "dense";
        if seed % 2 == 1 {
            // LED case: the decode path must dispatch a/b factors per layer.
            let report = auto_fact(
                &mut params,
                &AutoFactConfig {
                    rank: Rank::Ratio(0.5),
                    solver: Solver::Random,
                    num_iter: 0,
                    submodules: None,
                    ..Default::default()
                },
            )
            .unwrap();
            assert!(report.n_factorized() > 0, "seed {seed}: cfg too small for the Eq.-1 gate");
            variant = "led_r50";
        }
        let g = lm_graph(&cfg, variant, &params);
        let be = NativeBackend::new();
        let (s, vocab) = (cfg.seq, cfg.vocab);
        let toks: Vec<i32> = (0..s).map(|_| rng.below(vocab) as i32).collect();

        // Reference: one full-prefix forward pass, all positions at once
        // (row p of (1, S, V) is exactly the "scoring prefix 0..=p" pass).
        let full = be
            .run_fwd(&g, &params, &[Tensor::from_i32(&[1, s], toks.clone())])
            .unwrap();
        let full = full[0].as_f32().unwrap();

        // Candidate: prefill a random prompt split, then append the rest
        // one token at a time, checking every step's logits.
        let mut session = DecodeSession::new(&g, &params).unwrap();
        let p = 1 + rng.below(s - 1);
        let mut logits = be.run_decode_step(&g, &params, &mut session, &toks[..p]).unwrap();
        let mut pos = p - 1;
        loop {
            let got = logits.as_f32().unwrap();
            let want = &full[pos * vocab..(pos + 1) * vocab];
            assert_eq!(got.len(), vocab, "seed {seed}");
            for (j, (a, b)) in got.iter().zip(want).enumerate() {
                assert!(
                    (a - b).abs() <= TOL,
                    "seed {seed} ({variant}) pos {pos} logit {j}: decode {a} vs full {b}"
                );
            }
            if pos + 1 == s {
                break;
            }
            logits = be
                .run_decode_step(&g, &params, &mut session, &toks[pos + 1..pos + 2])
                .unwrap();
            pos += 1;
        }
        assert_eq!(session.len(), s, "seed {seed}");
    }
}

#[test]
fn chunked_prefill_matches_single_prefill() {
    for seed in 0..6u64 {
        let mut rng = Pcg64::new(seed, 301);
        let cfg = rand_lm_cfg(&mut rng);
        let params = init_text_params(&cfg, seed ^ 0xC4);
        let g = lm_graph(&cfg, "dense", &params);
        let be = NativeBackend::new();
        let s = cfg.seq;
        let toks: Vec<i32> = (0..s).map(|_| rng.below(cfg.vocab) as i32).collect();

        let mut one = DecodeSession::new(&g, &params).unwrap();
        let la = be.run_decode_step(&g, &params, &mut one, &toks).unwrap();

        let mut two = DecodeSession::new(&g, &params).unwrap();
        let k = 1 + rng.below(s - 1);
        be.run_decode_step(&g, &params, &mut two, &toks[..k]).unwrap();
        let lb = be.run_decode_step(&g, &params, &mut two, &toks[k..]).unwrap();

        assert_eq!(one.len(), two.len(), "seed {seed}");
        for (a, b) in la.as_f32().unwrap().iter().zip(lb.as_f32().unwrap()) {
            assert!((a - b).abs() <= TOL, "seed {seed} (split {k}): {a} vs {b}");
        }
    }
}

#[test]
fn fixed_sampling_seed_reproduces_the_token_stream() {
    let mut rng = Pcg64::new(9, 302);
    let cfg = rand_lm_cfg(&mut rng);
    let params = init_text_params(&cfg, 0xBEEF);
    let g = lm_graph(&cfg, "dense", &params);
    let be = NativeBackend::new();
    let prompt: Vec<i32> = (0..3).map(|_| rng.below(cfg.vocab) as i32).collect();
    let max_new = (cfg.seq - prompt.len()).min(24);

    let sampled = |seed: u64| {
        let s = SamplingCfg {
            temperature: 0.9,
            top_k: 12,
            seed,
        };
        generate(&be, &g, &params, &prompt, max_new, &s, |_, _| {}).unwrap().tokens
    };
    let a = sampled(5);
    assert_eq!(a, sampled(5), "same seed must reproduce the stream");
    // Distinct seeds must be able to diverge: with 8 independent seeds the
    // chance that every stream coincides is vanishing.
    let streams: Vec<Vec<i32>> = (100u64..108).map(&sampled).collect();
    assert!(
        streams.iter().any(|s| s != &streams[0]),
        "8 distinct seeds produced identical streams"
    );

    // Greedy decoding is seed-independent by construction.
    let greedy = |seed: u64| {
        let s = SamplingCfg {
            seed,
            ..SamplingCfg::greedy()
        };
        generate(&be, &g, &params, &prompt, max_new, &s, |_, _| {}).unwrap().tokens
    };
    assert_eq!(greedy(1), greedy(2));
}
