//! Finite-difference verification of the native backward pass (in-tree
//! generator over `Pcg64`; proptest is unavailable offline). Runs
//! hermetically: no artifacts, no PJRT.
//!
//! For every model family (text classifier, causal LM, CNN — dense and
//! LED/CED factorized) the analytic gradient of every parameter tensor is
//! checked against a central finite difference of the scalar training loss
//! at the tensor's largest-gradient index plus a random index, at rel-err
//! ≤ 1e-2 (the acceptance bar; an absolute floor covers near-zero
//! gradients, where f32 finite differences are dominated by rounding).
//!
//! Also pins the paper's structural invariant at the layer level: when
//! `w = a·b` exactly, the LED gradients are the chain rule of the dense
//! gradient — `dA = dW·Bᵀ`, `dB = Aᵀ·dW` — and the input gradients agree.

use greenformer::backend::grad::{linear_bwd, loss_and_grads, softmax_xent, Grads};
use greenformer::backend::native::{
    init_image_params, init_text_params, synth_train_graph, ImageModelCfg, TextModelCfg,
};
use greenformer::linalg::Matrix;
use greenformer::runtime::GraphSpec;
use greenformer::tensor::{ParamStore, Tensor};
use greenformer::util::Pcg64;

const REL_TOL: f32 = 1e-2;
/// Below this gradient magnitude the FD signal is mostly f32 noise; assert
/// only that the FD value is small too.
const SMALL: f32 = 1e-4;
/// Absolute floor: covers f32 loss rounding amplified by the smallest FD
/// step (~1.5e-7 / 4e-4).
const ABS_FLOOR: f32 = 5e-4;

fn fd_loss(graph: &GraphSpec, params: &ParamStore, batch: &[Tensor]) -> f32 {
    loss_and_grads(graph, params, batch).expect("loss").0
}

/// Check every parameter tensor of `params` against finite differences.
/// `smooth` adds a random probe per tensor (text/LM — every op there is
/// differentiable); the image model keeps only the strongest-gradient probe
/// since its ReLU/max-pool kinks make low-signal probes ill-posed.
///
/// Each probe accepts if ANY of several FD estimates matches the analytic
/// gradient: Richardson extrapolation at h = 1e-2/5e-3 (cancels the O(h²)
/// curvature term that dominates for early-layer parameters with steep
/// third derivatives), then plain central differences at decreasing h
/// (dodges max-pool/ReLU routing flips that land inside a larger ±h
/// bracket). A genuinely wrong gradient is off at every scale and fails all
/// estimates.
fn check_all_params(
    tag: &str,
    graph: &GraphSpec,
    params: &ParamStore,
    batch: &[Tensor],
    smooth: bool,
) {
    let (_, grads) = loss_and_grads(graph, params, batch).expect("analytic grads");
    let mut rng = Pcg64::seeded(0xfd);
    for (name, t) in params.iter() {
        let Some(g) = grads.get(name) else {
            panic!("{tag}: no gradient recorded for {name}");
        };
        assert_eq!(g.len(), t.len(), "{tag}: gradient size for {name}");
        // Probe the largest-|g| index (best signal-to-noise) + one random.
        let mut probes = vec![argmax_abs(g)];
        if smooth {
            probes.push(rng.below(g.len()));
        }
        probes.dedup();
        for &idx in &probes {
            let a = g[idx];
            let f1 = central_diff(graph, params, batch, name, idx, 1e-2);
            let f2 = central_diff(graph, params, batch, name, idx, 5e-3);
            let mut estimates = vec![(4.0 * f2 - f1) / 3.0];
            for h in [1e-3, 5e-4, 2e-4] {
                estimates.push(central_diff(graph, params, batch, name, idx, h));
            }
            let ok = estimates.iter().any(|&fd| {
                (a.abs() < SMALL && fd.abs() < SMALL)
                    || (fd - a).abs() <= REL_TOL * a.abs().max(fd.abs()) + ABS_FLOOR
            });
            assert!(ok, "{tag}: {name}[{idx}] analytic {a} vs fd estimates {estimates:?}");
        }
    }
}

fn argmax_abs(g: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in g.iter().enumerate() {
        if v.abs() > g[best].abs() {
            best = i;
        }
    }
    best
}

fn central_diff(
    graph: &GraphSpec,
    params: &ParamStore,
    batch: &[Tensor],
    name: &str,
    idx: usize,
    h: f32,
) -> f32 {
    let mut plus = params.clone();
    plus.get_mut(name).unwrap().as_f32_mut().unwrap()[idx] += h;
    let lp = fd_loss(graph, &plus, batch);
    let mut minus = params.clone();
    minus.get_mut(name).unwrap().as_f32_mut().unwrap()[idx] -= h;
    let lm = fd_loss(graph, &minus, batch);
    (lp - lm) / (2.0 * h)
}

fn tokens_batch(vocab: usize, b: usize, s: usize, seed: u64) -> Tensor {
    let mut rng = Pcg64::seeded(seed);
    let toks: Vec<i32> = (0..b * s).map(|_| rng.below(vocab) as i32).collect();
    Tensor::from_i32(&[b, s], toks)
}

fn labels_batch(classes: usize, b: usize, seed: u64) -> Tensor {
    let mut rng = Pcg64::seeded(seed);
    let ys: Vec<i32> = (0..b).map(|_| rng.below(classes) as i32).collect();
    Tensor::from_i32(&[b], ys)
}

#[test]
fn text_classifier_dense_gradients() {
    // Covers: embedding, positional table, LayerNorm (ln1/ln2/ln_f),
    // attention q/k/v/o, dense FFN, head, mean-pool, cross-entropy.
    let cfg = TextModelCfg {
        vocab: 40,
        seq: 6,
        d: 8,
        heads: 2,
        layers: 1,
        ff: 16,
        classes: 3,
    };
    let params = init_text_params(&cfg, 21);
    let graph = synth_train_graph("text", "dense", 3, &params).unwrap();
    let batch = [tokens_batch(cfg.vocab, 3, cfg.seq, 1), labels_batch(cfg.classes, 3, 2)];
    check_all_params("text-dense", &graph, &params, &batch, true);
}

#[test]
fn text_classifier_led_gradients() {
    // LED factors in the FFN and one attention projection. The tiny dims
    // fail the Eq.-1 gate, so the factors are planted directly — gradient
    // correctness is shape-independent.
    let cfg = TextModelCfg {
        vocab: 40,
        seq: 6,
        d: 8,
        heads: 2,
        layers: 1,
        ff: 16,
        classes: 3,
    };
    let mut params = init_text_params(&cfg, 22);
    let mut rng = Pcg64::seeded(23);
    for (prefix, k, n, r) in [
        ("block0/fc1", 8usize, 16usize, 3usize),
        ("block0/fc2", 16, 8, 3),
        ("block0/attn/q", 8, 8, 2),
    ] {
        params.remove(&format!("{prefix}/w"));
        let a = Matrix::randn(k, r, 0.4, &mut rng);
        let b = Matrix::randn(r, n, 0.4, &mut rng);
        params.insert(format!("{prefix}/a"), Tensor::from_f32(&[k, r], a.data));
        params.insert(format!("{prefix}/b"), Tensor::from_f32(&[r, n], b.data));
    }
    params.sort_canonical();
    let graph = synth_train_graph("text", "led", 2, &params).unwrap();
    let batch = [tokens_batch(cfg.vocab, 2, cfg.seq, 3), labels_batch(cfg.classes, 2, 4)];
    check_all_params("text-led", &graph, &params, &batch, true);
}

#[test]
fn lm_gradients() {
    // Covers the causal path + next-token cross-entropy (shifted labels).
    let cfg = TextModelCfg {
        vocab: 24,
        seq: 7,
        d: 12,
        heads: 6,
        layers: 1,
        ff: 20,
        classes: 24,
    };
    let params = init_text_params(&cfg, 25);
    let graph = synth_train_graph("lm", "dense", 2, &params).unwrap();
    let batch = [tokens_batch(cfg.vocab, 2, cfg.seq, 5)];
    check_all_params("lm", &graph, &params, &batch, true);
}

fn image_batch(b: usize, hw: usize, seed: u64) -> Tensor {
    let mut rng = Pcg64::seeded(seed);
    let mut px = vec![0.0f32; b * hw * hw];
    for p in px.iter_mut() {
        *p = rng.next_f32(); // positive pixels, like the real tasks
    }
    Tensor::from_f32(&[b, hw, hw, 1], px)
}

#[test]
fn image_dense_gradients() {
    // Covers: im2col Conv2d, ReLU, max-pool routing, dense FC, CE.
    let cfg = ImageModelCfg {
        hw: 8,
        ch: 1,
        classes: 3,
        c1: 4,
        c2: 8,
        fc: 16,
    };
    let params = init_image_params(&cfg, 26);
    let graph = synth_train_graph("image", "dense", 2, &params).unwrap();
    let batch = [image_batch(2, 8, 6), labels_batch(cfg.classes, 2, 7)];
    check_all_params("image-dense", &graph, &params, &batch, false);
}

#[test]
fn image_ced_gradients() {
    // conv2 as a CED pair (4-D factors through the collapsed 2-D view).
    let cfg = ImageModelCfg {
        hw: 8,
        ch: 1,
        classes: 3,
        c1: 4,
        c2: 8,
        fc: 16,
    };
    let mut params = init_image_params(&cfg, 27);
    let mut rng = Pcg64::seeded(28);
    params.remove("conv2/w");
    let a = Matrix::randn(3 * 3 * 4, 3, 0.2, &mut rng);
    let b = Matrix::randn(3, 8, 0.2, &mut rng);
    params.insert("conv2/a", Tensor::from_f32(&[3, 3, 4, 3], a.data));
    params.insert("conv2/b", Tensor::from_f32(&[1, 1, 3, 8], b.data));
    params.sort_canonical();
    let graph = synth_train_graph("image", "ced", 2, &params).unwrap();
    let batch = [image_batch(2, 8, 8), labels_batch(cfg.classes, 2, 9)];
    check_all_params("image-ced", &graph, &params, &batch, false);
}

#[test]
fn softmax_xent_gradient_matches_fd() {
    let mut rng = Pcg64::seeded(30);
    for case in 0..20u64 {
        let rows = 1 + rng.below(4);
        let width = 2 + rng.below(6);
        let mut logits = vec![0.0f32; rows * width];
        rng.fill_normal(&mut logits, 1.5);
        let labels: Vec<i32> = (0..rows).map(|_| rng.below(width) as i32).collect();
        let (_, d) = softmax_xent(&logits, &labels, rows, width).unwrap();
        let h = 1e-2f32; // CE is smooth; curvature at this scale is ~1e-6
        for idx in 0..logits.len() {
            let mut lp = logits.clone();
            lp[idx] += h;
            let mut lm = logits.clone();
            lm[idx] -= h;
            let fp = softmax_xent(&lp, &labels, rows, width).unwrap().0;
            let fm = softmax_xent(&lm, &labels, rows, width).unwrap().0;
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (fd - d[idx]).abs() <= REL_TOL * d[idx].abs().max(fd.abs()) + 2.0 * SMALL,
                "case {case}: logit {idx}: analytic {} vs fd {fd}",
                d[idx]
            );
        }
    }
}

#[test]
fn led_gradients_match_dense_chain_rule() {
    // With w = a·b exact: dA = dW·Bᵀ, dB = Aᵀ·dW, and dx agrees.
    let mut rng = Pcg64::seeded(31);
    for case in 0..30u64 {
        let m = 1 + rng.below(6);
        let k = 2 + rng.below(12);
        let n = 2 + rng.below(10);
        let r = 1 + rng.below(k.min(n));
        let a = Matrix::randn(k, r, 0.5, &mut rng);
        let b = Matrix::randn(r, n, 0.5, &mut rng);
        let w = a.matmul(&b);
        let x = Matrix::randn(m, k, 1.0, &mut rng);
        let dy = Matrix::randn(m, n, 1.0, &mut rng);

        let mut dense = ParamStore::new();
        dense.insert("fc/w", Tensor::from_f32(&[k, n], w.data.clone()));
        let mut led = ParamStore::new();
        led.insert("fc/a", Tensor::from_f32(&[k, r], a.data.clone()));
        led.insert("fc/b", Tensor::from_f32(&[r, n], b.data.clone()));

        let mut gd = Grads::default();
        let dx_dense = linear_bwd(&dense, "fc", m, k, &x.data, &dy.data, &mut gd).unwrap();
        let mut gl = Grads::default();
        let dx_led = linear_bwd(&led, "fc", m, k, &x.data, &dy.data, &mut gl).unwrap();

        let dw = Matrix::from_vec(k, n, gd.get("fc/w").unwrap().to_vec());
        let want_da = dw.matmul_nt(&b); // dW · Bᵀ
        let want_db = a.matmul_tn(&dw); // Aᵀ · dW
        let close = |x: &[f32], y: &[f32], tag: &str| {
            for (u, v) in x.iter().zip(y) {
                assert!(
                    (u - v).abs() <= 1e-3 * (1.0 + u.abs().max(v.abs())),
                    "case {case} {tag}: {u} vs {v}"
                );
            }
        };
        close(gl.get("fc/a").unwrap(), &want_da.data, "dA");
        close(gl.get("fc/b").unwrap(), &want_db.data, "dB");
        close(&dx_led, &dx_dense, "dx");
    }
}
