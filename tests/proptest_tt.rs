//! Randomized property tests for the tensor-train solver family (in-tree
//! generator over `Pcg64` — proptest is unavailable offline; the
//! methodology is the same: many random cases per invariant, failing seed
//! printed on panic). Runs hermetically: no artifacts, no PJRT.
//!
//! Invariants:
//! * `tt_svd` at `energy = 1.0` is an exact round-trip over adversarial
//!   shapes — prime dims (cores degrade to `1 × … × dim`), unbalanced
//!   splits, 2 and 3 modes — and at `energy = τ < 1` the relative error
//!   respects the per-sweep budget bound `err ≤ sqrt(1 − τ)`;
//! * an exact Kronecker product factorizes at internal TT rank 1;
//! * the TT-matvec core-chain contraction behind the public
//!   [`apply_linear`] entry point (including the bias epilogue) matches a
//!   matvec against the materialized weight to 1e-5;
//! * [`linear_bwd`] TT core gradients match central finite differences of
//!   a scalar loss on every core;
//! * KV-cached incremental decode over a TT-factorized LM is equivalent to
//!   full-prefix `run_fwd` at every position (row-count independence of
//!   the contraction);
//! * the `auto` chooser picks TT over LED on a Kronecker-structured layer
//!   where LED cannot win on serialized bytes at the same energy budget.

use greenformer::backend::grad::{linear_bwd, Grads};
use greenformer::backend::native::{apply_linear, synth_fwd_graph, TextModelCfg};
use greenformer::backend::{Backend, DecodeSession, NativeBackend};
use greenformer::experiments::kron_structured_lm;
use greenformer::factorize::auto_fact::Decision;
use greenformer::factorize::{auto_fact, AutoFactConfig, Solver, TtConfig};
use greenformer::linalg::Matrix;
use greenformer::tensor::{ParamStore, Tensor};
use greenformer::util::Pcg64;

const TOL: f32 = 1e-5;

/// `kron(a, b)` laid out so `mode_dims(m, 2)` / `mode_dims(n, 2)` recover
/// exactly the `(a, b)` block structure (square-ish factors: the greedy
/// splitter picks the divisor closest to sqrt).
fn kron(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, n) = (a.rows * b.rows, a.cols * b.cols);
    let mut w = Matrix::zeros(m, n);
    for i1 in 0..a.rows {
        for i2 in 0..b.rows {
            for j1 in 0..a.cols {
                for j2 in 0..b.cols {
                    *w.at_mut(i1 * b.rows + i2, j1 * b.cols + j2) = a.at(i1, j1) * b.at(i2, j2);
                }
            }
        }
    }
    w
}

#[test]
fn tt_reconstruct_exact_at_full_energy_adversarial_shapes() {
    // (m, n, modes): primes degrade to 1 x .. x dim cores, composites split.
    let shapes = [(13, 7, 2), (7, 13, 3), (12, 18, 2), (64, 27, 3), (30, 30, 3), (5, 5, 2)];
    for (case, &(m, n, modes)) in shapes.iter().enumerate() {
        let mut rng = Pcg64::new(case as u64, 310);
        let w = Matrix::randn(m, n, 1.0, &mut rng);
        let cfg = TtConfig { modes, energy: 1.0, max_rank: None };
        let tt = tt_svd_ok(&w, &cfg, case);
        assert_eq!(tt.ranks().len(), modes - 1, "case {case}: one internal rank per bond");
        let err = rel_err(&w, &tt.reconstruct());
        assert!(err < 1e-4, "case {case} ({m}x{n} modes {modes}): round-trip err {err}");
    }
}

#[test]
fn tt_truncation_error_respects_energy_budget_and_rank_cap() {
    for seed in 0..8u64 {
        let mut rng = Pcg64::new(seed, 311);
        let (m, n) = (8 + 4 * rng.below(5), 8 + 4 * rng.below(5));
        let w = Matrix::randn(m, n, 1.0, &mut rng);
        let tau = 0.9;
        let cfg = TtConfig { modes: 2, energy: tau, max_rank: None };
        let tt = tt_svd_ok(&w, &cfg, seed as usize);
        let err = rel_err(&w, &tt.reconstruct());
        let bound = (1.0 - tau).sqrt();
        assert!(err <= bound + 1e-2, "seed {seed}: err {err} above sqrt(1-tau) {bound}");

        let capped = tt_svd_ok(&w, &TtConfig { modes: 2, energy: 1.0, max_rank: Some(2) }, 0);
        assert!(capped.ranks().iter().all(|&r| r <= 2), "seed {seed}: {:?}", capped.ranks());
    }
}

#[test]
fn kron_products_factorize_at_tt_rank_one() {
    for seed in 0..6u64 {
        let mut rng = Pcg64::new(seed, 312);
        let a = Matrix::randn(6, 5, 1.0, &mut rng);
        let b = Matrix::randn(6, 5, 1.0, &mut rng);
        let w = kron(&a, &b); // 36x25: mode_dims -> [6,6] x [5,5]
        let cfg = TtConfig { modes: 2, energy: 0.5, max_rank: None };
        let tt = tt_svd_ok(&w, &cfg, seed as usize);
        assert_eq!(tt.ranks(), vec![1], "seed {seed}: kron must be TT-rank-1");
        let err = rel_err(&w, &tt.reconstruct());
        assert!(err < 1e-4, "seed {seed}: rank-1 chain must be exact, err {err}");
    }
}

#[test]
fn tt_matvec_via_apply_linear_matches_materialized() {
    for seed in 0..10u64 {
        let mut rng = Pcg64::new(seed, 313);
        let k = 6 + rng.below(27);
        let n = 6 + rng.below(27);
        let sigma = 1.0 / (k as f32).sqrt();
        let w = Matrix::randn(k, n, sigma, &mut rng);
        let modes = if seed % 2 == 0 { 2 } else { 3 };
        let tt = tt_svd_ok(&w, &TtConfig { modes, energy: 1.0, max_rank: None }, seed as usize);
        let rec = tt.reconstruct();

        let mut params = ParamStore::new();
        tt.insert_into(&mut params, "fc/");
        let bias: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.1).collect();
        params.insert("fc/bias", Tensor::from_f32(&[n], bias.clone()));

        let rows = 1 + rng.below(5);
        let x: Vec<f32> = (0..rows * k).map(|_| rng.normal_f32()).collect();
        let (got_n, y) = apply_linear(&params, "fc", rows, k, &x).unwrap();
        assert_eq!(got_n, n, "seed {seed}");
        for r in 0..rows {
            for j in 0..n {
                let want = bias[j] + (0..k).map(|i| x[r * k + i] * rec.at(i, j)).sum::<f32>();
                let got = y[r * n + j];
                assert!(
                    (got - want).abs() <= TOL * want.abs().max(1.0),
                    "seed {seed} row {r} col {j}: tt {got} vs materialized {want}"
                );
            }
        }
    }
}

#[test]
fn tt_core_gradients_match_finite_differences() {
    for seed in 0..4u64 {
        let mut rng = Pcg64::new(seed, 314);
        let (rows, k, n) = (3, 12, 10);
        let w = Matrix::randn(k, n, 1.0 / (k as f32).sqrt(), &mut rng);
        let tt = tt_svd_ok(&w, &TtConfig { modes: 2, energy: 1.0, max_rank: None }, 0);
        let mut params = ParamStore::new();
        tt.insert_into(&mut params, "fc/");

        let x: Vec<f32> = (0..rows * k).map(|_| rng.normal_f32()).collect();
        // Loss L = sum(c .* y) is linear in y, so dy = c exactly; y is
        // multilinear in the cores, so L is exactly linear in any single
        // perturbed entry — the central difference has no curvature term
        // and a generous step just dilutes f32 rounding noise.
        let c: Vec<f32> = (0..rows * n).map(|_| rng.normal_f32()).collect();
        let loss = |params: &ParamStore| -> f64 {
            let (_, y) = apply_linear(params, "fc", rows, k, &x).unwrap();
            y.iter().zip(&c).map(|(&yi, &ci)| yi as f64 * ci as f64).sum()
        };

        let mut grads = Grads::default();
        let dx = linear_bwd(&params, "fc", rows, k, &x, &c, &mut grads).unwrap();
        assert_eq!(dx.len(), rows * k, "seed {seed}");

        for core in 0..2 {
            let name = format!("fc/tt{core}");
            let g = grads.get(&name).unwrap_or_else(|| panic!("seed {seed}: no grad for {name}"));
            let len = params.get(&name).unwrap().len();
            assert_eq!(g.len(), len, "seed {seed}: grad size for {name}");
            let mut probes = vec![argmax_abs(g)];
            probes.push(rng.below(len));
            probes.dedup();
            for &idx in &probes {
                let fd = central_diff(&mut params, &name, idx, 1e-2, &loss);
                let a = g[idx];
                assert!(
                    (fd - a).abs() <= 1e-2 * a.abs().max(fd.abs()) + 1e-3,
                    "seed {seed} {name}[{idx}]: analytic {a} vs fd {fd}"
                );
            }
        }
        // dx check: same linear loss, perturbing x directly.
        let probe = argmax_abs(&dx);
        let mut xp = x.clone();
        let h = 1e-2f32;
        xp[probe] = x[probe] + h;
        let lp = loss_with_x(&params, rows, k, &xp, &c);
        xp[probe] = x[probe] - h;
        let lm = loss_with_x(&params, rows, k, &xp, &c);
        let fd = ((lp - lm) / (2.0 * h as f64)) as f32;
        let a = dx[probe];
        assert!(
            (fd - a).abs() <= 1e-2 * a.abs().max(fd.abs()) + 1e-3,
            "seed {seed} dx[{probe}]: analytic {a} vs fd {fd}"
        );
    }
}

#[test]
fn kv_cached_tt_decode_matches_full_recompute() {
    for seed in 0..4u64 {
        let mut rng = Pcg64::new(seed, 315);
        let vocab = 40 + rng.below(17);
        let cfg = TextModelCfg {
            vocab,
            seq: 8 + rng.below(5),
            d: 36,
            heads: 4,
            layers: 1 + rng.below(2),
            ff: 36,
            classes: vocab, // head width = vocab: causal LM
        };
        let mut params = kron_structured_lm(&cfg, seed ^ 0xA7).unwrap();
        let report = auto_fact(
            &mut params,
            &AutoFactConfig {
                solver: Solver::Tt,
                tt: TtConfig { modes: 2, energy: 0.99, max_rank: None },
                ..Default::default()
            },
        )
        .unwrap();
        let n_tt = report
            .layers
            .iter()
            .filter(|l| matches!(l.decision, Decision::FactorizedTt { .. }))
            .count();
        assert!(n_tt > 0, "seed {seed}: no layer took the TT path");

        let mut g = synth_fwd_graph("lm", "tt", 1, &params).unwrap();
        g.config.insert("heads".to_string(), cfg.heads);
        let be = NativeBackend::new();
        let (s, vocab) = (cfg.seq, cfg.vocab);
        let toks: Vec<i32> = (0..s).map(|_| rng.below(vocab) as i32).collect();

        let full = be
            .run_fwd(&g, &params, &[Tensor::from_i32(&[1, s], toks.clone())])
            .unwrap();
        let full = full[0].as_f32().unwrap();

        let mut session = DecodeSession::new(&g, &params).unwrap();
        let p = 1 + rng.below(s - 1);
        let mut logits = be.run_decode_step(&g, &params, &mut session, &toks[..p]).unwrap();
        let mut pos = p - 1;
        loop {
            let got = logits.as_f32().unwrap();
            let want = &full[pos * vocab..(pos + 1) * vocab];
            for (j, (a, b)) in got.iter().zip(want).enumerate() {
                assert!(
                    (a - b).abs() <= TOL,
                    "seed {seed} pos {pos} logit {j}: decode {a} vs full {b}"
                );
            }
            if pos + 1 == s {
                break;
            }
            logits = be
                .run_decode_step(&g, &params, &mut session, &toks[pos + 1..pos + 2])
                .unwrap();
            pos += 1;
        }
        assert_eq!(session.len(), s, "seed {seed}");
    }
}

#[test]
fn auto_chooser_beats_led_bytes_on_kron_layer() {
    let mut rng = Pcg64::new(9, 316);
    let a = Matrix::randn(8, 8, 1.0, &mut rng);
    let b = Matrix::randn(8, 8, 1.0, &mut rng);
    let w = kron(&a, &b); // 64x64, TT-rank-1 at modes=2; flat LED spectrum
    let mut params = ParamStore::new();
    params.insert("fc/w", Tensor::from_f32(&[64, 64], w.data.clone()));
    params.insert("fc/bias", Tensor::from_f32(&[64], vec![0.0; 64]));

    let report = auto_fact(
        &mut params,
        &AutoFactConfig {
            solver: Solver::Auto,
            tt: TtConfig { modes: 2, energy: 0.99, max_rank: None },
            ..Default::default()
        },
    )
    .unwrap();
    let fc = report.layers.iter().find(|l| l.name == "fc").expect("fc decision");
    assert!(
        matches!(fc.decision, Decision::FactorizedTt { .. }),
        "auto must pick TT on a Kronecker layer, got {:?}",
        fc.decision
    );
    assert!(
        report.bytes_after < report.bytes_before,
        "bytes {} -> {}",
        report.bytes_before,
        report.bytes_after
    );
    // 2 rank-1 cores of 64 f32 each + bias, vs the 64x64 dense layer.
    assert!(params.get("fc/tt0").is_some() && params.get("fc/w").is_none());
}

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

fn tt_svd_ok(w: &Matrix, cfg: &TtConfig, case: usize) -> greenformer::factorize::TtParams {
    greenformer::factorize::tt_svd(w, cfg)
        .unwrap_or_else(|e| panic!("case {case}: tt_svd failed: {e}"))
}

fn rel_err(w: &Matrix, rec: &Matrix) -> f64 {
    w.sub(rec).fro_norm() / w.fro_norm().max(1e-30)
}

fn argmax_abs(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in v.iter().enumerate() {
        if x.abs() > v[best].abs() {
            best = i;
        }
    }
    best
}

fn loss_with_x(params: &ParamStore, rows: usize, k: usize, x: &[f32], c: &[f32]) -> f64 {
    let (_, y) = apply_linear(params, "fc", rows, k, x).unwrap();
    y.iter().zip(c).map(|(&yi, &ci)| yi as f64 * ci as f64).sum()
}

/// Central finite difference of `loss` w.r.t. `params[name][idx]`.
fn central_diff(
    params: &mut ParamStore,
    name: &str,
    idx: usize,
    h: f32,
    loss: &dyn Fn(&ParamStore) -> f64,
) -> f32 {
    let orig = params.get(name).unwrap().as_f32().unwrap()[idx];
    params.get_mut(name).unwrap().as_f32_mut().unwrap()[idx] = orig + h;
    let lp = loss(params);
    params.get_mut(name).unwrap().as_f32_mut().unwrap()[idx] = orig - h;
    let lm = loss(params);
    params.get_mut(name).unwrap().as_f32_mut().unwrap()[idx] = orig;
    ((lp - lm) / (2.0 * h as f64)) as f32
}
